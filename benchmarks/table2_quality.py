"""Table 2: efficacy (MSE, r^2 vs oracle) + efficiency (time, memory) of
every analytical denoiser, per dataset (cifar/celeba/afhq analogues)."""
from __future__ import annotations



from benchmarks.common import efficacy, make_oracle, peak_rss_gb
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        PCADenoiser, PatchDenoiser, WienerDenoiser,
                        make_schedule)
from repro.data import afhq_like, celeba_like, cifar_like

DATASETS = {"cifar_like": (cifar_like, 32 * 32 * 3),
            "celeba_like": (celeba_like, 64 * 64 * 3),
            "afhq_like": (afhq_like, 64 * 64 * 3)}


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    names = ["cifar_like"] if fast else list(DATASETS)
    n_train = 1024 if fast else 4096
    n_samples = 8 if fast else 32
    rows = []
    for ds in names:
        fn, dim = DATASETS[ds]
        store = fn(n=n_train, seed=0)
        oracle = make_oracle(fn, n_train * 2, sch)
        methods = {
            "optimal": OptimalDenoiser(store, sch),
            "wiener": WienerDenoiser(store, sch, rank=min(n_train, 512)),
            "kamb": PatchDenoiser(store, sch, chunk=128),
            "pca": PCADenoiser(store, sch, chunk=128),
        }
        methods["golddiff"] = GoldDiff(PCADenoiser(store, sch, chunk=128),
                                       GoldDiffConfig())
        for name, den in methods.items():
            if fast and name == "kamb" and ds != "cifar_like":
                continue
            m = efficacy(den, oracle, sch, dim, num_samples=n_samples)
            rows.append({"dataset": ds, "method": name, **m,
                         "peak_rss_gb": peak_rss_gb()})
    # derived: GoldDiff vs PCA speedup + efficacy gain (the paper's 71x row)
    summary = {}
    for ds in names:
        pca = next(r for r in rows if r["dataset"] == ds and r["method"] == "pca")
        gd = next(r for r in rows if r["dataset"] == ds and r["method"] == "golddiff")
        summary[f"{ds}_speedup_vs_pca"] = pca["time_per_step_s"] / gd["time_per_step_s"]
        summary[f"{ds}_mse_gain_pct"] = 100 * (pca["mse"] - gd["mse"]) / pca["mse"]
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
