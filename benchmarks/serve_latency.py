"""Serving cost of the three trajectory execution modes (PR 5's claim).

Compares, on identical DDIM trajectories:

* **static**   — one program per timestep: exact per-step FLOPs (the
  paper's complexity table) but T cold compiles per batch shape;
* **masked**   — PR 4's single scan program: 1 cold compile but every
  step padded to the worst-case (m_max, k_max);
* **plan**     — bucketed shape compilation (``core/plan.py``):
  ``plan.num_buckets`` (typically 3-4) compiles at near-static FLOPs.

Three kinds of cells go into ``BENCH_serve.json``:

* ``serve/cold_programs/...`` + ``serve/cold_traj_us/...`` — denoise
  programs compiled for one batch shape, and the first (compiling)
  trajectory's wall-clock.  ``serve/warm_traj_us/...`` is the warm
  trajectory (recorded unpaired: on XLA:CPU the padded masked program
  and the plan differ by ~the padding overhead, which is small at
  these toy N).
* ``serve/{static,plan,masked}_flops/...`` — per-query candidate/
  support FLOPs summed over the trajectory (the quantity the caps
  actually pad).  ``static_flops -> plan_flops`` is a GATED pair:
  ``check_bench`` fails if the plan pays more than
  ``PLAN_FLOP_OVERHEAD_MAX`` (1.2x) of static mode's FLOPs.
* ``parity/serve/...`` — fraction of generated images matching static
  mode's within 1e-4 relative tolerance, exact and indexed paths,
  gated >= 0.999.

  PYTHONPATH=src python -m benchmarks.serve_latency
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import time_call
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        build_plan, make_schedule, sample, sample_plan,
                        sample_scan)
from repro.data import gmm
from repro.index import build_index

BENCH_JSON = "BENCH_serve.json"


def _image_parity(a, b, tol: float = 1e-4) -> float:
    """Fraction of rows of ``a`` matching ``b`` within relative tol."""
    a, b = np.asarray(a), np.asarray(b)
    scale = np.abs(b).max() + 1e-9
    return float(np.mean(np.abs(a - b).max(axis=-1) <= tol * scale))


def _fresh(store, sch, cfg, index=None):
    return GoldDiff(OptimalDenoiser(store, sch), cfg, index=index,
                    index_mode="always" if index is not None else "auto")


def _denoise_programs(gd) -> int:
    """Denoise-path programs in the engine cache (init/aux excluded)."""
    return sum(1 for k in gd.engine._programs
               if k[0] in ("denoise", "plan_seg", "serve_scan"))


def run(fast: bool = True):
    n, batch, steps = (2048, 8, 10) if fast else (16384, 16, 10)
    sch = make_schedule("ddpm_linear", 1000)
    cfg = GoldDiffConfig()
    store = gmm(n, dim=16, num_modes=8, spread=0.05, seed=0)
    rng = jax.random.PRNGKey(0)
    shape = (batch, 16)
    rows = []

    def make(mode, gd):
        if mode == "static":
            return lambda: sample(gd, sch, shape, rng, num_steps=steps)
        if mode == "masked":
            key = ("serve_scan", shape, steps)
            fn = gd.engine.program(key, lambda: jax.jit(
                lambda r: sample_scan(gd.call_masked, sch, shape, r,
                                      num_steps=steps)))
            return lambda: fn(rng)
        plan = build_plan(gd.engine, steps)
        return lambda: sample_plan(gd.call_masked, sch, shape, rng, plan,
                                   program_cache=gd.engine.program)

    plan = build_plan(_fresh(store, sch, cfg).engine, steps)
    flops = {"static": plan.exact_flops, "plan": plan.padded_flops,
             "masked": build_plan(_fresh(store, sch, cfg).engine, steps,
                                  threshold=float("inf")).padded_flops}
    outs = {}
    for mode in ("static", "masked", "plan"):
        gd = _fresh(store, sch, cfg)
        fn = make(mode, gd)
        t0 = time.perf_counter()
        outs[mode] = np.asarray(jax.block_until_ready(fn()))
        cold_s = time.perf_counter() - t0
        warm_s = time_call(fn)
        rows.append({"kind": "serve", "method": f"{mode}_mode", "N": n,
                     "steps": steps, "time_per_step_s": warm_s / steps,
                     "cold_s": cold_s,
                     "programs": _denoise_programs(gd),
                     "flops": flops[mode],
                     "flop_ratio_vs_static": flops[mode] / flops["static"]})
    parity = _image_parity(outs["plan"], outs["static"])
    rows[-1]["parity"] = parity

    # indexed path: plan-vs-static parity on a clustered store
    cfg_ix = GoldDiffConfig(m_min_frac=1 / 64, m_max_frac=1 / 16,
                            k_min_frac=1 / 128, k_max_frac=1 / 64)
    store_ix = gmm(2 * n, dim=16, num_modes=32, spread=0.05, seed=3)
    ix = build_index(store_ix, num_clusters=64)
    gd_st = _fresh(store_ix, sch, cfg_ix, index=ix)
    gd_pl = _fresh(store_ix, sch, cfg_ix, index=ix)
    plan_ix = build_plan(gd_pl.engine, steps)
    x_st = sample(gd_st, sch, shape, rng, num_steps=steps)
    x_pl = sample_plan(gd_pl.call_masked, sch, shape, rng, plan_ix,
                       program_cache=gd_pl.engine.program)
    parity_ix = _image_parity(x_pl, x_st)
    rows.append({"kind": "serve_indexed", "method": "plan_mode",
                 "N": 2 * n, "steps": steps,
                 "time_per_step_s": None,
                 "programs": _denoise_programs(gd_pl),
                 "flops": plan_ix.padded_flops, "parity": parity_ix})
    rows.append({"kind": "serve_indexed", "method": "static_mode",
                 "N": 2 * n, "steps": steps, "time_per_step_s": None,
                 "programs": _denoise_programs(gd_st),
                 "flops": plan_ix.exact_flops})

    by = {r["method"]: r for r in rows if r["kind"] == "serve"}
    summary = (f"plan: {by['plan_mode']['programs']} programs vs "
               f"{by['static_mode']['programs']} static / "
               f"{by['masked_mode']['programs']} masked; padded-FLOP "
               f"ratio {by['plan_mode']['flop_ratio_vs_static']:.3f}x "
               f"(masked {by['masked_mode']['flop_ratio_vs_static']:.3f}x, "
               f"gate <= 1.2x); parity exact {parity:.4f} / indexed "
               f"{parity_ix:.4f} (gate >= 0.999)")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable record.  ``*_flops`` cells are per-query
    trajectory FLOPs — check_bench gates static_flops -> plan_flops at
    <= PLAN_FLOP_OVERHEAD_MAX; parity/ cells gated >= 0.999; timing
    and program-count cells recorded unpaired."""
    record = {}
    for r in rows:
        tag = f"{r['kind']}/N{r['N']}/steps{r['steps']}"
        method = r["method"].replace("_mode", "")
        if r.get("time_per_step_s") is not None:
            record[f"serve/warm_step_us/{method}/{tag}"] = \
                round(r["time_per_step_s"] * 1e6, 1)
            record[f"serve/cold_traj_us/{method}/{tag}"] = \
                round(r["cold_s"] * 1e6, 1)
        record[f"serve/cold_programs/{method}/{tag}"] = r["programs"]
        record[f"serve/{method}_flops/{tag}"] = round(r["flops"], 1)
        if "parity" in r:
            record[f"parity/{tag}/plan_vs_static"] = round(r["parity"], 6)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
