"""Engine vs seed-eager GoldDiff hot path (this PR's headline perf claim).

Times a faithful replica of the seed implementation — gather +
broadcast-subtract ``[B, m, D]`` temporaries, exact candidate distances
computed twice per step, per-step ``jax.jit`` — against the
``GoldDiffEngine`` kernel-layer pipeline (matmul-form distances,
selection distances reused for aggregation), for the static, masked,
and full-scan paths on the synthetic benchmark config.

Also validates + times the ``pallas_interpret`` backend on a tiny shape
(interpret mode executes the kernel body in Python, so it is a
correctness vehicle, not a perf vehicle — the perf row is ``xla``).

Emits ``BENCH_engine.json`` (name -> us_per_call) so the perf
trajectory is tracked across PRs:

  PYTHONPATH=src python -m benchmarks.engine_speedup
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_bench_json, time_call
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule)
from repro.core import streaming
from repro.core.dataset import downsample_proxy
from repro.core.engine import schedule_sizes
from repro.data import mnist_like

BENCH_JSON = "BENCH_engine.json"


# -- faithful replicas of the seed hot path ----------------------------------

def _eager_coarse(store, q, m, factor):
    q_img = q.reshape(q.shape[:-1] + tuple(store.image_shape))
    qp = downsample_proxy(q_img, factor)
    d2 = (jnp.sum(qp * qp, -1, keepdims=True) + store.proxy_norms[None, :]
          - 2.0 * qp @ store.proxy.T)
    return jax.lax.top_k(-d2, m)[1]


def _eager_static_step(store, sch, cfg, t):
    """Seed GoldDiff static step: [B, m, D] broadcast-subtract temporaries,
    rows regathered and distances recomputed for the final softmax."""
    m_t, k_t = schedule_sizes(cfg, sch, t, store.n)
    a = float(sch.a[t])
    sig2 = float(sch.sigma_np(t)) ** 2

    @jax.jit
    def step(x_t):
        q = x_t / a
        cand = _eager_coarse(store, q, m_t, cfg.proxy_factor)
        xs = store.X[cand]
        d2 = jnp.sum((q[:, None, :] - xs) ** 2, -1)
        pos = jax.lax.top_k(-d2, k_t)[1]
        idx = jnp.take_along_axis(cand, pos, -1)
        xs_k = store.X[idx]
        d2k = jnp.sum((q[:, None, :] - xs_k) ** 2, -1)
        w = jax.nn.softmax(-d2k / (2.0 * sig2), -1)
        return jnp.einsum("bk,bkd->bd", w, xs_k)

    return step


def _eager_masked_step(store, sch, cfg):
    """Seed call_masked: exact candidate distances computed twice."""
    n = store.n
    m_min, m_max, k_min, k_max = cfg.sizes(n)
    a_arr = jnp.asarray(sch.a)
    b_arr = jnp.asarray(sch.b)

    @jax.jit
    def step(x_t, t):
        g = sch.g(t)
        m_t = jnp.floor(m_min + (m_max - m_min) * (1.0 - g)).astype(jnp.int32)
        k_t = jnp.floor(k_min + (k_max - k_min) * g).astype(jnp.int32)
        a = a_arr[t]
        sig = b_arr[t] / a
        q = x_t / a
        cand = _eager_coarse(store, q, m_max, cfg.proxy_factor)
        cand_mask = jnp.arange(m_max)[None, :] < m_t
        xs = store.X[cand]
        d2 = jnp.sum((q[:, None, :] - xs) ** 2, -1)
        d2 = jnp.where(cand_mask, d2, jnp.inf)
        pos = jax.lax.top_k(-d2, k_max)[1]
        idx = jnp.take_along_axis(cand, pos, -1)
        xs_k = store.X[idx]
        d2k = jnp.sum((q[:, None, :] - xs_k) ** 2, -1)
        lg = -d2k / (2.0 * sig * sig)
        lg = jnp.where(jnp.arange(k_max)[None, :] < k_t, lg, streaming.NEG_INF)
        w = jax.nn.softmax(lg, -1)
        return jnp.einsum("bk,bkd->bd", w, xs_k)

    return step


def _eager_full_scan(store, sch, t, chunk=8192):
    """Seed OptimalDenoiser full scan: [B, N] logits + chunked scan."""
    a = float(sch.a[t])
    sig2 = float(sch.sigma_np(t)) ** 2

    @jax.jit
    def step(x_t):
        q = x_t / a
        qn = jnp.sum(q * q, -1, keepdims=True)
        d2 = jnp.maximum(qn + store.x_norms[None, :] - 2.0 * q @ store.X.T,
                         0.0)
        return streaming.streaming_softmax_mean(-d2 / (2.0 * sig2), store.X,
                                                chunk)

    return step


# -- benchmark ----------------------------------------------------------------

def run(fast: bool = True):
    n, b = (4096, 32) if fast else (16384, 64)
    store = mnist_like(n, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    cfg = GoldDiffConfig()
    rng = jax.random.PRNGKey(0)
    rows = []
    speedups = []

    gd = GoldDiff(OptimalDenoiser(store, sch), cfg, backend="xla")
    x = float(sch.b[800]) * jax.random.normal(rng, (b, store.dim))

    # static per-step programs
    for t in (800, 400, 100):
        t_eager = time_call(_eager_static_step(store, sch, cfg, t), x)
        t_eng = time_call(lambda xx, _t=t: gd(xx, _t), x)
        speedups.append(t_eager / t_eng)
        rows.append({"kind": "static", "method": "seed_eager", "t": t,
                     "N": n, "time_per_step_s": t_eager})
        rows.append({"kind": "static", "method": "engine_xla", "t": t,
                     "N": n, "time_per_step_s": t_eng,
                     "speedup": t_eager / t_eng})

    # masked (scan/pjit-compatible) single program
    eager_masked = _eager_masked_step(store, sch, cfg)
    eng_masked = jax.jit(gd.call_masked)
    t_arr = jnp.asarray(400)
    t_eager = time_call(eager_masked, x, t_arr)
    t_eng = time_call(eng_masked, x, t_arr)
    speedups.append(t_eager / t_eng)
    rows.append({"kind": "masked", "method": "seed_eager", "t": 400,
                 "N": n, "time_per_step_s": t_eager})
    rows.append({"kind": "masked", "method": "engine_xla", "t": 400,
                 "N": n, "time_per_step_s": t_eng,
                 "speedup": t_eager / t_eng})

    # full-scan Optimal path (Eq. 2) through ops.golden_aggregate — the
    # seed was already in matmul form here, so this is a PARITY cell, not
    # a speedup claim: the two programs are the same GEMM + softmax and
    # time within ~1% of each other under best-of-N timing.  Gated as a
    # BUDGET pair (ops-routed <= 1.2x the seed form, like plan_flops)
    # because a strict >=1.0x speedup gate on a structurally-1.0x pair
    # is a coin flip against median-of-3 timer noise on a ~7 ms op.
    den = OptimalDenoiser(store, sch, backend="xla")
    t_eager = time_call(_eager_full_scan(store, sch, 400), x)
    t_eng = time_call(jax.jit(lambda xx: den(xx, 400)), x)
    full_scan_speedup = t_eager / t_eng
    rows.append({"kind": "full_scan", "method": "seed_matmul_us", "t": 400,
                 "N": n, "time_per_step_s": t_eager})
    rows.append({"kind": "full_scan", "method": "ops_routed_us", "t": 400,
                 "N": n, "time_per_step_s": t_eng,
                 "speedup": full_scan_speedup})

    # fused single-pass step vs the staged pipeline (gated pairs), both
    # engines pinned to the streamed-screen + gather-rerank regime —
    # the large-N shape the fused pass exists for, where the staged
    # pipeline materializes the [B, m, D] candidate tensor between the
    # screen and the re-rank.  (In the materialized/dense regime that
    # ``auto`` picks at this fast-mode N on XLA:CPU the two bodies
    # compile to the *same op sequence* — ``ops.fused_step`` routes
    # through the identical screen/rerank/scatter-aggregate forms — so
    # that pair would tautologically measure ~1.0x and pin nothing.)
    # Wall-clock AND peak temp bytes (memory_analysis(), the
    # screen_speedup template) come from the same two step bodies; the
    # fused form must never be slower, and must show the [B, m, D]
    # candidate materialization eliminated — its remaining temp peak is
    # the k-row aggregate gather both paths share.
    from benchmarks.screen_speedup import _temp_bytes
    gd_staged = GoldDiff(OptimalDenoiser(store, sch), cfg, backend="xla",
                         fused=False, screen="streamed", strategy="gather")
    gd_fused = GoldDiff(OptimalDenoiser(store, sch), cfg, backend="xla",
                        fused=True, screen="streamed", strategy="gather")
    for t in (800, 400, 100):
        t_staged = time_call(lambda xx, _t=t: gd_staged(xx, _t), x)
        t_fused = time_call(lambda xx, _t=t: gd_fused(xx, _t), x)
        rows.append({"kind": "fused", "method": "staged_step_us", "t": t,
                     "N": n, "time_per_step_s": t_staged})
        rows.append({"kind": "fused", "method": "fused_step_us", "t": t,
                     "N": n, "time_per_step_s": t_fused,
                     "speedup": t_staged / t_fused})
    t_mem = 400
    mem_staged = _temp_bytes(
        lambda xx: gd_staged.engine._denoise_body(xx, t_mem), x)
    mem_fused = _temp_bytes(
        lambda xx: gd_fused.engine._fused_body(xx, t_mem), x)
    if mem_staged is not None and mem_fused is not None:
        rows.append({"kind": "fused", "method": "staged_step_mem",
                     "t": t_mem, "N": n, "bytes": mem_staged})
        rows.append({"kind": "fused", "method": "fused_step_mem",
                     "t": t_mem, "N": n, "bytes": mem_fused,
                     "mem_reduction": mem_staged / max(mem_fused, 1.0)})

    # bf16 storage (ROADMAP item): dataset + proxy operands in bfloat16
    # (norms/accumulation stay fp32) on the same static steps, recording
    # BOTH speed and quality vs the fp32 engine — on XLA:CPU bf16 GEMMs
    # are software-emulated so this tracks bandwidth-vs-compute, while
    # on real TPUs it is the halved-HBM-traffic configuration.
    gd_bf16 = GoldDiff(OptimalDenoiser(store, sch), cfg, backend="xla",
                       storage_dtype=jnp.bfloat16)
    for t in (800, 400, 100):
        t_bf16 = time_call(lambda xx, _t=t: gd_bf16(xx, _t), x)
        out32 = np.asarray(gd(x, t), np.float32)
        out16 = np.asarray(gd_bf16(x, t), np.float32)
        relerr = float(np.abs(out16 - out32).max()
                       / (np.abs(out32).max() + 1e-9))
        rows.append({"kind": "static", "method": "engine_xla_bf16", "t": t,
                     "N": n, "time_per_step_s": t_bf16,
                     "bf16_relerr_vs_fp32": relerr})

    # pallas_interpret: correctness-path timing on a tiny shape (the
    # kernel body runs in Python — this row tracks that it stays usable
    # for validation, not that it is fast)
    tiny = mnist_like(256, seed=1)
    gd_int = GoldDiff(OptimalDenoiser(tiny, sch), cfg,
                      backend="pallas_interpret")
    x_tiny = float(sch.b[400]) * jax.random.normal(rng, (4, tiny.dim))
    t_int = time_call(lambda xx: gd_int(xx, 400), x_tiny, repeats=1)
    rows.append({"kind": "static_tiny", "method": "engine_pallas_interpret",
                 "t": 400, "N": 256, "time_per_step_s": t_int})

    mn, md = min(speedups), sorted(speedups)[len(speedups) // 2]
    summary = (f"engine_xla vs seed eager on the selection path: "
               f"min {mn:.1f}x, median {md:.1f}x over {len(speedups)} cells "
               f"(target >= 2x); full_scan parity {full_scan_speedup:.2f}x "
               f"(seed already matmul-form; budget-gated <= 1.2x)")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable perf record (name -> us_per_call) for cross-PR
    tracking; called by benchmarks.run after this table executes.
    Merge semantics: this table's cells are replaced, other tables'
    cells in the same record (``roofline/...``, ``obs/...``) survive."""
    cells = {}
    for r in rows:
        # N in the key: fast (N=4096) and --full (N=16384) runs must not
        # overwrite each other in the cross-PR record
        name = f"{r['kind']}/{r['method']}/N{r['N']}/t{r['t']}"
        if "bytes" in r:                 # *_mem pair cells hold bytes
            cells[name] = round(r["bytes"], 1)
            continue
        cells[name] = round(r["time_per_step_s"] * 1e6, 1)
        if "bf16_relerr_vs_fp32" in r:
            cells[f"{name}/bf16_relerr_vs_fp32"] = \
                round(r["bf16_relerr_vs_fp32"], 6)
    merge_bench_json(path, cells)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
