"""Table 4: validation across diverse schedules (EDM-VP / EDM-VE)."""
from __future__ import annotations

from benchmarks.common import efficacy, make_oracle
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        PCADenoiser, PatchDenoiser, WienerDenoiser,
                        make_schedule)
from repro.data import cifar_like


def run(fast: bool = True):
    n = 1024 if fast else 4096
    store = cifar_like(n=n, seed=0)
    rows = []
    for sched_name in ("edm_vp", "edm_ve"):
        sch = make_schedule(sched_name, 1000)
        oracle = make_oracle(cifar_like, n * 2, sch)
        methods = {
            "optimal": OptimalDenoiser(store, sch),
            "wiener": WienerDenoiser(store, sch, rank=min(n, 512)),
            "pca": PCADenoiser(store, sch, chunk=128),
            "golddiff": GoldDiff(PCADenoiser(store, sch, chunk=128),
                                 GoldDiffConfig()),
        }
        if not fast:
            methods["kamb"] = PatchDenoiser(store, sch, chunk=128)
        for name, den in methods.items():
            m = efficacy(den, oracle, sch, store.dim,
                         num_samples=8 if fast else 32)
            rows.append({"schedule": sched_name, "method": name, **m})
    summary = {}
    for sn in ("edm_vp", "edm_ve"):
        gd = next(r for r in rows if r["schedule"] == sn and r["method"] == "golddiff")
        pca = next(r for r in rows if r["schedule"] == sn and r["method"] == "pca")
        summary[f"{sn}_r2_gain"] = gd["r2"] - pca["r2"]
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
