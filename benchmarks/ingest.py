"""Incremental ingest vs full index rebuild (the store-lifecycle claim).

Measures the cost of getting ``b`` new rows live AND durable via the
appendable golden store (``repro.index.ingest.StoreLifecycle.append``:
one fsync'd journal frame + in-place capacity-slot fill — the rows are
serveable in ``view()`` and crash-recoverable the moment it returns)
against the only alternative the static layout offers: a full kmeans
rebuild of the grown store persisted as a fresh epoch.  Both paths end
in the same place — every row durable on disk and hot-swappable — so
the pair is apples-to-apples ("rebuild" includes its shape-specific
kmeans compile exactly as a real rebuild would pay it).  Epoch
compaction (``commit``) is deferred/amortized over many appends and is
recorded as an ungated informational cell (``ingest_commit_us``).

Also measures **post-append screening recall**: IVF-probed top-m_t
around the *appended* rows vs the exact proxy scan on the grown store
(queries biased to the new rows — the region where bad placement would
show).  Appends fill nearest-centroid capacity slots (local 2-means
into spare windows on overflow), so recall must stay >= 0.95 without
any rebuild.

Emits ``BENCH_ingest.json``: ``ingest/<cfg>/N<n>/ingest_rebuild_us``
vs ``.../ingest_append_us`` (gated by scripts/check_bench.py:
append <= 0.2x rebuild, i.e. >= 5x faster) plus ``recall/ingest/...``
cells (>= 0.95):

  PYTHONPATH=src python -m benchmarks.ingest
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core.dataset import make_store
from repro.data import gmm
from repro.index import (IngestConfig, StoreLifecycle, build_index,
                         screening_recall)

BENCH_JSON = "BENCH_ingest.json"

CONFIGS = (
    # (kind, n, dim, num_modes, num_clusters)
    ("quick", 4096, 32, 32, 64),
    # the acceptance cell: N >= 50k, 10% new rows, append >= 5x rebuild
    ("scale", 65536, 64, 256, 512),
)
NEW_FRAC = 0.10


def post_append_recall(ds, ix, new_rows: np.ndarray,
                       m: int, nprobe: int, seed: int = 0) -> float:
    """IVF-probed recall@m around the appended region.

    Queries are jittered copies of appended rows; candidates come from
    the ``nprobe`` nearest windows (spare windows carry +inf centroid
    norms, so they are never probed); exact baseline is the dense proxy
    scan over the occupied rows (+inf norm padding screens itself out).
    """
    rng = np.random.default_rng(seed)
    pick = rng.choice(new_rows.shape[0], size=min(32, new_rows.shape[0]),
                      replace=False)
    q = new_rows[pick] + 0.1 * rng.standard_normal(
        (pick.size, new_rows.shape[1])).astype(np.float32)

    pn = np.asarray(ds.proxy_norms)
    d2_exact = pn[None, :] - 2.0 * (q @ np.asarray(ds.proxy).T)
    exact_ids = np.argsort(d2_exact, axis=1, kind="stable")[:, :m]

    cent = np.asarray(ix.centroids)
    cn = np.asarray(ix.centroid_norms)
    d2c = np.where(np.isfinite(cn), cn, np.inf)[None, :] \
        - 2.0 * (q @ cent.T)
    probe = np.argsort(d2c, axis=1, kind="stable")[:, :nprobe]

    l_cap = ix.max_cluster
    slots = (probe[:, :, None] * l_cap
             + np.arange(l_cap)[None, None, :]).reshape(q.shape[0], -1)
    pns = np.asarray(ix.proxy_norms_sorted)
    ps = np.asarray(ix.proxy_sorted)
    d2s = np.take(pns, slots) - 2.0 * np.einsum(
        "qd,qsd->qs", q, ps[slots])
    top = np.argsort(d2s, axis=1, kind="stable")[:, :m]
    pos = np.take_along_axis(slots, top, 1)
    return float(screening_recall(pos, np.take_along_axis(d2s, top, 1),
                                  np.asarray(ix.perm), exact_ids))


def bench_config(kind: str, n: int, dim: int, num_modes: int,
                 num_clusters: int, rows: list, workdir: str) -> None:
    base = gmm(n, dim=dim, num_modes=num_modes, spread=0.10,
               seed=0)._replace(labels=None)
    b = int(n * NEW_FRAC)
    # new rows from the same generative process (a later draw)
    new = np.asarray(gmm(b, dim=dim, num_modes=num_modes, spread=0.10,
                         seed=1).X)

    index = build_index(base, num_clusters=num_clusters)  # warms kmeans
    lc = StoreLifecycle.create(os.path.join(workdir, f"{kind}_lc"),
                               base, index, IngestConfig(),
                               proxy_factor=1)

    # -- append path: fsync'd journal frame + in-place fill (rows are
    # live in view() and crash-recoverable when this returns)
    t0 = time.perf_counter()
    lc.append(new)
    t_append = time.perf_counter() - t0
    t0 = time.perf_counter()
    lc.commit()                          # deferred compaction (ungated)
    t_commit = time.perf_counter() - t0

    # -- rebuild path: full kmeans on the grown store + fresh epoch
    grown = make_store(np.concatenate([np.asarray(base.X), new]),
                       (dim,), proxy_factor=1)
    t0 = time.perf_counter()
    grown_ix = build_index(grown, num_clusters=num_clusters)
    StoreLifecycle.create(os.path.join(workdir, f"{kind}_rebuild"),
                          grown, grown_ix, IngestConfig(), proxy_factor=1)
    t_rebuild = time.perf_counter() - t0

    ds, ix = lc.view()
    # fractional probe width: 1/8 of windows at scale; the quick cell's
    # tiny cluster count (64 windows over 32 modes) needs a wider floor
    # for its top-m to concentrate (full-probe recall is 1.0 exactly)
    nprobe = max(24, num_clusters // 8)
    m = max(32, n // 128)
    recall = post_append_recall(ds, ix, new, m, nprobe)

    rows.append({"kind": kind, "method": "ingest_append_us", "N": n,
                 "time_per_step_s": t_append, "new_rows": b,
                 "recall": recall, "nprobe": nprobe, "m": m})
    rows.append({"kind": kind, "method": "ingest_commit_us", "N": n,
                 "time_per_step_s": t_commit, "new_rows": b})
    rows.append({"kind": kind, "method": "ingest_rebuild_us", "N": n,
                 "time_per_step_s": t_rebuild, "new_rows": b,
                 "speedup": t_rebuild / t_append})


def run(fast: bool = True):
    rows: list[dict] = []
    with tempfile.TemporaryDirectory(prefix="bench_ingest_") as workdir:
        for kind, n, dim, modes, clusters in CONFIGS:
            bench_config(kind, n, dim, modes, clusters, rows, workdir)
    sp = {r["kind"]: r["speedup"] for r in rows if "speedup" in r}
    rc = {r["kind"]: r["recall"] for r in rows if "recall" in r}
    summary = (f"durable append vs full rebuild at 10% new rows: "
               + ", ".join(f"{k} {v:.1f}x" for k, v in sp.items())
               + f" (target >= 5x at N >= 50k); post-append recall "
               + ", ".join(f"{k} {v:.3f}" for k, v in rc.items())
               + " (target >= 0.95)")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable record gated by scripts/check_bench.py: the
    rebuild/append budget pair (append <= 0.2x rebuild) plus recall."""
    record = {}
    for r in rows:
        name = f"ingest/{r['kind']}/N{r['N']}/{r['method']}"
        record[name] = round(r["time_per_step_s"] * 1e6, 1)
        if "recall" in r:
            record[f"recall/ingest/{r['kind']}/N{r['N']}"] = round(
                r["recall"], 4)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
