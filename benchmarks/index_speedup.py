"""Indexed vs exact coarse screening (the Golden Index headline claim).

Times the exact dense proxy scan (``ops.pdist`` + top-m_t, O(N d) per
step) against the clustered Golden Index path (``ops.ivf_screen``:
centroid scan + probed CSR windows, O(C d + nprobe_t L) — in capacity
mode every probed row is a candidate for the exact re-rank, so no
per-row proxy pass survives in the coarse stage at all), and measures
**recall@m_t**: the fraction of exact screening's top-m_t candidates
present in the indexed candidate set, at every timestep bucket.

Three configs:

* ``table1`` — the 32x32x3 procedural image manifold (10 classes).
  This data is a smooth *continuum* (deformation fields), essentially
  unclusterable — even an oracle probe assignment needs >1/3 of the
  clusters for 95% recall — so the shipped ``index_mode="auto"`` engine
  correctly serves every bucket from the exact scan (recall 1.0,
  speedup 1.0 by construction: same compiled program).  This cell
  exists to pin the graceful-degradation contract.
* ``table3`` — the ImageNet-1K analogue (64x64x3, many classes), same
  behavior at procedural-data geometry.
* ``scale`` — the N >= 50k acceptance cell: a mode-structured GMM
  (N = 65536, 256 modes), the synthetic-suite substrate whose cluster
  geometry matches the paper's premise for real image corpora
  (Posterior Progressive Concentration: golden neighborhoods live in a
  few clusters).  Here the index serves the mid/high-SNR buckets with
  nprobe_t from the time-aware schedule and the coarse stage runs an
  order of magnitude faster than the exact scan (target >= 3x).

Emits ``BENCH_index.json``: timing cells (name -> us_per_call) plus
``recall/...`` cells (name -> recall fraction in [0, 1]), both gated by
``scripts/check_bench.py`` (speedup >= 1x, recall >= 0.95):

  PYTHONPATH=src python -m benchmarks.index_speedup
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm, image_store, imagenet_like
from repro.index import ProbeSchedule, build_index, screening_recall

BENCH_JSON = "BENCH_index.json"

# Scale-appropriate subset fractions for indexed runs: m_t in
# [N/128, N/64] (the concentration regime the index serves — at
# N >= 50k the paper's m_max = N/4 would floor nprobe at most of the
# clusters), k_t half of that.
INDEXED_CFG = GoldDiffConfig(m_min_frac=1 / 128, m_max_frac=1 / 64,
                             k_min_frac=1 / 256, k_max_frac=1 / 128)
# Scale-cell schedule: a handful of clusters at high SNR, 2x wider at
# max noise, capacity floor 2 m_t.  Tighter than the safety-first
# default schedule because every probed row feeds the exact re-rank
# (IVF-Flat): probed capacity ~2-4x m_t keeps the *whole step* faster,
# not just the coarse scan (the exact_step/indexed_step pair records
# it).  Buckets whose probe width lands past the gather/GEMM crossover
# would fall back to the exact scan under "auto".
SCALE_PROBES = ProbeSchedule(f_lo=1 / 64, f_hi=1 / 32, safety=2.0)

T_BUCKETS = (900, 300, 100, 20)


def bench_config(kind: str, store, n: int, rows: list,
                 probe_schedule: ProbeSchedule | None = None,
                 num_clusters: int | None = None, batch: int = 32,
                 seed: int = 0):
    sch = make_schedule("ddpm_linear", 1000)
    t0 = time.perf_counter()
    index = build_index(store, num_clusters=num_clusters)
    build_s = time.perf_counter() - t0
    eng = GoldDiffEngine(store, sch, INDEXED_CFG, backend="xla",
                         index=index, probe_schedule=probe_schedule)
    rows.append({"kind": kind, "method": "index_build", "N": n, "t": 0,
                 "time_per_step_s": build_s,
                 "num_clusters": index.num_clusters,
                 "max_cluster": index.max_cluster})
    rng = jax.random.PRNGKey(seed)
    x0 = store.X[:batch]
    for t in T_BUCKETS:
        m_t, _ = eng.sizes(t)
        eps = jax.random.normal(jax.random.fold_in(rng, t), x0.shape)
        q = sch.add_noise(x0, eps, t) / float(sch.a[t])
        exact_fn = jax.jit(lambda qq, m=m_t: eng.coarse(qq, m))
        served = "index" if eng.use_index(t) else "exact"
        t_exact = time_call(exact_fn, q)
        exact_ids = np.asarray(exact_fn(q))
        if served == "index":
            mp, p_t = eng.padded_m(t), eng.nprobe(t)
            idx_fn = jax.jit(
                lambda qq, m=mp, p=p_t: eng.coarse_indexed(qq, m, p))
            t_idx = time_call(idx_fn, q)
            pos, pd2 = idx_fn(q)
            recall = screening_recall(pos, pd2, index.perm, exact_ids)
        else:
            # auto fallback runs the *same* compiled exact program, so
            # record identical timing instead of re-measuring noise
            t_idx = t_exact
            recall = 1.0
        rows.append({"kind": kind, "method": "exact_coarse", "N": n, "t": t,
                     "time_per_step_s": t_exact, "m_t": m_t})
        rows.append({"kind": kind, "method": "indexed_coarse", "N": n,
                     "t": t, "time_per_step_s": t_idx,
                     "speedup": t_exact / t_idx, "recall": recall,
                     "served_by_index": served == "index",
                     "nprobe": eng.nprobe(t), "m_t": m_t})
    # one full denoise-step pair: the indexed engine re-ranks *all*
    # probed rows (IVF-Flat), so its fine stage is wider than the exact
    # engine's m_t — this cell records that the whole step still wins,
    # not just the coarse scan
    t = T_BUCKETS[-1]
    if eng.use_index(t):
        exact_eng = GoldDiffEngine(store, sch, INDEXED_CFG, backend="xla")
        x_t = jnp.asarray(sch.add_noise(
            x0, jax.random.normal(jax.random.fold_in(rng, 7), x0.shape), t))
        t_ex = time_call(lambda xx: exact_eng.denoise(xx, t), x_t)
        t_ix = time_call(lambda xx: eng.denoise(xx, t), x_t)
        rows.append({"kind": kind, "method": "exact_step", "N": n, "t": t,
                     "time_per_step_s": t_ex})
        rows.append({"kind": kind, "method": "indexed_step", "N": n, "t": t,
                     "time_per_step_s": t_ix, "speedup": t_ex / t_ix})


def run(fast: bool = True):
    rows: list[dict] = []
    # table1 config: 32x32x3 procedural image manifold (graceful
    # degradation: auto serves these buckets from the exact scan)
    n1 = 8192
    bench_config("table1", image_store(n1, 32, 32, 3, seed=0), n1, rows)
    # table3 config: ImageNet-1K analogue (64x64x3, many classes)
    n3 = 8192 if fast else 20000
    bench_config("table3", imagenet_like(n=n3, num_classes=100 if fast
                                         else 1000, seed=0), n3, rows)
    # scale config: mode-structured GMM at N >= 50k — the sublinear
    # claim's acceptance cell (clustered manifold geometry)
    ns = 65536
    bench_config("scale", gmm(ns, dim=64, num_modes=256, spread=0.10,
                              seed=0), ns, rows,
                 probe_schedule=SCALE_PROBES, num_clusters=512)

    idx_rows = [r for r in rows if r["method"] == "indexed_coarse"]
    served = [r for r in idx_rows if r["served_by_index"]]
    big = [r for r in served if r["N"] >= 50000]
    min_recall = min(r["recall"] for r in idx_rows)
    sp = sorted(r["speedup"] for r in big) or [1.0]
    summary = (f"indexed vs exact coarse at N>=50k (index-served buckets): "
               f"min {sp[0]:.1f}x, median {sp[len(sp) // 2]:.1f}x over "
               f"{len(sp)} cells (target >= 3x); min recall@m_t "
               f"{min_recall:.3f} over {len(idx_rows)} buckets "
               f"(target >= 0.95); {len(served)}/{len(idx_rows)} buckets "
               f"index-served")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable record: timing cells in us_per_call plus
    ``recall/...`` fraction cells; gated by scripts/check_bench.py."""
    record = {}
    for r in rows:
        name = f"{r['kind']}/{r['method']}/N{r['N']}/t{r['t']}"
        record[name] = round(r["time_per_step_s"] * 1e6, 1)
        if "recall" in r:
            record[f"recall/{r['kind']}/N{r['N']}/t{r['t']}"] = round(
                r["recall"], 4)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
