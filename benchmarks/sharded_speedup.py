"""Sharded GoldDiffEngine vs single host on an emulated 8-device mesh.

Wall-clock on an *emulated* mesh is not a speedup claim — the eight XLA
"devices" share one physical CPU and every collective is a memcpy — so
the timing cells here are recorded **unpaired** (a trajectory to watch,
not a gate; real-hardware scaling is the ROADMAP follow-on).  What IS
gated (``scripts/check_bench.py``, >= 0.95 like every recall cell) is
**parity**: the sharded engine must keep producing the single-host
golden sets and denoised outputs —

* ``recall/sharded_parity/<kind>/...``        golden-set overlap of
  ``select()`` (sharded vs single host), exact and indexed modes;
* ``recall/sharded_parity/<kind>_masked/...`` masked-path output
  agreement, ``1 - min(1, rel_err / 1e-3)``: fp32-reduction-order
  differences (~1e-7) score ~1.0, a broken merge scores 0.

The mesh needs ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before jax initializes, so ``run()`` re-executes this module as a child
process and parses one JSON line from its stdout:

  PYTHONPATH=src python -m benchmarks.sharded_speedup
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

BENCH_JSON = "BENCH_sharded.json"
MARK = "SHARDED_BENCH_JSON:"
T_BUCKETS = (900, 300, 100, 20)


def _child(fast: bool) -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import time_call
    from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
    from repro.data import gmm
    from repro.index import build_index

    sch = make_schedule("ddpm_linear", 1000)
    mesh = jax.make_mesh((8,), ("data",))
    rows: list[dict] = []

    def overlap(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return float(np.mean([len(set(a[i]) & set(b[i])) / a.shape[1]
                              for i in range(a.shape[0])]))

    def bench(kind, store, n, batch, **eng_kw):
        ref = GoldDiffEngine(store, sch, GoldDiffConfig(), **eng_kw)
        sh = GoldDiffEngine(store, sch, GoldDiffConfig(), mesh=mesh,
                            **eng_kw)
        rng = jax.random.PRNGKey(0)
        x0 = store.X[:batch]
        for t in T_BUCKETS:
            eps = jax.random.normal(jax.random.fold_in(rng, t), x0.shape)
            x_t = jnp.asarray(sch.add_noise(x0, eps, t))
            t_one = time_call(lambda xx, tt=t: ref.denoise(xx, tt), x_t)
            t_sh = time_call(lambda xx, tt=t: sh.denoise(xx, tt), x_t)
            par = overlap(sh.select(x_t, t), ref.select(x_t, t))
            rows.append({"kind": kind, "method": "single_host", "N": n,
                         "t": t, "time_per_step_s": t_one})
            rows.append({"kind": kind, "method": "sharded8", "N": n, "t": t,
                         "time_per_step_s": t_sh, "recall": par,
                         "indexed": sh.use_index(t)})
        # masked (scan/pjit) path: one program, traced t
        t = T_BUCKETS[1]
        ta = jnp.asarray(t)
        f_ref = jax.jit(lambda xx, tt: ref.denoise_masked(xx, tt))
        f_sh = jax.jit(lambda xx, tt: sh.denoise_masked(xx, tt))
        x_t = jnp.asarray(sch.add_noise(
            x0, jax.random.normal(jax.random.fold_in(rng, 7), x0.shape), t))
        t_one = time_call(f_ref, x_t, ta)
        t_sh = time_call(f_sh, x_t, ta)
        r, s = np.asarray(f_ref(x_t, ta)), np.asarray(f_sh(x_t, ta))
        err = np.abs(s - r).max() / (np.abs(r).max() + 1e-9)
        rows.append({"kind": f"{kind}_masked", "method": "single_host",
                     "N": n, "t": t, "time_per_step_s": t_one})
        rows.append({"kind": f"{kind}_masked", "method": "sharded8", "N": n,
                     "t": t, "time_per_step_s": t_sh, "rel_err": float(err),
                     "recall": max(0.0, 1.0 - min(1.0, float(err) / 1e-3))})

    n_exact = 8192 if fast else 32768
    bench("exact", gmm(n_exact, dim=32, num_modes=64, spread=0.1, seed=0),
          n_exact, batch=16)
    n_ix = 8192 if fast else 32768
    store = gmm(n_ix, dim=32, num_modes=64, spread=0.1, seed=1)
    bench("indexed", store, n_ix, batch=16,
          index=build_index(store, num_clusters=128), index_mode="always")
    return rows


def run(fast: bool = True):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"   # TPU autodetect hangs without a TPU
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.sharded_speedup", "--emit-json"]
    if fast:
        cmd.append("--fast")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env)
    line = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith(MARK)), None)
    if line is None:
        raise RuntimeError(f"sharded bench child failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    rows = json.loads(line[len(MARK):])
    pars = [r_["recall"] for r_ in rows if "recall" in r_]
    summary = (f"sharded(8 emulated)-vs-single-host parity: min "
               f"{min(pars):.4f} over {len(pars)} cells (gated >= 0.95); "
               f"timings recorded unpaired (emulated mesh, no speedup "
               f"claim)")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Timing cells (us_per_call, unpaired) + gated parity cells."""
    record = {}
    for r in rows:
        name = f"{r['kind']}/{r['method']}/N{r['N']}/t{r['t']}"
        record[name] = round(r["time_per_step_s"] * 1e6, 1)
        if "recall" in r:
            record[f"recall/sharded_parity/{r['kind']}/N{r['N']}/t{r['t']}"
                   ] = round(r["recall"], 4)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    if "--emit-json" in sys.argv:
        rows = _child(fast="--fast" in sys.argv)
        print(MARK + json.dumps(rows))
        return
    rows, summary = run(fast="--full" not in sys.argv)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
