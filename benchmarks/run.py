"""Benchmark entrypoint: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark cell) plus
per-table summaries.  ``--full`` runs the paper-scale variants (slow on
CPU); the default fast mode keeps the whole suite minutes-scale.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2_quality]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (engine_speedup, fig3_sensitivity, fig6_hparams,
                        index_speedup, ingest, roofline, screen_speedup,
                        serve_latency, serve_resilience,
                        serve_throughput, sharded_speedup,
                        table1_complexity, table2_quality, table3_scale,
                        table4_edm, table5_orthogonality, table6_bias)

TABLES = {
    "table1_complexity": table1_complexity,
    "table2_quality": table2_quality,
    "table3_scale": table3_scale,
    "table4_edm": table4_edm,
    "table5_orthogonality": table5_orthogonality,
    "table6_bias": table6_bias,
    "fig3_sensitivity": fig3_sensitivity,
    "fig6_hparams": fig6_hparams,
    "roofline": roofline,
    "engine_speedup": engine_speedup,
    "index_speedup": index_speedup,
    "ingest": ingest,
    "screen_speedup": screen_speedup,
    "serve_latency": serve_latency,
    "serve_resilience": serve_resilience,
    "serve_throughput": serve_throughput,
    "sharded_speedup": sharded_speedup,
}


def _csv_cell(table: str, row: dict) -> str:
    keyish = [str(row.get(k)) for k in ("dataset", "method", "setting",
                                        "schedule", "weighting", "param",
                                        "value", "N", "n_sub", "t", "steps",
                                        "arch", "shape", "kind")
              if row.get(k) is not None]
    name = f"{table}/" + "/".join(keyish) if keyish else table
    us = row.get("time_per_step_s")
    us = f"{us * 1e6:.1f}" if isinstance(us, (int, float)) else ""
    derived = ";".join(f"{k}={v:.5g}" for k, v in row.items()
                       if isinstance(v, (int, float)) and not isinstance(v, bool)
                       and k not in ("time_per_step_s",))
    return f"{name},{us},{derived}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(TABLES) + [None])
    args = ap.parse_args()

    failures = []
    for name, mod in TABLES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            rows, summary = mod.run(fast=not args.full)
            for r in rows:
                print(_csv_cell(name, r), flush=True)
            if hasattr(mod, "write_bench_json"):
                # machine-readable perf record (e.g. BENCH_engine.json)
                mod.write_bench_json(rows)
            print(f"# {name} summary: {summary}  ({time.time()-t0:.1f}s)",
                  flush=True)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
