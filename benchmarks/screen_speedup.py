"""Streamed vs materialized exact screening (this PR's perf/memory claim).

Benchmarks ``ops.screen_topm`` (fused tiled pdist + running top-m,
O(B * (m + tile)) live memory) against the materialized path (full
[B, N] distance matrix + one wide ``lax.top_k``), and the streaming
full-scan LSE against the dense [B, N]-logits form, on XLA:CPU shapes.

Three kinds of cells go into ``BENCH_screen.json``:

* timing (``screen_materialized`` / ``screen_streamed`` etc.) —
  recorded UNPAIRED: on XLA:CPU the materialized form wins wall-clock
  (one big multi-threaded GEMM + top_k vs a serialized scan), which is
  exactly why the engine's ``screen="auto"`` keeps it below the byte
  budget.  No fake speedup claim.
* peak live memory (``materialized_mem`` -> ``streamed_mem``, bytes
  from ``jit(...).lower().compile().memory_analysis()``) — a GATED
  pair: the streamed form must never allocate more than the
  materialized one, and at N = 65536 the measured reduction is the
  headline (>= 8x, the memory-wall removal the paper's coarse stage
  needs at ImageNet scale).
* ``parity/...`` cells — fraction of rows whose streamed top-m
  candidate set equals ``lax.top_k``'s exactly (finite slots; ties
  resolve identically by construction), gated >= 0.999 by
  ``scripts/check_bench.py``.

  PYTHONPATH=src python -m benchmarks.screen_speedup
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.kernels import ops, ref

BENCH_JSON = "BENCH_screen.json"
TILE = 4096


def _temp_bytes(fn, *args) -> float | None:
    """Peak temp allocation of the compiled program, if XLA reports it."""
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return None if ma is None else float(ma.temp_size_in_bytes)
    except Exception:
        return None


def _set_parity(idx_a, d2_a, idx_b, d2_b) -> float:
    """Fraction of rows whose selected sets match exactly (finite slots)."""
    idx_a, idx_b = np.asarray(idx_a), np.asarray(idx_b)
    fin_a = np.isfinite(np.asarray(d2_a))
    fin_b = np.isfinite(np.asarray(d2_b))
    if not np.array_equal(fin_a, fin_b):
        return 0.0
    return float(np.mean([
        set(idx_a[i][fin_a[i]]) == set(idx_b[i][fin_b[i]])
        for i in range(idx_a.shape[0])]))


def run(fast: bool = True):
    b, d = 32, 48
    configs = [(16384, 256), (65536, 256), (65536, 1024)]
    if not fast:
        configs.append((262144, 1024))
    rows = []
    key_q, key_x = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(key_q, (b, d))
    headline_mem = None
    parities = []

    for n, m in configs:
        x = jax.random.normal(key_x, (n, d))
        xn = jnp.sum(x * x, -1)
        mat = jax.jit(lambda q: ref.screen_topm_ref(q, x, m, x_norms=xn))
        st = jax.jit(lambda q: ops.screen_topm(
            q, x, m, x_norms=xn, backend="xla", stream=True, tile=TILE))
        i_m, d_m = mat(q)
        i_s, d_s = st(q)
        parity = _set_parity(i_s, d_s, i_m, d_m)
        parities.append(parity)
        t_mat, t_st = time_call(mat, q), time_call(st, q)
        mem_mat = _temp_bytes(
            lambda q: ref.screen_topm_ref(q, x, m, x_norms=xn), q)
        mem_st = _temp_bytes(
            lambda q: ops.screen_topm(q, x, m, x_norms=xn, backend="xla",
                                      stream=True, tile=TILE), q)
        rows.append({"kind": "screen", "method": "screen_materialized",
                     "N": n, "m": m, "time_per_step_s": t_mat})
        rows.append({"kind": "screen", "method": "screen_streamed",
                     "N": n, "m": m, "time_per_step_s": t_st,
                     "parity": parity})
        if mem_mat and mem_st:
            rows.append({"kind": "screen", "method": "materialized_mem",
                         "N": n, "m": m, "bytes": mem_mat})
            rows.append({"kind": "screen", "method": "streamed_mem",
                         "N": n, "m": m, "bytes": mem_st,
                         "mem_reduction": mem_mat / mem_st})
            if n >= 65536 and headline_mem is None:
                headline_mem = mem_mat / mem_st

    # streaming full-scan LSE vs the dense [B, N]-logits aggregate
    n_fs = 65536
    x = jax.random.normal(key_x, (n_fs, d))
    xn = jnp.sum(x * x, -1)
    sig2 = 0.7
    dense = jax.jit(lambda q: ref.golden_aggregate_ref(q, x, sig2, xn))
    stream = jax.jit(lambda q: ops.golden_aggregate(
        q, x, sig2, x_norms=xn, backend="xla", stream=True, tile=TILE))
    out_d, out_s = np.asarray(dense(q)), np.asarray(stream(q))
    fs_err = float(np.abs(out_s - out_d).max() / (np.abs(out_d).max() + 1e-9))
    fs_parity = float(np.mean(
        np.abs(out_s - out_d).max(-1)
        <= 1e-4 * (np.abs(out_d).max() + 1e-9)))
    parities.append(fs_parity)
    t_d, t_s = time_call(dense, q), time_call(stream, q)
    rows.append({"kind": "full_scan", "method": "fullscan_materialized",
                 "N": n_fs, "m": 0, "time_per_step_s": t_d})
    rows.append({"kind": "full_scan", "method": "fullscan_streamed",
                 "N": n_fs, "m": 0, "time_per_step_s": t_s,
                 "parity": fs_parity, "relerr": fs_err})
    mem_d = _temp_bytes(lambda q: ref.golden_aggregate_ref(q, x, sig2, xn), q)
    mem_s = _temp_bytes(
        lambda q: ops.golden_aggregate(q, x, sig2, x_norms=xn, backend="xla",
                                       stream=True, tile=TILE), q)
    if mem_d and mem_s:
        rows.append({"kind": "full_scan", "method": "materialized_mem",
                     "N": n_fs, "m": 0, "bytes": mem_d})
        rows.append({"kind": "full_scan", "method": "streamed_mem",
                     "N": n_fs, "m": 0, "bytes": mem_s,
                     "mem_reduction": mem_d / mem_s})

    summary = (f"streamed screening: parity min "
               f"{min(parities):.4f} (target >= 0.999); peak-temp-memory "
               f"reduction at N=65536 "
               f"{headline_mem:.1f}x (target >= 8x)" if headline_mem else
               f"streamed screening: parity min {min(parities):.4f}; "
               f"memory_analysis unavailable on this backend")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable record.  Timing cells in us; ``*_mem`` cells in
    bytes (the materialized_mem -> streamed_mem pair is gated >= 1x by
    check_bench, i.e. streaming never allocates MORE); ``parity/``
    cells gated >= 0.999."""
    record = {}
    for r in rows:
        name = f"{r['kind']}/{r['method']}/N{r['N']}/m{r['m']}"
        if "bytes" in r:
            record[name] = round(r["bytes"], 1)
        else:
            record[name] = round(r["time_per_step_s"] * 1e6, 1)
        if "parity" in r:
            record[f"parity/{r['kind']}/N{r['N']}/m{r['m']}"] = \
                round(r["parity"], 6)
        if "mem_reduction" in r:
            record[f"{r['kind']}/mem_reduction/N{r['N']}/m{r['m']}"] = \
                round(r["mem_reduction"], 2)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
