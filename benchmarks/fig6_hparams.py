"""Fig. 6: sensitivity to m_max (coarse pool) and k_min (golden floor)."""
from __future__ import annotations

from benchmarks.common import efficacy, make_oracle
from repro.core import GoldDiff, GoldDiffConfig, OptimalDenoiser, make_schedule
from repro.data import cifar_like, mnist_like


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    datasets = {"cifar_like": cifar_like}
    if not fast:
        datasets["mnist_like"] = mnist_like
    n = 1024 if fast else 4096
    rows = []
    for ds, fn in datasets.items():
        store = fn(n=n, seed=0)
        oracle = make_oracle(fn, 2 * n, sch)
        for m_max in ([1 / 4, 1 / 8] if fast else [1, 1 / 2, 1 / 3, 1 / 4, 1 / 5]):
            cfg = GoldDiffConfig(m_max_frac=m_max)
            den = GoldDiff(OptimalDenoiser(store, sch), cfg)
            m = efficacy(den, oracle, sch, store.dim, num_samples=4)
            rows.append({"dataset": ds, "param": "m_max", "value": m_max, **m})
        for k_min in ([1 / 10, 1 / 40] if fast
                      else [1 / 4, 1 / 10, 1 / 20, 1 / 30, 1 / 40]):
            cfg = GoldDiffConfig(k_min_frac=k_min)
            den = GoldDiff(OptimalDenoiser(store, sch), cfg)
            m = efficacy(den, oracle, sch, store.dim, num_samples=4)
            rows.append({"dataset": ds, "param": "k_min", "value": k_min, **m})
    return rows, {}


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
