"""Continuous-batching tail latency under flash-crowd load (PR 8).

Replays the *same* offered load — one pre-drawn arrival schedule —
through two runtimes that differ in a single flag:

* ``wave``       — ``RuntimeConfig(continuous=False)``: wave-at-a-time
  admission (the PR 6 behavior).  A wave's row set is fixed at
  formation; slots freed mid-trajectory ride out the remaining plan
  buckets empty, and queued requests eat the full wave latency.
* ``continuous`` — ``RuntimeConfig(continuous=True)``: freed slots
  accept queued requests at every plan-bucket seam; joiners catch up
  to the in-flight cursor group and then share all remaining segment
  dispatches with it (``runtime._pick_segment`` catch-up-and-merge).

The arrival process is a flash crowd: a leader request, a burst of
followers trailing 1-2 scheduler steps behind it (retry fan-in /
session arrivals — the p99-shaping pattern for admission policy), then
an exponential idle gap.  Smooth one-at-a-time Poisson arrival is the
one regime where wave-at-a-time is near-optimal (each request forms its
own wave immediately); real tail latency is made by exactly these
bursts that land just after a wave forms.

**Measurement.**  Latency is end-to-end (queue + compute), measured on
a deterministic discrete-event clock: one scheduler step == one
``pump()`` == one segment-dispatch slot, the same "the pump is the
unit of service time" convention ``benchmarks/serve_resilience.py``
uses for its arrival gaps.  That makes every recorded cell exactly
reproducible — the gate can never flake.  Busy wall-clock latencies
(cumulative real dispatch time between submit and delivery) are
recorded alongside as evidence the step metric is not an artifact of
unit choice; the per-dispatch cost curve is nearly flat across batch
buckets here, so sharing a dispatch is nearly free in wall time too.

Recorded cells (merged into BENCH_serve.json under the ``throughput/``
segment this table owns):

* ``throughput/flashcrowd/wave_p99_steps`` vs
  ``.../continuous_p99_steps`` — GATED as a budget pair in
  ``scripts/check_bench.py``: continuous must stay <= 2/3x the wave
  baseline, i.e. *at least 1.5x lower p99* at identical offered load
  (the ISSUE 8 acceptance bar; measured ~2.0x).
* ``throughput/flashcrowd/{wave,continuous}_p50_steps`` and
  ``..._busy_p99_us`` — unpaired, for the table.
* ``throughput/{wave,continuous}/mean_steps`` / ``delivered`` /
  ``joins`` / ``mixed_segments`` / ``compiles_post_warmup``.

Invariants enforced inline (the bench fails loudly, not just the
gate): every submitted request is delivered exactly once with finite
images in BOTH modes, and the post-warmup compile count is 0 in BOTH
modes — mixed-cursor programs come out of ``warmup()``, never the hot
path.

  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import merge_bench_json
from repro.launch.runtime import RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine

BENCH_JSON = "BENCH_serve.json"

MODES = ("wave", "continuous")
FOLLOWERS = 3                  # burst size behind each leader
IDLE_GAP_STEPS = 24.0          # mean idle steps between flash crowds


class StepClock:
    """Deterministic discrete-event clock: the driver advances it one
    unit per ``pump()``.  Injected as ``RuntimeConfig.clock`` so ticket
    latencies come out in scheduler steps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += s


def _schedule(n_req: int, seed: int) -> list:
    """One flash-crowd arrival schedule, shared verbatim by both modes:
    (request_id, num_images, noise_seed, steps_until_next_arrival)."""
    rng = np.random.default_rng(seed)
    out, i = [], 0
    while i < n_req:
        # leader, with the burst trailing 1-2 steps behind it
        out.append((i, 2, int(rng.integers(0, 1 << 20)),
                    1 + int(rng.integers(0, 2))))
        i += 1
        k = min(FOLLOWERS, n_req - i)
        for j in range(k):
            gap = (0 if j < k - 1
                   else 1 + int(rng.exponential(IDLE_GAP_STEPS)))
            out.append((i, 2, int(rng.integers(0, 1 << 20)), gap))
            i += 1
    return out


def _drive(eng: ServeEngine, continuous: bool, schedule: list) -> dict:
    """Replay ``schedule`` through one runtime; step + wall latencies."""
    clk = StepClock()
    rt = ServeRuntime(eng, RuntimeConfig(max_queue=4 * len(schedule),
                                         continuous=continuous,
                                         clock=clk, sleep=clk.sleep,
                                         seed=7))
    rt.warmup()
    builds0 = eng.engine._builds
    tickets, wall_sub, wall_del = [], {}, {}
    busy = 0.0                   # cumulative real dispatch seconds

    def pump():
        nonlocal busy
        t0 = time.perf_counter()
        rt.pump()
        busy += time.perf_counter() - t0
        clk.t += 1.0
        for t in tickets:
            rid = t.request.request_id
            if t.status == "done" and rid not in wall_del:
                wall_del[rid] = busy

    for rid, size, noise, gap in schedule:
        wall_sub[rid] = busy
        tickets.append(rt.submit(Request(rid, size, seed=noise)))
        for _ in range(gap):
            pump()
    guard = 0
    while any(t.status in ("queued", "running") for t in tickets):
        pump()
        guard += 1
        if guard > 100 * len(schedule):
            raise RuntimeError("drain did not converge")
    h = rt.health()
    mode = "continuous" if continuous else "wave"
    for t in tickets:
        if t.status != "done":
            raise RuntimeError(f"{mode}: request "
                               f"{t.request.request_id} ended "
                               f"{t.status!r} (no deadlines were set)")
        if not np.isfinite(t.images).all():
            raise RuntimeError(f"{mode}: non-finite image delivered to "
                               f"request {t.request.request_id}")
    if eng.engine._builds != builds0 or h["compiles_post_warmup"] != 0:
        raise RuntimeError(f"{mode}: compiled post-warmup "
                           f"({eng.engine._builds - builds0} builds)")
    steps = np.asarray([t.latency_s for t in tickets], np.float64)
    wall = np.asarray([wall_del[t.request.request_id]
                       - wall_sub[t.request.request_id]
                       for t in tickets], np.float64)
    return {
        "mode": mode,
        "p50_steps": float(np.percentile(steps, 50)),
        "p99_steps": float(np.percentile(steps, 99)),
        "mean_steps": float(steps.mean()),
        "busy_p99_s": float(np.percentile(wall, 99)),
        "delivered": len(tickets),
        "joins": rt.counters["joins"],
        "mixed_segments": rt.counters["mixed_segments"],
        "compiles_post_warmup": h["compiles_post_warmup"],
    }


def run(fast: bool = True):
    n, steps, n_req = (1024, 16, 48) if fast else (8192, 16, 96)
    # plan_threshold=0.05 gives a fine-grained ~7-bucket plan: more
    # seams to admit at, longer trajectories in segments — the regime
    # continuous batching exists for
    eng = ServeEngine("gmm", {"n": n, "dim": 16}, num_steps=steps,
                      max_batch=8, plan_threshold=0.05)
    schedule = _schedule(n_req, seed=2024)
    rows = []
    for mode in MODES:
        stats = _drive(eng, continuous=(mode == "continuous"),
                       schedule=schedule)
        rows.append({"kind": "throughput", "method": mode, "N": n,
                     "steps": steps, "time_per_step_s": None,
                     "requests": n_req, **stats})
    by = {r["mode"]: r for r in rows}
    ratio = by["wave"]["p99_steps"] / by["continuous"]["p99_steps"]
    wall = by["wave"]["busy_p99_s"] / by["continuous"]["busy_p99_s"]
    summary = (f"{n_req} requests, same flash-crowd schedule: p99 "
               f"{by['wave']['p99_steps']:.0f} steps (wave) vs "
               f"{by['continuous']['p99_steps']:.0f} (continuous) = "
               f"{ratio:.2f}x lower (gate >= 1.5x; busy-wall p99 "
               f"{wall:.2f}x), {by['continuous']['joins']} joins, "
               f"{by['continuous']['mixed_segments']} mixed segments, "
               f"0 post-warmup compiles in both modes")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Merge ``throughput/...`` cells into BENCH_serve.json (the
    ``serve``/``parity`` segments stay owned by serve_latency).  The
    (wave_p99_steps, continuous_p99_steps) pair is gated at <= 2/3x by
    ``scripts/check_bench.py``'s BUDGET_PAIRS."""
    cells = {}
    for r in rows:
        m = r["mode"]
        cells[f"throughput/flashcrowd/{m}_p99_steps"] = \
            round(r["p99_steps"], 2)
        cells[f"throughput/flashcrowd/{m}_p50_steps"] = \
            round(r["p50_steps"], 2)
        cells[f"throughput/flashcrowd/{m}_busy_p99_us"] = \
            round(r["busy_p99_s"] * 1e6, 1)
        cells[f"throughput/{m}/mean_steps"] = round(r["mean_steps"], 3)
        cells[f"throughput/{m}/delivered"] = r["delivered"]
        cells[f"throughput/{m}/joins"] = r["joins"]
        cells[f"throughput/{m}/mixed_segments"] = r["mixed_segments"]
        cells[f"throughput/{m}/compiles_post_warmup"] = \
            r["compiles_post_warmup"]
    merge_bench_json(path, cells)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# merged throughput/ cells into {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
