"""§Perf hillclimbing round 2 — informed by round-1 refutations.

Round-1 findings: the 1.18 TB of train all-gathers are ACTIVATION
d_model-resharding gathers (ZeRO-1 left them untouched), and
paper-faithful golden decode matches full attention on bytes because the
per-step summary re-pooling reads the whole cache anyway.

  PYTHONPATH=src python -m benchmarks.hillclimb2
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from benchmarks.hillclimb import baseline, show  # noqa: E402
from repro.launch import dryrun as D  # noqa: E402


def main():
    print("== pair 1 (round 2): qwen2.5-32b x train_4k ==")
    baseline("qwen2.5-32b", "train_4k")
    print(" H4: drop act_embed sharding (kills per-layer d-gathers) + "
          "ZeRO-1 (kills weight gathers) + mb16 (memory via accumulation)")
    show("H4 zero1+no-act-shard+mb16", D.run_one(
        "qwen2.5-32b", "train_4k", zero1=True, num_microbatches=16,
        extra_rules={"act_embed": None}, tag="_hc_h4"))
    print(" H5: same at mb8 (fewer serial steps if memory allows)")
    show("H5 zero1+no-act-shard+mb8", D.run_one(
        "qwen2.5-32b", "train_4k", zero1=True, num_microbatches=8,
        extra_rules={"act_embed": None}, tag="_hc_h5"))

    print("== pair 2 (round 2): dbrx-132b x train_4k ==")
    baseline("dbrx-132b", "train_4k")
    print(" H4: cf=1.0 (round-1 win) + drop act_embed sharding")
    show("H4 cf1+no-act-shard", D.run_one(
        "dbrx-132b", "train_4k",
        cfg_overrides={"capacity_factor": 1.0},
        extra_rules={"act_embed": None}, tag="_hc_h4moe"))
    print(" H5: H4 + mb16 if memory blew up")
    show("H5 cf1+no-act-shard+mb16", D.run_one(
        "dbrx-132b", "train_4k", num_microbatches=16,
        cfg_overrides={"capacity_factor": 1.0},
        extra_rules={"act_embed": None}, tag="_hc_h5moe"))

    print("== pair 3 (round 2): qwen2.5-32b x long_500k ==")
    baseline("qwen2.5-32b", "long_500k")
    print(" H1': cached summaries streamed as scan xs/ys (carry-slicing "
          "them caused SPMD replication in round 1)")
    show("H1' cached summaries (xs/ys)", D.run_one(
        "qwen2.5-32b", "long_500k",
        cfg_overrides={"golden_cached_summaries": True}, tag="_hc_summ2"))
    print(" H2': summaries + block 256 / 32 golden blocks")
    show("H2' summ + block256", D.run_one(
        "qwen2.5-32b", "long_500k",
        cfg_overrides={"golden_cached_summaries": True,
                       "golden_block_size": 256, "golden_blocks": 32},
        tag="_hc_summ256b"))


if __name__ == "__main__":
    main()
