"""Table 6: biased (WSS) vs unbiased (SS) weight estimation on the golden
subset (the ablation behind Sec. 3.2)."""
from __future__ import annotations

from benchmarks.common import efficacy, make_oracle
from repro.core import GoldDiff, PCADenoiser, make_schedule
from repro.data import afhq_like, celeba_like


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    datasets = {"celeba_like": celeba_like}
    if not fast:
        datasets["afhq_like"] = afhq_like
    n = 512 if fast else 2048
    rows = []
    for ds, fn in datasets.items():
        store = fn(n=n, seed=0)
        oracle = make_oracle(fn, n * 2, sch)
        for weighting in ("wss", "ss"):
            den = GoldDiff(PCADenoiser(store, sch, chunk=64,
                                       weighting=weighting))
            den.base.weighting = weighting   # keep the biased variant biased
            m = efficacy(den, oracle, sch, store.dim,
                         num_samples=4 if fast else 16)
            rows.append({"dataset": ds, "weighting": weighting, **m})
    summary = {}
    for ds in datasets:
        wss = next(r for r in rows if r["dataset"] == ds and r["weighting"] == "wss")
        ss = next(r for r in rows if r["dataset"] == ds and r["weighting"] == "ss")
        summary[f"{ds}_ss_beats_wss"] = bool(ss["mse"] <= wss["mse"])
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
