"""Table 1: per-step cost vs dataset size N.

The paper's complexity claim: full-scan methods scale O(N D) per step
while GoldDiff's exact-distance/aggregation work is decoupled from N
(O(N d) proxy term only, d = D/16).  We time one denoise step across N
and report the measured scaling exponents.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import time_call
from repro.core import GoldDiff, GoldDiffConfig, OptimalDenoiser, make_schedule
from repro.core.denoisers import PCADenoiser
from repro.data import image_store


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    sizes = [512, 1024, 2048] if fast else [1024, 4096, 16384, 65536]
    t = 500
    rows = []
    for n in sizes:
        store = image_store(n, 32, 32, 3, seed=0)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, store.dim))
        full = OptimalDenoiser(store, sch)
        gold = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
        pca = PCADenoiser(store, sch, chunk=256)
        row = {"N": n,
               "optimal_s": time_call(lambda: full(x, t)),
               "golddiff_s": time_call(lambda: gold(x, t))}
        if not fast and n <= 4096:
            row["pca_s"] = time_call(lambda: pca(x, t))
        row["speedup"] = row["optimal_s"] / row["golddiff_s"]
        rows.append(row)

    def slope(key):
        ys = [r[key] for r in rows]
        return float(np.polyfit(np.log(sizes), np.log(ys), 1)[0])

    summary = {"optimal_scaling_exp": slope("optimal_s"),
               "golddiff_scaling_exp": slope("golddiff_s")}
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
