"""Live roofline: per-stage achieved FLOP/s and byte/s vs measured peaks.

Each GoldDiff step is a fused coarse-screen -> rerank -> aggregate
program, so production never exposes per-stage wall-clock.  This
benchmark reconstructs the roofline honestly: it dispatches each stage
as a standalone compiled program built from the engine's OWN ops
(``engine.coarse`` / ``engine.coarse_indexed``, ``ops.golden_rerank``,
``ops.golden_support_aggregate``, ``ops.golden_aggregate``) on the
engine's own operands, times it warm, and divides by the *analytic*
costs from ``repro.core.plan.step_stage_costs`` — the same numbers the
engine's trace spans carry at serve time (``stage.*`` events), so
offline roofline cells and online traces speak one cost model.

Machine peaks are measured in-process the same way: a fat fp32 GEMM for
peak FLOP/s, a large streaming add for peak byte/s.  The analytic
traffic model is optimistic (perfect reuse), so every achieved cell
must land at or below its peak — ``scripts/check_bench.py`` gates
exactly that, plus the presence of all core stages (screen, rerank,
aggregate, full_scan, and the fused single-pass ``fused_step`` kind).

Also emits the **tracing-overhead gate**: a warm engine step timed with
the tracer disabled (``obs_base_us``) vs enabled (``obs_traced_us``);
check_bench's budget pair requires traced <= 1.03x base.

Cells merge into ``BENCH_engine.json`` (``roofline/...``, ``obs/...``)
without touching ``engine_speedup``'s cells:

  PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import merge_bench_json
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, streaming)
from repro.core.plan import (full_scan_costs, fused_step_costs,
                             step_stage_costs)
from repro.data import mnist_like
from repro.index import build_index
from repro.kernels import ops
from repro.obs import trace as obs_trace

BENCH_JSON = "BENCH_engine.json"


def _best_time(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Min wall-clock seconds per call — the roofline estimator (the
    least-perturbed run is the one closest to the hardware's capability;
    medians admit scheduler noise into a gated ratio)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_peaks(rng) -> tuple[float, float]:
    """(peak GFLOP/s, peak GB/s) measured in-process.

    GEMM for compute (2k^3 flops, compute-bound at k=1024); a streaming
    ``x + 1`` over 8 MB and 64 MB buffers for bandwidth, keeping the
    most favorable (so cache-resident stage operands cannot beat it).
    """
    k = 1024
    ka, kb = jax.random.split(rng)
    a = jax.random.normal(ka, (k, k), jnp.float32)
    b = jax.random.normal(kb, (k, k), jnp.float32)
    t_gemm = _best_time(jax.jit(lambda x, y: x @ y), a, b)
    peak_gflops = 2.0 * k ** 3 / t_gemm / 1e9
    peak_gbps = 0.0
    for mb in (8, 64):
        v = jnp.zeros((mb * (1 << 20) // 4,), jnp.float32)
        t_copy = _best_time(jax.jit(lambda x: x + 1.0), v)
        peak_gbps = max(peak_gbps, 2.0 * v.size * 4 / t_copy / 1e9)
    return peak_gflops, peak_gbps


def _stage_programs(eng, t: int, x) -> dict:
    """stage -> (fn, args): standalone compiled programs for each stage
    of the engine's step at ``t``, fed the engine's real operands (the
    rerank gets the screen's candidates, the aggregate gets the
    rerank's support + logits — same dataflow as the fused step)."""
    a, sig2 = eng.constants(t)
    q = x / a
    m_t, k_t = eng.sizes(t)
    stages = {}
    if eng.use_index(t):
        mp, npb = eng.padded_m(t), eng.nprobe(t)
        screen = jax.jit(lambda qq: eng.coarse_indexed(qq, mp, npb))
        pos, pd2 = jax.block_until_ready(screen(q))
        cand = eng.index.perm[pos]
        valid = jnp.isfinite(pd2)
        k_eff = min(k_t, mp)
        rerank = jax.jit(lambda qq, cc, vv: ops.golden_rerank(
            qq, eng.X, cc, k_eff, x_norms=eng.x_norms,
            backend=eng.backend, strategy="gather", valid=vv))
        idx, d2 = jax.block_until_ready(rerank(q, cand, valid))
        stages["ivf_screen"] = (screen, (q,))
        stages["rerank"] = (rerank, (q, cand, valid))
    else:
        screen = jax.jit(lambda qq: eng.coarse(qq, m_t))
        cand = jax.block_until_ready(screen(q))
        rerank = jax.jit(lambda qq, cc: ops.golden_rerank(
            qq, eng.X, cc, k_t, x_norms=eng.x_norms,
            backend=eng.backend, strategy=eng.strategy))
        idx, d2 = jax.block_until_ready(rerank(q, cand))
        stages["screen"] = (screen, (q,))
        stages["rerank"] = (rerank, (q, cand))
    lg = jnp.maximum(-d2 / (2.0 * sig2), streaming.NEG_INF)
    agg = jax.jit(lambda ii, ll: ops.golden_support_aggregate(
        eng.X, ii, ll, backend=eng.backend, strategy=eng.strategy_for(t)))
    stages["aggregate"] = (agg, (idx, lg))
    return stages


def _roofline_rows(kind: str, eng, t: int, x, costs: dict,
                   peak_gflops: float, peak_gbps: float,
                   stages: dict) -> list[dict]:
    n = eng.store.n
    rows = []
    for stage, (fn, args) in stages.items():
        c = costs[stage]
        dt = _best_time(fn, *args)
        gflops = c["flops"] / dt / 1e9
        gbps = c["bytes"] / dt / 1e9
        rows.append({
            "kind": kind, "stage": stage, "t": t, "N": n,
            "time_per_step_s": dt,
            "achieved_gflops": gflops, "achieved_gbps": gbps,
            "frac_peak_flops": gflops / peak_gflops,
            "frac_peak_bytes": gbps / peak_gbps,
            "bench": {
                f"roofline/{kind}/N{n}/t{t}/{stage}/achieved_gflops":
                    round(gflops, 4),
                f"roofline/{kind}/N{n}/t{t}/{stage}/achieved_gbps":
                    round(gbps, 4),
            },
        })
    return rows


def run(fast: bool = True):
    n, b = (4096, 32) if fast else (16384, 64)
    store = mnist_like(n, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    rng = jax.random.PRNGKey(0)
    peak_gflops, peak_gbps = measure_peaks(rng)
    rows = [{"kind": "peak", "stage": "machine", "N": n,
             "achieved_gflops": peak_gflops, "achieved_gbps": peak_gbps,
             "bench": {"roofline/peak/peak_gflops": round(peak_gflops, 4),
                       "roofline/peak/peak_gbps": round(peak_gbps, 4)}}]

    # exact-routing engine: screen / rerank / aggregate at a high- and a
    # low-noise step (the concentration schedule moves the FLOP split)
    gd = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig(),
                  backend="xla")
    eng = gd.engine
    for t in (800, 100):
        x = float(sch.b[t]) * jax.random.normal(rng, (b, store.dim))
        costs = step_stage_costs(eng, t, batch=b)
        stages = _stage_programs(eng, t, x)
        rows += _roofline_rows("denoise", eng, t, x, costs,
                               peak_gflops, peak_gbps, stages)

    # indexed-routing engine: the ivf_screen stage (sublinear coarse)
    ix = build_index(store, num_clusters=64)
    gd_ix = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig(),
                     backend="xla", index=ix, index_mode="always")
    t = 800
    x = float(sch.b[t]) * jax.random.normal(rng, (b, store.dim))
    costs = step_stage_costs(gd_ix.engine, t, batch=b)
    stages = _stage_programs(gd_ix.engine, t, x)
    rows += _roofline_rows("denoise_ivf", gd_ix.engine, t, x, costs,
                           peak_gflops, peak_gbps, stages)

    # full-scan baseline stage (Eq. 2): the bandwidth-bound wall
    t = 400
    a, sig2 = eng.constants(t)
    x = float(sch.b[t]) * jax.random.normal(rng, (b, store.dim))
    fs = jax.jit(lambda qq: ops.golden_aggregate(
        qq, eng.X, sig2, x_norms=eng.x_norms, backend=eng.backend))
    rows += _roofline_rows("full_scan", eng, t, x,
                           full_scan_costs(eng, batch=b),
                           peak_gflops, peak_gbps,
                           {"full_scan": (fs, (x / a,))})

    # fused single-pass step stage: the whole step is ONE program
    # (kernels/fused_step.py), costed by the read-each-operand-once
    # fused accounting.  Eliminating the staged path's [B, N]-shaped
    # aggregate work roughly halves bytes per step, so this cell should
    # sit closer to the rerank corner of the roof than the staged
    # screen/aggregate cells do.
    for t in (800, 100):
        x = float(sch.b[t]) * jax.random.normal(rng, (b, store.dim))
        fb = jax.jit(lambda xx, _t=t: eng._fused_body(xx, _t))
        rows += _roofline_rows("fused", eng, t, x,
                               fused_step_costs(eng, t, batch=b),
                               peak_gflops, peak_gbps,
                               {"fused_step": (fb, (x,))})

    # tracing-overhead gate: the same warm engine step, tracer off vs on
    # (the default engine fuses its dense-strategy steps, so this pair
    # re-gates the <= 1.03x budget with the fused path ON — the traced
    # span tags then carry the fused_step stage costs)
    t = 800
    x = float(sch.b[t]) * jax.random.normal(rng, (b, store.dim))
    t_base = _best_time(lambda: eng.denoise(x, t), repeats=10, warmup=3)
    tr = obs_trace.Tracer(capacity=1 << 15)
    prev = obs_trace.set_tracer(tr)
    try:
        t_traced = _best_time(lambda: eng.denoise(x, t),
                              repeats=10, warmup=3)
    finally:
        obs_trace.set_tracer(prev)
    rows.append({
        "kind": "obs_overhead", "stage": "denoise", "t": t, "N": n,
        "time_per_step_s": t_traced,
        "overhead_x": t_traced / t_base,
        "bench": {
            f"obs/denoise/N{n}/t{t}/obs_base_us": round(t_base * 1e6, 1),
            f"obs/denoise/N{n}/t{t}/obs_traced_us":
                round(t_traced * 1e6, 1),
        },
    })

    hot = [r for r in rows if r.get("stage") == "rerank"]
    summary = (f"peaks {peak_gflops:.0f} GFLOP/s / {peak_gbps:.1f} GB/s; "
               f"rerank frac-of-peak-flops "
               f"{max(r['frac_peak_flops'] for r in hot):.2f}; "
               f"tracing overhead {t_traced / t_base:.3f}x "
               f"(gate <= 1.03x)")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Merge this table's ``roofline/...`` + ``obs/...`` cells into the
    shared record, preserving ``engine_speedup``'s cells."""
    cells: dict = {}
    for r in rows:
        cells.update(r.get("bench", {}))
    merge_bench_json(path, cells)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print({k: v for k, v in r.items() if k != "bench"})
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
