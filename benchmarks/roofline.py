"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
three-term roofline table (EXPERIMENTS.md §Roofline reads this output)."""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "16x16", tag: str = "") -> list[dict]:
    rows = []
    for p in sorted(ART.glob(f"*_{mesh}{tag}.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh:
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "hbm_gib": d["memory"].get("total_hbm_bytes", 0) / 2**30,
            "fits": d.get("fits_hbm"),
            "useful_ratio": d.get("useful_flops_ratio"),
            "coll_gb": d["collectives"]["total"] / 1e9,
        })
    return rows


def run(fast: bool = True):
    rows = load()
    summary = {}
    if rows:
        summary["n_combos"] = len(rows)
        summary["n_fit"] = sum(1 for r in rows if r["fits"])
        worst = min(rows, key=lambda r: r["useful_ratio"] or 9e9)
        summary["worst_useful_ratio"] = f"{worst['arch']}/{worst['shape']}"
        coll = max(rows, key=lambda r: (r["collective_s"]
                                        / max(max(r["compute_s"],
                                                  r["memory_s"]), 1e-12)))
        summary["most_collective_bound"] = f"{coll['arch']}/{coll['shape']}"
    return rows, summary


def main():
    rows, s = run()
    if not rows:
        print("no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collective_s':>12s} {'bneck':>10s} {'HBM GiB':>8s} "
           f"{'fits':>5s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:12.4f} "
              f"{r['bottleneck']:>10s} {r['hbm_gib']:8.2f} "
              f"{str(r['fits']):>5s} "
              f"{r['useful_ratio'] if r['useful_ratio'] else -1:7.3f}")
    print(s)


if __name__ == "__main__":
    main()
