"""Fig. 3b/3c: sensitivity to random subset size across noise regimes.

Reproduces the two-regime behaviour: at high noise a small RANDOM subset
is badly biased but a large one matches the full scan (Monte-Carlo
integration regime); at low noise even tiny subsets suffice PROVIDED the
true neighbours are included (selection regime) — random tiny subsets
miss them, golden tiny subsets don't.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GoldDiff, GoldDiffConfig, OptimalDenoiser, make_schedule
from repro.data import cifar_like


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    n = 2048 if fast else 8192
    store = cifar_like(n=n, seed=0)
    full = OptimalDenoiser(store, sch)
    x0 = store.X[:8]
    rows = []
    key = jax.random.PRNGKey(0)
    subset_sizes = [10, 100, 1000] if fast else [10, 100, 1000, 5000]
    for t, regime in ((900, "high_noise"), (80, "low_noise")):
        eps = jax.random.normal(jax.random.fold_in(key, t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        ref = np.asarray(full(xt, t))
        scale = float(np.abs(ref).mean()) + 1e-9
        for nsub in subset_sizes:
            if nsub > n:
                continue
            # random subset
            perm = jax.random.permutation(jax.random.fold_in(key, nsub), n)
            idx = jnp.tile(perm[:nsub][None], (xt.shape[0], 1))
            est = np.asarray(full(xt, t, support=idx))
            rel = float(np.abs(est - ref).mean()) / scale
            rows.append({"t": t, "regime": regime, "kind": "random",
                         "n_sub": nsub, "rel_err": rel})
        # golden subset of the scheduled size
        gd = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
        est = np.asarray(gd(xt, t))
        rows.append({"t": t, "regime": regime, "kind": "golden",
                     "n_sub": -1,
                     "rel_err": float(np.abs(est - ref).mean()) / scale})
    # key claim: at high noise, random-10 is much worse than random-1000
    hi = {r["n_sub"]: r["rel_err"] for r in rows
          if r["regime"] == "high_noise" and r["kind"] == "random"}
    summary = {"high_noise_small_vs_large": hi[10] / max(hi[1000], 1e-12)}
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
