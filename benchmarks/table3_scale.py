"""Table 3: large-scale (ImageNet-1K analogue) unconditional + conditional.

PCA (biased WSS) vs PCA-Unbiased (full-corpus SS) vs GoldDiff, at two
sampling budgets (T = 10, 100 in the paper; we scale down in fast mode).
Conditional generation restricts the store to one class.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import efficacy, make_oracle
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        PCADenoiser, make_schedule)
from repro.core.dataset import restrict
from repro.data import imagenet_like


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    n = 4096 if fast else 20000
    classes = 100 if fast else 1000
    store = imagenet_like(n=n, num_classes=classes, seed=0)
    oracle = make_oracle(lambda n, seed: imagenet_like(n=n, num_classes=classes,
                                                       seed=seed),
                         n * 2, sch)
    dim = store.dim
    budgets = [10] if fast else [10, 100]
    rows = []
    for steps in budgets:
        methods = {
            "pca_wss": PCADenoiser(store, sch, chunk=128),                # biased
            "pca_unbiased": PCADenoiser(store, sch, chunk=128,
                                        weighting="ss"),
            "golddiff": GoldDiff(PCADenoiser(store, sch, chunk=128),
                                 GoldDiffConfig()),
        }
        for name, den in methods.items():
            m = efficacy(den, oracle, sch, dim, num_samples=4 if fast else 16,
                         num_steps=steps)
            rows.append({"setting": "unconditional", "steps": steps,
                         "method": name, **m})
    # conditional: restrict support to one class (store + oracle)
    cls = 0
    idx = jnp.nonzero(store.labels == cls)[0]
    if int(idx.shape[0]) >= 8:
        sub = restrict(store, idx)
        osub = OptimalDenoiser(
            restrict(oracle.store, jnp.nonzero(oracle.store.labels == cls)[0]),
            sch)
        for name, den in {
            "pca_wss": PCADenoiser(sub, sch, chunk=64),
            "golddiff": GoldDiff(PCADenoiser(sub, sch, chunk=64),
                                 GoldDiffConfig()),
        }.items():
            m = efficacy(den, osub, sch, dim, num_samples=4, num_steps=10)
            rows.append({"setting": "conditional", "steps": 10,
                         "method": name, **m})
    gd = next(r for r in rows if r["method"] == "golddiff")
    pca = next(r for r in rows if r["method"] == "pca_wss")
    return rows, {"speedup_vs_pca": pca["time_per_step_s"] / gd["time_per_step_s"],
                  "n_dataset": n}


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
