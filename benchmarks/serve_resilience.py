"""Serving resilience under deterministic fault injection (PR 6).

Drives Poisson arrivals through ``ServeRuntime`` (admission control,
deadlines, retries, degradation ladder — ``repro/launch/runtime.py``)
under each seeded fault regime of ``repro/launch/faults.py`` and records
what a service owner would gate on:

* ``completion/resilience/<regime>`` — delivered / admitted.  GATED
  = 1.0 by ``scripts/check_bench.py``: under every fault regime the
  runtime must finish everything it admitted (deadlines here are
  generous; misses would mean dropped work, not tight deadlines).
* ``resilience/<regime>/p99_us`` vs ``.../p99_budget_us`` — a budget
  pair: delivery-time expiry makes "completed" imply "within deadline",
  so p99 <= deadline structurally and the gate is honest.
* ``resilience/<regime>/deadline_miss_rate`` + fault/degradation
  counters (retries, finite-guard trips, Gaussian fallback segments,
  post-warmup compiles) — recorded unpaired, for the table.

Every delivered image is checked finite here as well — the bench fails
loudly if the finite-output guarantee ever regresses.

The ``shard_dropout`` regime only runs when >1 JAX device is visible
(CI's emulated 8-device mesh); on a 1-device host it is skipped.

  PYTHONPATH=src python -m benchmarks.serve_resilience
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.launch.faults import FaultConfig, injected, uninstall
from repro.launch.runtime import RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine

BENCH_JSON = "BENCH_resilience.json"

DEADLINE_S = 120.0          # generous: the gate is completion, not SLO

REGIMES = [
    ("none", None),
    ("nan_storm", FaultConfig(seed=11, nan_rate=0.3)),
    ("transient_errors", FaultConfig(seed=12, error_rate=0.3)),
    ("latency_spikes", FaultConfig(seed=13, latency_rate=0.5,
                                   latency_s=0.02)),
    ("oom", FaultConfig(seed=14, oom_rate=0.2)),
    ("recompile_storm", FaultConfig(seed=15, evict_rate=0.2)),
    ("shard_dropout", FaultConfig(seed=16, shard_drop_rate=0.15)),
]


def _drive(eng: ServeEngine, n_req: int, seed: int) -> dict:
    """One regime's traffic: Poisson arrivals, inline pump loop."""
    rt = ServeRuntime(eng, RuntimeConfig(max_queue=4 * n_req,
                                         default_deadline_s=DEADLINE_S,
                                         backoff_base_s=0.001,
                                         backoff_max_s=0.01,
                                         breaker_cooldown_s=0.5,
                                         seed=seed))
    rt.warmup()
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, eng.max_batch + 1, n_req)
    tickets = []
    for i in range(n_req):
        tickets.append(rt.submit(Request(i, int(sizes[i]),
                                         seed=int(rng.integers(0, 1 << 20)))))
        # Poisson arrivals: advance the scheduler a gap's worth of steps
        # instead of sleeping (the pump is the unit of service time here)
        for _ in range(1 + int(rng.exponential(1.0))):
            rt.pump()
    rt.run_until_idle()
    h = rt.health()
    done = [t for t in tickets if t.status == "done"]
    for t in done:
        assert np.isfinite(t.images).all(), \
            f"non-finite image delivered to request {t.request.request_id}"
        assert t.images.shape[0] == t.request.num_images
    lat = np.asarray([t.latency_s for t in done], np.float64)
    return {
        "completion": len(done) / n_req,
        "p99_s": float(np.percentile(lat, 99)) if lat.size else 0.0,
        "deadline_miss_rate": h["deadline_miss_rate"],
        "retries": h["n_retries"],
        "finite_trips": h["n_finite_trips"],
        "gauss_segments": h["n_gauss_segments"],
        "oom_splits": h["n_oom_splits"],
        "scan_waves": h["n_scan_waves"],
        "compiles_post_warmup": h["compiles_post_warmup"],
    }


def run(fast: bool = True):
    n, batch, steps, n_req = (1024, 4, 8, 10) if fast else (8192, 8, 10, 40)
    eng = ServeEngine("gmm", {"n": n, "dim": 16}, num_steps=steps,
                      max_batch=batch)
    rows = []
    for regime, cfg in REGIMES:
        if regime == "shard_dropout" and len(jax.devices()) < 2:
            continue                     # inert without an emulated mesh
        uninstall()                      # belt: no injector leaks across
        if cfg is None:
            stats = _drive(eng, n_req, seed=101)
        else:
            with injected(cfg):
                stats = _drive(eng, n_req, seed=101)
        rows.append({"kind": "resilience", "method": regime, "N": n,
                     "steps": steps, "time_per_step_s": None,
                     "requests": n_req, **stats})
    worst = min(r["completion"] for r in rows)
    p99s = max(r["p99_s"] for r in rows)
    summary = (f"{len(rows)} regimes x {n_req} requests: worst completion "
               f"{worst:.3f} (gate = 1.0), max p99 {p99s:.2f}s "
               f"(budget {DEADLINE_S:.0f}s), total retries "
               f"{sum(r['retries'] for r in rows)}, finite-guard trips "
               f"{sum(r['finite_trips'] for r in rows)}, post-warmup "
               f"compiles {sum(r['compiles_post_warmup'] for r in rows)}")
    return rows, summary


def write_bench_json(rows, path: str = BENCH_JSON) -> None:
    """Machine-readable record: completion/ cells gated = 1.0,
    (p99_budget_us, p99_us) gated as a 1.0x budget pair, the rest
    recorded unpaired (see scripts/check_bench.py)."""
    record = {}
    for r in rows:
        regime = r["method"]
        record[f"completion/resilience/{regime}"] = round(r["completion"], 6)
        record[f"resilience/{regime}/p99_us"] = round(r["p99_s"] * 1e6, 1)
        record[f"resilience/{regime}/p99_budget_us"] = DEADLINE_S * 1e6
        record[f"resilience/{regime}/deadline_miss_rate"] = \
            round(r["deadline_miss_rate"], 6)
        for k in ("retries", "finite_trips", "gauss_segments", "oom_splits",
                  "scan_waves", "compiles_post_warmup"):
            record[f"resilience/{regime}/{k}"] = r[k]
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def main():
    rows, summary = run(fast=True)
    for r in rows:
        print(r)
    write_bench_json(rows)
    print(f"# wrote {BENCH_JSON}")
    print(f"# {summary}")


if __name__ == "__main__":
    main()
