"""Shared benchmark utilities.

Efficacy protocol (paper Sec. 4.1): MSE / r^2 between a denoiser's
x0-estimate and a *generalizing oracle* along shared DDIM trajectories.
The paper's oracle is a trained U-Net; offline we use the **held-out
empirical-Bayes oracle**: the exact posterior mean over an independent,
larger sample from the same generative process.  Like the neural oracle,
it represents the underlying manifold rather than the training set, so
memorization (the Optimal denoiser's failure mode) scores poorly and
generalizing estimators score well — the same ordering the paper's
protocol induces.  ``examples/train_oracle.py`` additionally provides a
real trained conv-denoiser oracle for cross-checking.
"""
from __future__ import annotations

import json
import os
import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OptimalDenoiser, sampling_timesteps
from repro.core.schedules import Schedule


def time_call(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def peak_rss_gb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6


def merge_bench_json(path: str, cells: dict) -> None:
    """Merge ``cells`` into a flat BENCH_*.json record.

    Ownership is by the first ``/``-segment of the cell name: existing
    cells whose first segment appears in ``cells`` are replaced (stale
    cells from this writer's previous run die), every other segment is
    preserved verbatim.  This lets several benchmark tables share one
    record — e.g. ``engine_speedup`` (``static/...``) and ``roofline``
    (``roofline/...``, ``obs/...``) both write BENCH_engine.json
    without truncating each other's cells.
    """
    owned = {name.split("/", 1)[0] for name in cells}
    record: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict):
                record = {k: v for k, v in prev.items()
                          if k.split("/", 1)[0] not in owned}
        except (OSError, json.JSONDecodeError):
            record = {}                  # corrupt record: rewrite fresh
    record.update(cells)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)


def make_oracle(dataset_fn, n_oracle: int, schedule: Schedule, seed: int = 777):
    """Held-out empirical-Bayes oracle (disjoint draw, larger support)."""
    store = dataset_fn(n=n_oracle, seed=seed)
    return OptimalDenoiser(store, schedule)


def efficacy(denoiser, oracle, schedule: Schedule, dim: int,
             num_samples: int = 32, num_steps: int = 10, seed: int = 0,
             time_repeats: int = 2):
    """Paper's protocol: run a shared DDIM trajectory; at each step compare
    the denoiser's x0-hat with the oracle's.  Returns dict of metrics."""
    ts = sampling_timesteps(schedule, num_steps)
    rng = jax.random.PRNGKey(seed)
    x = float(schedule.b[int(ts[0])]) * jax.random.normal(
        rng, (num_samples, dim))
    se, var_acc, n_acc = 0.0, [], 0
    step_times = []
    for t, t_prev in zip(ts[:-1], ts[1:]):
        t = int(t)
        x0_o = np.asarray(oracle(x, t))
        x0_d = np.asarray(denoiser(x, t))   # warmup: jit compile per step
        t0 = time.perf_counter()
        x0_d = np.asarray(denoiser(x, t))
        step_times.append(time.perf_counter() - t0)
        se += float(((x0_d - x0_o) ** 2).sum())
        var_acc.append(x0_o)
        n_acc += x0_o.size
        # advance the trajectory with the ORACLE (shared path for all
        # methods, as the paper fixes the initial noise / trajectory)
        x0c = jnp.clip(jnp.asarray(x0_o), -3, 3)
        x = schedule.ddim_step(x, x0c, t, int(t_prev))
    mse = se / n_acc
    o = np.concatenate([v.reshape(-1) for v in var_acc])
    r2 = 1.0 - se / float(((o - o.mean()) ** 2).sum())
    return {"mse": mse, "r2": r2,
            "time_per_step_s": float(np.median(step_times))}


def fmt_rows(rows: list[dict], cols: list[str]) -> str:
    head = " | ".join(f"{c:>14s}" for c in cols)
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(" | ".join(
            f"{r.get(c, ''):>14.4g}" if isinstance(r.get(c), float)
            else f"{str(r.get(c, '')):>14s}" for c in cols))
    return "\n".join(lines)
