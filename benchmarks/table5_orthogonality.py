"""Table 5: plug-and-play orthogonality — GoldDiff + {Optimal, Kamb}.

(The Wiener filter is excluded as in the paper: it never scans the corpus.)
"""
from __future__ import annotations

from benchmarks.common import efficacy, make_oracle
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        PatchDenoiser, make_schedule)
from repro.data import afhq_like, celeba_like


def run(fast: bool = True):
    sch = make_schedule("ddpm_linear", 1000)
    datasets = {"celeba_like": celeba_like}
    if not fast:
        datasets["afhq_like"] = afhq_like
    n = 512 if fast else 2048
    rows = []
    for ds, fn in datasets.items():
        store = fn(n=n, seed=0)
        oracle = make_oracle(fn, n * 2, sch)
        for base_name, base_cls in (("optimal", OptimalDenoiser),
                                    ("kamb", PatchDenoiser)):
            kw = {} if base_cls is OptimalDenoiser else {"chunk": 64}
            plain = base_cls(store, sch, **kw)
            wrapped = GoldDiff(base_cls(store, sch, **kw), GoldDiffConfig())
            for name, den in ((base_name, plain),
                              (base_name + "+golddiff", wrapped)):
                m = efficacy(den, oracle, sch, store.dim,
                             num_samples=4 if fast else 16)
                rows.append({"dataset": ds, "method": name, **m})
    summary = {}
    for ds in datasets:
        for b in ("optimal", "kamb"):
            p = next(r for r in rows if r["dataset"] == ds and r["method"] == b)
            w = next(r for r in rows
                     if r["dataset"] == ds and r["method"] == b + "+golddiff")
            summary[f"{ds}_{b}_speedup"] = (p["time_per_step_s"]
                                            / w["time_per_step_s"])
            summary[f"{ds}_{b}_mse_delta"] = p["mse"] - w["mse"]
    return rows, summary


if __name__ == "__main__":
    rows, s = run(fast=False)
    for r in rows:
        print(r)
    print(s)
