"""§Perf hillclimbing driver: the three selected (arch x shape) pairs.

Each iteration: hypothesis -> change (a dryrun knob) -> re-lower ->
measure the three roofline terms -> confirm/refute.  Results are saved as
tagged artifacts (artifacts/dryrun/*_hc_*.json) and summarized for
EXPERIMENTS.md §Perf.

Run AFTER the baseline artifacts exist (single-core container: never run
concurrently with the baseline sweep):

  PYTHONPATH=src python -m benchmarks.hillclimb
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402
import pathlib  # noqa: E402

from repro.launch import dryrun as D  # noqa: E402

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def show(label, rec):
    r = rec["roofline"]
    print(f"  {label:40s} compute={r['compute_s']:8.3f}s "
          f"memory={r['memory_s']:8.3f}s coll={r['collective_s']:8.3f}s "
          f"hbm={rec['memory'].get('total_hbm_bytes', 0)/2**30:6.2f}GiB "
          f"useful={rec.get('useful_flops_ratio') or -1:.3f}", flush=True)
    return rec


def baseline(arch, shape):
    p = ART / f"{arch}_{shape}_16x16.json"
    rec = json.loads(p.read_text())
    show(f"BASELINE {arch}/{shape}", rec)
    return rec


def main():
    # ---------------- pair 1: qwen2.5-32b x train_4k (collective-bound) ----
    print("== pair 1: qwen2.5-32b x train_4k ==")
    baseline("qwen2.5-32b", "train_4k")
    print(" H1: grad-accumulator sharding constraint -> reduce-scatter "
          "(predicted: all-reduce 551GB -> ~halved)")
    D.SHARD_GRAD_ACCUM = True
    show("H1 shard_grad_accum", D.run_one("qwen2.5-32b", "train_4k",
                                          tag="_hc_gradaccum"))
    D.SHARD_GRAD_ACCUM = False
    print(" H2: ZeRO-1 (params replicated over data, opt state sharded) — "
          "predicted: weight all-gathers 1.18TB -> ~65GB/step + grad RS")
    show("H2 zero1", D.run_one("qwen2.5-32b", "train_4k", zero1=True,
                               tag="_hc_zero1"))
    print(" H3: ZeRO-1 + 8 microbatches (fit margin for bigger seq) ")
    show("H3 zero1+mb8", D.run_one("qwen2.5-32b", "train_4k", zero1=True,
                                   num_microbatches=8, tag="_hc_zero1mb8"))

    # ---------------- pair 2: dbrx-132b x train_4k (MoE) -------------------
    print("== pair 2: dbrx-132b x train_4k ==")
    baseline("dbrx-132b", "train_4k")
    print(" H1: MoE group 512->256 (dispatch/capacity halves; predicted "
          "memory term down, slight drop-rate up)")
    show("H1 group256", D.run_one("dbrx-132b", "train_4k",
                                  cfg_overrides={"moe_group_size": 256},
                                  tag="_hc_moeg256"))
    print(" H2: capacity factor 1.25 -> 1.0")
    show("H2 cf1.0", D.run_one("dbrx-132b", "train_4k",
                               cfg_overrides={"capacity_factor": 1.0},
                               tag="_hc_moecf10"))
    print(" H3: ZeRO-1 on the non-expert params (experts stay 2D-sharded)")
    show("H3 zero1", D.run_one("dbrx-132b", "train_4k", zero1=True,
                               cfg_overrides={"moe_group_size": 256},
                               tag="_hc_zero1moe"))

    # ------------- pair 3: qwen2.5-32b x long_500k (golden attention) ------
    print("== pair 3: qwen2.5-32b x long_500k ==")
    baseline("qwen2.5-32b", "long_500k")
    print(" paper-faithful comparison: FULL flash-decoding (no golden)")
    show("full attention", D.run_one("qwen2.5-32b", "long_500k",
                                     cfg_overrides={"attn_kind_decode": "full"},
                                     tag="_hc_fullattn"))
    print(" H1: cached incremental block summaries (beyond-paper; per-step "
          "proxy O(S/Bs) instead of O(S))")
    show("H1 cached summaries", D.run_one(
        "qwen2.5-32b", "long_500k",
        cfg_overrides={"golden_cached_summaries": True},
        tag="_hc_summcache"))
    print(" H2: cached summaries + bigger blocks (256) — fewer summaries "
          "to scan, same coverage")
    show("H2 summ+block256", D.run_one(
        "qwen2.5-32b", "long_500k",
        cfg_overrides={"golden_cached_summaries": True,
                       "golden_block_size": 256, "golden_blocks": 32},
        tag="_hc_summ256"))


if __name__ == "__main__":
    main()
