"""Golden attention on an LLM KV cache (the paper's mechanism transplanted
onto long-context decode — DESIGN §4).

Builds a reduced llama3.2-3b-family model, prefreezes a long cache, and
compares full flash-decoding vs golden (top-k block) attention: agreement
of the next-token distribution and the per-step FLOP estimate.  The
final section drives the *shipped kernel hot path* directly — the
backend-dispatched ``repro.kernels.ops`` wrappers
(``select_golden_blocks`` + ``golden_attention_decode``), the same entry
points the model and the GoldDiffEngine route through — rather than any
seed-era inline attention math.

  PYTHONPATH=src python examples/golden_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import ops
from repro.models import transformer as T
from repro.models.module import init_params
from repro.models.transformer import model_specs, zero_cache


def main():
    cfg = get_config("llama3.2-3b").reduced(num_layers=4, d_model=256,
                                            d_ff=512, vocab=1024)
    cfg = dataclasses.replace(cfg, golden_block_size=64)
    s, b = 4096, 2
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))

    # build a "long" cache by prefilling random tokens
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size, jnp.int32)
    print(f"prefilling {s}-token cache...")
    _, cache = T.prefill(cfg, params, toks)
    pos = jnp.asarray(s - 1, jnp.int32)
    tok = toks[:, -1]

    cfg_full = dataclasses.replace(cfg, attn_kind_decode="full")
    dec_full = jax.jit(lambda c, t, p: T.decode_step(cfg_full, params, c, t, p))
    lg_full, _ = dec_full(cache, tok, pos)

    nb = s // cfg.golden_block_size
    print(f"\n{'k blocks':>9s} {'coverage':>9s} {'KL(full||gold)':>15s} "
          f"{'top1 match':>11s} {'cache read':>11s}")
    p_full = jax.nn.softmax(lg_full.astype(jnp.float32), -1)
    for kb in (nb, nb // 2, nb // 4, nb // 8, nb // 16):
        cfg_g = dataclasses.replace(cfg, attn_kind_decode="golden",
                                    golden_blocks=kb)
        dec = jax.jit(lambda c, t, p: T.decode_step(cfg_g, params, c, t, p))
        lg_g, _ = dec(cache, tok, pos)
        p_g = jax.nn.log_softmax(lg_g.astype(jnp.float32), -1)
        kl = float(jnp.sum(p_full * (jnp.log(p_full + 1e-20) - p_g), -1).mean())
        top1 = float((jnp.argmax(lg_g, -1) == jnp.argmax(lg_full, -1)).mean())
        print(f"{kb:9d} {kb/nb:9.1%} {kl:15.5f} {top1:11.0%} "
              f"{kb/nb:10.1%}+summaries")
    print("\nTheorem 1 in action: golden attention reads a fraction of the"
          "\ncache; the attention-score logit gap makes the truncated"
          "\nposterior converge to the full one (KL -> 0 fast in k).")

    # --- ops-layer hot path: the kernels the engine ships ----------------
    # One layer-0 attention step through the backend-dispatched ops
    # wrappers (xla reference vs pallas_interpret kernel body), checking
    # the golden kernel against dense attention over the same blocks.
    bs = cfg.golden_block_size
    kc, vc = cache["l0"]["k"][0], cache["l0"]["v"][0]     # [B, Hkv, S, dh]
    hq = cfg.num_heads // cfg.num_kv_heads
    qh = jax.random.normal(jax.random.PRNGKey(3),
                           (b, cfg.num_kv_heads, hq, cfg.hdim), jnp.float32)
    blk, valid = ops.select_golden_blocks(qh, kc, num_blocks=nb // 8,
                                          block_size=bs)
    outs = {be: np.asarray(ops.golden_attention_decode(
        qh, kc, vc, blk, valid, block_size=bs, backend=be))
        for be in ("xla", "pallas_interpret")}
    err = np.abs(outs["xla"] - outs["pallas_interpret"]).max()
    print(f"\nops-layer golden_attention_decode, {nb // 8}/{nb} blocks: "
          f"xla vs pallas_interpret max|delta| = {err:.2e}")


if __name__ == "__main__":
    main()
