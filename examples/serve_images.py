"""End-to-end serving driver (the paper's inference kind, deliverable b).

Stands up the serving engine over a CIFAR-scale procedural dataset,
precompiles every (batch-bucket x shape-bucket) program with
``warmup()``, and serves a queue of batched generation requests,
reporting per-request latency and throughput; then repeats with the
full-scan baseline engine to show the speedup on identical requests.

``--plan`` (default) serves through the bucketed trajectory plan —
3-4 compiled programs per batch shape at near-static FLOPs;
``--no-plan`` falls back to the single worst-case-padded masked
program; ``--buckets N`` forces a shape-program budget.

  PYTHONPATH=src python examples/serve_images.py [--no-plan] [--buckets 2]
"""
import argparse
import time

import numpy as np

from repro.launch.serve import Request, ServeEngine
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--plan", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="bucketed trajectory plan (default); --no-plan "
                         "uses the single worst-case-padded masked program")
    ap.add_argument("--buckets", type=int, default=None,
                    help="cap the number of shape buckets (compiled "
                         "programs per batch shape; floor: one per "
                         "indexed/exact routing region)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing and dump the event log as JSONL "
                         "to PATH on exit")
    ap.add_argument("--metrics", action="store_true",
                    help="count dispatches/compiles and print a "
                         "Prometheus text snapshot on exit")
    args = ap.parse_args()
    n, batch = args.n, args.batch

    tracer = (obs_trace.Tracer(capacity=1 << 16) if args.trace_out
              else obs_trace.NULL_TRACER)
    if args.trace_out or args.metrics:
        obs_trace.set_tracer(tracer)
        obs_trace.install_dispatch_tracing(
            tracer, obs_metrics.REGISTRY if args.metrics else None)
    reqs = [Request(i, num_images=4, seed=100 + i) for i in range(4)]

    print(f"== GoldDiff engine (N={n}) ==")
    eng = ServeEngine("cifar_like", {"n": n}, base="optimal",
                      num_steps=args.steps, max_batch=batch,
                      mode="plan" if args.plan else "scan",
                      max_buckets=args.buckets)
    if eng.plan is not None:
        print(eng.plan.describe())
    stats = eng.warmup()
    print(f"  warmup: {stats['programs_compiled']} programs "
          f"({len(stats['batch_buckets'])} batch buckets x "
          f"{stats['shape_buckets']} shape buckets) "
          f"in {stats['warmup_s']:.2f}s")
    t0 = time.time()
    res = eng.serve(list(reqs))
    t_gold = time.time() - t0
    for r in res:
        print(f"  request {r.request_id}: {r.images.shape} "
              f"latency={r.latency_s:.2f}s finite={np.isfinite(r.images).all()}")
    n_img = sum(r.images.shape[0] for r in res)
    print(f"  {n_img} images in {t_gold:.2f}s ({t_gold/n_img:.3f}s/img, warm)")

    print(f"== full-scan baseline engine (same requests) ==")

    class FullScanEngine(ServeEngine):
        def __init__(self, *a, **kw):
            kw["mode"] = "static"      # the raw base has no masked body
            super().__init__(*a, **kw)
            self.denoiser = self.denoiser.base       # unwrap GoldDiff

    eng2 = FullScanEngine("cifar_like", {"n": n}, base="optimal",
                          num_steps=args.steps, max_batch=batch)
    eng2.warmup()        # warm both engines: compare compute, not compiles
    t0 = time.time()
    res2 = eng2.serve(list(reqs))
    t_full = time.time() - t0
    n_img2 = sum(r.images.shape[0] for r in res2)
    print(f"  {n_img2} images in {t_full:.2f}s ({t_full/n_img2:.3f}s/img)")
    print(f"== speedup: {t_full / t_gold:.1f}x ==")
    if args.trace_out:
        tracer.dump(args.trace_out)
        print(f"trace: {len(tracer.events())} events "
              f"({tracer.dropped} dropped) -> {args.trace_out}")
    if args.metrics:
        print(obs_metrics.REGISTRY.prometheus(), end="")


if __name__ == "__main__":
    main()
