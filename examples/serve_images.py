"""End-to-end serving driver (the paper's inference kind, deliverable b).

Stands up the GoldDiffEngine over a CIFAR-scale procedural dataset and
serves a queue of batched generation requests, reporting per-request
latency and throughput; then repeats with the full-scan baseline engine
to show the speedup on identical requests.

  PYTHONPATH=src python examples/serve_images.py
"""
import time

import numpy as np

from repro.launch.serve import Request, ServeEngine


def main():
    n, batch = 2048, 8
    reqs = [Request(i, num_images=4, seed=100 + i) for i in range(4)]

    print(f"== GoldDiff engine (N={n}) ==")
    eng = ServeEngine("cifar_like", {"n": n}, base="optimal",
                      num_steps=10, max_batch=batch)
    t0 = time.time()
    res = eng.serve(list(reqs))
    t_gold = time.time() - t0
    for r in res:
        print(f"  request {r.request_id}: {r.images.shape} "
              f"latency={r.latency_s:.2f}s finite={np.isfinite(r.images).all()}")
    n_img = sum(r.images.shape[0] for r in res)
    print(f"  {n_img} images in {t_gold:.2f}s ({t_gold/n_img:.3f}s/img)")

    print(f"== full-scan baseline engine (same requests) ==")

    class FullScanEngine(ServeEngine):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.denoiser = self.denoiser.base       # unwrap GoldDiff

    eng2 = FullScanEngine("cifar_like", {"n": n}, base="optimal",
                          num_steps=10, max_batch=batch)
    t0 = time.time()
    res2 = eng2.serve(list(reqs))
    t_full = time.time() - t0
    n_img2 = sum(r.images.shape[0] for r in res2)
    print(f"  {n_img2} images in {t_full:.2f}s ({t_full/n_img2:.3f}s/img)")
    print(f"== speedup: {t_full / t_gold:.1f}x ==")


if __name__ == "__main__":
    main()
