"""Train a small conv U-Net denoiser oracle, then score the analytical
denoisers against it (the paper's efficacy protocol with a REAL neural
oracle instead of the held-out empirical-Bayes surrogate).

~100-300 steps on CPU in a few minutes at 16x16 resolution.

  PYTHONPATH=src python examples/train_oracle.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        PCADenoiser, WienerDenoiser, make_schedule)
from repro.data import image_store
from repro.training import optimizer as opt

H = W = 16
C = 3


def conv(x, w, b, stride=1):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def init_unet(key, ch=32):
    """Tiny 2-level U-Net (no attention, as the paper's oracle)."""
    ks = jax.random.split(key, 10)
    he = lambda k, shape: jax.random.normal(k, shape) * np.sqrt(
        2.0 / np.prod(shape[:3]))
    return {
        "in": (he(ks[0], (3, 3, C + 1, ch)), jnp.zeros(ch)),
        "d1": (he(ks[1], (3, 3, ch, ch * 2)), jnp.zeros(ch * 2)),
        "d2": (he(ks[2], (3, 3, ch * 2, ch * 2)), jnp.zeros(ch * 2)),
        "mid": (he(ks[3], (3, 3, ch * 2, ch * 2)), jnp.zeros(ch * 2)),
        "u1": (he(ks[4], (3, 3, ch * 4, ch)), jnp.zeros(ch)),
        "u2": (he(ks[5], (3, 3, ch * 2, ch)), jnp.zeros(ch)),
        "out": (he(ks[6], (3, 3, ch, C)) * 0.1, jnp.zeros(C)),
    }


def unet_apply(p, x_img, t_frac):
    """x_img: [B,H,W,C]; t_frac: [B] in [0,1] -> x0 prediction."""
    tt = jnp.broadcast_to(t_frac[:, None, None, None],
                          x_img.shape[:3] + (1,))
    h0 = jax.nn.silu(conv(jnp.concatenate([x_img, tt], -1), *p["in"]))
    h1 = jax.nn.silu(conv(h0, *p["d1"], stride=2))       # 8x8
    h2 = jax.nn.silu(conv(h1, *p["d2"], stride=2))       # 4x4
    m = jax.nn.silu(conv(h2, *p["mid"]))
    u = jax.image.resize(m, h1.shape[:1] + (H // 2, W // 2, m.shape[-1]),
                         "nearest")
    u = jax.nn.silu(conv(jnp.concatenate([u, h1], -1), *p["u1"]))
    u = jax.image.resize(u, h0.shape[:1] + (H, W, u.shape[-1]), "nearest")
    u = jax.nn.silu(conv(jnp.concatenate([u, h0], -1), *p["u2"]))
    return conv(u, *p["out"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-train", type=int, default=2048)
    args = ap.parse_args()

    sch = make_schedule("ddpm_linear", 1000)
    store = image_store(args.n_train, H, W, C, num_classes=10, seed=0)
    data = jnp.asarray(store.X).reshape(-1, H, W, C)

    params = init_unet(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps,
                           weight_decay=0.01)
    state = opt.init_state(params)

    @jax.jit
    def train_step(params, state, key):
        k1, k2, k3 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (args.batch,), 0, data.shape[0])
        x0 = data[idx]
        t = jax.random.randint(k2, (args.batch,), 1, 1000)
        eps = jax.random.normal(k3, x0.shape)
        a = jnp.asarray(sch.a)[t][:, None, None, None]
        b = jnp.asarray(sch.b)[t][:, None, None, None]
        xt = a * x0 + b * eps

        def loss_fn(p):
            pred = unet_apply(p, xt, t / 1000.0)
            return jnp.mean((pred - x0) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.apply_updates(ocfg, params, grads, state)
        return params, state, loss

    print(f"training tiny U-Net oracle on {args.n_train} {H}x{W} images...")
    t0 = time.time()
    for i in range(args.steps):
        params, state, loss = train_step(params, state,
                                         jax.random.PRNGKey(1000 + i))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} mse={float(loss):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    def oracle(x_flat, t):
        img = x_flat.reshape(-1, H, W, C)
        pred = unet_apply(params, img,
                          jnp.full((img.shape[0],), t / 1000.0))
        return pred.reshape(x_flat.shape)

    # --- paper's efficacy protocol against the trained oracle ------------
    from benchmarks.common import efficacy
    print("\nefficacy vs trained neural oracle (MSE lower / r2 higher = better):")
    methods = {
        "optimal": OptimalDenoiser(store, sch),
        "wiener": WienerDenoiser(store, sch, rank=256),
        "pca": PCADenoiser(store, sch, chunk=128),
        "golddiff(pca)": GoldDiff(PCADenoiser(store, sch, chunk=128),
                                  GoldDiffConfig()),
    }
    for name, den in methods.items():
        m = efficacy(den, oracle, sch, store.dim, num_samples=16)
        print(f"  {name:16s} mse={m['mse']:.4f} r2={m['r2']:+.3f} "
              f"t/step={m['time_per_step_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
