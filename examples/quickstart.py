"""Quickstart: GoldDiff on the Moons toy (paper Fig. 1) in ~30 seconds.

Demonstrates the whole public API surface:
  1. build a dataset store + schedule,
  2. watch Posterior Progressive Concentration (the golden support
     shrinking as t -> 0),
  3. verify Theorem 1's truncation bound at both noise regimes,
  4. sample with the full-scan Optimal denoiser vs GoldDiff and compare.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, sample, schedule_sizes)
from repro.core import bounds
from repro.data import moons


def main():
    store = moons(n=2000, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    den = OptimalDenoiser(store, sch)
    gd = GoldDiff(den, GoldDiffConfig())

    # --- 2. posterior progressive concentration -------------------------
    print("Posterior Progressive Concentration (effective golden support):")
    x0 = store.X[:16]
    key = jax.random.PRNGKey(0)
    print(f"  {'t':>5s} {'sigma_t':>10s} {'support (PR)':>14s} "
          f"{'m_t':>6s} {'k_t':>6s}")
    for t in (999, 800, 600, 400, 200, 50):
        eps = jax.random.normal(jax.random.fold_in(key, t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        lg = den.logits(xt, t)
        pr = float(jnp.mean(bounds.participation_ratio(lg)))
        m_t, k_t = schedule_sizes(gd.cfg, sch, t, store.n)
        print(f"  {t:5d} {float(sch.sigma(t)):10.3f} {pr:14.1f} "
              f"{m_t:6d} {k_t:6d}")

    # --- 3. Theorem 1 ----------------------------------------------------
    print("\nTheorem 1 truncation bound (err <= 2R(N-k)exp(-Delta_k)):")
    radius = bounds.data_radius(store.X)
    for t in (900, 100):
        eps = jax.random.normal(jax.random.fold_in(key, 7 * t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        lg = den.logits(xt, t)
        k = store.n // 20
        err = float(jnp.mean(bounds.truncation_error(lg, store.X, k)))
        bnd = float(jnp.mean(bounds.theorem1_bound(lg, k, radius)))
        print(f"  t={t:4d}  measured={err:.3e}  bound={bnd:.3e}  "
              f"holds={err <= bnd + 1e-6}")

    # --- 4. sampling ------------------------------------------------------
    print("\nSampling 256 points (10 DDIM steps):")
    import time
    t0 = time.time()
    xs_full = sample(den, sch, (256, 2), jax.random.PRNGKey(1), num_steps=10)
    t_full = time.time() - t0
    t0 = time.time()
    xs_gold = sample(gd, sch, (256, 2), jax.random.PRNGKey(1), num_steps=10)
    t_gold = time.time() - t0

    def manifold_dist(xs):
        d2 = jnp.sum((xs[:, None] - store.X[None]) ** 2, -1)
        return float(jnp.sqrt(jnp.min(d2, -1)).mean())

    print(f"  full scan : {t_full:6.2f}s  mean-dist-to-manifold="
          f"{manifold_dist(xs_full):.4f}")
    print(f"  golddiff  : {t_gold:6.2f}s  mean-dist-to-manifold="
          f"{manifold_dist(xs_gold):.4f}")
    print(f"  outputs agree: "
          f"{float(jnp.abs(xs_full - xs_gold).mean()):.4f} mean |delta|")


if __name__ == "__main__":
    main()
