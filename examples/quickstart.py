"""Quickstart: GoldDiff on the Moons toy (paper Fig. 1) in ~30 seconds.

Demonstrates the whole public API surface, routed through the shipped
``GoldDiffEngine`` hot path (kernel-layer coarse -> rerank -> aggregate
with a compiled-program cache — not the seed-era inline jnp loops):
  1. build a dataset store + schedule + engine-backed denoisers,
  2. watch Posterior Progressive Concentration (the golden support
     shrinking as t -> 0),
  3. verify Theorem 1's truncation bound at both noise regimes,
  4. sample with the full-scan Optimal denoiser vs GoldDiff — and, with
     ``--indexed``, GoldDiff screening through the clustered Golden
     Index (sublinear coarse stage) — and compare.

  PYTHONPATH=src python examples/quickstart.py [--backend xla] [--indexed]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, sample, schedule_sizes)
from repro.core import bounds
from repro.data import moons
from repro.index import ProbeSchedule, build_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas_interpret", "pallas"],
                    help="engine backend (pallas needs a real TPU)")
    ap.add_argument("--indexed", action="store_true",
                    help="also run GoldDiff with the clustered Golden "
                         "Index serving coarse screening")
    args = ap.parse_args()

    store = moons(n=2000, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    den = OptimalDenoiser(store, sch, backend=args.backend)
    gd = GoldDiff(den, GoldDiffConfig(), backend=args.backend)
    eng = gd.engine
    print(f"engine: backend={eng.backend} strategy={eng.strategy} "
          f"(gather/GEMM crossover ~{eng.crossover_frac:.0%} of N)")

    # --- 2. posterior progressive concentration -------------------------
    print("\nPosterior Progressive Concentration (effective golden support):")
    x0 = store.X[:16]
    key = jax.random.PRNGKey(0)
    print(f"  {'t':>5s} {'sigma_t':>10s} {'support (PR)':>14s} "
          f"{'m_t':>6s} {'k_t':>6s}")
    for t in (999, 800, 600, 400, 200, 50):
        eps = jax.random.normal(jax.random.fold_in(key, t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        lg = den.logits(xt, t)
        pr = float(jnp.mean(bounds.participation_ratio(lg)))
        m_t, k_t = schedule_sizes(gd.cfg, sch, t, store.n)
        print(f"  {t:5d} {float(sch.sigma(t)):10.3f} {pr:14.1f} "
              f"{m_t:6d} {k_t:6d}")

    # --- 3. Theorem 1 ----------------------------------------------------
    print("\nTheorem 1 truncation bound (err <= 2R(N-k)exp(-Delta_k)):")
    radius = bounds.data_radius(store.X)
    for t in (900, 100):
        eps = jax.random.normal(jax.random.fold_in(key, 7 * t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        lg = den.logits(xt, t)
        k = store.n // 20
        err = float(jnp.mean(bounds.truncation_error(lg, store.X, k)))
        bnd = float(jnp.mean(bounds.theorem1_bound(lg, k, radius)))
        print(f"  t={t:4d}  measured={err:.3e}  bound={bnd:.3e}  "
              f"holds={err <= bnd + 1e-6}")

    # --- 4. sampling ------------------------------------------------------
    print("\nSampling 256 points (10 DDIM steps):")
    import time
    runs = {"full scan": den, "golddiff": gd}
    if args.indexed:
        # Golden Index: k-means clusters over the proxy space; nprobe_t
        # follows g(sigma_t) (wide at low SNR, a handful at high SNR).
        # index_mode="always" forces the indexed path so this toy
        # (N=2000 — far below the regime where the index pays off; see
        # BENCH_index.json for the N>=50k wall-clock claim) actually
        # exercises it end to end.
        index = build_index(store)
        gd_idx = GoldDiff(OptimalDenoiser(store, sch, backend=args.backend),
                          GoldDiffConfig(), backend=args.backend,
                          index=index, index_mode="always",
                          probe_schedule=ProbeSchedule(f_lo=1 / 16,
                                                       f_hi=1 / 4,
                                                       safety=2.0))
        e = gd_idx.engine
        print(f"  golden index: C={index.num_clusters} clusters, "
              f"L={index.max_cluster}; nprobe t=999->{e.nprobe(999)} "
              f"t=50->{e.nprobe(50)} (correctness demo at toy N)")
        runs["golddiff+index"] = gd_idx
    outs = {}
    for name, d in runs.items():
        t0 = time.time()
        outs[name] = sample(d, sch, (256, 2), jax.random.PRNGKey(1),
                            num_steps=10)
        dt = time.time() - t0

        d2 = jnp.sum((outs[name][:, None] - store.X[None]) ** 2, -1)
        mdist = float(jnp.sqrt(jnp.min(d2, -1)).mean())
        print(f"  {name:15s}: {dt:6.2f}s  mean-dist-to-manifold={mdist:.4f}")
    ref = outs["full scan"]
    for name, xs in outs.items():
        if name != "full scan":
            print(f"  full scan vs {name}: "
                  f"{float(jnp.abs(ref - xs).mean()):.4f} mean |delta|")


if __name__ == "__main__":
    main()
