import os

# Tests run on the single real CPU device; ONLY launch/dryrun.py forces the
# 512-device placeholder topology (and runs in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
