"""Golden attention: paper mechanism on the KV cache (+cached summaries)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.models.module import init_params
from repro.models.transformer import model_specs, zero_cache

CFG = ModelConfig(name="tiny", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  attn_kind_decode="golden", golden_blocks=2,
                  golden_block_size=8, dtype="float32", remat=False)


def _rand(k, *s):
    return jax.random.normal(jax.random.PRNGKey(k), s)


def test_full_coverage_equals_dense_attention():
    b, hkv, g, dh, s = 2, 2, 3, 16, 64
    q, k, v = _rand(0, b, hkv, g, dh), _rand(1, b, hkv, s, dh), _rand(2, b, hkv, s, dh)
    mask = jnp.ones((b, s), bool)
    m, l, acc = L.golden_decode_partials(q, k, v, mask, num_blocks=8,
                                         block_size=8)
    out = acc / l[..., None]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) / dh ** 0.5
    ref = jnp.einsum("bhgs,bhsd->bhgd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_cached_summaries_match_recomputed():
    b, hkv, g, dh, s = 2, 2, 3, 16, 64
    q, k, v = _rand(3, b, hkv, g, dh), _rand(4, b, hkv, s, dh), _rand(5, b, hkv, s, dh)
    mask = jnp.arange(s)[None] < 40
    mask = jnp.broadcast_to(mask, (b, s))
    summ = L.block_summaries(k, mask, 8)
    a = L.golden_decode_partials(q, k, v, mask, 4, 8)
    b_ = L.golden_decode_partials(q, k, v, mask, 4, 8, summaries=summ)
    for x, y in zip(a, b_):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                                   atol=1e-5)


def test_partial_merge_equals_single_shard():
    """Split-S partials + LSE merge == unsplit attention (flash-decoding)."""
    b, hkv, g, dh, s = 1, 2, 2, 16, 64
    q, k, v = _rand(6, b, hkv, g, dh), _rand(7, b, hkv, s, dh), _rand(8, b, hkv, s, dh)
    mask = jnp.ones((b, s), bool)
    m, l, acc = L.decode_attention_local(q, k, v, mask)
    full = acc / l[..., None]
    h = s // 2
    parts = [L.decode_attention_local(q, k[:, :, :h], v[:, :, :h], mask[:, :h]),
             L.decode_attention_local(q, k[:, :, h:], v[:, :, h:], mask[:, h:])]
    m1, l1, a1 = parts[0]
    m2, l2, a2 = parts[1]
    mg = jnp.maximum(m1, m2)
    lg = l1 * jnp.exp(m1 - mg) + l2 * jnp.exp(m2 - mg)
    ag = a1 * jnp.exp(m1 - mg)[..., None] + a2 * jnp.exp(m2 - mg)[..., None]
    np.testing.assert_allclose(np.asarray(ag / lg[..., None]),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


def test_decode_summary_cache_consistent_over_steps():
    cfg = dataclasses.replace(CFG, golden_cached_summaries=True)
    specs = model_specs(CFG)
    params = init_params(specs, jax.random.PRNGKey(0))
    s, b = 32, 2
    c_plain = zero_cache(CFG, b, s)
    c_summ = zero_cache(cfg, b, s)
    assert "summ" in c_summ["l0"]
    tok = jnp.zeros((b,), jnp.int32)
    for pos in range(2, 10):
        l1, c_plain = T.decode_step(CFG, params, c_plain, tok, jnp.int32(pos))
        l2, c_summ = T.decode_step(cfg, params, c_summ, tok, jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-4)


def test_golden_truncation_follows_theorem1():
    """More golden blocks -> lower error vs dense attention (Theorem 1 on
    the KV posterior)."""
    b, hkv, g, dh, s = 2, 2, 2, 32, 256
    q, k, v = _rand(9, b, hkv, g, dh), _rand(10, b, hkv, s, dh), _rand(11, b, hkv, s, dh)
    mask = jnp.ones((b, s), bool)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) / dh ** 0.5
    dense = jnp.einsum("bhgs,bhsd->bhgd", jax.nn.softmax(scores, -1), v)
    errs = []
    for kb in (1, 4, 16, 32):
        m, l, acc = L.golden_decode_partials(q, k, v, mask, kb, 8)
        out = acc / l[..., None]
        errs.append(float(jnp.abs(out - dense).max()))
    assert errs[-1] < 1e-5                       # full coverage == dense
    assert errs[0] >= errs[1] >= errs[2] - 1e-6  # monotone in coverage
