"""Pallas flash-attention kernel vs dense oracle: shape/dtype/causal sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,hkv,g,s,dh,qc,kc", [
    (1, 1, 1, 64, 32, 32, 32),
    (2, 2, 3, 128, 64, 32, 64),
    (1, 4, 5, 256, 64, 64, 128),   # GQA, uneven tiles over diagonal
    (2, 1, 2, 96, 32, 32, 48),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hkv, g, s, dh, qc, kc, causal, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, hkv, g, s, dh), dtype)
    k = jax.random.normal(keys[1], (b, hkv, s, dh), dtype)
    v = jax.random.normal(keys[2], (b, hkv, s, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, qc=qc, kc=kc)
    expect = ref.flash_attention_ref(q, k, v, causal)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_layer_attention():
    """Kernel agrees with the pure-JAX production path (models.layers)."""
    from repro.models.layers import AttnDims, flash_attention as jax_flash
    b, s, h, hkv, dh = 2, 128, 4, 2, 32
    dims = AttnDims(h, hkv, dh)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, hkv, dh))
    o_jax = jax_flash(q, k, v, dims, q_chunk=32, kv_chunk=64)
    qg = q.reshape(b, s, hkv, h // hkv, dh).transpose(0, 2, 3, 1, 4)
    o_k = ops.flash_attention(qg, k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), qc=32, kc=64)
    o_k = o_k.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_jax),
                               rtol=2e-5, atol=2e-5)
