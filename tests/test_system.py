"""End-to-end behaviour tests for the whole system."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, sample)
from repro.data import gmm


def test_end_to_end_generation_quality():
    """Full pipeline: dataset -> GoldDiff engine -> samples on-manifold."""
    store = gmm(2048, dim=16, num_modes=8, spread=0.05, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    gd = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
    out = sample(gd, sch, (16, 16), jax.random.PRNGKey(0), num_steps=10)
    assert bool(jnp.isfinite(out).all())
    d = jnp.sqrt(jnp.min(jnp.sum((out[:, None] - store.X[None]) ** 2, -1), -1))
    assert float(d.mean()) < 0.5, float(d.mean())


def test_serving_engine():
    from repro.launch.serve import Request, ServeEngine
    eng = ServeEngine("gmm", {"n": 1024, "dim": 16}, base="optimal",
                      num_steps=5, max_batch=4)
    res = eng.serve([Request(0, 3, seed=1), Request(1, 2, seed=2),
                     Request(2, 6, seed=3)])
    assert [r.request_id for r in res] == [0, 1, 2]
    assert sum(r.images.shape[0] for r in res) >= 3 + 2 + 4
    assert all(np.isfinite(r.images).all() for r in res)


def test_train_loop_loss_decreases():
    """Reduced-LLM training: loss falls over 30 steps (substrate works)."""
    from repro.launch.train import train
    losses = train("llama3.2-3b", smoke=True, steps=30, batch=4, seq=128,
                   ckpt_dir=None, use_mesh=False, log_every=100)
    assert np.isfinite(losses).all()
    assert losses[-5:].mean() < losses[:5].mean() - 0.05, \
        f"loss did not fall: {losses[:3]} -> {losses[-3:]}"


def test_checkpoint_roundtrip(tmp_path):
    from repro.training import checkpoint
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    checkpoint.save(tmp_path, 7, tree)
    assert checkpoint.latest_step(tmp_path) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = checkpoint.restore(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hlo_collective_parser():
    from repro.distributed.hlo_analysis import collective_bytes
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[16]{0}, f32[16]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = bf16[8,8]{1,0} all-to-all(%z), dimensions={0}
  %cp = u32[2]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ard = f32[999]{0} all-reduce-done(%ar.1)
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 4 * 128 * 2
    assert cb["all-reduce"] == 256 * 4
    assert cb["reduce-scatter"] == 2 * 16 * 4
    assert cb["all-to-all"] == 64 * 2
    assert cb["collective-permute"] == 2 * 4
    assert cb["total"] == sum(cb[k] for k in cb if k != "total")


def test_model_flops_formula():
    from repro.configs import get_config
    from repro.distributed.hlo_analysis import model_flops
    from repro.launch.inputs import SHAPES
    cfg = get_config("llama3.2-3b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    assert 1e16 < f_train < 1e17, f_train
    assert 1e11 < f_decode < 2e13, f_decode
    # MoE counts only active experts
    moe = get_config("dbrx-132b")
    active = model_flops(moe, SHAPES["train_4k"])
    frac = active / (6 * 132e9 * 4096 * 256)
    assert frac < 0.45, "active-expert accounting should be ~4/16 of total"


def test_distributed_retrieval_subprocess():
    """Distributed golden retrieval == single-host GoldDiff (8 fake devs)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import GoldDiff, GoldDiffConfig, OptimalDenoiser, make_schedule
from repro.core.golddiff import schedule_sizes
from repro.data import gmm
from repro.distributed.retrieval import shard_store, distributed_golden_denoise

mesh = jax.make_mesh((4, 2), ("data", "model"))
store = gmm(1024, dim=16, seed=0)
sch = make_schedule("ddpm_linear", 1000)
gd = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
sstore = shard_store(store, mesh, "data")
x0 = store.X[:4]
ok = True
for t in (100, 500):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    ref = np.asarray(gd(xt, t))
    m, k = schedule_sizes(gd.cfg, sch, t, store.n)
    a = float(sch.a[t]); s2 = float(sch.sigma(t))**2
    with mesh:
        out = np.asarray(distributed_golden_denoise(
            sstore, mesh, xt / a, s2, m, k, proxy_factor=1))
    err = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
    print("t", t, "rel err", err)
    ok &= err < 0.05
print("PASS" if ok else "FAIL")
"""
    import os
    from pathlib import Path
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin the child to CPU: with libtpu installed but no TPU attached,
    # platform autodetection hangs inside TPU client init.  The 8 fake
    # devices come from XLA_FLAGS, which works on the CPU platform.
    env["JAX_PLATFORMS"] = "cpu"
    repo = str(Path(__file__).resolve().parent.parent)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=repo, env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
