"""Sharded GoldDiffEngine == single-host engine (emulated 8-device mesh).

The mesh tests run in subprocesses: ``XLA_FLAGS=--xla_force_host_
platform_device_count=8`` must be set before jax initializes, and the
parent test process runs on the single real CPU device (conftest pins
JAX_PLATFORMS=cpu, which the children inherit — with libtpu installed
but no TPU attached, platform autodetection hangs in TPU client init).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent

# The exact-parity test below stays in tier-1 (one subprocess, like the
# existing distributed-retrieval test); the other mesh subprocess tests
# are slow-marked — CI's `mesh` job selects this file by path with no
# -m filter, so they all still run there on every push/PR.


def _run_child(code: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=str(REPO), env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
    return r.stdout


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm

def relerr(a, b):
    return np.abs(np.asarray(a) - np.asarray(b)).max() / \
        (np.abs(np.asarray(b)).max() + 1e-9)

def overlap(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.mean([len(set(a[i]) & set(b[i])) / a.shape[1]
                    for i in range(a.shape[0])])
"""


def test_sharded_engine_exact_parity_subprocess():
    """Exact mode: denoise / denoise_masked / select / full_scan match
    the single-host engine to fp32 reduction order, on an uneven
    N % devices != 0 store."""
    code = _PRELUDE + r"""
mesh = jax.make_mesh((8,), ("data",))
store = gmm(1003, dim=16, seed=0)            # 1003 % 8 != 0: padded tail
sch = make_schedule("ddpm_linear", 1000)
ref = GoldDiffEngine(store, sch, GoldDiffConfig())
sh = GoldDiffEngine(store, sch, GoldDiffConfig(), mesh=mesh)
x0 = store.X[:4]
ok = True
for t in (100, 500, 900):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    e1 = relerr(sh.denoise(xt, t), ref.denoise(xt, t))
    e2 = relerr(sh.denoise_masked(xt, jnp.asarray(t)),
                ref.denoise_masked(xt, jnp.asarray(t)))
    e3 = relerr(sh.full_scan(xt, t), ref.full_scan(xt, t))
    ov = overlap(sh.select(xt, t), ref.select(xt, t))
    print("t", t, e1, e2, e3, ov)
    ok &= e1 < 1e-5 and e2 < 1e-5 and e3 < 1e-5 and ov == 1.0
print("PASS" if ok else "FAIL")
"""
    _run_child(code)


@pytest.mark.slow
def test_sharded_engine_indexed_parity_subprocess():
    """Indexed mode: the globally-partitioned index reproduces the
    single-host probe set exactly, so indexed sharded screening is an
    equality test too (static and masked paths, 4-way data axis of a
    (4, 2) data/model mesh)."""
    code = _PRELUDE + r"""
from repro.index import build_index

mesh = jax.make_mesh((4, 2), ("data", "model"))
store = gmm(2003, dim=16, num_modes=32, spread=0.05, seed=0)
sch = make_schedule("ddpm_linear", 1000)
ix = build_index(store, num_clusters=32)
ref = GoldDiffEngine(store, sch, GoldDiffConfig(), index=ix,
                     index_mode="always")
sh = GoldDiffEngine(store, sch, GoldDiffConfig(), index=ix,
                    index_mode="always", mesh=mesh)
x0 = store.X[:4]
ok = True
for t in (100, 500, 900):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    e1 = relerr(sh.denoise(xt, t), ref.denoise(xt, t))
    e2 = relerr(sh.denoise_masked(xt, jnp.asarray(t)),
                ref.denoise_masked(xt, jnp.asarray(t)))
    ov = overlap(sh.select(xt, t), ref.select(xt, t))
    print("t", t, e1, e2, ov)
    ok &= e1 < 1e-5 and e2 < 1e-5 and ov == 1.0
print("PASS" if ok else "FAIL")
"""
    _run_child(code)


@pytest.mark.slow
def test_two_stage_merge_equals_global_softmax_subprocess():
    """Regression: the two-stage top-k + LSE merge primitives equal a
    global top-k + softmax computed in fp32 on one host."""
    code = _PRELUDE + r"""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.sharding import (crossshard_kth, lse_merge_mean,
                                        shard_map_compat)
from repro.kernels import ops

mesh = jax.make_mesh((8,), ("data",))
S, B, kloc, nloc, D, k = 8, 5, 6, 32, 12, 17
rng = np.random.default_rng(0)
neg = rng.standard_normal((S, B, kloc)).astype(np.float32)
X = rng.standard_normal((S, nloc, D)).astype(np.float32)
idx = rng.integers(0, nloc, (S, B, kloc)).astype(np.int32)
s2 = 0.37

def local(neg_sh, X_sh, idx_sh):
    neg_l, X_l, idx_l = neg_sh[0], X_sh[0], idx_sh[0]
    kth = crossshard_kth(neg_l, k, k, "data")
    lg = jnp.where(neg_l >= kth[:, None], neg_l / (2.0 * s2), -1e30)
    acc, m, l = ops.golden_partial_aggregate(X_l, idx_l, lg)
    return lse_merge_mean(acc, m, l, "data")

sp = P("data")
put = lambda a: jax.device_put(jnp.asarray(a), NamedSharding(mesh, sp))
out = np.asarray(shard_map_compat(local, mesh, (sp, sp, sp), P())(
    put(neg), put(X), put(idx)))

# single-host oracle: global top-k + softmax over the gathered rows
flat_neg = neg.transpose(1, 0, 2).reshape(B, S * kloc)
rows = np.stack([np.concatenate([X[s][idx[s, b]] for s in range(S)])
                 for b in range(B)])                      # [B, S*kloc, D]
ref = np.zeros((B, D), np.float32)
for b in range(B):
    top = np.argsort(-flat_neg[b])[:k]
    lg = flat_neg[b][top] / (2.0 * s2)
    w = np.exp(lg - lg.max()); w /= w.sum()
    ref[b] = w @ rows[b][top]
err = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
print("merge rel err", err)
print("PASS" if err < 1e-5 else "FAIL")
"""
    _run_child(code)


@pytest.mark.slow
def test_sharded_golddiff_wrapper_and_scan_subprocess():
    """GoldDiff(mesh=...) end-to-end: static steps and the scan-based
    masked sampler both run sharded and stay on-manifold."""
    code = _PRELUDE + r"""
from repro.core import GoldDiff, OptimalDenoiser, sample_scan

mesh = jax.make_mesh((8,), ("data",))
store = gmm(1024, dim=16, num_modes=8, spread=0.05, seed=0)
sch = make_schedule("ddpm_linear", 1000)
gd_ref = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
gd_sh = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig(), mesh=mesh)
xt = sch.add_noise(store.X[:4],
                   jax.random.normal(jax.random.PRNGKey(0), (4, 16)), 300)
ok = relerr(gd_sh(xt, 300), gd_ref(xt, 300)) < 1e-5
out = sample_scan(gd_sh.call_masked, sch, (8, 16), jax.random.PRNGKey(1),
                  num_steps=6)
ok &= bool(jnp.isfinite(out).all())
d = jnp.sqrt(jnp.min(jnp.sum((out[:, None] - store.X[None]) ** 2, -1), -1))
ok &= float(d.mean()) < 0.5
print("scan dist", float(d.mean()))
print("PASS" if ok else "FAIL")
"""
    _run_child(code)


def test_partition_windows_host():
    """Window partition: monotone cuts covering all windows, balanced
    row counts, robust to skewed window sizes and S > C."""
    from repro.index.shard import partition_windows
    rng = np.random.default_rng(3)
    for sizes in (rng.integers(1, 50, 37), np.array([1000, 1, 1, 1]),
                  np.array([5])):
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        for s in (1, 4, 8):
            cuts = partition_windows(offsets, s)
            assert cuts[0] == 0 and cuts[-1] == len(offsets) - 1
            assert (np.diff(cuts) >= 0).all()
            rows = np.diff(offsets[cuts])
            assert rows.sum() == offsets[-1]
            if len(sizes) >= s:
                # no shard exceeds an even share by more than one window
                assert rows.max() <= offsets[-1] / s + sizes.max()


def test_sharded_layout_single_device():
    """shard_layout on a 1-device mesh is a plain (padded) re-stack:
    ids/rows round-trip and padding carries +inf norms."""
    import jax
    from repro.data import gmm
    from repro.index import build_index
    from repro.index.shard import shard_layout

    mesh = jax.make_mesh((1,), ("data",))
    store = gmm(257, dim=8, seed=0)
    lay = shard_layout(store, mesh, "data")
    assert lay.n_loc == 257 and not lay.indexed
    np.testing.assert_array_equal(np.asarray(lay.ids)[0], np.arange(257))
    np.testing.assert_allclose(np.asarray(lay.X)[0], np.asarray(store.X))

    ix = build_index(store, num_clusters=8)
    lay = shard_layout(store, mesh, "data", index=ix)
    assert lay.indexed and lay.w_max == ix.num_clusters
    perm = np.asarray(ix.perm)
    np.testing.assert_array_equal(np.asarray(lay.ids)[0], perm)
    np.testing.assert_allclose(np.asarray(lay.X)[0],
                               np.asarray(store.X)[perm])
    np.testing.assert_array_equal(np.asarray(lay.offsets)[0],
                                  np.asarray(ix.offsets))
