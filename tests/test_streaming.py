"""Streaming softmax: exactness, merge associativity, WSS bias (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import streaming

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


@pytest.mark.parametrize("n,d,chunk", [(17, 3, 4), (64, 8, 64), (100, 5, 7),
                                       (4096, 16, 512), (33, 2, 1)])
def test_streaming_equals_reference(n, d, chunk):
    lg = 5.0 * _rand(0, 2, n)
    vals = _rand(1, n, d)
    out = streaming.streaming_softmax_mean(lg, vals, chunk)
    ref = streaming.softmax_mean_reference(lg, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(2, 200), st.integers(1, 8), st.integers(0, 10_000),
       st.floats(0.1, 30.0))
def test_streaming_chunk_invariance(n, d, seed, scale):
    """Property: result is independent of the chunking (unbiasedness)."""
    key = jax.random.PRNGKey(seed)
    lg = scale * jax.random.normal(key, (n,))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    outs = [streaming.streaming_softmax_mean(lg, vals, c)
            for c in (1, max(n // 3, 1), n)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 1000))
def test_merge_associative_and_exact(n1, n2, seed):
    """Shard-merge (LSE) == single-pass over the concatenation."""
    key = jax.random.PRNGKey(seed)
    lg = 8.0 * jax.random.normal(key, (n1 + n2,))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n1 + n2, 4))
    s1 = streaming.update_state(streaming.init_state((), 4), lg[:n1], vals[:n1])
    s2 = streaming.update_state(streaming.init_state((), 4), lg[n1:], vals[n1:])
    merged = streaming.finalize(streaming.merge_states(s1, s2))
    ref = streaming.softmax_mean_reference(lg, vals)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_masking():
    lg = _rand(3, 10)
    vals = _rand(4, 10, 2)
    mask = jnp.arange(10) < 6
    out = streaming.streaming_softmax_mean(lg, vals, 3, mask=mask)
    ref = streaming.softmax_mean_reference(lg[:6], vals[:6])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_wss_is_biased_flattening():
    """The WSS (PCA-style) estimator flattens the weight distribution:
    when one chunk holds a dominant logit, WSS pulls the estimate toward
    the other chunks' means relative to the exact softmax (Sec. 3.2)."""
    n, d = 64, 3
    lg = jnp.zeros((n,)).at[5].set(12.0)       # sharp posterior in chunk 0
    vals = jnp.concatenate([jnp.ones((32, d)), -jnp.ones((32, d))])
    exact = streaming.softmax_mean_reference(lg, vals)
    wss = streaming.weighted_streaming_softmax_mean(lg, vals, chunk=32)
    # exact ~ +1 (the dominant sample); WSS is dragged toward the mean
    assert float(exact[0]) > 0.99
    assert float(wss[0]) < float(exact[0]) - 0.2
