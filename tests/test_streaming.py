"""Streaming softmax: exactness, merge associativity, WSS bias (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import streaming

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


@pytest.mark.parametrize("n,d,chunk", [(17, 3, 4), (64, 8, 64), (100, 5, 7),
                                       (4096, 16, 512), (33, 2, 1)])
def test_streaming_equals_reference(n, d, chunk):
    lg = 5.0 * _rand(0, 2, n)
    vals = _rand(1, n, d)
    out = streaming.streaming_softmax_mean(lg, vals, chunk)
    ref = streaming.softmax_mean_reference(lg, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(2, 200), st.integers(1, 8), st.integers(0, 10_000),
       st.floats(0.1, 30.0))
def test_streaming_chunk_invariance(n, d, seed, scale):
    """Property: result is independent of the chunking (unbiasedness)."""
    key = jax.random.PRNGKey(seed)
    lg = scale * jax.random.normal(key, (n,))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    outs = [streaming.streaming_softmax_mean(lg, vals, c)
            for c in (1, max(n // 3, 1), n)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 1000))
def test_merge_associative_and_exact(n1, n2, seed):
    """Shard-merge (LSE) == single-pass over the concatenation."""
    key = jax.random.PRNGKey(seed)
    lg = 8.0 * jax.random.normal(key, (n1 + n2,))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n1 + n2, 4))
    s1 = streaming.update_state(streaming.init_state((), 4), lg[:n1], vals[:n1])
    s2 = streaming.update_state(streaming.init_state((), 4), lg[n1:], vals[n1:])
    merged = streaming.finalize(streaming.merge_states(s1, s2))
    ref = streaming.softmax_mean_reference(lg, vals)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_masking():
    lg = _rand(3, 10)
    vals = _rand(4, 10, 2)
    mask = jnp.arange(10) < 6
    out = streaming.streaming_softmax_mean(lg, vals, 3, mask=mask)
    ref = streaming.softmax_mean_reference(lg[:6], vals[:6])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_wss_tail_remainder_not_dropped():
    """Regression: with n % chunk != 0 the tail used to be silently
    dropped (``usable = num * chunk``); the dominant sample below lives
    entirely in the remainder."""
    n, d, chunk = 70, 3, 32                        # remainder of 6
    lg = jnp.zeros((n,)).at[n - 1].set(15.0)       # sharp mode in the tail
    vals = jnp.zeros((n, d)).at[n - 1].set(5.0)
    out = streaming.weighted_streaming_softmax_mean(lg, vals, chunk)
    assert float(out[0]) > 1.0, np.asarray(out)    # old code returned ~0


def test_wss_tail_fold_matches_manual_chunking():
    """The folded tail equals the explicit ragged-chunk WSS formula
    (w_c ∝ n_c exp(mean logit), local softmax means)."""
    n, d, chunk = 23, 4, 8                         # chunks of 8, 8, 15-8=... -> [8, 15]
    key = jax.random.PRNGKey(0)
    lg = 3.0 * jax.random.normal(key, (n,))
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    out = streaming.weighted_streaming_softmax_mean(lg, vals, chunk)
    bounds_ = [(0, 8), (8, 23)]                    # num=2 -> one head + tail
    mus, mls, ns = [], [], []
    for s, e in bounds_:
        w = jax.nn.softmax(lg[s:e])
        mus.append(w @ vals[s:e])
        mls.append(float(jnp.mean(lg[s:e])))
        ns.append(e - s)
    wc = jax.nn.softmax(jnp.asarray(mls) + jnp.log(jnp.asarray(ns, jnp.float32)))
    ref = jnp.einsum("n,nd->d", wc, jnp.stack(mus))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_wss_combine_tail_remainder():
    """wss_combine had the same dropped-tail bug on per-query supports."""
    k, d, chunk = 10, 2, 4                         # remainder of 2
    lg = jnp.zeros((3, k)).at[:, -1].set(12.0)
    vals = jnp.broadcast_to(jnp.zeros((k, d)).at[-1].set(3.0), (3, k, d))
    out = streaming.wss_combine(lg, vals, chunk)
    assert np.all(np.asarray(out)[:, 0] > 0.5), np.asarray(out)


def test_wss_is_biased_flattening():
    """The WSS (PCA-style) estimator flattens the weight distribution:
    when one chunk holds a dominant logit, WSS pulls the estimate toward
    the other chunks' means relative to the exact softmax (Sec. 3.2)."""
    n, d = 64, 3
    lg = jnp.zeros((n,)).at[5].set(12.0)       # sharp posterior in chunk 0
    vals = jnp.concatenate([jnp.ones((32, d)), -jnp.ones((32, d))])
    exact = streaming.softmax_mean_reference(lg, vals)
    wss = streaming.weighted_streaming_softmax_mean(lg, vals, chunk=32)
    # exact ~ +1 (the dominant sample); WSS is dragged toward the mean
    assert float(exact[0]) > 0.99
    assert float(wss[0]) < float(exact[0]) - 0.2
