"""scripts/check_bench.py: the tier-2 perf gate must fail loudly (and
cleanly) on malformed records, and keep gating good ones."""
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
spec = importlib.util.spec_from_file_location(
    "check_bench", REPO / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def _write(tmp_path, name, payload) -> str:
    p = tmp_path / name
    p.write_text(payload if isinstance(payload, str)
                 else json.dumps(payload))
    return str(p)


def test_good_record_passes(tmp_path):
    p = _write(tmp_path, "BENCH_x.json",
               {"a/seed_eager/t1": 100.0, "a/engine_xla/t1": 10.0,
                "recall/a/t1": 0.99})
    assert check_bench.check_file(p, 1.0) == []


def test_regression_and_recall_floor_fail(tmp_path):
    p = _write(tmp_path, "BENCH_x.json",
               {"a/seed_eager/t1": 10.0, "a/engine_xla/t1": 100.0,
                "recall/a/t1": 0.5})
    fails = check_bench.check_file(p, 1.0)
    assert len(fails) == 2
    assert any("speedup" in f for f in fails)
    assert any("recall floor" in f for f in fails)


def test_malformed_json_is_clean_failure(tmp_path):
    p = _write(tmp_path, "BENCH_bad.json", "{not json!")
    fails = check_bench.check_file(p, 1.0)
    assert len(fails) == 1 and "malformed JSON" in fails[0]


def test_missing_file_is_clean_failure(tmp_path):
    fails = check_bench.check_file(str(tmp_path / "BENCH_gone.json"), 1.0)
    assert len(fails) == 1 and "unreadable" in fails[0]


def test_wrong_toplevel_and_empty_and_nonnumeric(tmp_path):
    assert "expected a JSON object" in check_bench.check_file(
        _write(tmp_path, "BENCH_l.json", [1, 2]), 1.0)[0]
    assert "empty bench record" in check_bench.check_file(
        _write(tmp_path, "BENCH_e.json", {}), 1.0)[0]
    fails = check_bench.check_file(
        _write(tmp_path, "BENCH_n.json",
               {"a/seed_eager/t1": "fast", "b": True}), 1.0)
    assert "non-numeric cell" in fails[0]
    assert "a/seed_eager/t1" in fails[0] and "b" in fails[0]


def test_recall_out_of_range(tmp_path):
    fails = check_bench.check_file(
        _write(tmp_path, "BENCH_r.json", {"recall/a/t1": 1.7}), 1.0)
    assert len(fails) == 1 and "outside [0, 1]" in fails[0]


def test_parity_floor(tmp_path):
    """parity/ cells gate at the exactness floor (0.999), far tighter
    than recall's 0.95 — 0.98 must fail as parity but pass as recall."""
    p = _write(tmp_path, "BENCH_p.json",
               {"parity/screen/N1/m1": 1.0, "parity/screen/N2/m2": 0.98,
                "parity/screen/N3/m3": 1.2})
    fails = check_bench.check_file(p, 1.0)
    assert len(fails) == 2
    assert any("exact-parity floor" in f and "N2" in f for f in fails)
    assert any("outside [0, 1]" in f and "N3" in f for f in fails)


def test_memory_pair_gated(tmp_path):
    """materialized_mem -> streamed_mem is a gated pair: streaming must
    never allocate more than the materialized form it replaces."""
    good = _write(tmp_path, "BENCH_m.json",
                  {"screen/materialized_mem/N1": 16e6,
                   "screen/streamed_mem/N1": 1.3e6})
    assert check_bench.check_file(good, 1.0) == []
    bad = _write(tmp_path, "BENCH_m2.json",
                 {"screen/materialized_mem/N1": 1.0e6,
                  "screen/streamed_mem/N1": 2.0e6})
    fails = check_bench.check_file(bad, 1.0)
    assert len(fails) == 1 and "streamed_mem" in fails[0]


def test_cli_exit_codes(tmp_path):
    """End-to-end: exit 1 + message on a broken record, exit 0 on good."""
    _write(tmp_path, "BENCH_bad.json", "{oops")
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_bench.py"),
         "--dir", str(tmp_path)], capture_output=True, text=True)
    assert r.returncode == 1
    assert "malformed JSON" in r.stdout and "Traceback" not in r.stderr
    (tmp_path / "BENCH_bad.json").unlink()
    _write(tmp_path, "BENCH_ok.json", {"x/seed_eager/t": 5.0,
                                       "x/engine_xla/t": 1.0})
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_bench.py"),
         "--dir", str(tmp_path)], capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_completion_floor(tmp_path):
    """completion/ cells gate at exactly 1.0: the serving runtime must
    finish 100% of admitted requests in every fault regime."""
    good = _write(tmp_path, "BENCH_c.json",
                  {"completion/resilience/nan_storm": 1.0,
                   "completion/resilience/none": 1})
    assert check_bench.check_file(good, 1.0) == []
    p = _write(tmp_path, "BENCH_c2.json",
               {"completion/resilience/nan_storm": 0.9,
                "completion/resilience/oom": 1.3})
    fails = check_bench.check_file(p, 1.0)
    assert len(fails) == 2
    assert any("completion floor" in f and "nan_storm" in f for f in fails)
    assert any("outside [0, 1]" in f and "oom" in f for f in fails)


def test_p99_budget_pair(tmp_path):
    """p99_budget_us -> p99_us is a 1.0x budget pair: delivered p99 must
    stay within the declared deadline budget."""
    ok = _write(tmp_path, "BENCH_d.json",
                {"resilience/nan_storm/p99_budget_us": 1.2e8,
                 "resilience/nan_storm/p99_us": 0.9e8})
    assert check_bench.check_file(ok, 1.0) == []
    over = _write(tmp_path, "BENCH_d2.json",
                  {"resilience/nan_storm/p99_budget_us": 1.2e8,
                   "resilience/nan_storm/p99_us": 1.3e8})
    fails = check_bench.check_file(over, 1.0)
    assert len(fails) == 1 and "exceeds" in fails[0] \
        and "p99_us" in fails[0]


def test_budget_pair_gates_plan_flops(tmp_path):
    """static_flops -> plan_flops is a budget pair: the plan may pay
    MORE FLOPs than static, but only up to 1.2x."""
    ok = _write(tmp_path, "BENCH_s.json",
                {"serve/static_flops/t1": 100.0,
                 "serve/plan_flops/t1": 110.0})
    assert check_bench.check_file(ok, 1.0) == []
    over = _write(tmp_path, "BENCH_o.json",
                  {"serve/static_flops/t1": 100.0,
                   "serve/plan_flops/t1": 130.0})
    fails = check_bench.check_file(over, 1.0)
    assert len(fails) == 1 and "exceeds" in fails[0] \
        and "1.30x" in fails[0]
    bad = _write(tmp_path, "BENCH_z.json",
                 {"serve/static_flops/t1": 0.0,
                  "serve/plan_flops/t1": 10.0})
    assert any("non-positive" in f
               for f in check_bench.check_file(bad, 1.0))
    bad_subj = _write(tmp_path, "BENCH_y.json",
                      {"serve/static_flops/t1": 100.0,
                       "serve/plan_flops/t1": -1.0})
    assert any("non-positive" in f
               for f in check_bench.check_file(bad_subj, 1.0))


def test_obs_overhead_budget_pair(tmp_path):
    """obs_base_us -> obs_traced_us is a 1.03x budget pair: a warm step
    with the tracer enabled may cost at most 3% over tracing-off."""
    ok = _write(tmp_path, "BENCH_t.json",
                {"obs/denoise/N4096/t800/obs_base_us": 1000.0,
                 "obs/denoise/N4096/t800/obs_traced_us": 1020.0})
    assert check_bench.check_file(ok, 1.0) == []
    over = _write(tmp_path, "BENCH_t2.json",
                  {"obs/denoise/N4096/t800/obs_base_us": 1000.0,
                   "obs/denoise/N4096/t800/obs_traced_us": 1050.0})
    fails = check_bench.check_file(over, 1.0)
    assert len(fails) == 1 and "exceeds" in fails[0] \
        and "obs_traced_us" in fails[0]


def _roofline_record(**over):
    rec = {"roofline/peak/peak_gflops": 100.0,
           "roofline/peak/peak_gbps": 20.0}
    for stage in check_bench.ROOFLINE_STAGES:
        rec[f"roofline/denoise/N1/t1/{stage}/achieved_gflops"] = 50.0
        rec[f"roofline/denoise/N1/t1/{stage}/achieved_gbps"] = 10.0
    rec.update(over)
    return rec


def test_roofline_good_record_passes(tmp_path):
    p = _write(tmp_path, "BENCH_r.json", _roofline_record())
    assert check_bench.check_file(p, 1.0) == []
    # roofline gating is opt-in: records without roofline cells skip it
    q = _write(tmp_path, "BENCH_r0.json", {"a/seed_eager/t1": 2.0,
                                           "a/engine_xla/t1": 1.0})
    assert check_bench.check_file(q, 1.0) == []


def test_roofline_achieved_must_not_exceed_peak(tmp_path):
    p = _write(tmp_path, "BENCH_r.json", _roofline_record(**{
        "roofline/denoise/N1/t1/rerank/achieved_gflops": 150.0,
        "roofline/denoise/N1/t1/screen/achieved_gbps": 25.0}))
    fails = check_bench.check_file(p, 1.0)
    assert len(fails) == 2
    assert all("exceeds the measured peak" in f for f in fails)
    zero = _write(tmp_path, "BENCH_rz.json", _roofline_record(**{
        "roofline/denoise/N1/t1/rerank/achieved_gflops": 0.0}))
    assert any("must be positive" in f
               for f in check_bench.check_file(zero, 1.0))


def test_roofline_requires_peaks_and_all_stages(tmp_path):
    rec = _roofline_record()
    del rec["roofline/peak/peak_gbps"]
    for k in list(rec):
        if "/full_scan/" in k:
            del rec[k]
    p = _write(tmp_path, "BENCH_r.json", rec)
    fails = check_bench.check_file(p, 1.0)
    assert any("peak_gbps" in f and "missing" in f for f in fails)
    assert any("missing required stage" in f and "full_scan" in f
               for f in fails)
