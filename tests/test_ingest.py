"""Appendable golden-store lifecycle: durability, crash windows, replay
determinism, capacity behavior, and post-append retrieval quality.

The crash-safety tests simulate kills at every ``commit`` stage and at
torn-journal boundaries, then assert *bit-identical* recovery — the
recovered arrays equal the pre-crash in-memory state exactly, not
approximately.
"""
import os

import numpy as np
import pytest

from repro.data import gmm
from repro.index import (IngestConfig, StoreCapacityError,
                         StoreCorruptionError, StoreLifecycle, build_index,
                         screening_recall, validate_index)
from repro.index.ingest import JOURNAL_FILE
from repro.launch.faults import corrupt_store


def make_lifecycle(root, n=512, dim=16, seed=3, num_clusters=8,
                   cfg=None):
    store = gmm(n, dim=dim, seed=seed)._replace(labels=None)
    index = build_index(store, num_clusters=num_clusters)
    return StoreLifecycle.create(str(root), store, index,
                                 cfg or IngestConfig()), store


def new_rows(b, dim=16, seed=100):
    return np.random.default_rng(seed).normal(
        size=(b, dim)).astype(np.float32)


def snapshot(lc):
    return {k: v.copy() for k, v in lc._arrays().items()}


def assert_state_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


class Kill(RuntimeError):
    pass


def kill_at(stage):
    def hook(s):
        if s == stage:
            raise Kill(stage)
    return hook


# -- roundtrip + append durability -------------------------------------------

def test_create_open_roundtrip_bit_identical(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    before = snapshot(lc)
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert_state_equal(before, snapshot(lc2))
    assert lc2.epoch == 0 and lc2.n_rows == lc.n_rows
    assert lc2.quarantined == []


def test_append_then_reopen_replays_bit_identical(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(16))
    lc.append(new_rows(8, seed=101))
    before = snapshot(lc)
    # no commit: the journal is the only durable record of the appends
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert lc2.replayed_frames == 2
    assert lc2.n_rows == lc.n_rows
    assert_state_equal(before, snapshot(lc2))


def test_append_journal_precedes_memory(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    j = os.path.join(str(tmp_path), JOURNAL_FILE)
    size0 = os.path.getsize(j)
    lc.append(new_rows(4))
    assert os.path.getsize(j) > size0


def test_commit_then_reopen(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(16))
    epoch = lc.commit()
    assert epoch == 1 and lc.pending_rows == 0
    before = snapshot(lc)
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert lc2.epoch == 1 and lc2.replayed_frames == 0
    assert_state_equal(before, snapshot(lc2))


def test_commit_without_pending_is_noop(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    assert lc.commit() == 0
    assert lc.epoch == 0


# -- crash windows (satellite 3) ---------------------------------------------

@pytest.mark.parametrize("stage", ["epoch_written", "current_flipped",
                                   "journal_truncated"])
def test_kill_during_commit_recovers_bit_identical(tmp_path, stage):
    """A crash at ANY commit stage recovers to the exact pre-crash
    state: the new epoch dir is invisible until CURRENT flips, and
    stale journal frames are skipped by their epoch tag after it."""
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(12))
    before = snapshot(lc)
    with pytest.raises(Kill):
        lc.commit(kill=kill_at(stage))
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert_state_equal(before, snapshot(lc2))
    assert lc2.n_rows == lc.n_rows
    # the recovered lifecycle is fully functional: commit + reopen again
    lc2.append(new_rows(4, seed=7))
    lc2.commit()
    lc3 = StoreLifecycle.open(str(tmp_path))
    assert_state_equal(snapshot(lc2), snapshot(lc3))


def test_torn_journal_tail_replays_valid_prefix(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(8))
    mid = snapshot(lc)
    lc.append(new_rows(8, seed=101))
    j = os.path.join(str(tmp_path), JOURNAL_FILE)
    size = os.path.getsize(j)
    with open(j, "r+b") as f:           # tear the second frame mid-payload
        f.truncate(size - 10)
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert lc2.replayed_frames == 1
    assert_state_equal(mid, snapshot(lc2))
    # the torn tail was truncated away: a fresh append + reopen works
    lc2.append(new_rows(4, seed=9))
    lc3 = StoreLifecycle.open(str(tmp_path))
    assert_state_equal(snapshot(lc2), snapshot(lc3))


def test_corrupt_journal_frame_stops_replay(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(8))
    before_append = StoreLifecycle.open(str(tmp_path), fallback=False)
    j = os.path.join(str(tmp_path), JOURNAL_FILE)
    data = bytearray(open(j, "rb").read())
    data[-5] ^= 0xFF                    # flip a payload byte: CRC mismatch
    with open(j, "wb") as f:
        f.write(data)
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert lc2.replayed_frames == 0     # invalid frame = not applied
    assert lc2.n_rows == before_append.n_rows - 8


def test_replay_is_idempotent_across_reopens(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(8))
    s1 = snapshot(StoreLifecycle.open(str(tmp_path)))
    s2 = snapshot(StoreLifecycle.open(str(tmp_path)))
    assert_state_equal(s1, s2)


# -- quarantine / fallback (tentpole d) ---------------------------------------

def test_open_quarantines_corrupt_current_epoch(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(8))
    lc.commit()                          # epoch 1 is CURRENT
    npz = os.path.join(str(tmp_path), "epoch_00000001", "arrays.npz")
    corrupt_store(npz, "bitflip", seed=5)
    lc2 = StoreLifecycle.open(str(tmp_path))
    assert lc2.epoch == 0                # walked back to the survivor
    assert len(lc2.quarantined) == 1
    assert lc2.quarantined[0][0] == "epoch_00000001"
    # journal frames were epoch-1-tagged: skipped against epoch 0
    assert lc2.replayed_frames == 0


def test_open_no_fallback_raises_typed(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    lc.append(new_rows(8))
    lc.commit()
    npz = os.path.join(str(tmp_path), "epoch_00000001", "arrays.npz")
    corrupt_store(npz, "truncate")
    with pytest.raises(StoreCorruptionError):
        StoreLifecycle.open(str(tmp_path), fallback=False)


def test_open_all_epochs_corrupt_raises(tmp_path):
    lc, _ = make_lifecycle(tmp_path)
    for name in os.listdir(str(tmp_path)):
        if name.startswith("epoch_"):
            corrupt_store(os.path.join(str(tmp_path), name, "arrays.npz"),
                          "torn_rename")
    with pytest.raises(StoreCorruptionError):
        StoreLifecycle.open(str(tmp_path))


# -- determinism + capacity ---------------------------------------------------

def test_append_is_deterministic(tmp_path):
    lcs = []
    for sub in ("a", "b"):
        lc, _ = make_lifecycle(tmp_path / sub)
        for s in (100, 101, 102):
            lc.append(new_rows(8, seed=s))
        lcs.append(lc)
    assert_state_equal(snapshot(lcs[0]), snapshot(lcs[1]))


def test_capacity_error_before_journaling(tmp_path):
    lc, _ = make_lifecycle(tmp_path, cfg=IngestConfig(slack=1.0,
                                                      spare_frac=0.01))
    j = os.path.join(str(tmp_path), JOURNAL_FILE)
    free = lc.n_capacity - lc.n_rows
    size0 = os.path.getsize(j)
    with pytest.raises(StoreCapacityError):
        lc.append(new_rows(free + 1))
    assert os.path.getsize(j) == size0   # nothing was journaled
    assert lc.n_rows == 512              # nothing was applied


def test_shapes_invariant_across_appends(tmp_path):
    """The whole hot-swap contract: appends never change any shape,
    offsets, or the static padded width."""
    lc, _ = make_lifecycle(tmp_path)
    ds0, ix0 = lc.view()
    lc.append(new_rows(64))
    lc.commit()
    ds1, ix1 = lc.view()
    assert ds1.X.shape == ds0.X.shape
    assert ix1.max_cluster == ix0.max_cluster
    assert ix1.num_clusters == ix0.num_clusters
    np.testing.assert_array_equal(np.asarray(ix1.offsets),
                                  np.asarray(ix0.offsets))


def test_view_never_aliases_live_buffers(tmp_path):
    """``view()`` must hand out COPIES: on CPU a zero-copy jax array
    would let a later append mutate an installed engine epoch in place
    (the hot-swap correctness bug this pins)."""
    lc, _ = make_lifecycle(tmp_path)
    ds, ix = lc.view()
    x_before = np.asarray(ds.X).copy()
    ps_before = np.asarray(ix.proxy_sorted).copy()
    lc.append(new_rows(32))
    np.testing.assert_array_equal(np.asarray(ds.X), x_before)
    np.testing.assert_array_equal(np.asarray(ix.proxy_sorted), ps_before)


def test_recluster_fills_spares_and_stays_valid(tmp_path):
    """Enough appends to overflow windows: local 2-means moves rows to
    spare windows, and the resulting index still passes the full
    semantic validation."""
    lc, _ = make_lifecycle(tmp_path, cfg=IngestConfig(slack=1.05,
                                                      spare_frac=0.5))
    free = lc.n_capacity - lc.n_rows
    lc.append(new_rows(free))            # fill to the brim
    assert lc.n_rows == lc.n_capacity
    _, ix = lc.view()
    validate_index({f: np.asarray(getattr(ix, f)) for f in
                    ("centroids", "centroid_norms", "perm", "offsets",
                     "proxy_sorted", "proxy_norms_sorted")},
                   ix.max_cluster)
    # every appended row is selectable exactly once
    fin = np.isfinite(np.asarray(ix.proxy_norms_sorted))
    ids = np.asarray(ix.perm)[fin]
    assert ids.size == lc.n_rows == np.unique(ids).size


def test_view_through_engine_full_recall_on_padded_layout(tmp_path):
    """The capacity-padded view is an ordinary (store, index) pair: an
    unmodified engine screens it with recall 1.0 vs the exact scan on
    the occupied rows (+inf padding never screens in)."""
    import jax.numpy as jnp

    from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
    from repro.index.schedule import ProbeSchedule

    lc, store = make_lifecycle(tmp_path, n=1024, num_clusters=16)
    lc.append(new_rows(64, seed=42))
    ds, ix = lc.view()
    eng = GoldDiffEngine(ds, make_schedule("ddpm_linear", 1000),
                         GoldDiffConfig(), index=ix, index_mode="always",
                         probe_schedule=ProbeSchedule())
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, store.dim)).astype(np.float32))
    for t in (900, 300, 50):
        ids = np.asarray(eng.select(x, t))
        occupied = ids < lc.n_rows
        assert occupied.all()            # padding rows never selected
        assert np.isfinite(np.asarray(eng.denoise(x, t))).all()


def test_post_append_recall_floor(tmp_path):
    """Screening recall vs the exact top-m on the grown store stays
    >= 0.95 after appends at 10% of N (the acceptance floor the ingest
    benchmark gates; checked here at test scale)."""
    lc, store = make_lifecycle(tmp_path, n=1024, num_clusters=16)
    lc.append(new_rows(102, seed=42))    # ~10% growth
    ds, ix = lc.view()
    q = np.random.default_rng(1).normal(size=(8, 16)).astype(np.float32)
    prox = np.asarray(ds.proxy)
    pn = np.asarray(ds.proxy_norms)
    m = 64
    d2_exact = pn[None, :] - 2.0 * (q @ prox.T)
    exact_ids = np.argsort(d2_exact, axis=1, kind="stable")[:, :m]

    # indexed candidates: probe ALL windows' slots (capacity layout) and
    # keep the finite top-m — measures placement quality, not schedule
    pns = np.asarray(ix.proxy_norms_sorted)
    ps = np.asarray(ix.proxy_sorted)
    d2_idx = pns[None, :] - 2.0 * (q @ ps.T)
    top = np.argsort(d2_idx, axis=1, kind="stable")[:, :m]
    rec = screening_recall(top, np.take_along_axis(d2_idx, top, 1),
                           np.asarray(ix.perm), exact_ids)
    assert rec >= 0.95, rec
