"""Tiny deterministic stand-in for ``hypothesis`` (not installed here).

Implements just the surface the test suite uses — ``@given`` over
``st.integers``/``st.floats`` strategies plus the ``settings`` profile
calls — by sampling a fixed number of pseudo-random examples from a
seeded RNG.  This keeps the property tests *running* (rather than
skipped) in environments without hypothesis; when hypothesis is
available the real library is used instead (see the try/except imports
in the test modules).
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


class settings:
    """Profile registry mimicking ``hypothesis.settings``."""

    _profiles: dict = {"default": {"max_examples": 10}}
    _active: str = "default"

    def __init__(self, **kw):  # accept-and-ignore decorator form
        pass

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name: str, **kw) -> None:
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._active = name

    @classmethod
    def max_examples(cls) -> int:
        return int(cls._profiles.get(cls._active, {}).get("max_examples", 10))


def given(*strats: _Strategy):
    """Run the test body over ``max_examples`` deterministic draws."""

    def deco(fn):
        def runner():
            rng = random.Random(0xD1FF05E)
            for _ in range(settings.max_examples()):
                fn(*(s.sample(rng) for s in strats))

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)


st = _StrategiesModule()
strategies = st
