"""Theorem 1: the truncation bound holds and shows both asymptotic regimes."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import bounds
from repro.core.schedules import make_schedule
from repro.data import gmm

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


@given(st.integers(4, 128), st.integers(2, 8), st.integers(1, 50),
       st.integers(0, 5000), st.floats(0.05, 20.0))
def test_theorem1_bound_holds(n, d, k, seed, sigma):
    """Property: measured truncation error <= 2R(N-k)exp(-Delta_k)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    q = jax.random.normal(jax.random.fold_in(key, 1), (3, d))
    k = min(k, n - 1)
    d2 = jnp.sum((q[:, None] - x[None]) ** 2, -1)
    logits = -d2 / (2 * sigma ** 2)
    err = bounds.truncation_error(logits, x, k)
    bnd = bounds.theorem1_bound(logits, k, bounds.data_radius(x))
    assert np.all(np.asarray(err) <= np.asarray(bnd) + 1e-5), \
        f"bound violated: err={err}, bound={bnd}"


def test_regime_asymptotics():
    """Delta_k -> 0 at high noise (bound ~ 2R(N-k)); explodes at low noise."""
    store = gmm(512, dim=8, seed=0)
    x = store.X
    q = x[:4] + 0.01
    d2 = jnp.sum((q[:, None] - x[None]) ** 2, -1)
    k = 16
    lo = bounds.logit_gap(-d2 / (2 * 100.0 ** 2), k)     # sigma = 100
    hi = bounds.logit_gap(-d2 / (2 * 0.05 ** 2), k)      # sigma = 0.05
    assert np.all(np.asarray(lo) < 1e-2)
    assert np.all(np.asarray(hi) > 10.0)
    # error bound at low noise is negligible despite k << N
    bnd = bounds.theorem1_bound(-d2 / (2 * 0.05 ** 2), k,
                                bounds.data_radius(x))
    assert np.all(np.asarray(bnd) < 1e-3)


def test_posterior_progressive_concentration():
    """Fig. 1 / 3a: the effective golden support (participation ratio)
    shrinks monotonically (up to noise) as t -> 0."""
    store = gmm(1024, dim=8, seed=1)
    sch = make_schedule("ddpm_linear", 1000)
    key = jax.random.PRNGKey(0)
    x0 = store.X[:8]
    prs = []
    for t in [900, 600, 300, 100, 20]:
        eps = jax.random.normal(jax.random.fold_in(key, t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        q = xt / float(sch.a[t])
        d2 = jnp.sum((q[:, None] - store.X[None]) ** 2, -1)
        logits = -d2 / (2 * float(sch.sigma(t)) ** 2)
        prs.append(float(jnp.mean(bounds.participation_ratio(logits))))
    # strictly decreasing across the sweep ends, high -> low support
    assert prs[0] > 100.0, prs
    assert prs[-1] < 10.0, prs
    assert all(prs[i] >= prs[i + 1] * 0.5 for i in range(len(prs) - 1)), prs
