"""Finite-output guards in the kernel layer (PR 6 satellite 2).

Degenerate inputs that used to NaN (or host-crash) silently:

* extreme / zero / negative sigma^2 -> ``1 / (2 sigma2)`` overflow or
  ZeroDivisionError, then ``0 * inf`` NaN logits;
* all-masked supports (every logit at the hard ``-inf`` or the NEG_INF
  sentinel) -> softmax 0/0;
* ``m > N`` surplus screen slots (+inf distances) -> ``-inf`` logits
  meeting the clamp;
* every shard carrying a hard ``-inf`` running max -> ``-inf - -inf``
  NaN in the LSE merge scale.

All of these must now degrade to FINITE outputs (uniform / data-mean
aggregates), on every backend, streamed and materialized — the serving
runtime's per-segment finite guard is the last line of defense, not the
only one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import gmm
from repro.distributed.sharding import lse_merge_mean, shard_map_compat
from repro.kernels import ops, ref

STORE = gmm(128, dim=8, seed=0)
X = STORE.X
XN = STORE.x_norms
Q = jnp.asarray(np.random.default_rng(1).normal(size=(3, 8)), jnp.float32)

BACKENDS = ("xla", "pallas_interpret")
DEGENERATE_SIGMA2 = (0.0, -1.0, 1e-45, float("nan"))


def test_finite_inv_two_sigma2():
    assert ref.finite_inv_two_sigma2(0.5) == 1.0
    assert ref.finite_inv_two_sigma2(2.0) == 0.25
    for s in DEGENERATE_SIGMA2:
        assert ref.finite_inv_two_sigma2(s) == ref.MAX_INV_TWO_SIGMA2
    # tiny-but-positive sigma2 clamps instead of overflowing fp32
    inv = ref.finite_inv_two_sigma2(1e-40)
    assert inv == ref.MAX_INV_TWO_SIGMA2
    assert np.isfinite(np.float32(inv))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sigma2", DEGENERATE_SIGMA2 + (1e6,))
def test_full_scan_finite_at_extreme_sigma(backend, sigma2):
    """golden_aggregate degrades to a finite (data-mean-ish) estimate
    at degenerate sigma2 on every backend, streamed and dense."""
    outs = [np.asarray(ops.golden_aggregate(Q, X, sigma2, x_norms=XN,
                                            backend=backend, stream=s))
            for s in ((False, True) if backend == "xla" else (False,))]
    for out in outs:
        assert np.isfinite(out).all(), (backend, sigma2)
    # degenerate sigma2 clamps every logit -> uniform weights = mean
    if sigma2 in DEGENERATE_SIGMA2:
        mean = np.asarray(X).mean(0)
        for out in outs:
            np.testing.assert_allclose(out, np.tile(mean, (Q.shape[0], 1)),
                                       rtol=0, atol=1e-4)


@pytest.mark.parametrize("sigma2", DEGENERATE_SIGMA2)
def test_full_scan_partial_states_finite(sigma2):
    """The shard-local halves (dense + streamed) stay finite and agree
    under degenerate sigma2 (they used to ZeroDivisionError / NaN)."""
    for stream in (False, True):
        acc, m, l = ops.golden_full_partial(Q, X, sigma2, x_norms=XN,
                                            stream=stream, tile=32)
        assert np.isfinite(np.asarray(acc)).all()
        assert np.isfinite(np.asarray(m)).all()     # NEG_INF sentinel, not -inf
        assert np.isfinite(np.asarray(l)).all() and (np.asarray(l) > 0).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_masked_support_aggregate_finite(backend):
    """Every support slot masked to NEG_INF: uniform weights over the
    gathered rows, never 0/0."""
    idx = jnp.tile(jnp.arange(4)[None, :], (Q.shape[0], 1))
    lg = jnp.full((Q.shape[0], 4), ref.NEG_INF, jnp.float32)
    out = np.asarray(ops.golden_support_aggregate(X, idx, lg,
                                                  backend=backend))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, np.tile(np.asarray(X[:4]).mean(0),
                                            (Q.shape[0], 1)), atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_surplus_screen_slots_stay_finite(backend):
    """m > N: surplus slots carry d2=+inf out of the screen; the masked
    aggregation path must clamp them to zero weight, not NaN."""
    n = X.shape[0]
    m = n + 16
    idx, d2 = ops.screen_topm(Q, X, m, x_norms=XN, stream=True, tile=32,
                              backend=backend)
    d2 = np.asarray(d2)
    assert np.isinf(d2[:, n:]).all() and np.isfinite(d2[:, :n]).all()
    # feed the screen's +inf straight into logits like denoise does
    lg = jnp.maximum(-jnp.asarray(d2) * ref.finite_inv_two_sigma2(0.25),
                     ref.NEG_INF)
    lg = jnp.where(jnp.isnan(lg), ref.NEG_INF, lg)
    out = np.asarray(ops.golden_support_aggregate(
        X, jnp.asarray(idx), lg,
        backend=backend, strategy="gather"))
    assert np.isfinite(out).all()


def test_lse_merge_mean_all_hard_neg_inf():
    """Every shard reporting a hard -inf max (degenerate all-masked
    candidate sets): the merge degrades to finite zeros instead of the
    -inf - -inf NaN scale."""
    mesh = jax.make_mesh((1,), ("data",))

    def body(acc, m, l):
        return lse_merge_mean(acc, m, l, "data")

    from jax.sharding import PartitionSpec as P
    fn = shard_map_compat(body, mesh, (P("data"), P("data"), P("data")),
                          P("data"))
    acc = jnp.zeros((2, 4), jnp.float32)
    m = jnp.full((2,), -jnp.inf, jnp.float32)
    l = jnp.zeros((2,), jnp.float32)
    out = np.asarray(fn(acc, m, l))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out, np.zeros_like(out))
    # and the normal finite-sentinel path still merges exactly
    m2 = jnp.full((2,), ref.NEG_INF, jnp.float32)
    acc2 = jnp.ones((2, 4), jnp.float32)
    l2 = jnp.ones((2,), jnp.float32)
    out2 = np.asarray(fn(acc2, m2, l2))
    np.testing.assert_allclose(out2, np.ones_like(out2), rtol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_normal_sigma_unchanged(backend):
    """The guard is an identity in the normal regime: multiplying by
    the precomputed 1/(2 sigma2) equals the old division bit-for-bit
    against the reference."""
    sigma2 = 0.37
    out = np.asarray(ops.golden_aggregate(Q, X, sigma2, x_norms=XN,
                                          backend=backend))
    d2 = np.asarray(ref.pdist_ref(Q, X, x_norms=XN), np.float64)
    w = np.exp(-(d2 - d2.min(1, keepdims=True)) / (2 * sigma2))
    expect = (w / w.sum(1, keepdims=True)) @ np.asarray(X, np.float64)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-5)
