"""Trajectory plans: bucketed shape compilation (core/plan.py).

Pins the three-way contract of plan mode:

* **parity** — ``sample_plan`` matches per-step static sampling to fp32
  reduction order on the exact, indexed, and (subprocess) sharded
  paths: within a bucket the traced masks reproduce each step's static
  shapes exactly, so bucketing changes programs, not math.
* **edges** — threshold 0 degenerates to static mode (one bucket per
  step), threshold inf to the PR-4 masked mode (one bucket), and
  ``max_buckets`` forces a program budget.
* **program economy** — a trajectory compiles exactly
  ``plan.num_buckets`` (<= 4 at the default threshold) denoise
  programs per batch shape, counted in the engine's ``_programs``
  cache, and re-running compiles nothing.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, sample, sample_plan, sample_scan)
from repro.core.plan import BucketCaps, build_plan, step_shapes
from repro.data import gmm
from repro.index import build_index

SCH = make_schedule("ddpm_linear", 1000)
REPO = Path(__file__).resolve().parent.parent


def relerr(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-9)


@pytest.fixture(scope="module")
def gd_exact():
    store = gmm(1024, dim=16, num_modes=8, spread=0.05, seed=0)
    return GoldDiff(OptimalDenoiser(store, SCH), GoldDiffConfig())


@pytest.fixture(scope="module")
def gd_indexed():
    store = gmm(4096, dim=16, num_modes=32, spread=0.05, seed=3)
    cfg = GoldDiffConfig(m_min_frac=1 / 64, m_max_frac=1 / 16,
                         k_min_frac=1 / 128, k_max_frac=1 / 64)
    ix = build_index(store, num_clusters=64)
    return GoldDiff(OptimalDenoiser(store, SCH), cfg, index=ix,
                    index_mode="always")


def test_plan_structure_and_overhead(gd_exact):
    """Default threshold: few buckets, each under the overhead cap,
    caps covering every member step, contiguous full coverage."""
    plan = build_plan(gd_exact.engine, num_steps=10)
    assert 1 <= plan.num_buckets <= 4
    assert plan.buckets[0].start == 0
    assert plan.buckets[-1].stop == plan.num_steps == 10
    for a, b in zip(plan.buckets, plan.buckets[1:]):
        assert a.stop == b.start
    for bk in plan.buckets:
        assert bk.overhead <= plan.threshold + 1e-9
        for s in plan.steps[bk.start: bk.stop]:
            assert s.m_t <= bk.caps.m_cap
            assert s.k_t <= bk.caps.k_cap
            assert s.nprobe_t <= bk.caps.nprobe_cap or not s.indexed
            assert s.indexed == bk.caps.indexed
    # the plan pays less than masked mode's full worst-case padding
    masked = build_plan(gd_exact.engine, num_steps=10,
                        threshold=float("inf"))
    assert plan.padded_flops < masked.padded_flops
    assert plan.exact_flops == masked.exact_flops


def test_plan_edge_cases(gd_exact):
    """threshold=0 == static (one bucket per step, zero overhead);
    threshold=inf == masked (one bucket); max_buckets forces a count."""
    per_step = build_plan(gd_exact.engine, num_steps=10, threshold=0.0)
    assert per_step.num_buckets == 10
    assert per_step.overhead == 0.0
    one = build_plan(gd_exact.engine, num_steps=10, threshold=float("inf"))
    assert one.num_buckets == 1
    _, steps = step_shapes(gd_exact.engine, 10)
    assert one.buckets[0].caps.m_cap == max(s.m_t for s in steps)
    assert one.buckets[0].caps.k_cap == max(s.k_t for s in steps)
    forced = build_plan(gd_exact.engine, num_steps=10, threshold=0.0,
                        max_buckets=2)
    assert forced.num_buckets == 2
    # output-level degeneracies: the 1-bucket plan IS the masked scan
    # program, the per-step plan IS static mode (same PRNG schedule)
    rng = jax.random.PRNGKey(2)
    x_one = sample_plan(gd_exact.call_masked, SCH, (3, 16), rng, one)
    x_scan = sample_scan(gd_exact.call_masked, SCH, (3, 16), rng,
                         num_steps=10)
    assert relerr(x_one, x_scan) < 1e-6
    x_per = sample_plan(gd_exact.call_masked, SCH, (3, 16), rng, per_step)
    x_static = sample(gd_exact, SCH, (3, 16), rng, num_steps=10)
    assert relerr(x_per, x_static) < 1e-6


def test_plan_never_straddles_index_boundary():
    """Steps the engine routes through the index cannot share a bucket
    with exact-screening steps, no matter the threshold."""

    class FakeEngine:
        class store:
            dim = 8

        class index:
            max_cluster = 16

        schedule = SCH

        def sizes(self, t):
            return 100, 50

        def use_index(self, t):
            return t > 500          # routing flips mid-grid

        def nprobe(self, t):
            return 4

    plan = build_plan(FakeEngine(), num_steps=10, threshold=float("inf"))
    assert plan.num_buckets == 2     # inf threshold still cannot merge
    assert plan.buckets[0].caps.indexed and not plan.buckets[1].caps.indexed


def test_plan_vs_static_and_scan_parity_exact(gd_exact):
    """Exact path: plan == static == scan to fp32 reduction order,
    identical PRNG schedule across all three samplers."""
    rng = jax.random.PRNGKey(7)
    plan = build_plan(gd_exact.engine, num_steps=10)
    x_static = sample(gd_exact, SCH, (4, 16), rng, num_steps=10)
    x_scan = sample_scan(gd_exact.call_masked, SCH, (4, 16), rng,
                         num_steps=10)
    x_plan = sample_plan(gd_exact.call_masked, SCH, (4, 16), rng, plan,
                         program_cache=gd_exact.engine.program)
    assert relerr(x_plan, x_static) < 1e-5
    assert relerr(x_plan, x_scan) < 1e-5


def test_plan_vs_static_parity_indexed(gd_indexed):
    """Indexed path: the traced occupancy floor (jnp.searchsorted at
    the traced k_t) makes per-bucket probe counts equal the static
    programs' nprobe(t), so parity is fp order here too."""
    rng = jax.random.PRNGKey(11)
    plan = build_plan(gd_indexed.engine, num_steps=10)
    assert all(b.caps.indexed for b in plan.buckets)
    x_static = sample(gd_indexed, SCH, (4, 16), rng, num_steps=10)
    x_plan = sample_plan(gd_indexed.call_masked, SCH, (4, 16), rng, plan,
                         program_cache=gd_indexed.engine.program)
    assert relerr(x_plan, x_static) < 1e-5


def test_masked_caps_equals_uncapped_masked(gd_exact):
    """A caps tuple padded to the global worst case reproduces the
    legacy caps=None masked program bit-for-bit."""
    eng = gd_exact.engine
    n = eng.store.n
    _, m_max, _, k_max = eng.cfg.sizes(n)
    caps = BucketCaps(m_cap=m_max, k_cap=k_max)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16))
    for t in (900, 400, 50):
        a = np.asarray(eng.denoise_masked(x, jnp.asarray(t)))
        b = np.asarray(eng.denoise_masked(x, jnp.asarray(t), caps))
        np.testing.assert_array_equal(a, b)


def test_plan_program_count_and_cache_reuse():
    """One compiled program per (bucket, batch shape), <= 4 at the
    default threshold; re-sampling compiles nothing new."""
    store = gmm(512, dim=16, num_modes=8, spread=0.05, seed=5)
    gd = GoldDiff(OptimalDenoiser(store, SCH), GoldDiffConfig())
    plan = build_plan(gd.engine, num_steps=10)
    assert plan.num_buckets <= 4
    rng = jax.random.PRNGKey(0)
    sample_plan(gd.call_masked, SCH, (4, 16), rng, plan,
                program_cache=gd.engine.program)
    segs = [k for k in gd.engine._programs if k[0] == "plan_seg"]
    assert len(segs) == plan.num_buckets
    n0 = len(gd.engine._programs)
    sample_plan(gd.call_masked, SCH, (4, 16), rng, plan,
                program_cache=gd.engine.program)
    assert len(gd.engine._programs) == n0            # warm: zero compiles
    sample_plan(gd.call_masked, SCH, (8, 16), rng, plan,
                program_cache=gd.engine.program)     # new batch shape
    segs = [k for k in gd.engine._programs if k[0] == "plan_seg"]
    assert len(segs) == 2 * plan.num_buckets


@pytest.mark.slow
def test_sharded_plan_parity_subprocess():
    """sample_plan over a data-sharded engine == single-host static
    sampling, on an emulated 8-device mesh (uneven N % 8)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, sample, sample_plan, build_plan)
from repro.data import gmm

mesh = jax.make_mesh((8,), ("data",))
store = gmm(1003, dim=16, num_modes=8, spread=0.05, seed=0)
sch = make_schedule("ddpm_linear", 1000)
gd_ref = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
gd_sh = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig(), mesh=mesh)
plan = build_plan(gd_sh.engine, num_steps=8)
rng = jax.random.PRNGKey(11)
x_ref = np.asarray(sample(gd_ref, sch, (4, 16), rng, num_steps=8))
x_sh = np.asarray(sample_plan(gd_sh.call_masked, sch, (4, 16), rng, plan,
                              program_cache=gd_sh.engine.program))
err = np.abs(x_sh - x_ref).max() / (np.abs(x_ref).max() + 1e-9)
segs = sum(1 for k in gd_sh.engine._programs if k[0] == "plan_seg")
print("rel err", err, "segments", segs)
print("PASS" if err < 1e-5 and segs == plan.num_buckets else "FAIL")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=str(REPO), env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
