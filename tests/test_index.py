"""Golden Index correctness: build, store, schedule, screening recall.

Covers the ISSUE-2 acceptance surface:
* k-means build determinism under a fixed PRNG key,
* CSR layout validity (perm is a permutation, clusters contiguous and
  nearest-centroid consistent),
* save/load round-trip,
* ``ivf_screen`` backend parity (xla vs pallas_interpret),
* recall@m_t >= 0.95 vs exact screening at every timestep bucket,
* indexed engine end-to-end parity with the exact engine,
* program-cache keys extended with (nprobe_t, padded candidate count).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GoldDiff, GoldDiffConfig, GoldDiffEngine,
                        OptimalDenoiser, make_schedule)
from repro.data import gmm, mnist_like
from repro.index import (GoldenIndex, ProbeSchedule, build_index, kmeans,
                         load_index, save_index, screening_recall)
from repro.kernels import ops

SCH = make_schedule("ddpm_linear", 1000)
BACKENDS = ["xla", "pallas_interpret"]
if any(d.platform == "tpu" for d in jax.devices()):
    BACKENDS.append("pallas")

# scale-appropriate fractions (the regime the index serves; the paper's
# m_max = N/4 would floor nprobe at ~half the clusters)
CFG = GoldDiffConfig(m_min_frac=1 / 64, m_max_frac=1 / 16,
                     k_min_frac=1 / 128, k_max_frac=1 / 64)
T_BUCKETS = (999, 800, 600, 400, 200, 50)


@pytest.fixture(scope="module")
def gmm_setup():
    store = gmm(4096, dim=16, seed=3)
    index = build_index(store, num_clusters=64)
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 16))
    return store, index, x


@pytest.fixture(scope="module")
def image_setup():
    store = mnist_like(2048, seed=1)
    index = build_index(store, num_clusters=32)
    return store, index


# -- builder ------------------------------------------------------------------

def test_kmeans_build_determinism(gmm_setup):
    store, index, _ = gmm_setup
    again = build_index(store, num_clusters=64)
    assert np.array_equal(np.asarray(index.centroids),
                          np.asarray(again.centroids))
    assert np.array_equal(np.asarray(index.perm), np.asarray(again.perm))
    assert np.array_equal(np.asarray(index.offsets),
                          np.asarray(again.offsets))
    assert index.max_cluster == again.max_cluster
    other = build_index(store, num_clusters=64, key=jax.random.PRNGKey(9))
    assert not np.array_equal(np.asarray(index.centroids),
                              np.asarray(other.centroids))


@pytest.mark.slow
def test_kmeans_improves_quantization():
    """Lloyd iterations must reduce the k-means objective vs seeding."""
    store = gmm(2048, dim=16, seed=5)
    key = jax.random.PRNGKey(0)
    from repro.index.build import kmeans_plusplus, _sq_dists
    seeds = kmeans_plusplus(key, store.proxy, 32)
    cents, _ = kmeans(key, store.proxy, 32, iters=25)
    obj = lambda c: float(jnp.min(_sq_dists(store.proxy, c), -1).mean())
    assert obj(cents) <= obj(seeds) + 1e-6


def test_csr_layout_valid(gmm_setup):
    store, index, _ = gmm_setup
    perm = np.asarray(index.perm)
    off = np.asarray(index.offsets)
    assert sorted(perm.tolist()) == list(range(store.n))
    assert off[0] == 0 and off[-1] == store.n
    assert (np.diff(off) >= 0).all()
    assert int(np.diff(off).max()) == index.max_cluster
    # every row in window c is nearest (among centroids) to window c's
    # centroid — up to duplicated centroids from balance splitting, which
    # tie exactly, so compare centroid vectors rather than window ids
    d2 = ops.centroid_scan(store.proxy, index.centroids,
                           index.centroid_norms, backend="xla")
    assign = np.asarray(jnp.argmin(d2, -1))[perm]
    cents = np.asarray(index.centroids)
    for c in range(index.num_clusters):
        rows = assign[off[c]:off[c + 1]]
        np.testing.assert_array_equal(cents[rows], np.broadcast_to(
            cents[c], (len(rows),) + cents[c].shape))
    # sorted proxy rows really are the permuted originals
    np.testing.assert_array_equal(np.asarray(index.proxy_sorted),
                                  np.asarray(store.proxy)[perm])


def test_save_load_roundtrip(gmm_setup, tmp_path):
    _, index, _ = gmm_setup
    path = str(tmp_path / "golden_index.npz")
    save_index(index, path)
    back = load_index(path)
    assert isinstance(back, GoldenIndex)
    assert back.max_cluster == index.max_cluster
    for f in GoldenIndex._fields:
        np.testing.assert_array_equal(np.asarray(getattr(back, f)),
                                      np.asarray(getattr(index, f)))


# -- probe schedule -----------------------------------------------------------

def test_probe_schedule_shape():
    ps = ProbeSchedule(f_lo=1 / 16, f_hi=1.0, safety=2.0, min_probes=4)
    n, c = 4096, 64
    # wide at low SNR (g=1), a handful at high SNR (g=0)
    assert ps.nprobe(1.0, 64, n, c) == c
    assert ps.nprobe(0.0, 64, n, c) == max(4, c // 16)
    # capacity floor: probed clusters must cover safety * m_t rows
    big_m = n // 4
    assert ps.nprobe(0.0, big_m, n, c) >= int(np.ceil(2.0 * big_m * c / n))
    # traced mirror agrees with the host rule
    for g, m in ((0.0, 64), (0.5, 200), (1.0, 1024)):
        assert int(ps.nprobe_jnp(jnp.asarray(g), jnp.asarray(m), n, c)) \
            == ps.nprobe(g, m, n, c)


# -- ivf_screen ---------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_ivf_screen_backend_parity(gmm_setup, backend):
    store, index, x = gmm_setup
    m, p = 128, 16
    pos, d2 = ops.ivf_screen(x, index.proxy_sorted, index.proxy_norms_sorted,
                             index.offsets, index.centroids,
                             index.centroid_norms, m, p, index.max_cluster,
                             backend=backend)
    ref_pos, ref_d2 = ops.ivf_screen(
        x, index.proxy_sorted, index.proxy_norms_sorted, index.offsets,
        index.centroids, index.centroid_norms, m, p, index.max_cluster,
        backend="xla")
    assert np.array_equal(np.sort(np.asarray(pos), -1),
                          np.sort(np.asarray(ref_pos), -1))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(ref_d2),
                               rtol=1e-4, atol=1e-4)


def test_ivf_screen_traced_nprobe_matches_static(gmm_setup):
    """Masking probes via a traced nprobe == probing fewer statically."""
    store, index, x = gmm_setup
    m, p_max, p = 64, 16, 7
    args = (x, index.proxy_sorted, index.proxy_norms_sorted, index.offsets,
            index.centroids, index.centroid_norms, m)
    static_pos, static_d2 = ops.ivf_screen(
        *args, p, index.max_cluster, backend="xla")
    masked_pos, masked_d2 = jax.jit(
        lambda np_t: ops.ivf_screen(*args, p_max, index.max_cluster,
                                    nprobe=np_t, backend="xla")
    )(jnp.asarray(p))
    assert np.array_equal(np.sort(np.asarray(masked_pos), -1),
                          np.sort(np.asarray(static_pos), -1))
    np.testing.assert_allclose(np.asarray(masked_d2),
                               np.asarray(static_d2), rtol=1e-5, atol=1e-5)


def test_ivf_screen_excludes_unprobed_rows(gmm_setup):
    """Every returned candidate must belong to a probed cluster."""
    store, index, x = gmm_setup
    p = 5
    cd2 = ops.centroid_scan(x, index.centroids, index.centroid_norms,
                            backend="xla")
    probes = np.asarray(jax.lax.top_k(-cd2, p)[1])
    pos, d2 = ops.ivf_screen(x, index.proxy_sorted,
                             index.proxy_norms_sorted, index.offsets,
                             index.centroids, index.centroid_norms,
                             64, p, index.max_cluster, backend="xla")
    off = np.asarray(index.offsets)
    for b in range(x.shape[0]):
        ok_rows = set()
        for c in probes[b]:
            ok_rows.update(range(off[c], off[c + 1]))
        finite = np.isfinite(np.asarray(d2)[b])
        assert set(np.asarray(pos)[b][finite]) <= ok_rows


# -- screening recall (the acceptance criterion) ------------------------------

@pytest.mark.parametrize("setup_name", ["gmm_setup", "image_setup"])
def test_recall_at_mt_every_bucket(request, setup_name):
    """Indexed coarse screening recalls >= 0.95 of the exact top-m_t
    candidate set at every timestep bucket (synthetic suite)."""
    setup = request.getfixturevalue(setup_name)
    store, index = setup[0], setup[1]
    eng = GoldDiffEngine(store, SCH, CFG, backend="xla", index=index,
                         index_mode="always",
                         probe_schedule=ProbeSchedule(f_lo=1 / 8, f_hi=1.0,
                                                      safety=4.0))
    key = jax.random.PRNGKey(0)
    x0 = store.X[:8]
    perm = np.asarray(index.perm)
    for t in T_BUCKETS:
        m_t, _ = eng.sizes(t)
        eps = jax.random.normal(jax.random.fold_in(key, t), x0.shape)
        q = SCH.add_noise(x0, eps, t) / float(SCH.a[t])
        exact = np.asarray(eng.coarse(q, m_t))
        pos, pd2 = eng.coarse_indexed(q, eng.padded_m(t), eng.nprobe(t))
        recall = screening_recall(pos, pd2, perm, exact)
        assert recall >= 0.95, (setup_name, t, recall, eng.nprobe(t))


# -- engine integration -------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_indexed_denoise_matches_exact(gmm_setup, backend):
    store, index, x = gmm_setup
    exact = GoldDiffEngine(store, SCH, CFG, backend="xla")
    idx = GoldDiffEngine(store, SCH, CFG, backend=backend, index=index,
                         index_mode="always")
    for t in (900, 400, 50):
        np.testing.assert_allclose(np.asarray(idx.denoise(x, t)),
                                   np.asarray(exact.denoise(x, t)),
                                   rtol=2e-3, atol=2e-3)


def test_engine_indexed_select_returns_dataset_ids(gmm_setup):
    store, index, x = gmm_setup
    exact = GoldDiffEngine(store, SCH, CFG, backend="xla")
    idx = GoldDiffEngine(store, SCH, CFG, backend="xla", index=index,
                         index_mode="always")
    for t in (800, 100):
        a = np.sort(np.asarray(exact.select(x, t)), -1)
        b = np.sort(np.asarray(idx.select(x, t)), -1)
        # ids live in dataset space; on well-clustered data the golden
        # sets agree (allow a row of slack for distance ties)
        matches = (a == b).mean()
        assert matches >= 0.95, (t, matches)
        assert b.max() < store.n


def test_engine_indexed_masked_matches_exact(gmm_setup):
    store, index, x = gmm_setup
    exact = GoldDiffEngine(store, SCH, CFG, backend="xla")
    idx = GoldDiffEngine(store, SCH, CFG, backend="xla", index=index,
                         index_mode="always")
    masked = jax.jit(idx.denoise_masked)
    for t in (900, 400, 50):
        np.testing.assert_allclose(
            np.asarray(masked(x, jnp.asarray(t))),
            np.asarray(exact.denoise_masked(x, jnp.asarray(t))),
            rtol=2e-3, atol=2e-3)


def test_engine_cache_keys_extended_with_probe_signature(gmm_setup):
    store, index, x = gmm_setup
    eng = GoldDiffEngine(store, SCH, CFG, backend="xla", index=index,
                         index_mode="always")
    t = 500
    eng.denoise(x, t)
    (key,) = [k for k in eng._programs if k[0] == "denoise"]
    assert key[-2:] == (eng.nprobe(t), eng.padded_m(t))
    n0 = len(eng._programs)
    eng.denoise(x, t)
    assert len(eng._programs) == n0          # cache hit
    eng.denoise(x, 100)                      # new t -> new program
    assert len(eng._programs) == n0 + 1


def test_engine_index_validation(gmm_setup):
    store, index, _ = gmm_setup
    other = gmm(512, dim=16, seed=0)
    with pytest.raises(ValueError):
        GoldDiffEngine(other, SCH, CFG, backend="xla", index=index)
    with pytest.raises(ValueError):
        GoldDiffEngine(store, SCH, CFG, backend="xla", strategy="bogus")
    with pytest.raises(ValueError):
        GoldDiffEngine(store, SCH, CFG, backend="xla", index_mode="bogus")


def test_engine_strategy_selection(gmm_setup):
    store, _, _ = gmm_setup
    # explicit strategies are respected
    for s in ("gather", "dense"):
        assert GoldDiffEngine(store, SCH, CFG, backend="xla",
                              strategy=s).strategy == s
    # auto picks by the (m_max / N) vs crossover-fraction rule
    eng = GoldDiffEngine(store, SCH, CFG, backend="xla")
    frac = eng.cfg.sizes(store.n)[1] / store.n
    want = "gather" if frac <= eng.crossover_frac else "dense"
    assert eng.strategy == want
    # measured crossover produces a sane fraction and a valid strategy
    m = GoldDiffEngine(store, SCH, CFG, backend="xla", strategy="measure")
    assert 0.0 < m.crossover_frac <= 1.0
    assert m.strategy in ("gather", "dense")


def test_golddiff_wrapper_with_index(gmm_setup):
    store, index, x = gmm_setup
    gd = GoldDiff(OptimalDenoiser(store, SCH), CFG, index=index,
                  index_mode="always")
    ref = GoldDiff(OptimalDenoiser(store, SCH), CFG)
    for t in (800, 200):
        np.testing.assert_allclose(np.asarray(gd(x, t)),
                                   np.asarray(ref(x, t)),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_distributed_indexed_retrieval_subprocess():
    """Shard-local index + two-stage merge == single-host GoldDiff."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import GoldDiff, GoldDiffConfig, OptimalDenoiser, make_schedule
from repro.core.golddiff import schedule_sizes
from repro.data import gmm
from repro.distributed.retrieval import (shard_store,
                                         distributed_golden_denoise,
                                         build_shard_indexes)

mesh = jax.make_mesh((4, 2), ("data", "model"))
store = gmm(1024, dim=16, seed=0)
sch = make_schedule("ddpm_linear", 1000)
gd = GoldDiff(OptimalDenoiser(store, sch), GoldDiffConfig())
sstore = shard_store(store, mesh, "data")
sidx = build_shard_indexes(store, mesh, "data", num_clusters=16)
x0 = store.X[:4]
ok = True
for t in (100, 500):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    ref = np.asarray(gd(xt, t))
    m, k = schedule_sizes(gd.cfg, sch, t, store.n)
    a = float(sch.a[t]); s2 = float(sch.sigma(t))**2
    with mesh:
        out = np.asarray(distributed_golden_denoise(
            sstore, mesh, xt / a, s2, m, k, proxy_factor=1,
            index=sidx, nprobe=12))
    err = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
    print("t", t, "rel err", err)
    ok &= err < 0.05
print("PASS" if ok else "FAIL")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    from pathlib import Path
    repo = str(Path(__file__).resolve().parent.parent)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=repo, env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
