"""Sampler + data pipeline + distributed retrieval (single device)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, OptimalDenoiser,
                        make_schedule, sample, sample_scan,
                        denoise_trajectory, sampling_timesteps)
from repro.core.dataset import downsample_proxy
from repro.data import (TokenPipeline, TokenPipelineConfig, cifar_like,
                        fast_batch, gmm, moons)

SCH = make_schedule("ddpm_linear", 1000)


def test_sampling_timesteps_grid():
    ts = sampling_timesteps(SCH, 10)
    assert ts[0] == 1000 and ts[-1] == 0
    assert all(a > b for a, b in zip(ts, ts[1:]))
    assert len(ts) == 11


def test_sample_lands_near_manifold():
    """DDIM with the full-scan optimal denoiser lands on/near data points
    (the memorization property of the exact denoiser, Sec. 2)."""
    store = gmm(512, dim=8, num_modes=4, spread=0.05, seed=0)
    den = OptimalDenoiser(store, SCH)
    out = sample(den, SCH, (8, 8), jax.random.PRNGKey(0), num_steps=20)
    d2 = jnp.min(jnp.sum((out[:, None] - store.X[None]) ** 2, -1), -1)
    assert float(jnp.sqrt(d2).mean()) < 0.35, float(jnp.sqrt(d2).mean())


def test_scan_and_perstep_agree():
    store = gmm(256, dim=4, seed=1)
    gd = GoldDiff(OptimalDenoiser(store, SCH))
    x1 = sample(gd, SCH, (4, 4), jax.random.PRNGKey(3), num_steps=10,
                clip_value=None)
    x2 = sample_scan(gd.call_masked, SCH, (4, 4), jax.random.PRNGKey(3),
                     num_steps=10, clip_value=None)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=2e-3,
                               atol=2e-3)


def test_paired_trajectory_deterministic():
    store = moons(512)
    den = OptimalDenoiser(store, SCH)
    xT = jax.random.normal(jax.random.PRNGKey(5), (4, 2))
    a, xs_a = denoise_trajectory(den, SCH, xT, num_steps=10)
    b, xs_b = denoise_trajectory(den, SCH, xT, num_steps=10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(xs_a) == 11


def test_downsample_proxy_dims():
    x = jnp.zeros((5, 32, 32, 3))
    p = downsample_proxy(x, 4)
    assert p.shape == (5, 8 * 8 * 3)
    # low-dim data falls back to identity flatten
    q = jnp.zeros((5, 2))
    assert downsample_proxy(q, 4).shape == (5, 2)


def test_dataset_stores():
    st = cifar_like(64, seed=0)
    assert st.X.shape == (64, 3072) and st.proxy.shape == (64, 192)
    assert st.labels is not None and st.labels.shape == (64,)
    assert bool(jnp.isfinite(st.X).all())
    # standardized
    assert abs(float(st.X.mean())) < 0.1
    assert 0.5 < float(st.X.std()) < 2.0


def test_token_pipeline_deterministic():
    cfg = TokenPipelineConfig(vocab_size=512, seq_len=64, global_batch=4,
                              seed=3)
    tp = TokenPipeline(cfg)
    b1 = tp.batch(5)
    b2 = TokenPipeline(cfg).batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 64)
    assert int(b1["tokens"].max()) < 512
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    fb = fast_batch(cfg, 0)
    assert fb["tokens"].shape == (4, 64)


def test_conditional_store_restriction():
    from repro.core.dataset import restrict
    st = cifar_like(128, seed=0)
    idx = jnp.nonzero(st.labels == 0)[0]
    sub = restrict(st, idx)
    assert sub.n == int(idx.shape[0])
    assert bool((sub.labels == 0).all())
