"""ServeEngine on trajectory plans: seeds, warmup, and recompile guard.

* **per-request determinism** — a request's images depend only on its
  own seed (per-row ``fold_in`` keys), never on which wave co-batched
  it or which batch bucket the wave padded to.  The pre-plan engine
  seeded a whole wave from its first request's seed, so outputs
  changed with wave packing.
* **warmup** — ``warmup()`` precompiles every (batch-bucket x
  shape-bucket) program; serving any mixed request stream afterwards
  never grows the engine's ``_programs`` cache.  The subprocess
  variant runs the same guard under ``jax.log_compiles`` on an
  emulated 8-device mesh (the CI `mesh` job's recompile guard).
* **mode parity** — plan / scan / static serving agree on identical
  requests.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.launch.serve import Request, ServeEngine

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def eng():
    return ServeEngine("gmm", {"n": 1024, "dim": 16}, num_steps=6,
                       max_batch=8)


def test_batch_buckets(eng):
    assert eng.batch_buckets() == [1, 2, 4, 8]
    assert eng._bucket_for(1) == 1
    assert eng._bucket_for(3) == 4
    assert eng._bucket_for(8) == 8
    assert eng._bucket_for(100) == 8      # oversized: capped at max_batch
    odd = ServeEngine("gmm", {"n": 256, "dim": 8}, num_steps=3, max_batch=6)
    assert odd.batch_buckets() == [1, 2, 4, 6]


def test_serve_seed_determinism(eng):
    """Same request alone vs co-batched (different wave AND different
    batch bucket) -> same images; rows are key-independent."""
    alone = eng.serve([Request(0, 2, seed=7)])[0].images
    res = eng.serve([Request(0, 2, seed=7), Request(1, 3, seed=9)])
    np.testing.assert_allclose(res[0].images, alone, rtol=0, atol=1e-6)
    # order flipped: request 7 lands at a different row offset
    res2 = eng.serve([Request(1, 3, seed=9), Request(0, 2, seed=7)])
    np.testing.assert_allclose(res2[1].images, alone, rtol=0, atol=1e-6)
    # and request 9's images are equally wave-independent
    np.testing.assert_allclose(res2[0].images, res[1].images,
                               rtol=0, atol=1e-6)
    # different seeds genuinely differ
    other = eng.serve([Request(0, 2, seed=8)])[0].images
    assert not np.allclose(alone, other)


def test_serve_request_packing(eng):
    res = eng.serve([Request(0, 3, seed=1), Request(1, 2, seed=2),
                     Request(2, 6, seed=3)])
    assert [r.request_id for r in res] == [0, 1, 2]
    assert sum(r.images.shape[0] for r in res) >= 3 + 2 + 6
    assert all(np.isfinite(r.images).all() for r in res)


def test_serve_oversized_request_fully_served(eng):
    """A request larger than max_batch is chunked across waves: every
    image is delivered, and chunking does not change any row's noise
    stream (row i always draws from fold_in(seed, i))."""
    res = eng.serve([Request(0, 19, seed=5)])          # max_batch = 8
    assert res[0].images.shape[0] == 19
    assert np.isfinite(res[0].images).all()
    # same request on a wider engine: rows agree, so chunk boundaries
    # are invisible to the caller
    wide = ServeEngine("gmm", {"n": 1024, "dim": 16}, num_steps=6,
                       max_batch=32)
    res_w = wide.serve([Request(0, 19, seed=5)])
    np.testing.assert_allclose(res[0].images, res_w[0].images,
                               rtol=0, atol=1e-6)
    # zero-image requests come back empty, not broken
    res0 = eng.serve([Request(1, 0, seed=1), Request(2, 2, seed=2)])
    assert res0[0].images.shape[0] == 0
    assert res0[1].images.shape[0] == 2


def test_serve_warmup_then_no_recompile():
    eng = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=5,
                      max_batch=4)
    stats = eng.warmup()
    # (batch buckets) x (plan segments + init-noise + row-key programs)
    n_batch = len(eng.batch_buckets())
    assert stats["programs_compiled"] == \
        n_batch * (eng.plan.num_buckets + 2)
    n0 = len(eng.engine._programs)
    eng.serve([Request(0, 1, seed=1), Request(1, 3, seed=2),
               Request(2, 2, seed=3), Request(3, 4, seed=4)])
    assert len(eng.engine._programs) == n0, \
        "serving recompiled after warmup"


def test_serve_modes_agree():
    """plan == scan == static serving on identical requests (identical
    per-row noise streams, fp32-tolerance outputs)."""
    reqs = [Request(0, 2, seed=3), Request(1, 2, seed=4)]
    outs = {}
    for mode in ("plan", "scan", "static"):
        e = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=5,
                        max_batch=4, mode=mode)
        outs[mode] = np.concatenate(
            [r.images.reshape(r.images.shape[0], -1)
             for r in e.serve(list(reqs))])
    np.testing.assert_allclose(outs["plan"], outs["static"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["plan"], outs["scan"],
                               rtol=1e-4, atol=1e-5)


def test_serve_mode_validation():
    with pytest.raises(ValueError):
        ServeEngine("gmm", {"n": 256, "dim": 8}, mode="bogus")
    with pytest.raises(ValueError):
        ServeEngine("cifar_like", {"n": 128}, base="pca", mode="plan")
    # patch bases fall back to static under auto
    e = ServeEngine("cifar_like", {"n": 128}, base="pca", num_steps=3)
    assert e.mode == "static" and e.plan is None


@pytest.mark.slow
def test_serve_warmup_recompile_guard_subprocess():
    """CI recompile guard (emulated 8-device mesh): after warmup(), a
    mixed request stream must not compile ANY program — checked both
    by the engine cache size and by jax.log_compiles capture."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import io, logging
import jax, numpy as np
from repro.launch.serve import Request, ServeEngine

mesh = jax.make_mesh((8,), ("data",))
eng = ServeEngine("gmm", {"n": 1003, "dim": 16}, num_steps=5,
                  max_batch=8, mesh=mesh)
stats = eng.warmup()
print("warmup:", stats)
n0 = len(eng.engine._programs)

log = io.StringIO()
handler = logging.StreamHandler(log)
logging.getLogger("jax").addHandler(handler)
with jax.log_compiles(True):
    res = eng.serve([Request(0, 1, seed=1), Request(1, 5, seed=2),
                     Request(2, 3, seed=3), Request(3, 8, seed=4),
                     Request(4, 2, seed=5)])
logging.getLogger("jax").removeHandler(handler)

ok = all(np.isfinite(r.images).all() for r in res)
cache_grew = len(eng.engine._programs) - n0
compiled = [ln for ln in log.getvalue().splitlines()
            if "Compiling" in ln and "jit(" in ln]
print("cache delta:", cache_grew)
print("post-warmup compiles:", compiled[:5])
print("PASS" if ok and cache_grew == 0 and not compiled else "FAIL")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=str(REPO), env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
