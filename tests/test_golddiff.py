"""GoldDiff selection/schedule invariants + convergence to the full scan."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (GoldDiff, GoldDiffConfig, OptimalDenoiser,
                        make_schedule, schedule_sizes)
from repro.core.golddiff import coarse_screen, golden_select
from repro.data import cifar_like, gmm

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

SCH = make_schedule("ddpm_linear", 1000)


@given(st.integers(100, 100_000))
def test_schedule_counter_monotonic(n):
    """m_t increases and k_t decreases as t -> 0 (Eqs. 4/6), k_t <= m_t."""
    cfg = GoldDiffConfig()
    ts = [999, 800, 600, 400, 200, 50, 1]
    ms, ks = [], []
    for t in ts:
        m, k = schedule_sizes(cfg, SCH, t, n)
        assert 1 <= k <= m <= n
        ms.append(m)
        ks.append(k)
    assert all(a <= b for a, b in zip(ms, ms[1:])), ms   # m grows as t drops
    assert all(a >= b for a, b in zip(ks, ks[1:])), ks   # k shrinks


def test_selection_is_true_topk():
    """golden_select returns exactly the k nearest points when m = N."""
    store = gmm(256, dim=4, seed=2)
    q = jax.random.normal(jax.random.PRNGKey(0), (5, 4))
    cand = jnp.tile(jnp.arange(256)[None], (5, 1))
    idx = golden_select(store, q, cand, 10)
    d2 = jnp.sum((q[:, None] - store.X[None]) ** 2, -1)
    ref = jax.lax.top_k(-d2, 10)[1]
    assert np.array_equal(np.sort(np.asarray(idx), -1),
                          np.sort(np.asarray(ref), -1))


def test_coarse_screen_recall():
    """Proxy screening keeps the true nearest neighbours with high recall
    (hierarchical consistency on smooth procedural images)."""
    store = cifar_like(512, seed=0)
    x0 = store.X[:8]
    eps = 0.25 * jax.random.normal(jax.random.PRNGKey(1), x0.shape)
    q = x0 + eps
    cand = coarse_screen(store, q, 128, 4)
    d2 = jnp.sum((q[:, None] - store.X[None]) ** 2, -1)
    true_top = jax.lax.top_k(-d2, 16)[1]
    recall = np.mean([
        len(set(np.asarray(cand[i])) & set(np.asarray(true_top[i]))) / 16
        for i in range(8)])
    assert recall > 0.8, recall


def test_golddiff_matches_full_scan_low_noise():
    """Golden-subset estimate converges to the full scan within the
    Theorem 1 truncation bound (the quantity the paper guarantees)."""
    from repro.core import bounds
    from repro.core.golddiff import schedule_sizes
    store = gmm(1024, dim=8, seed=3)
    den = OptimalDenoiser(store, SCH)
    gd = GoldDiff(den, GoldDiffConfig())
    radius = bounds.data_radius(store.X)
    x0 = store.X[:6]
    for t in (50, 150):
        eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
        xt = SCH.add_noise(x0, eps, t)
        full = np.asarray(den(xt, t))
        gold = np.asarray(gd(xt, t))
        err = np.linalg.norm(full - gold, axis=-1)
        # proxy == identity for gmm stores, so selection = exact top-k_t
        # and Theorem 1 applies verbatim
        _, k_t = schedule_sizes(gd.cfg, SCH, t, store.n)
        bnd = np.asarray(bounds.theorem1_bound(den.logits(xt, t), k_t, radius))
        assert np.all(err <= bnd + 1e-6), (t, err, bnd)
        # and in absolute terms the agreement is tight at low noise
        assert err.max() < 0.15, (t, err.max())


def test_masked_mode_matches_static():
    """Masked (scan-compatible) execution == static per-step execution."""
    store = gmm(512, dim=8, seed=4)
    gd = GoldDiff(OptimalDenoiser(store, SCH))
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 8))
    for t in (900, 500, 100):
        a = gd(x, t)
        b = gd.call_masked(x, jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_plug_and_play_all_bases():
    """GoldDiff wraps every corpus-scanning base denoiser (Tab. 5)."""
    from repro.core import PCADenoiser, PatchDenoiser
    store = cifar_like(256, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, store.dim))
    for cls in (OptimalDenoiser, PatchDenoiser, PCADenoiser):
        gd = GoldDiff(cls(store, SCH))
        out = gd(x, 400)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())
        assert gd.base.weighting == "ss"   # unbiased SS enforced


def test_error_decreases_with_k():
    """Truncation error is monotone (on average) in the golden size."""
    store = gmm(2048, dim=8, seed=5)
    den = OptimalDenoiser(store, SCH)
    x0 = store.X[:8]
    t = 300
    eps = jax.random.normal(jax.random.PRNGKey(9), x0.shape)
    xt = SCH.add_noise(x0, eps, t)
    full = den(xt, t)
    errs = []
    for frac in (0.02, 0.1, 0.5):
        cfg = GoldDiffConfig(m_min_frac=max(frac, 0.05), m_max_frac=0.5,
                             k_min_frac=frac, k_max_frac=frac)
        gd = GoldDiff(OptimalDenoiser(store, SCH), cfg)
        errs.append(float(jnp.linalg.norm(gd(xt, t) - full) / x0.shape[0]))
    assert errs[0] >= errs[1] >= errs[2] - 1e-6, errs
