"""Continuous batching (repro/launch/runtime.py): mid-trajectory
admission at plan-bucket seams.

Pins the three tentpole guarantees:

* solo-vs-co-batched **bitwise parity** — a request admitted into a
  freed slot mid-trajectory of another wave is bit-identical to the same
  request served alone (per-row activity masking in
  ``sampler.plan_segment_mixed`` + per-request ``fold_in(seed, row)``
  noise streams make placement invisible);
* seam **interactions** — joins compose with deadline compaction and
  OOM wave splits at the same seam;
* an exactly-once **delivery property** over adversarial admission
  schedules, including single-count ``request.admit`` events for
  requests that wait across many seams.

Request sizes here are >= 2 rows: one-row batch buckets take a
different GEMM path (matrix-vector vs matrix-matrix) whose fp32
reduction order differs, so the bitwise claim is pinned on the >= 2
buckets where row content is invariant to the batch bucket (the
compaction-invariance test in test_runtime.py covers the 1-row repack
at atol 1e-5).
"""
import numpy as np
import pytest

from repro.launch.faults import FaultConfig, injected
from repro.launch.runtime import RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine
from repro.obs.trace import Tracer, set_tracer


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


@pytest.fixture(scope="module")
def eng():
    return ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=6,
                       max_batch=4)


def _fresh(eng, **kw):
    kw.setdefault("backoff_base_s", 0.001)
    kw.setdefault("backoff_max_s", 0.005)
    kw.setdefault("breaker_cooldown_s", 0.2)
    r = ServeRuntime(eng, RuntimeConfig(**kw))
    r.warmup()
    return r


# -- tentpole: bitwise parity under mid-trajectory admission -----------------

def test_mid_trajectory_join_bitwise_parity_zero_compiles(eng):
    """B joins A's in-flight wave at a seam; both must be bitwise equal
    to serving each alone, with zero post-warmup compiles."""
    assert eng.plan.num_buckets >= 2
    r = _fresh(eng)
    b0 = eng.engine._builds
    t_a = r.submit(Request(0, 2, seed=11))
    assert r.pump()                      # A runs segment 0 alone
    t_b = r.submit(Request(1, 2, seed=12))
    r.run_until_idle()                   # B joins A's wave at the seam
    assert t_a.status == "done" and t_b.status == "done"
    assert r.counters["joins"] == 1
    assert r.counters["mixed_segments"] >= 1
    assert eng.engine._builds == b0, "continuous admission compiled"
    assert r.health()["compiles_post_warmup"] == 0
    alone = eng.serve([Request(0, 2, seed=11), Request(1, 2, seed=12)],
                      )
    solo_a = eng.serve([Request(0, 2, seed=11)])[0]
    solo_b = eng.serve([Request(1, 2, seed=12)])[0]
    np.testing.assert_array_equal(t_a.images, solo_a.images)
    np.testing.assert_array_equal(t_b.images, solo_b.images)
    # and co-batched-from-the-start serving agrees too (row independence)
    np.testing.assert_array_equal(alone[0].images, solo_a.images)


def test_joiner_advances_first_when_more_urgent(eng):
    """EDF picks the fresh joiner's cursor group while the older group
    freezes: the joiner itself runs MIXED segments as the active
    minority and must still be bitwise equal to solo serving."""
    r = _fresh(eng)
    t_a = r.submit(Request(0, 2, seed=21))            # no deadline
    assert r.pump()
    t_b = r.submit(Request(1, 2, seed=22, deadline_s=1000.0))
    mixed0 = r.counters["mixed_segments"]
    r.run_until_idle()
    assert t_a.status == "done" and t_b.status == "done"
    assert r.counters["joins"] == 1
    assert r.counters["mixed_segments"] > mixed0
    solo_a = eng.serve([Request(0, 2, seed=21)])[0]
    solo_b = eng.serve([Request(1, 2, seed=22)])[0]
    np.testing.assert_array_equal(t_a.images, solo_a.images)
    np.testing.assert_array_equal(t_b.images, solo_b.images)


def test_wave_at_a_time_mode_never_joins(eng):
    """RuntimeConfig(continuous=False) restores lockstep cohorts (the
    serve_throughput baseline): same results, zero joins."""
    r = _fresh(eng, continuous=False)
    t_a = r.submit(Request(0, 2, seed=31))
    assert r.pump()
    t_b = r.submit(Request(1, 2, seed=32))
    r.run_until_idle()
    assert t_a.status == "done" and t_b.status == "done"
    assert r.counters["joins"] == 0
    assert r.counters["mixed_segments"] == 0
    np.testing.assert_array_equal(
        t_b.images, eng.serve([Request(1, 2, seed=32)])[0].images)


# -- seam interactions: join + deadline compaction + OOM splits --------------

def test_join_and_deadline_compaction_same_seam(eng):
    """At one seam: A expires (compacted + repacked), C joins the freed
    slot in the SAME pump; the survivor B stays bit-identical to
    serving alone."""
    clk = FakeClock()
    r = _fresh(eng, clock=clk, sleep=clk.sleep, max_inflight_waves=1)
    t_a = r.submit(Request(0, 2, seed=41, deadline_s=5.0))
    t_b = r.submit(Request(1, 2, seed=42))
    assert r.pump()                      # A+B run segment 0 (bucket 4)
    clk.t = 10.0                         # A is now past its deadline
    t_c = r.submit(Request(2, 2, seed=43))
    r.run_until_idle()
    assert t_a.status == "expired" and t_a.images is None
    assert t_b.status == "done" and t_c.status == "done"
    assert r.counters["joins"] >= 1
    np.testing.assert_array_equal(
        t_b.images, eng.serve([Request(1, 2, seed=42)])[0].images)
    np.testing.assert_array_equal(
        t_c.images, eng.serve([Request(2, 2, seed=43)])[0].images)


def test_join_then_oom_split_preserves_cursors(eng):
    """A mixed-cursor wave that OOM-splits keeps each part's cursor:
    every request still delivers finite images exactly once."""
    r = _fresh(eng, max_retries=1, breaker_threshold=1)
    t_a = r.submit(Request(0, 2, seed=51))
    assert r.pump()
    t_b = r.submit(Request(1, 2, seed=52))
    with injected(FaultConfig(seed=7, oom_rate=0.7)):
        r.run_until_idle()
    for t in (t_a, t_b):
        assert t.status == "done", t.status
        assert np.isfinite(t.images).all()
    assert r.counters["joins"] >= 1
    assert r.counters["oom_splits"] >= 1


def test_gauss_fallback_freezes_inactive_rows(eng):
    """Retries exhausted on a MIXED segment: the Gaussian fallback may
    only replace the active rows — frozen wave-mates pass through and
    stay exact (bitwise) for their remaining segments."""
    r = _fresh(eng, max_retries=1)
    t_a = r.submit(Request(0, 2, seed=61))
    assert r.pump()                      # A finishes segment 0 cleanly
    t_b = r.submit(Request(1, 2, seed=62, deadline_s=1000.0))
    # EDF now runs B's cursor-0 group first (mixed, A frozen); errors
    # exhaust retries there and Gaussian-fallback B's rows only
    with injected(FaultConfig(seed=6, error_rate=1.0)):
        assert r.pump()
    assert r.counters["gauss_segments"] >= 1
    r.run_until_idle()
    assert t_a.status == "done" and t_b.status == "done"
    assert t_b.degraded and np.isfinite(t_b.images).all()
    # A never took a degraded segment: exact vs solo
    assert np.isfinite(t_a.images).all()
    np.testing.assert_array_equal(
        t_a.images, eng.serve([Request(0, 2, seed=61)])[0].images)


# -- property: every admission schedule delivers exactly once ----------------

def test_any_admission_schedule_delivers_exactly_once(eng):
    """Randomized submit/pump/expiry interleavings: every ticket reaches
    exactly one terminal state, images delivered iff done, and
    ``request.admit`` fires exactly once per request no matter how many
    seams it waited across (the PR 8 double-count audit)."""
    for schedule_seed in (0, 1, 2):
        rng = np.random.default_rng(schedule_seed)
        clk = FakeClock()
        r = _fresh(eng, clock=clk, sleep=clk.sleep)
        tr = Tracer()
        prev = set_tracer(tr)
        try:
            tickets, rid = [], 0
            for _ in range(8):           # bursts interleaved with pumps
                for _ in range(int(rng.integers(0, 3))):
                    dl = (None if rng.random() < 0.5
                          else float(rng.uniform(0.5, 50.0)))
                    tickets.append(r.submit(Request(
                        rid, int(rng.integers(1, 4)), seed=100 + rid,
                        deadline_s=dl)))
                    rid += 1
                for _ in range(int(rng.integers(0, 3))):
                    r.pump()
                clk.t += float(rng.uniform(0.0, 1.5))
            r.run_until_idle()
        finally:
            set_tracer(prev)
        assert len(tickets) == r.counters["submitted"]
        term = {"done", "expired", "failed"}
        assert all(t.status in term for t in tickets)
        done = sum(t.status == "done" for t in tickets)
        assert done == r.counters["completed"]
        assert (r.counters["completed"] + r.counters["expired"]
                + r.counters["failed"]) == r.counters["submitted"]
        for t in tickets:
            assert (t.images is not None) == (t.status == "done")
            if t.images is not None:
                assert np.isfinite(t.images).all()
                assert t.images.shape[0] == t.request.num_images
        admits = [e for e in tr.events()
                  if e["kind"] == "point" and e["name"] == "request.admit"]
        per_req = {}
        for e in admits:
            per_req[e["tags"]["request"]] = \
                per_req.get(e["tags"]["request"], 0) + 1
        assert all(c == 1 for c in per_req.values()), per_req
        assert len(per_req) == len(tickets)
        delivers = [e for e in tr.events()
                    if e["kind"] == "point"
                    and e["name"] == "request.deliver"]
        per_del = {}
        for e in delivers:
            per_del[e["tags"]["request"]] = \
                per_del.get(e["tags"]["request"], 0) + 1
        assert all(c == 1 for c in per_del.values()), per_del
        assert len(per_del) == done
