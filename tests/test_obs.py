"""Observability layer (repro/obs): tracing must be free when off and
cheap when on, metrics must be bounded and exact-enough, and the online
quality monitors must agree with the offline gated metrics.

The two load-bearing guarantees pinned here:

* **disabled == absent** — with the tracer off, engine and runtime
  outputs are bit-identical to an uninstrumented run and no program
  recompiles (the observability layer cannot perturb what it watches);
* **enabled == warm** — with tracing + monitors on, a warmed runtime
  still serves with zero post-warmup compiles (probe programs are part
  of warmup's contract).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import merge_bench_json
from repro.core import GoldDiffEngine, make_schedule
from repro.core.plan import full_scan_costs, step_stage_costs
from repro.data import gmm
from repro.index import build_index
from repro.index.store import screening_recall
from repro.kernels import ops
from repro.launch.faults import FaultConfig, injected
from repro.launch.runtime import CircuitBreaker, RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine
from repro.obs import (NULL_TRACER, MetricsRegistry, QualityMonitor, Tracer,
                       install_dispatch_tracing, set_tracer, tracer,
                       uninstall_dispatch_tracing)
from repro.obs import metrics as obs_metrics

SCH = make_schedule("ddpm_linear", 1000)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


@pytest.fixture(autouse=True)
def _no_obs_leak():
    """Tests must restore the null tracer and the dispatch seam."""
    yield
    assert tracer() is NULL_TRACER, "a test leaked an installed tracer"
    assert ops.dispatch_hook() is None, "a test leaked a dispatch hook"


def _engine(**kw):
    return GoldDiffEngine(gmm(256, dim=8, seed=0), SCH, **kw)


# -- tracer ------------------------------------------------------------------

def test_null_tracer_is_default_and_noop():
    assert tracer() is NULL_TRACER and not NULL_TRACER.enabled
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.event("y")
    assert NULL_TRACER.events() == [] and NULL_TRACER.dropped == 0


def test_set_tracer_returns_previous_and_none_restores_null():
    tr = Tracer()
    prev = set_tracer(tr)
    assert prev is NULL_TRACER and tracer() is tr
    assert set_tracer(None) is tr
    assert tracer() is NULL_TRACER


def test_span_nesting_and_durations_under_fake_clock():
    tr = Tracer(clock=FakeClock())
    with tr.span("outer", t=400):
        tr.event("mark", rows=3)
        with tr.span("inner"):
            pass
    ev = tr.events()
    kinds = [(e["kind"], e["name"]) for e in ev]
    assert kinds == [("begin", "outer"), ("point", "mark"),
                     ("begin", "inner"), ("end", "inner"), ("end", "outer")]
    b_out, mark, b_in, e_in, e_out = ev
    assert [e["seq"] for e in ev] == list(range(5))
    assert b_out["parent"] == 0 and b_out["tags"] == {"t": 400}
    assert mark["span"] == b_out["span"]          # point inside outer
    assert b_in["parent"] == b_out["span"]        # nesting recorded
    assert e_in["span"] == b_in["span"] and e_out["span"] == b_out["span"]
    # fake clock ticks once per read: every duration is deterministic
    assert e_in["tags"]["dur"] > 0 and e_out["tags"]["dur"] > 0
    assert e_out["tags"]["dur"] > e_in["tags"]["dur"]


def test_ring_buffer_wrap_keeps_latest_and_counts_drops():
    tr = Tracer(capacity=4, clock=FakeClock())
    for i in range(10):
        tr.event(f"e{i}")
    ev = tr.events()
    assert [e["name"] for e in ev] == ["e6", "e7", "e8", "e9"]
    assert [e["seq"] for e in ev] == [6, 7, 8, 9]  # globally monotone
    assert tr.dropped == 6
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_dump_round_trips_json_lines(tmp_path):
    tr = Tracer(clock=FakeClock())
    with tr.span("a", key=(1, 2)):
        tr.event("b")
    p = tmp_path / "trace.jsonl"
    assert tr.dump(str(p)) == 3
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert [e["name"] for e in lines] == ["a", "b", "a"]
    assert all(set(e) == {"seq", "ts", "kind", "name", "span", "parent",
                          "tags"} for e in lines)


# -- metrics -----------------------------------------------------------------

def test_counter_gauge_basics_and_type_collisions():
    r = MetricsRegistry()
    c = r.counter("req_total", "requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)
    g = r.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0
    assert r.counter("req_total") is c            # idempotent constructor
    with pytest.raises(TypeError, match="already registered"):
        r.gauge("req_total")


def test_histogram_exact_small_then_reservoir_accurate():
    h = obs_metrics.Histogram("lat", reservoir=64, seed=3)
    small = [5.0, 1.0, 9.0, 3.0]
    for v in small:
        h.observe(v)
    # count <= reservoir: the sample IS the stream, quantiles exact
    assert h.quantile(0.5) == np.percentile(small, 50)
    assert h.quantile(1.0) == 9.0 and h.min == 1.0 and h.max == 9.0
    # long stream: bounded memory, quantiles near the exact percentiles
    stream = [obs_metrics._unit(11, i) * 100.0 for i in range(4000)]
    h2 = obs_metrics.Histogram("lat2", reservoir=256, seed=0)
    for v in stream:
        h2.observe(v)
    assert len(h2._sample) == 256 and h2.count == 4000
    assert abs(h2.quantile(0.5) - np.percentile(stream, 50)) < 10.0
    assert abs(h2.quantile(0.99) - np.percentile(stream, 99)) < 5.0
    cell = h2.cell()
    assert cell["count"] == 4000 and cell["p50"] == h2.quantile(0.5)


def test_registry_snapshot_and_prometheus_round_trip():
    r = MetricsRegistry()
    r.counter("a_total", "things").inc(4)
    r.gauge("b_depth").set(2.5)
    h = r.histogram("c_lat", "latency", reservoir=16)
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = r.snapshot()
    assert snap["a_total"] == {"type": "counter", "value": 4.0}
    assert snap["b_depth"]["value"] == 2.5
    assert snap["c_lat"]["count"] == 3 and snap["c_lat"]["p50"] == 2.0
    json.dumps(snap)                              # JSON-clean
    prom = r.prometheus()
    assert "# TYPE a_total counter\na_total 4" in prom
    assert "b_depth 2.5" in prom
    assert '# TYPE c_lat summary' in prom
    assert 'c_lat{quantile="0.5"} 2' in prom
    assert "c_lat_sum 6" in prom and "c_lat_count 3" in prom


def test_register_adopts_external_metric_last_wins():
    r = MetricsRegistry()
    old = obs_metrics.Histogram("serve_latency_seconds", reservoir=4)
    r.register(old)
    new = obs_metrics.Histogram("serve_latency_seconds", reservoir=4)
    r.register(new)
    assert r.histogram("serve_latency_seconds") is new


# -- dispatch-seam tracing ---------------------------------------------------

def test_dispatch_spans_carry_compile_tags_and_count_metrics():
    eng = _engine()
    x = jnp.ones((2, 8))
    tr = Tracer(capacity=1 << 12)
    reg = MetricsRegistry()
    prev = set_tracer(tr)
    hook = install_dispatch_tracing(tr, registry=reg)
    try:
        eng.denoise(x, 500)                       # cold: compiles
        n_cold = len(tr.events())
        eng.denoise(x, 500)                       # warm: cache hits
    finally:
        uninstall_dispatch_tracing(hook)
        set_tracer(prev)
    assert ops.dispatch_hook() is None
    spans = [e for e in tr.events() if e["kind"] == "begin"
             and e["name"].startswith("dispatch.")]
    assert spans, "dispatches must be spanned"
    cold = [e for e in spans if e["seq"] < n_cold]
    warm = [e for e in spans if e["seq"] >= n_cold]
    assert all(e["tags"]["compile"] for e in cold)
    assert warm and not any(e["tags"]["compile"] for e in warm)
    compiles = reg.snapshot()["golddiff_compiles_total"]["value"]
    assert compiles == len(cold) == eng._builds
    # fused="auto" (the default) routes this dense-strategy static step
    # through the single-pass fused program kind
    kinds = {e["name"].split(".", 1)[1] for e in spans}
    assert kinds == {"fused_step"}
    assert reg.snapshot()["golddiff_dispatch_total_fused_step"]["value"] == 2


def test_disabled_tracer_is_bit_identical_with_zero_recompiles():
    eng = _engine()
    x = jnp.linspace(-1.0, 1.0, 16).reshape(2, 8)
    ref = {t: np.asarray(eng.denoise(x, t)) for t in (800, 300)}
    b0 = eng._builds
    # enabled tracing must reuse the same compiled programs and produce
    # the same bits; back to disabled must again change nothing
    tr = Tracer(capacity=1 << 12)
    prev = set_tracer(tr)
    try:
        traced = {t: np.asarray(eng.denoise(x, t)) for t in (800, 300)}
    finally:
        set_tracer(prev)
    after = {t: np.asarray(eng.denoise(x, t)) for t in (800, 300)}
    for t in ref:
        np.testing.assert_array_equal(traced[t], ref[t])
        np.testing.assert_array_equal(after[t], ref[t])
    assert eng._builds == b0, "tracing must not change program cache keys"
    names = {e["name"] for e in tr.events()}
    # fused="auto" (the default) routes these dense-strategy steps
    # through the single-pass fused program
    assert "engine.fused_step" in names and "stage.fused_step" in names


def test_fault_events_land_on_the_trace_stream():
    eng = _engine()
    x = jnp.ones((4, 8))
    tr = Tracer(capacity=1 << 12)
    prev = set_tracer(tr)
    try:
        with injected(FaultConfig(seed=42, nan_rate=0.5)) as inj:
            for t in (900, 600, 300, 100):
                eng.denoise(x, t)
    finally:
        set_tracer(prev)
    fault_ev = [e for e in tr.events() if e["name"].startswith("fault.")]
    assert len(inj.events) >= 1
    assert len(fault_ev) == len(inj.events)
    for e, (kind, program, n) in zip(fault_ev, inj.events):
        assert e["name"] == f"fault.{kind}" and e["kind"] == "point"
        assert e["tags"]["program"] == program and e["tags"]["counter"] == n


# -- analytic stage costs ----------------------------------------------------

def test_stage_costs_cover_the_pipeline_and_are_positive():
    eng = _engine()
    costs = step_stage_costs(eng, 400, batch=4)
    assert set(costs) == {"screen", "rerank", "aggregate"}
    ix = build_index(gmm(256, dim=8, seed=0), num_clusters=8)
    eng_ix = _engine(index=ix, index_mode="always")
    costs_ix = step_stage_costs(eng_ix, 400, batch=4)
    assert set(costs_ix) == {"ivf_screen", "rerank", "aggregate"}
    fs = full_scan_costs(eng, batch=4)
    assert set(fs) == {"full_scan"}
    for table in (costs, costs_ix, fs):
        for stage, c in table.items():
            assert c["flops"] > 0 and c["bytes"] > 0, stage
            assert set(c) == {"flops", "bytes"}, stage
    # full scan reads every row for distances AND aggregation: it must
    # dominate the selection path's screen traffic
    assert fs["full_scan"]["bytes"] > costs["screen"]["bytes"]


# -- online quality monitors -------------------------------------------------

def test_recall_probe_matches_direct_screening_recall():
    store = gmm(256, dim=8, seed=0)
    eng = GoldDiffEngine(store, SCH, index=build_index(store, num_clusters=8),
                         index_mode="always")
    mon = QualityMonitor(eng, registry=MetricsRegistry(), probe_rows=2)
    t = 400
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    rec = mon.probe_recall(x, t)
    # recompute from the engine's own screens, outside the monitor
    a, _ = eng.constants(t)
    q = jnp.asarray(np.asarray(x[:2], np.float32) / float(a))
    m_t, _ = eng.sizes(t)
    exact_ids = np.asarray(eng.coarse(q, m_t))
    pos, pd2 = eng.coarse_indexed(q, eng.padded_m(t), eng.nprobe(t))
    direct = screening_recall(pos, pd2, eng.index.perm, exact_ids)
    assert rec == pytest.approx(direct)
    assert 0.0 <= rec <= 1.0
    h = mon.health()
    assert h["n_recall_probes"] == 1
    assert h["screen_recall_last"] == pytest.approx(rec)


def test_probe_is_static_shape_and_warmup_precompiles():
    store = gmm(256, dim=8, seed=0)
    eng = GoldDiffEngine(store, SCH, index=build_index(store, num_clusters=8),
                         index_mode="always")
    mon = QualityMonitor(eng, registry=MetricsRegistry(), probe_rows=2)
    assert mon.warmup([400, 700]) == 2
    b0 = eng._builds
    x4 = jnp.ones((4, 8))
    x1 = jnp.ones((1, 8))                         # short wave: tiled up
    assert mon.probe_recall(x4, 400) is not None
    assert mon.probe_recall(x1, 700) is not None
    assert eng._builds == b0, "warmed probes must not compile"


def test_maybe_probe_sampling_is_deterministic_and_concentration_records():
    store = gmm(256, dim=8, seed=0)
    eng = GoldDiffEngine(store, SCH, index=build_index(store, num_clusters=8),
                         index_mode="always")
    x = jnp.ones((2, 8))

    def decisions():
        mon = QualityMonitor(eng, registry=MetricsRegistry(),
                             sample_rate=0.5, seed=7)
        return [mon.maybe_probe_recall(x, 400) is not None
                for _ in range(16)]

    d1 = decisions()
    assert d1 == decisions(), "probe sampling must be reproducible"
    assert any(d1) and not all(d1), "rate 0.5 should mix probes and skips"
    # concentration curve: analytic, recorded on every reported step
    mon = QualityMonitor(eng, registry=MetricsRegistry())
    for t in (900, 500, 100):
        mon.record_step(t)
    snap = mon.registry.snapshot()
    assert snap["golddiff_steps_total"]["value"] == 3
    assert snap["golddiff_subset_frac"]["count"] == 3
    occ = [snap[f"golddiff_occupancy_t{t}"]["value"] for t in (900, 500, 100)]
    assert all(0.0 < o <= 1.0 for o in occ)
    with pytest.raises(ValueError, match="sample_rate"):
        QualityMonitor(eng, registry=MetricsRegistry(), sample_rate=1.5)


# -- serving runtime integration ---------------------------------------------

@pytest.fixture(scope="module")
def serve_eng():
    return ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=6,
                       max_batch=4)


@pytest.fixture(scope="module")
def obs_rt(serve_eng):
    r = ServeRuntime(serve_eng, RuntimeConfig(latency_reservoir=4),
                     monitor=QualityMonitor(serve_eng.engine,
                                            registry=MetricsRegistry()),
                     registry=MetricsRegistry())
    r.warmup()
    return r


def test_request_lifecycle_reconstructable_from_trace(serve_eng, obs_rt):
    tr = Tracer(capacity=1 << 14)
    prev = set_tracer(tr)
    try:
        tk = [obs_rt.submit(Request(i, 2, seed=50 + i)) for i in range(2)]
        obs_rt.run_until_idle()
    finally:
        set_tracer(prev)
    assert all(t.status == "done" for t in tk)
    assert obs_rt.health()["compiles_post_warmup"] == 0, \
        "tracing+monitoring must not compile post-warmup"
    ev = tr.events()
    admits = [e for e in ev if e["name"] == "request.admit"]
    delivers = [e for e in ev if e["name"] == "request.deliver"]
    assert {e["tags"]["request"] for e in admits} == {0, 1}
    assert {e["tags"]["request"] for e in delivers} == {0, 1}
    assert all(e["tags"]["latency_s"] >= 0 for e in delivers)
    waves = [e for e in ev
             if e["name"] == "wave.segment" and e["kind"] == "begin"]
    assert waves and all("bucket" in e["tags"] and "cursor" in e["tags"]
                         for e in waves)
    # lifecycle ordering: admit precedes the first segment precedes deliver
    assert admits[0]["seq"] < waves[0]["seq"] < delivers[-1]["seq"]


def test_traced_serving_is_bit_identical_to_untraced(serve_eng, obs_rt):
    req = Request(7, 3, seed=99)
    ref = serve_eng.serve([req])[0]
    tr = Tracer(capacity=1 << 14)
    prev = set_tracer(tr)
    try:
        t = obs_rt.submit(Request(7, 3, seed=99))
        obs_rt.run_until_idle()
    finally:
        set_tracer(prev)
    assert t.status == "done"
    np.testing.assert_array_equal(t.images, ref.images)


def test_health_merges_monitor_and_exports_metrics(serve_eng, obs_rt):
    t = obs_rt.submit(Request(3, 2, seed=5))
    obs_rt.run_until_idle()
    assert t.status == "done"
    h = obs_rt.health()
    for k in ("p50_ms", "p95_ms", "p99_ms", "latency_samples",
              "dwell_exec_s", "dwell_screen_s", "dwell_oom_s",
              "dwell_compile_s", "screen_recall_p50", "subset_frac_p50",
              "n_steps_observed"):
        assert k in h, k
    assert h["p99_ms"] >= h["p50_ms"] >= 0.0
    assert h["n_steps_observed"] > 0     # concentration recorded per step
    # bounded latency sample regardless of traffic (satellite: the
    # unbounded _latencies list is gone)
    assert not hasattr(obs_rt, "_latencies")
    assert len(obs_rt._lat_hist._sample) <= 4
    assert obs_rt._lat_hist.count == h["latency_samples"] >= 4
    snap = obs_rt.metrics_snapshot()
    assert snap["serve_latency_seconds"]["count"] == h["latency_samples"]
    assert snap["serve_completed_total"]["value"] == \
        obs_rt.counters["completed"]
    prom = obs_rt.prometheus()
    assert "serve_latency_seconds_count" in prom
    assert "serve_queue_depth" in prom


def test_breaker_dwell_time_accounting():
    br = CircuitBreaker(threshold=2, window_s=10.0, cooldown_s=5.0)
    assert br.dwell_s(0.0) == 0.0
    br.record_failure(0.0)
    assert br.state(0.5) == "closed" and br.dwell_s(3.0) == 0.0
    br.record_failure(1.0)                        # trips: opens at t=1
    assert br.state(2.0) == "open"
    assert br.dwell_s(4.0) == pytest.approx(3.0)  # in-progress episode
    br.record_success(3.0)                        # still open: ignored
    assert br.dwell_s(4.0) == pytest.approx(3.0)
    assert br.state(7.0) == "half_open"           # past cooldown
    br.record_success(7.0)                        # probe succeeds: closes
    assert br.state(8.0) == "closed"
    assert br.dwell_s(100.0) == pytest.approx(6.0)   # frozen once closed
    br.record_failure(20.0)
    br.record_failure(21.0)                       # second episode
    assert br.dwell_s(25.0) == pytest.approx(6.0 + 4.0)


# -- bench record merge ------------------------------------------------------

def test_merge_bench_json_group_ownership(tmp_path):
    p = str(tmp_path / "BENCH_x.json")
    merge_bench_json(p, {"static/a/t1": 1.0, "static/b/t1": 2.0})
    merge_bench_json(p, {"roofline/peak/peak_gflops": 9.0,
                         "obs/denoise/obs_base_us": 5.0})
    rec = json.load(open(p))
    assert set(rec) == {"static/a/t1", "static/b/t1",
                        "roofline/peak/peak_gflops",
                        "obs/denoise/obs_base_us"}
    # re-emitting a group replaces ONLY that group's cells
    merge_bench_json(p, {"static/a/t1": 3.0})
    rec = json.load(open(p))
    assert rec["static/a/t1"] == 3.0 and "static/b/t1" not in rec
    assert rec["roofline/peak/peak_gflops"] == 9.0
    # corrupt prior record: start fresh rather than crash
    (tmp_path / "BENCH_y.json").write_text("{broken")
    py = str(tmp_path / "BENCH_y.json")
    merge_bench_json(py, {"static/a/t1": 1.0})
    assert json.load(open(py)) == {"static/a/t1": 1.0}
