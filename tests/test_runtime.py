"""Fault-tolerant serving runtime (repro/launch/runtime.py): admission
validation, deadlines at plan seams, retry/fallback, the degradation
ladder, and the zero-compile guarantee on every warmed rung."""
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.launch.faults import FaultConfig, injected
from repro.launch.runtime import (CircuitBreaker, QueueFullError,
                                  RuntimeConfig, ServeRuntime,
                                  validate_request)
from repro.launch.serve import Request, ServeEngine

REPO = Path(__file__).resolve().parent.parent


class FakeClock:
    """Deterministic clock + sleep pair for deadline/backoff tests."""

    def __init__(self):
        self.t = 0.0
        self.slept = []

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.slept.append(s)
        self.t += s


@pytest.fixture(scope="module")
def eng():
    e = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=6, max_batch=4)
    return e


@pytest.fixture(scope="module")
def rt(eng):
    r = ServeRuntime(eng, RuntimeConfig(backoff_base_s=0.001,
                                        backoff_max_s=0.005,
                                        breaker_cooldown_s=0.2))
    r.warmup()
    return r


def _fresh(eng, **kw):
    """A fresh runtime sharing the module engine's warm program cache
    (its warmup is all cache hits)."""
    kw.setdefault("backoff_base_s", 0.001)
    kw.setdefault("backoff_max_s", 0.005)
    kw.setdefault("breaker_cooldown_s", 0.2)
    r = ServeRuntime(eng, RuntimeConfig(**kw))
    r.warmup()
    return r


# -- satellite 1: admission validation ---------------------------------------

def test_validate_request_rejects_bad_inputs():
    with pytest.raises(ValueError, match="num_images must be an int"):
        validate_request(Request(0, 2.5, seed=0), 8)
    with pytest.raises(ValueError, match="num_images must be an int"):
        validate_request(Request(0, True, seed=0), 8)
    with pytest.raises(ValueError, match=">= 1"):
        validate_request(Request(0, 0, seed=0), 8)
    with pytest.raises(ValueError, match=">= 1"):
        validate_request(Request(0, -3, seed=0), 8)
    with pytest.raises(ValueError, match="exceeds the per-request cap"):
        validate_request(Request(0, 9, seed=0), 8)
    with pytest.raises(ValueError, match="seed must be an int"):
        validate_request(Request(0, 1, seed=1.5), 8)
    with pytest.raises(ValueError, match="seed must be an int"):
        validate_request(Request(0, 1, seed=False), 8)
    with pytest.raises(ValueError, match="seed must be >= 0"):
        validate_request(Request(0, 1, seed=-1), 8)
    with pytest.raises(ValueError, match="deadline_s must be positive"):
        validate_request(Request(0, 1, seed=0, deadline_s=0.0), 8)
    validate_request(Request(0, 8, seed=0, deadline_s=1.0), 8)  # all valid
    validate_request(Request(0, np.int64(2), seed=np.int32(3)), 8)


def test_submit_validates_and_bounds_queue(eng, rt):
    with pytest.raises(ValueError):
        rt.submit(Request(0, 0, seed=1))
    with pytest.raises(ValueError):
        rt.submit(Request(0, eng.max_batch + 1, seed=1))
    small = ServeRuntime(eng, RuntimeConfig(max_queue=2))
    small.warmup()
    small.submit(Request(0, 1, seed=1))
    small.submit(Request(1, 1, seed=2))
    with pytest.raises(QueueFullError):
        small.submit(Request(2, 1, seed=3))
    small.run_until_idle()               # admission control, not data loss
    assert small.counters["completed"] == 2


def test_static_mode_engine_rejected():
    e = ServeEngine("cifar_like", {"n": 64}, base="pca", num_steps=3)
    assert e.mode == "static"
    with pytest.raises(ValueError, match="static"):
        ServeRuntime(e)


# -- clean path: parity + zero compiles --------------------------------------

def test_clean_path_matches_serve_bitwise_with_zero_compiles(eng, rt):
    reqs = [Request(0, 3, seed=7), Request(1, 1, seed=9)]
    b0 = eng.engine._builds
    tickets = [rt.submit(Request(r.request_id, r.num_images, seed=r.seed))
               for r in reqs]
    rt.run_until_idle()
    res = eng.serve(reqs)
    for t, r in zip(tickets, res):
        assert t.status == "done" and not t.degraded
        assert t.latency_s is not None and t.latency_s >= 0.0
        np.testing.assert_array_equal(t.images, r.images)
    assert eng.engine._builds == b0, "clean serving must not compile"
    assert rt.health()["compiles_post_warmup"] == 0


# -- deadlines ---------------------------------------------------------------

def test_deadline_expiry_in_queue_and_at_seams(eng):
    clk = FakeClock()
    r = _fresh(eng, clock=clk, sleep=clk.sleep, default_deadline_s=None)
    # (a) expires while still queued: never runs
    t_q = r.submit(Request(0, 1, seed=1, deadline_s=5.0))
    clk.t = 10.0
    r.run_until_idle()
    assert t_q.status == "expired" and t_q.images is None
    # (b) expires between segments: rows dropped at the seam, wave-mates
    # unaffected and bit-identical to serving alone (compaction proof)
    assert eng.plan.num_buckets >= 2, "test needs >= 2 plan segments"
    t_a = r.submit(Request(1, 1, seed=21))                    # no deadline
    t_b = r.submit(Request(2, 2, seed=22, deadline_s=5.0))
    assert r.pump()                      # segment 1 at t=10, both running
    clk.t = 20.0                         # b is now past its deadline
    r.run_until_idle()
    assert t_b.status == "expired" and t_b.images is None
    assert t_a.status == "done"
    assert r.counters["repacks"] >= 1    # 3 rows -> 1 row: smaller bucket
    alone = eng.serve([Request(1, 1, seed=21)])[0]
    np.testing.assert_allclose(t_a.images, alone.images, rtol=0, atol=1e-5)
    # (c) strict delivery-time check: completed => within deadline
    t_c = r.submit(Request(3, 1, seed=23, deadline_s=1000.0))
    r.pump()
    clk.t = 20.0 + 2000.0
    r.run_until_idle()
    assert t_c.status == "expired"
    h = r.health()
    assert h["deadline_miss_rate"] == pytest.approx(3 / 4)
    assert h["n_completed"] == 1


# -- failure handling / degradation ladder -----------------------------------

def test_nan_storm_finite_guard_and_exact_rung(eng):
    r = _fresh(eng, breaker_threshold=1)
    with injected(FaultConfig(seed=3, nan_rate=1.0)):
        t1 = r.submit(Request(0, 2, seed=31))
        r.run_until_idle()
        t2 = r.submit(Request(1, 2, seed=32))   # screen breaker now open
        r.run_until_idle()
    for t in (t1, t2):
        assert t.status == "done" and t.degraded
        assert np.isfinite(t.images).all(), "NaN crossed a seam"
    assert r.counters["finite_trips"] >= 1
    assert r.counters["gauss_segments"] >= 1
    assert r.counters["exact_waves"] >= 1       # ladder switched rungs


def test_transient_errors_retry_then_succeed(eng):
    r = _fresh(eng, max_retries=100)
    with injected(FaultConfig(seed=5, error_rate=0.6)) as inj:
        t = r.submit(Request(0, 3, seed=41))
        r.run_until_idle()
    assert t.status == "done" and np.isfinite(t.images).all()
    assert any(e[0] == "error" for e in inj.events)
    assert r.counters["retries"] >= 1


def test_retries_exhausted_falls_back_to_gaussian(eng):
    r = _fresh(eng, max_retries=2)
    with injected(FaultConfig(seed=6, error_rate=1.0)):
        t = r.submit(Request(0, 2, seed=51))
        r.run_until_idle()
    assert t.status == "done" and t.degraded
    assert np.isfinite(t.images).all()
    assert r.counters["gauss_segments"] >= 1


def test_oom_splits_wave_and_halves_admission(eng):
    r = _fresh(eng, max_retries=1, breaker_threshold=1)
    with injected(FaultConfig(seed=7, oom_rate=0.7)):
        t1 = r.submit(Request(0, 2, seed=61))
        t2 = r.submit(Request(1, 2, seed=62))
        r.run_until_idle()
    for t in (t1, t2):
        assert t.status == "done" and np.isfinite(t.images).all()
    assert r.counters["oom_splits"] >= 1
    h = r.health()
    assert h["n_short_waves"] >= 1 or h["n_oom_splits"] >= 1


def test_recompile_storm_trips_compile_breaker_to_scan_mode(eng):
    r = _fresh(eng, breaker_threshold=1)
    b0 = eng.engine._builds
    with injected(FaultConfig(seed=8, evict_rate=1.0)):
        t1 = r.submit(Request(0, 2, seed=71))
        r.run_until_idle()
        t2 = r.submit(Request(1, 2, seed=72))   # compile breaker open
        r.run_until_idle()
    for t in (t1, t2):
        assert t.status == "done" and np.isfinite(t.images).all()
    assert eng.engine._builds > b0              # real rebuilds happened
    assert r.health()["compiles_post_warmup"] > 0
    assert r.counters["scan_waves"] >= 1        # plan -> scan rung


def test_breaker_state_machine():
    br = CircuitBreaker(threshold=2, window_s=10.0, cooldown_s=5.0)
    assert br.state(0.0) == "closed"
    br.record_failure(1.0)
    assert br.state(1.0) == "closed"            # below threshold
    br.record_failure(2.0)
    assert br.state(2.0) == "open" and br.is_open(2.0)
    assert br.state(7.5) == "half_open" and not br.is_open(7.5)
    br.record_success(7.5)                      # half-open probe passes
    assert br.state(7.5) == "closed"
    # failures outside the window don't accumulate
    br.record_failure(100.0)
    br.record_failure(120.0)
    assert br.state(120.0) == "closed"


def test_backoff_is_deterministic_and_bounded(eng):
    clk1, clk2 = FakeClock(), FakeClock()
    cfgs = dict(max_retries=3, backoff_base_s=0.01, backoff_max_s=0.04,
                jitter_frac=0.25, seed=123)
    r1 = _fresh(eng, clock=clk1, sleep=clk1.sleep, **cfgs)
    r2 = _fresh(eng, clock=clk2, sleep=clk2.sleep, **cfgs)
    for r in (r1, r2):
        with injected(FaultConfig(seed=9, error_rate=1.0)):
            r.submit(Request(0, 1, seed=81))
            r.run_until_idle()
    assert clk1.slept == clk2.slept and len(clk1.slept) >= 1
    for s, attempt in zip(clk1.slept, range(1, len(clk1.slept) + 1)):
        cap = min(0.04, 0.01 * 2 ** (attempt - 1)) * 1.25
        assert 0.0 <= s <= cap + 1e-12


# -- observability / lifecycle ----------------------------------------------

def test_health_snapshot_shape(rt):
    h = rt.health()
    for k in ("queue_depth", "inflight_waves", "breaker_exec",
              "breaker_screen", "breaker_oom", "breaker_compile",
              "degraded_scan_mode", "degraded_exact_screen",
              "degraded_reduced_batch", "compiles_post_warmup",
              "p50_ms", "p99_ms", "deadline_miss_rate", "n_completed",
              "n_expired", "n_retries", "n_finite_trips"):
        assert k in h, k
    assert h["queue_depth"] == 0 and h["inflight_waves"] == 0
    assert h["p99_ms"] >= h["p50_ms"] >= 0.0


def test_background_thread_serves(eng, rt):
    rt.start()
    try:
        t = rt.submit(Request(0, 2, seed=91))
        deadline = time.time() + 60.0
        while t.status not in ("done", "expired", "failed"):
            assert time.time() < deadline, "background loop stalled"
            time.sleep(0.01)
        assert t.status == "done" and np.isfinite(t.images).all()
    finally:
        rt.stop()
    rt.start()                           # restartable
    rt.stop()


def test_scan_mode_engine_runtime(eng):
    e = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=6, max_batch=4,
                    mode="scan")
    r = ServeRuntime(e, RuntimeConfig(backoff_base_s=0.001))
    r.warmup()
    t = r.submit(Request(0, 2, seed=5))
    r.run_until_idle()
    assert t.status == "done" and not t.degraded
    assert np.isfinite(t.images).all()


@pytest.mark.slow
def test_shard_dropout_on_emulated_mesh_subprocess():
    """Chaos on an emulated 8-device mesh: shard-dropout faults at the
    dispatch seam must retry to completion with finite images."""
    code = """
import jax
import numpy as np
from repro.launch.faults import FaultConfig, injected
from repro.launch.runtime import RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((8,), ("data",))
eng = ServeEngine("gmm", {"n": 1003, "dim": 16}, num_steps=5,
                  max_batch=4, mesh=mesh)
rt = ServeRuntime(eng, RuntimeConfig(backoff_base_s=0.001,
                                     max_retries=50))
rt.warmup()
with injected(FaultConfig(seed=2, shard_drop_rate=0.3)) as inj:
    tickets = [rt.submit(Request(i, 2, seed=100 + i)) for i in range(3)]
    rt.run_until_idle()
assert any(e[0] == "shard_drop" for e in inj.events), inj.events
for t in tickets:
    assert t.status == "done", t.status
    assert np.isfinite(t.images).all()
print("OK retries=", rt.counters["retries"])
"""
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=REPO, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK retries=" in r.stdout
