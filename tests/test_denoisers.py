"""Denoiser correctness: Eq. 2 vs brute force, Wiener optimality, patch paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (OptimalDenoiser, PCADenoiser, PatchDenoiser,
                        WienerDenoiser, make_schedule)
from repro.core.dataset import pairwise_sq_dists
from repro.data import cifar_like, gmm, mnist_like

SCH = make_schedule("ddpm_linear", 1000)


def test_optimal_matches_bruteforce():
    store = gmm(300, dim=6, seed=0)
    den = OptimalDenoiser(store, SCH, chunk=64)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6))
    t = 400
    a = float(SCH.a[t]); sig2 = float(SCH.sigma(t)) ** 2
    d2 = np.asarray(pairwise_sq_dists(x / a, store.X))
    w = jax.nn.softmax(jnp.asarray(-d2 / (2 * sig2)), -1)
    ref = np.asarray(w @ store.X)
    np.testing.assert_allclose(np.asarray(den(x, t)), ref, rtol=1e-4,
                               atol=1e-5)


def test_optimal_support_restriction():
    store = gmm(300, dim=6, seed=1)
    den = OptimalDenoiser(store, SCH)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6))
    idx = jnp.tile(jnp.arange(300)[None], (3, 1))
    np.testing.assert_allclose(np.asarray(den(x, 300, support=idx)),
                               np.asarray(den(x, 300)), rtol=1e-4, atol=1e-5)


def test_wiener_is_linear_mmse_on_gaussian():
    """On exactly Gaussian data the Wiener filter IS the optimal denoiser
    in expectation; check it beats the mean-predictor on heldout noise."""
    rng = np.random.default_rng(0)
    cov_half = rng.normal(size=(8, 8)) * 0.3
    x = rng.normal(size=(2048, 8)) @ cov_half
    from repro.core.dataset import make_store
    store = make_store(x.astype(np.float32), (8,), proxy_factor=1)
    den = WienerDenoiser(store, SCH)
    t = 500
    x0 = jnp.asarray(x[:64], jnp.float32)
    eps = jax.random.normal(jax.random.PRNGKey(2), x0.shape)
    xt = SCH.add_noise(x0, eps, t)
    est = den(xt, t)
    mse_w = float(jnp.mean((est - x0) ** 2))
    mse_mean = float(jnp.mean((jnp.asarray(x.mean(0)) - x0) ** 2))
    mse_id = float(jnp.mean((xt / float(SCH.a[t]) - x0) ** 2))
    assert mse_w < mse_mean and mse_w < mse_id


def test_patch_denoiser_patch_schedule():
    store = cifar_like(64, seed=0)
    den = PatchDenoiser(store, SCH, patch_min=3, patch_max=11)
    assert den.patch_size(999) >= den.patch_size(10)
    assert den.patch_size(999) % 2 == 1 and den.patch_size(10) % 2 == 1


@pytest.mark.parametrize("cls", [PatchDenoiser, PCADenoiser])
def test_patch_denoisers_shapes_and_finiteness(cls):
    store = mnist_like(128, seed=0)
    den = cls(store, SCH, chunk=64)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, store.dim))
    for t in (900, 400, 30):
        out = den(x, t)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())


def test_pca_full_vs_support_consistency():
    """Support=all indices must reproduce the full-scan (unbiased) path."""
    store = mnist_like(96, seed=1)
    den = PCADenoiser(store, SCH, weighting="ss", chunk=96)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, store.dim))
    idx = jnp.tile(jnp.arange(96)[None], (2, 1))
    full = den(x, 300)
    sub = den(x, 300, support=idx)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sub),
                               rtol=2e-4, atol=2e-4)


def test_schedules_consistency():
    for name in ("ddpm_linear", "cosine", "edm_vp", "edm_ve"):
        sch = make_schedule(name, 256)
        assert sch.num_steps == 256
        sig = np.asarray([float(sch.sigma(t)) for t in (1, 128, 256)])
        assert np.all(np.diff(sig) > 0), f"{name}: sigma must increase"
        g = np.asarray([float(sch.g(t)) for t in (1, 128, 256)])
        assert g[0] <= g[1] <= g[2] and g[0] == 0.0 and g[2] == 1.0
