"""Fused single-pass GoldDiff step (``kernels/fused_step.py``).

The fused megakernel / scan twin collapses coarse screen -> exact
re-rank -> softmax aggregation into ONE pass over the store, emitting
the posterior mean directly.  These tests pin:

* fused == staged engine outputs to fp32 reduction order on every
  backend (candidate *sets* are bit-identical; distances differ only
  by per-tile vs [B, N] GEMM blocking), static and masked/caps paths;
* ops-level edges — m > N surplus slots stay weightless, an all-masked
  step (m_t = k_t = 0) degrades finitely instead of NaN;
* the engine's fused policy (``fused="auto"|True|False``) and its
  program-cache kind;
* sharded parity on an emulated 8-device mesh: the overlap-ordered
  ``fused_local_step`` is BITWISE identical to the staged sharded path
  (same ops in the same order, only collective issue order differs),
  and a 2D (batch x store) mesh matches the single host;
* zero post-warmup compiles with ``fused=True`` in static and plan
  serving modes, including the continuous-batching ``plan_seg_mix``
  programs.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm
from repro.kernels import ops

REPO = Path(__file__).resolve().parent.parent
SCH = make_schedule("ddpm_linear", 1000)

BACKENDS = ["xla", "pallas_interpret"]
if any(d.platform == "tpu" for d in jax.devices()):
    BACKENDS.append("pallas")


def _pair(backend, **kw):
    """(store, staged engine, fused engine) sharing one store."""
    store = gmm(512, dim=16, seed=0)
    staged = GoldDiffEngine(store, SCH, GoldDiffConfig(), backend=backend,
                            fused=False, **kw)
    fused = GoldDiffEngine(store, SCH, GoldDiffConfig(), backend=backend,
                           fused=True, **kw)
    return store, staged, fused


def _noisy(store, t, b=4, seed=0):
    x0 = store.X[:b]
    eps = jax.random.normal(jax.random.PRNGKey(seed), x0.shape)
    return SCH.add_noise(x0, eps, t)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_matches_staged_static(backend):
    store, staged, fused = _pair(backend)
    for t in (900, 500, 100):
        xt = _noisy(store, t, seed=t)
        np.testing.assert_allclose(np.asarray(fused.denoise(xt, t)),
                                   np.asarray(staged.denoise(xt, t)),
                                   rtol=1e-5, atol=5e-6)
    kinds = {k[0] for k in fused._programs}
    assert "fused_step" in kinds
    assert "fused_step" not in {k[0] for k in staged._programs}


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_matches_staged_masked(backend):
    """Traced-t masked path (the serve-plan body) with caps."""
    store, staged, fused = _pair(backend)
    for t in (800, 300):
        xt = _noisy(store, t, seed=t)
        tt = jnp.asarray(t)
        np.testing.assert_allclose(
            np.asarray(fused.denoise_masked(xt, tt)),
            np.asarray(staged.denoise_masked(xt, tt)),
            rtol=1e-5, atol=5e-6)


def test_fused_policy():
    """``use_fused``: False never fuses, True always, auto fuses the
    dense-strategy steps on a single host (a gather step touches only
    m_t rows — streaming the full store cannot beat it)."""
    store = gmm(512, dim=16, seed=0)
    dense = GoldDiffEngine(store, SCH, strategy="dense")
    gather = GoldDiffEngine(store, SCH, strategy="gather")
    t = 500
    assert dense.use_fused(t)
    assert not gather.use_fused(t)
    assert GoldDiffEngine(store, SCH, strategy="gather",
                          fused=True).use_fused(t)
    assert not GoldDiffEngine(store, SCH, strategy="dense",
                              fused=False).use_fused(t)
    with pytest.raises(ValueError, match="fused"):
        GoldDiffEngine(store, SCH, fused="yes")


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_surplus_slots_weightless(backend):
    """m > N: surplus candidate slots carry +inf and contribute zero
    weight — the posterior equals the m = N result exactly."""
    n, d = 50, 8
    kx, kq = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (n, d), jnp.float32)
    q = jax.random.normal(kq, (4, d), jnp.float32)
    out_big = ops.fused_step(q, q, x, x, 80, 10, 0.5, backend=backend)
    out_fit = ops.fused_step(q, q, x, x, n, 10, 0.5, backend=backend)
    assert np.isfinite(np.asarray(out_big)).all()
    np.testing.assert_allclose(np.asarray(out_big), np.asarray(out_fit),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_all_masked_is_finite(backend):
    """m_t = k_t = 0 (every slot masked): the clamped-logit sentinel
    keeps the softmax defined — uniform over the gathered rows, no
    NaN."""
    n, d = 64, 8
    kx, kq = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(kx, (n, d), jnp.float32)
    q = jax.random.normal(kq, (3, d), jnp.float32)
    out = ops.fused_step(q, q, x, x, 16, 4, jnp.asarray(0.5),
                         backend=backend, m_t=jnp.asarray(0),
                         k_t=jnp.asarray(0))
    assert np.isfinite(np.asarray(out)).all()


def _run_child(code: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=str(REPO), env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
    return r.stdout


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm

def maxerr(a, b):
    return float(np.abs(np.asarray(a) - np.asarray(b)).max())
"""


def test_fused_sharded_overlap_bitwise_subprocess():
    """8-device mesh: the overlap-ordered fused local step is BITWISE
    equal to the staged sharded path (identical ops, identical order —
    only collective issue order differs), and both match the single
    host to fp32 reduction order.  Uneven N exercises padded shards."""
    code = _PRELUDE + r"""
mesh = jax.make_mesh((8,), ("data",))
store = gmm(1003, dim=16, seed=0)
sch = make_schedule("ddpm_linear", 1000)
host = GoldDiffEngine(store, sch, fused=True)
sh_st = GoldDiffEngine(store, sch, mesh=mesh, fused=False)
sh_fu = GoldDiffEngine(store, sch, mesh=mesh, fused=True)
x0 = store.X[:4]
ok = True
for t in (100, 500, 900):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    bit = maxerr(sh_fu.denoise(xt, t), sh_st.denoise(xt, t))
    e_h = maxerr(sh_fu.denoise(xt, t), host.denoise(xt, t))
    tt = jnp.asarray(t)
    bit_m = maxerr(sh_fu.denoise_masked(xt, tt), sh_st.denoise_masked(xt, tt))
    print("t", t, "bitwise", bit, bit_m, "vs host", e_h)
    ok &= bit == 0.0 and bit_m == 0.0 and e_h < 1e-5
kinds = {k[0] for k in sh_fu._programs}
ok &= "fused_step" in kinds
print("PASS" if ok else "FAIL")
"""
    _run_child(code)


@pytest.mark.slow
def test_fused_2d_mesh_parity_subprocess():
    """2D (batch x store) mesh: queries shard over the batch axis,
    collectives stay on the store axis, outputs match the single host;
    an indivisible batch raises instead of silently mis-sharding."""
    code = _PRELUDE + r"""
host = None
ok = True
store = gmm(1000, dim=16, seed=0)
sch = make_schedule("ddpm_linear", 1000)
host = GoldDiffEngine(store, sch, fused=True)
x0 = store.X[:8]
for shape, names in (((2, 4), ("batch", "data")), ((4, 2), ("data", "batch"))):
    mesh = jax.make_mesh(shape, names)
    eng = GoldDiffEngine(store, sch, mesh=mesh, shard_axis="data",
                         batch_axis="batch", fused=True)
    for t in (150, 750):
        eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
        xt = sch.add_noise(x0, eps, t)
        e = maxerr(eng.denoise(xt, t), host.denoise(xt, t))
        em = maxerr(eng.denoise_masked(xt, jnp.asarray(t)),
                    host.denoise_masked(xt, jnp.asarray(t)))
        print("mesh", shape, "t", t, e, em)
        ok &= e < 1e-5 and em < 1e-5
    try:
        eng.denoise(xt[:5], 500)         # 5 % batch_shards != 0
        ok = False
    except ValueError as err:
        ok &= "batch" in str(err)
print("PASS" if ok else "FAIL")
"""
    _run_child(code)


def test_fused_warmup_zero_recompiles():
    """ServeEngine.warmup() with fused=True precompiles the fused
    program kinds: serving afterward never touches the compiler, in
    static and plan modes."""
    from repro.launch.serve import Request, ServeEngine
    for mode in ("static", "plan"):
        eng = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=5,
                          max_batch=4, mode=mode, fused=True)
        eng.warmup()
        n0 = len(eng.engine._programs)
        b0 = eng.engine._builds
        eng.serve([Request(0, 1, seed=1), Request(1, 3, seed=2),
                   Request(2, 4, seed=3)])
        assert len(eng.engine._programs) == n0, f"{mode}: cache grew"
        assert eng.engine._builds == b0, f"{mode}: recompiled"
        if mode == "static":
            assert "fused_step" in {k[0] for k in eng.engine._programs
                                    if isinstance(k, tuple)}


def test_fused_runtime_warms_mixed_segments():
    """ServeRuntime.warmup() with fused=True also precompiles every
    continuous-batching ``plan_seg_mix`` program — re-requesting them
    is a pure cache hit (build counter unchanged)."""
    from repro.launch.runtime import RuntimeConfig, ServeRuntime
    from repro.launch.serve import ServeEngine
    eng = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=5,
                      max_batch=4, mode="plan", fused=True)
    rt = ServeRuntime(eng, RuntimeConfig())
    rt.warmup()
    kinds = {k[0] for k in rt.engine._programs if isinstance(k, tuple)}
    assert "plan_seg_mix" in kinds
    b0 = rt.engine._builds
    for b in eng.batch_buckets():
        for plan in rt.plans.values():
            for pb in plan.buckets:
                rt._mixed_program(b, plan, pb, compile_only=True)
    assert rt.engine._builds == b0, "mixed segment recompiled post-warmup"
