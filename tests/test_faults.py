"""Fault-injection harness (repro/launch/faults.py): the faults must be
deterministic under a fixed seed, must actually reach the engine's
dispatch seam, and must be a provable no-op when disabled."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GoldDiffEngine, make_schedule
from repro.data import gmm
from repro.kernels import ops
from repro.launch import faults
from repro.launch.faults import (DEFAULT_TARGETS, RETRYABLE_ERRORS,
                                 FaultConfig, FaultInjector, XlaRuntimeError,
                                 injected, unit_uniform)

SCH = make_schedule("ddpm_linear", 1000)


def _engine():
    return GoldDiffEngine(gmm(256, dim=8, seed=0), SCH)


@pytest.fixture(autouse=True)
def _no_hook_leak():
    yield
    assert ops.dispatch_hook() is None, "a test leaked an installed hook"


def test_unit_uniform_deterministic_and_in_range():
    a = [unit_uniform(7, n, 3) for n in range(64)]
    assert a == [unit_uniform(7, n, 3) for n in range(64)]
    assert all(0.0 <= u < 1.0 for u in a)
    # seed, counter and salt all perturb the stream
    assert unit_uniform(7, 0, 3) != unit_uniform(8, 0, 3)
    assert unit_uniform(7, 0, 3) != unit_uniform(7, 1, 3)
    assert unit_uniform(7, 0, 3) != unit_uniform(7, 0, 4)


def test_disabled_is_identity():
    """No injector installed: engine.program returns the RAW cached
    callable — not a wrapper — and outputs are unchanged."""
    eng = _engine()
    x = jnp.zeros((2, 8))
    ref_out = np.asarray(eng.denoise(x, 500))
    assert len(eng._programs) > 0
    for k, fn in list(eng._programs.items()):
        assert eng.program(k, lambda: None) is fn       # raw, unwrapped
    np.testing.assert_array_equal(np.asarray(eng.denoise(x, 500)), ref_out)


def test_zero_rate_injector_is_behavioral_noop():
    """Installed but all rates 0: no events, no evictions, no output
    change, and the cache still stores unwrapped callables."""
    eng = _engine()
    x = jnp.ones((2, 8))
    clean = np.asarray(eng.denoise(x, 400))
    n_prog = len(eng._programs)
    with injected(FaultConfig(seed=1)) as inj:
        out = np.asarray(eng.denoise(x, 400))
    np.testing.assert_array_equal(out, clean)
    assert inj.events == []
    assert inj.dispatches == 1 and inj.lookups == 1
    assert len(eng._programs) == n_prog
    assert eng._builds == n_prog


def test_faults_reach_dispatch_seam_and_are_deterministic():
    """Same seed + same call sequence => identical event log, firing at
    the real engine.program seam (kind recorded from the key)."""
    cfg = FaultConfig(seed=42, nan_rate=0.5)

    def workload():
        eng = _engine()
        x = jnp.ones((4, 8))
        outs = []
        with injected(cfg) as inj:
            for t in (900, 600, 300, 100):
                outs.append(np.asarray(eng.denoise(x, t)))
        return inj.events, outs

    ev1, out1 = workload()
    ev2, out2 = workload()
    assert ev1 == ev2
    # the default engine fuses its dense-strategy steps, so the seam
    # records the fused program kind (a DEFAULT_TARGETS member)
    assert len(ev1) >= 1 and all(e[0] == "nan" and e[1] == "fused_step"
                                 for e in ev1)
    # the corrupted dispatches produced exactly one NaN row each
    for o1, o2 in zip(out1, out2):
        np.testing.assert_array_equal(o1, o2)
    n_nan_rows = sum(int(np.isnan(o).any(axis=1).sum()) for o in out1)
    assert n_nan_rows == len(ev1)


def test_error_and_oom_raise_retryable():
    eng = _engine()
    x = jnp.zeros((2, 8))
    with injected(FaultConfig(seed=0, error_rate=1.0)):
        with pytest.raises(RETRYABLE_ERRORS, match="transient"):
            eng.denoise(x, 500)
    with injected(FaultConfig(seed=0, oom_rate=1.0)):
        with pytest.raises(XlaRuntimeError, match="RESOURCE_EXHAUSTED"):
            eng.denoise(x, 500)
    # a fresh dispatch draws a fresh decision: rate < 1 clears on retry
    cfg = FaultConfig(seed=9, error_rate=0.5)
    with injected(cfg) as inj:
        done = False
        for _ in range(32):
            try:
                eng.denoise(x, 500)
                done = True
                break
            except RETRYABLE_ERRORS:
                continue
        assert done and any(e[0] == "error" for e in inj.events)


def test_latency_injection_sleeps():
    eng = _engine()
    x = jnp.zeros((2, 8))
    eng.denoise(x, 500)                       # compile outside the clock
    with injected(FaultConfig(seed=0, latency_rate=1.0, latency_s=0.05)):
        t0 = time.perf_counter()
        eng.denoise(x, 500)
        assert time.perf_counter() - t0 >= 0.05


def test_evict_forces_real_recompile():
    eng = _engine()
    x = jnp.zeros((2, 8))
    eng.denoise(x, 500)
    b0 = eng._builds
    with injected(FaultConfig(seed=0, evict_rate=1.0)) as inj:
        out = np.asarray(eng.denoise(x, 500))
    assert eng._builds == b0 + 1              # rebuilt, cache size unchanged
    assert any(e[0] == "evict" for e in inj.events)
    assert np.isfinite(out).all()


def test_target_kinds_filtering():
    """Kinds outside target_kinds are untouched even at rate 1.0 —
    the runtime's init-noise and Gaussian-fallback programs rely on
    this."""
    inj = FaultInjector(FaultConfig(seed=0, nan_rate=1.0, evict_rate=1.0))
    assert inj._targets(("plan_seg", 0, 3))
    assert inj._targets(("serve_scan", (4, 16)))
    for k in (("serve_keys", 4), ("serve_init", 4, 16),
              ("gauss_seg", 4, 16, 7, 3.0), ("select", 1), "not-a-tuple"):
        assert not inj._targets(k)
    assert "gauss_seg" not in DEFAULT_TARGETS
    eng = _engine()
    x = jnp.zeros((2, 8))
    with injected(FaultConfig(seed=0, error_rate=1.0,
                              target_kinds=("full_scan",))):
        out = np.asarray(eng.denoise(x, 500))  # fused kind not targeted
        assert np.isfinite(out).all()
        with pytest.raises(RETRYABLE_ERRORS):
            eng.full_scan(x, 500)


def test_install_uninstall_active():
    assert faults.active() is None
    inj = faults.install(FaultConfig(seed=1))
    assert faults.active() is inj
    faults.uninstall()
    assert faults.active() is None
