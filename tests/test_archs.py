"""Per-arch smoke tests: reduced variants of every assigned architecture.

One forward/train step + prefill/decode on CPU; asserts output shapes and
no NaNs (deliverable f).  Full configs are exercised only via the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import steps as step_lib
from repro.distributed.sharding import make_rules
from repro.models import transformer as T
from repro.models.module import init_params, param_count
from repro.models.transformer import model_specs, zero_cache
from repro.training import optimizer as opt

RULES = make_rules("none")


def _batch(cfg, b=2, s=32):
    key = jax.random.PRNGKey(0)
    f = min(cfg.frontend_tokens, 16) if cfg.frontend else 0
    toks = jax.random.randint(key, (b, s - f), 0, cfg.vocab_size, jnp.int32)
    out = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if f:
        out["embeds"] = 0.02 * jax.random.normal(key, (b, f, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.source, "every config must cite its public-pool source"
    spec = {
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced config: one full train step (fwd+bwd+AdamW), loss finite."""
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 8
    assert cfg.num_experts <= 4
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    state = opt.init_state(params)
    step = jax.jit(step_lib.make_train_step(cfg, RULES))
    batch = _batch(cfg, b=2, s=64 if not cfg.ssm_state else 32)
    params2, state2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved (warmup LR is tiny: compare exact bits)
    moved = [bool((np.asarray(a, np.float32) != np.asarray(b, np.float32)).any())
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))]
    assert all(moved), f"{sum(moved)}/{len(moved)} leaves updated"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    """Reduced config: prefill then one decode step; logits finite/shaped."""
    cfg = get_smoke_config(arch)
    b, s = 2, 32
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(1))
    f = min(cfg.frontend_tokens, 16) if cfg.frontend else 0
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s - f), 0,
                              cfg.vocab_size, jnp.int32)
    emb = (0.02 * jax.random.normal(jax.random.PRNGKey(3),
                                    (b, f, cfg.d_model), jnp.float32)
           if f else None)
    logits, cache = T.prefill(cfg, params, toks, emb)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    # decode against a fresh length-s cache (as the dry-run shape does)
    cache = zero_cache(cfg, b, s)
    lg, cache2 = T.decode_step(cfg, params, cache, toks[:, 0],
                               jnp.asarray(s - 1, jnp.int32))
    assert lg.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(lg).all())
    # attention caches got updated in place at pos
    for key, leaf in cache2.items():
        tree = jax.tree.leaves(leaf)
        assert all(bool(jnp.isfinite(x).all()) for x in tree)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "jamba-v0.1-52b",
                                  "musicgen-medium"])
def test_smoke_golden_vs_full_decode(arch):
    """Golden decode attention ~= full attention when blocks cover cache."""
    cfg = get_smoke_config(arch)
    b, s = 2, 64
    cfg_full = dataclasses.replace(cfg, attn_kind_decode="full")
    cfg_gold = dataclasses.replace(cfg, attn_kind_decode="golden",
                                   golden_blocks=4, golden_block_size=16)
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(4))
    cache = zero_cache(cfg, b, s)
    # fill cache with random values so attention is nontrivial
    cache = jax.tree.map(
        lambda x: 0.1 * jax.random.normal(jax.random.PRNGKey(5), x.shape,
                                          jnp.float32).astype(x.dtype), cache)
    tok = jnp.zeros((b,), jnp.int32)
    pos = jnp.asarray(s - 1, jnp.int32)
    lg_f, _ = T.decode_step(cfg_full, params, cache, tok, pos)
    lg_g, _ = T.decode_step(cfg_gold, params, cache, tok, pos)
    # 4 blocks x 16 = full 64-token coverage -> identical
    np.testing.assert_allclose(np.asarray(lg_g, np.float32),
                               np.asarray(lg_f, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_scale():
    """Full configs approximate their nameplate sizes (sanity, no alloc)."""
    approx = {"qwen2.5-32b": 33e9, "qwen2-7b": 7.6e9, "llama3.2-3b": 3.6e9,
              "dbrx-132b": 132e9, "mamba2-2.7b": 2.7e9,
              "starcoder2-3b": 3.2e9, "musicgen-medium": 1.5e9,
              "internvl2-1b": 0.8e9, "phi3.5-moe-42b-a6.6b": 42e9,
              "jamba-v0.1-52b": 52e9}
    for arch, expect in approx.items():
        n = param_count(model_specs(get_config(arch)))
        assert 0.55 * expect < n < 1.7 * expect, f"{arch}: {n:.2e} vs {expect:.2e}"
