"""Streamed one-pass screening == lax.top_k semantics, on every backend.

Property tests for ``ops.screen_topm`` / ``kernels.screen`` (tied
distances, ``m >= N`` edge cases, ragged tile remainders) plus
regressions pinning that routing the engine's coarse stage, masked
path, full scan, and sharded screen through the streamed form leaves
every output unchanged.

Integer-valued inputs make the distance arithmetic exact in fp32, so
the streamed result must equal the materialized oracle BIT-FOR-BIT
including tie order (carry-first merge == lax.top_k's lowest-index-wins
rule).  Float inputs get tolerance on distances (XLA blocks GEMMs
differently per shape, so last-ulp wiggle is expected) and exact
candidate-set equality away from ties.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (GoldDiff, GoldDiffConfig, GoldDiffEngine,
                        OptimalDenoiser, make_schedule)
from repro.data import gmm
from repro.kernels import ops, ref

SCH = make_schedule("ddpm_linear", 1000)

BACKENDS = ["xla", "pallas_interpret"]
if any(d.platform == "tpu" for d in jax.devices()):
    BACKENDS.append("pallas")


def _int_data(key, b, n, d, lo=-4, hi=5):
    kq, kx = jax.random.split(jax.random.PRNGKey(key))
    q = jax.random.randint(kq, (b, d), lo, hi).astype(jnp.float32)
    x = jax.random.randint(kx, (n, d), lo, hi).astype(jnp.float32)
    return q, x


def _assert_matches_oracle(q, x, m, backend, **kw):
    ri, rd = ref.screen_topm_ref(q, x, m)
    si, sd = ops.screen_topm(q, x, m, backend=backend, **kw)
    # distances equal everywhere (+inf marks the same surplus slots)...
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(rd))
    # ...and indices equal on every real slot, including tie order
    fin = np.isfinite(np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(si)[fin], np.asarray(ri)[fin])
    # surplus (m > N) slots stay gather-safe: in-range indices
    assert np.asarray(si).min() >= 0
    assert np.asarray(si).max() < x.shape[0]


@settings(max_examples=10)
@given(st.integers(0, 10 ** 6), st.integers(1, 400), st.integers(1, 450),
       st.integers(4, 200))
def test_screen_topm_property(seed, n, m, tile):
    """Streamed == materialized oracle for arbitrary (n, m, tile) —
    small integer coordinates force MANY exact distance ties; m may
    exceed n."""
    q, x = _int_data(seed, 3, n, 8)
    for backend in BACKENDS:
        _assert_matches_oracle(q, x, m, backend, tile=tile)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,m,tile", [
    (1000, 64, 256),     # plain streaming
    (1000, 64, 1024),    # single tile covers everything
    (100, 100, 32),      # m == N
    (50, 80, 16),        # m > N: surplus slots +inf, clamped indices
    (4097, 7, 512),      # ragged final tile
    (16, 1, 8),          # m == 1
])
def test_screen_topm_shapes(backend, n, m, tile):
    q, x = _int_data(7, 5, n, 16)
    _assert_matches_oracle(q, x, m, backend, tile=tile)


@pytest.mark.parametrize("n,m,tile", [
    (1000, 64, 128),     # many tiles, deep merge tree
    (1000, 64, 250),     # ragged final tile, odd level-0 count
    (999, 30, 100),      # odd tile count at every tree level
    (1200, 1500, 256),   # m > N: surplus slots survive the tree
])
def test_screen_topm_hier_matches_oracle(n, m, tile):
    """The opt-in two-level hierarchical merge (per-tile top-m + tree
    reduce) is bit-identical to the oracle AND to the default carry,
    including lowest-index tie order (integer data forces ties)."""
    from repro.kernels.screen import screen_topm_scan
    q, x = _int_data(11, 4, n, 8)
    ri, rd = ref.screen_topm_ref(q, x, m)
    hi_, hd = screen_topm_scan(q, x, m, tile=tile, hier=True)
    ci, cd = screen_topm_scan(q, x, m, tile=tile)
    np.testing.assert_array_equal(np.asarray(hd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(hd), np.asarray(cd))
    fin = np.isfinite(np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(hi_)[fin], np.asarray(ri)[fin])
    np.testing.assert_array_equal(np.asarray(hi_)[fin], np.asarray(ci)[fin])
    assert np.asarray(hi_).min() >= 0 and np.asarray(hi_).max() < n


@pytest.mark.parametrize("backend", BACKENDS)
def test_screen_topm_all_tied(backend):
    """Fully degenerate store (every distance identical): the streamed
    selection must reproduce lax.top_k's lowest-index-first order."""
    x = jnp.ones((40, 4))
    q = jnp.zeros((2, 4))
    ri, rd = ref.screen_topm_ref(q, x, 12)
    si, sd = ops.screen_topm(q, x, 12, backend=backend, tile=8)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(rd))


def test_screen_topm_float_parity():
    """Float data: distances allclose; candidate sets identical."""
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (6, 24))
    x = jax.random.normal(kx, (2000, 24))
    ri, rd = ref.screen_topm_ref(q, x, 128)
    for backend in BACKENDS:
        si, sd = ops.screen_topm(q, x, 128, backend=backend, tile=512)
        np.testing.assert_allclose(np.asarray(sd), np.asarray(rd),
                                   rtol=1e-5, atol=1e-5)
        for i in range(q.shape[0]):
            assert set(np.asarray(si)[i]) == set(np.asarray(ri)[i])


def test_screen_topm_padded_rows_excluded():
    """+inf norms (the sharded layouts' padding convention) never screen
    in: their slots carry +inf distance markers."""
    q, x = _int_data(3, 4, 64, 8)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, -1).at[50:].set(jnp.inf)
    for backend in BACKENDS:
        idx, d2 = ops.screen_topm(q, x, 60, x_norms=xn, backend=backend,
                                  tile=16)
        idx, d2 = np.asarray(idx), np.asarray(d2)
        assert (idx[np.isfinite(d2)] < 50).all()
        assert (~np.isfinite(d2)).sum(-1).min() >= 10  # 14 real rows short
        assert np.isfinite(d2[:, :50]).all()


def test_full_scan_stream_matches_dense():
    """Streaming LSE full scan == dense [B, N]-logits aggregate, and the
    partial states LSE-merge to the same mean."""
    kq, kx = jax.random.split(jax.random.PRNGKey(1))
    q = jax.random.normal(kq, (4, 16))
    x = jax.random.normal(kx, (777, 16))
    for sig2 in (0.05, 0.7, 4.0):
        dense = np.asarray(ref.golden_aggregate_ref(q, x, sig2))
        stream = np.asarray(ops.golden_aggregate(
            q, x, sig2, backend="xla", stream=True, tile=128))
        np.testing.assert_allclose(stream, dense, rtol=1e-5, atol=1e-5)
        acc_s, m_s, l_s = ops.golden_full_partial(q, x, sig2, stream=True,
                                                  tile=100)   # ragged tail
        acc_d, m_d, l_d = ops.golden_full_partial(q, x, sig2, stream=False)
        np.testing.assert_allclose(np.asarray(m_s), np.asarray(m_d),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(acc_s / l_s[:, None]),
                                   np.asarray(acc_d / l_d[:, None]),
                                   rtol=1e-5, atol=1e-5)


# -- engine regressions: streaming must not change any output ----------------

@pytest.fixture(scope="module")
def gmm_setup():
    store = gmm(700, dim=16, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
    return store, x


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_streamed_parity(gmm_setup, backend):
    """denoise / select / full_scan identical whichever screen mode the
    engine compiles."""
    store, x = gmm_setup
    ref_eng = GoldDiffEngine(store, SCH, GoldDiffConfig(), backend=backend,
                             screen="materialized")
    st_eng = GoldDiffEngine(store, SCH, GoldDiffConfig(), backend=backend,
                            screen="streamed", screen_tile=128)
    for t in (800, 300, 50):
        np.testing.assert_allclose(
            np.asarray(st_eng.denoise(x, t)),
            np.asarray(ref_eng.denoise(x, t)), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(st_eng.full_scan(x, t)),
            np.asarray(ref_eng.full_scan(x, t)), rtol=2e-4, atol=2e-4)
        np.testing.assert_array_equal(
            np.sort(np.asarray(st_eng.select(x, t)), -1),
            np.sort(np.asarray(ref_eng.select(x, t)), -1))


def test_masked_streamed_parity(gmm_setup):
    """Masked (scan/pjit) mode unchanged when screening is streamed."""
    store, x = gmm_setup
    gd_ref = GoldDiff(OptimalDenoiser(store, SCH), screen="materialized")
    gd_st = GoldDiff(OptimalDenoiser(store, SCH), screen="streamed",
                     screen_tile=96)
    for t in (900, 400, 50):
        np.testing.assert_allclose(
            np.asarray(gd_st.call_masked(x, jnp.asarray(t))),
            np.asarray(gd_ref.call_masked(x, jnp.asarray(t))),
            rtol=2e-4, atol=2e-4)


def test_streamed_cache_keys_distinct(gmm_setup):
    """Streamed and materialized programs never collide in the cache,
    and the tile size is part of the streamed program's identity."""
    store, x = gmm_setup
    st_eng = GoldDiffEngine(store, SCH, GoldDiffConfig(),
                            screen="streamed", screen_tile=128)
    mat_eng = GoldDiffEngine(store, SCH, GoldDiffConfig(),
                             screen="materialized")
    k_st = st_eng._key("denoise", 500, x)
    k_mat = mat_eng._key("denoise", 500, x)
    assert k_st != k_mat
    assert ("screen", "streamed", 128) in k_st
    assert ("screen", "materialized") in k_mat
    st_eng2 = GoldDiffEngine(store, SCH, GoldDiffConfig(),
                             screen="streamed", screen_tile=256)
    assert st_eng2._key("denoise", 500, x) != k_st


def test_engine_rejects_unknown_screen_mode(gmm_setup):
    store, _ = gmm_setup
    with pytest.raises(ValueError):
        GoldDiffEngine(store, SCH, screen="lazy")


def test_auto_crossover_policy(gmm_setup):
    """auto == materialized below the byte budget, streamed above it."""
    store, _ = gmm_setup
    eng = GoldDiffEngine(store, SCH, GoldDiffConfig())
    assert not eng.use_stream(8)                   # tiny store: dense
    eng._screen_budget = 4 * 8 * store.n - 1
    assert eng.use_stream(8)                       # budget crossed
    assert not eng.use_stream(8, n=4)              # local-n override


def test_sharded_streamed_parity_subprocess():
    """Sharded engine outputs unchanged (vs the single-host MATERIALIZED
    engine) when every shard-local screen streams — the candidate
    partition and two-stage merge are unaffected by how the local top-m
    is computed."""
    import os
    import subprocess
    import sys
    from pathlib import Path
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm

def relerr(a, b):
    return np.abs(np.asarray(a) - np.asarray(b)).max() / \
        (np.abs(np.asarray(b)).max() + 1e-9)

mesh = jax.make_mesh((8,), ("data",))
store = gmm(1003, dim=16, seed=0)            # uneven N % 8: padded tails
sch = make_schedule("ddpm_linear", 1000)
ref = GoldDiffEngine(store, sch, GoldDiffConfig(), screen="materialized")
sh = GoldDiffEngine(store, sch, GoldDiffConfig(), mesh=mesh,
                    screen="streamed", screen_tile=64)
x0 = store.X[:4]
ok = True
for t in (100, 500, 900):
    eps = jax.random.normal(jax.random.PRNGKey(t), x0.shape)
    xt = sch.add_noise(x0, eps, t)
    e1 = relerr(sh.denoise(xt, t), ref.denoise(xt, t))
    e2 = relerr(sh.denoise_masked(xt, jnp.asarray(t)),
                ref.denoise_masked(xt, jnp.asarray(t)))
    e3 = relerr(sh.full_scan(xt, t), ref.full_scan(xt, t))
    a, b = np.asarray(sh.select(xt, t)), np.asarray(ref.select(xt, t))
    ov = np.mean([len(set(a[i]) & set(b[i])) / a.shape[1]
                  for i in range(a.shape[0])])
    print("t", t, e1, e2, e3, ov)
    ok &= e1 < 1e-5 and e2 < 1e-5 and e3 < 1e-5 and ov == 1.0
print("PASS" if ok else "FAIL")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    repo = str(Path(__file__).resolve().parent.parent)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=repo, env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
