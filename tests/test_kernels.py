"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.golden_attention import select_golden_blocks


@pytest.mark.parametrize("b,n,d", [(1, 16, 8), (7, 100, 32), (37, 1000, 96),
                                   (128, 257, 64), (4, 4096, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdist_sweep(b, n, d, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, d), dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d), dtype)
    out = ops.pdist(q, x)
    expect = ref.pdist_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n,d,sigma2", [
    (1, 64, 8, 1.0), (5, 500, 32, 0.25), (16, 1000, 64, 4.0),
    (3, 130, 16, 0.01),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_golden_aggregate_sweep(b, n, d, sigma2, dtype):
    q = jax.random.normal(jax.random.PRNGKey(2), (b, d), dtype)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d), dtype)
    out = ops.golden_aggregate(q, x, sigma2)
    expect = ref.golden_aggregate_ref(q, x, sigma2)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_golden_aggregate_matches_optimal_denoiser():
    """Kernel == the core library's full-scan posterior mean (Eq. 2)."""
    from repro.core import OptimalDenoiser, make_schedule
    from repro.data import gmm
    store = gmm(512, dim=16, seed=0)
    sch = make_schedule("ddpm_linear", 1000)
    den = OptimalDenoiser(store, sch)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16))
    t = 300
    a = float(sch.a[t])
    out_k = ops.golden_aggregate(x / a, store.X, float(sch.sigma(t)) ** 2)
    out_d = den(x, t)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,hkv,g,dh,s,bs,kb", [
    (1, 1, 1, 32, 256, 64, 2), (2, 4, 3, 64, 1024, 128, 5),
    (3, 2, 8, 64, 512, 128, 4), (2, 8, 1, 128, 2048, 256, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_golden_attention_sweep(b, hkv, g, dh, s, bs, kb, dtype):
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (b, hkv, g, dh), dtype)
    k = jax.random.normal(keys[1], (b, hkv, s, dh), dtype)
    v = jax.random.normal(keys[2], (b, hkv, s, dh), dtype)
    idx, valid = select_golden_blocks(q, k, kb, bs)
    valid = valid.at[:, :, -1].set(0)           # exercise padding mask
    out = ops.golden_attention_decode(q, k, v, idx, valid, bs)
    expect = ref.golden_attention_decode_ref(q, k, v, idx, valid, bs)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_golden_attention_full_blocks_equals_dense():
    """Selecting ALL blocks reproduces exact attention."""
    b, hkv, g, dh, s, bs = 2, 2, 2, 32, 512, 64
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(keys[0], (b, hkv, g, dh))
    k = jax.random.normal(keys[1], (b, hkv, s, dh))
    v = jax.random.normal(keys[2], (b, hkv, s, dh))
    nb = s // bs
    idx = jnp.tile(jnp.arange(nb)[None, None], (b, hkv, 1)).astype(jnp.int32)
    valid = jnp.ones_like(idx)
    out = ops.golden_attention_decode(q, k, v, idx, valid, bs)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) / dh ** 0.5
    dense = jnp.einsum("bhgs,bhsd->bhgd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_xla_backend_dispatch():
    q = jax.random.normal(jax.random.PRNGKey(7), (3, 16))
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 16))
    np.testing.assert_allclose(
        np.asarray(ops.pdist(q, x, backend="xla")),
        np.asarray(ops.pdist(q, x)), rtol=1e-4, atol=1e-4)
