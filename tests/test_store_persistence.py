"""Crash-safe persistence suite: the shared atomic artifact writer,
golden-index save/load validation, checkpoint consolidation, and every
on-disk corruption regime (``faults.corrupt_store``) surfacing as a
TYPED load error — never silent garbage.
"""
import json
import os

import numpy as np
import pytest

from repro.data import gmm
from repro.index import (StoreCorruptionError, StoreVersionError,
                         build_index, load_index, save_index,
                         validate_index)
from repro.launch.faults import STORE_CORRUPTIONS, corrupt_store
from repro.training import checkpoint
from repro.utils import atomic


@pytest.fixture(scope="module")
def small_index():
    store = gmm(512, dim=16, seed=3)
    return store, build_index(store, num_clusters=8)


# -- atomic writer ------------------------------------------------------------

def test_atomic_write_leaves_no_temp_files(tmp_path):
    p = tmp_path / "artifact.bin"
    atomic.atomic_write_bytes(str(p), b"payload")
    assert p.read_bytes() == b"payload"
    leftovers = [f for f in os.listdir(tmp_path) if f != "artifact.bin"]
    assert leftovers == []


def test_save_arrays_manifest_checksums(tmp_path):
    p = str(tmp_path / "arr.npz")
    arrays = {"a": np.arange(6, dtype=np.float32).reshape(2, 3)}
    atomic.save_arrays(p, arrays, fmt="test-fmt", version=1)
    with open(p + ".manifest.json") as f:
        m = json.load(f)
    assert m["format"] == "test-fmt" and m["format_version"] == 1
    assert m["arrays"]["a"]["sha256"] == atomic.sha256_hex(
        np.ascontiguousarray(arrays["a"]).tobytes())
    out, _ = atomic.load_arrays(p, fmt="test-fmt", version=1)
    np.testing.assert_array_equal(out["a"], arrays["a"])


def test_load_arrays_missing_manifest_is_typed(tmp_path):
    p = str(tmp_path / "arr.npz")
    atomic.save_arrays(p, {"a": np.zeros(3)}, fmt="f", version=1)
    os.remove(p + ".manifest.json")
    with pytest.raises(atomic.ArtifactCorruptionError):
        atomic.load_arrays(p, fmt="f", version=1)


def test_load_arrays_wrong_format_is_typed(tmp_path):
    p = str(tmp_path / "arr.npz")
    atomic.save_arrays(p, {"a": np.zeros(3)}, fmt="f", version=1)
    with pytest.raises(atomic.ArtifactCorruptionError):
        atomic.load_arrays(p, fmt="other", version=1)


# -- golden-index save/load (satellite 1) -------------------------------------

def test_index_roundtrip_bit_identical(small_index, tmp_path):
    _, index = small_index
    p = str(tmp_path / "index.npz")
    save_index(index, p)
    loaded = load_index(p)
    assert loaded.max_cluster == index.max_cluster
    for f in ("centroids", "centroid_norms", "perm", "offsets",
              "proxy_sorted", "proxy_norms_sorted"):
        np.testing.assert_array_equal(np.asarray(getattr(loaded, f)),
                                      np.asarray(getattr(index, f)))


@pytest.mark.parametrize("kind", STORE_CORRUPTIONS)
def test_index_corruption_regimes_are_typed(small_index, tmp_path, kind):
    """Every corruption regime loads as StoreCorruptionError /
    StoreVersionError — the acceptance contract for damaged artifacts."""
    _, index = small_index
    p = str(tmp_path / "index.npz")
    save_index(index, p)
    corrupt_store(p, kind, seed=7)
    expected = (StoreVersionError if kind == "stale_manifest"
                else StoreCorruptionError)
    with pytest.raises(expected):
        load_index(p)


def test_index_missing_array_is_typed(small_index, tmp_path):
    _, index = small_index
    p = str(tmp_path / "index.npz")
    save_index(index, p)
    # drop one array from the npz, leave the manifest stale
    data = dict(np.load(p))
    del data["perm"]
    np.savez(p, **data)
    with pytest.raises(StoreCorruptionError):
        load_index(p)


def _fields(index):
    return {f: np.asarray(getattr(index, f)) for f in
            ("centroids", "centroid_norms", "perm", "offsets",
             "proxy_sorted", "proxy_norms_sorted")}


def test_validate_index_rejects_unsorted_offsets(small_index):
    _, index = small_index
    f = _fields(index)
    f["offsets"] = f["offsets"].copy()
    f["offsets"][1], f["offsets"][2] = f["offsets"][2], f["offsets"][1]
    with pytest.raises(StoreCorruptionError, match="not sorted"):
        validate_index(f, index.max_cluster)


def test_validate_index_rejects_bad_span(small_index):
    _, index = small_index
    f = _fields(index)
    f["offsets"] = f["offsets"].copy()
    f["offsets"][-1] += 1
    with pytest.raises(StoreCorruptionError, match="span"):
        validate_index(f, index.max_cluster)


def test_validate_index_rejects_small_max_cluster(small_index):
    _, index = small_index
    with pytest.raises(StoreCorruptionError, match="max_cluster"):
        validate_index(_fields(index), 1)


def test_validate_index_rejects_duplicate_perm(small_index):
    _, index = small_index
    f = _fields(index)
    f["perm"] = f["perm"].copy()
    f["perm"][1] = f["perm"][0]
    with pytest.raises(StoreCorruptionError, match="bijection"):
        validate_index(f, index.max_cluster)


def test_validate_index_rejects_out_of_range_perm(small_index):
    _, index = small_index
    f = _fields(index)
    f["perm"] = f["perm"].copy()
    f["perm"][0] = index.n + 5
    with pytest.raises(StoreCorruptionError, match="out-of-range"):
        validate_index(f, index.max_cluster)


def test_validate_index_rejects_nan_norms(small_index):
    _, index = small_index
    f = _fields(index)
    f["proxy_norms_sorted"] = f["proxy_norms_sorted"].copy()
    f["proxy_norms_sorted"][0] = np.nan
    with pytest.raises(StoreCorruptionError, match="NaN"):
        validate_index(f, index.max_cluster)


def test_validate_index_rejects_float_perm(small_index):
    _, index = small_index
    f = _fields(index)
    f["perm"] = f["perm"].astype(np.float32)
    with pytest.raises(StoreCorruptionError, match="integer"):
        validate_index(f, index.max_cluster)


# -- training checkpoints ride the same writer (satellite 2) ------------------

def _tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones(4, np.float32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 3, _tree())
    assert checkpoint.latest_step(d) == 3
    out = checkpoint.restore(d, 3, _tree())
    np.testing.assert_array_equal(np.asarray(out["w"]), _tree()["w"])


@pytest.mark.parametrize("kind", STORE_CORRUPTIONS)
def test_checkpoint_corruption_is_typed(tmp_path, kind):
    """Checkpoints use the SAME atomic writer, so the same corruption
    regimes surface as the same typed errors (consolidation guarantee,
    not a parallel bespoke format)."""
    d = str(tmp_path / "ckpt")
    step_dir = checkpoint.save(d, 1, _tree())
    npz = str(step_dir / "arrays.npz")
    if kind == "stale_manifest":
        # checkpoints keep their manifest under <dir>/manifest.json
        # (corrupt_store's sidecar convention doesn't apply here)
        with open(step_dir / "manifest.json") as f:
            m = json.load(f)
        m["format_version"] = int(m["format_version"]) + 1
        with open(step_dir / "manifest.json", "w") as f:
            json.dump(m, f)
        expected = checkpoint.CheckpointVersionError
    else:
        corrupt_store(npz, kind, seed=11)
        expected = checkpoint.CheckpointCorruptionError
    with pytest.raises(expected):
        checkpoint.restore(d, 1, _tree())


def test_checkpoint_key_mismatch_is_typed(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, _tree())
    other = {"w": np.zeros((3, 4), np.float32),
             "extra": np.zeros(2, np.float32)}
    with pytest.raises(checkpoint.CheckpointCorruptionError,
                       match="key mismatch"):
        checkpoint.restore(d, 1, other)
