"""GoldDiffEngine backend/dtype parity: xla == pallas_interpret == eager.

The engine routes the coarse -> fine -> aggregate pipeline through
``repro.kernels.ops`` with two execution strategies (dense GEMM form on
``xla``, tiled gather kernels on ``pallas*``).  These tests pin all of
them to the plain eager-jnp formulation the seed used (gather +
broadcast-subtract + recompute), for every stage and end-to-end, in
fp32 and bf16 storage.

The real-TPU ``pallas`` backend is exercised automatically when a TPU
platform is present (it cannot lower on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GoldDiff, GoldDiffConfig, GoldDiffEngine,
                        OptimalDenoiser, make_schedule)
from repro.core.dataset import downsample_proxy
from repro.core.golddiff import coarse_screen, golden_select
from repro.kernels import ops
from repro.data import cifar_like, gmm

SCH = make_schedule("ddpm_linear", 1000)

BACKENDS = ["xla", "pallas_interpret"]
if any(d.platform == "tpu" for d in jax.devices()):
    BACKENDS.append("pallas")


def _eager_coarse(store, q, m, factor):
    """The seed's inline coarse screen (broadcast proxy distances)."""
    q_img = q.reshape(q.shape[:-1] + tuple(store.image_shape))
    qp = downsample_proxy(q_img, factor)
    d2 = (jnp.sum(qp * qp, -1, keepdims=True) + store.proxy_norms[None, :]
          - 2.0 * qp @ store.proxy.T)
    return jax.lax.top_k(-d2, m)[1]


def _eager_step(store, sch, cfg, x_t, t):
    """The seed GoldDiff static step: gather + broadcast-subtract,
    distances recomputed in the aggregation stage."""
    from repro.core.engine import schedule_sizes
    m_t, k_t = schedule_sizes(cfg, sch, t, store.n)
    a = float(sch.a[t])
    sig2 = float(sch.sigma_np(t)) ** 2
    q = x_t / a
    cand = _eager_coarse(store, q, m_t, cfg.proxy_factor)
    xs = store.X[cand]
    d2 = jnp.sum((q[:, None, :] - xs) ** 2, -1)
    pos = jax.lax.top_k(-d2, k_t)[1]
    idx = jnp.take_along_axis(cand, pos, -1)
    xs_k = store.X[idx]
    d2k = jnp.sum((q[:, None, :] - xs_k) ** 2, -1)
    w = jax.nn.softmax(-d2k / (2.0 * sig2), -1)
    return jnp.einsum("bk,bkd->bd", w, xs_k)


@pytest.fixture(scope="module")
def image_setup():
    store = cifar_like(512, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(0), (6, store.dim))
    return store, x


@pytest.fixture(scope="module")
def gmm_setup():
    store = gmm(512, dim=16, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
    return store, x


@pytest.mark.parametrize("backend", BACKENDS)
def test_coarse_screen_parity(image_setup, backend):
    store, x = image_setup
    m = 128
    eager = _eager_coarse(store, x, m, 4)
    got = coarse_screen(store, x, m, 4, backend=backend)
    assert np.array_equal(np.sort(np.asarray(got), -1),
                          np.sort(np.asarray(eager), -1))


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_rerank_parity(gmm_setup, backend):
    store, x = gmm_setup
    b = x.shape[0]
    cand = jnp.tile(jnp.arange(256)[None], (b, 1))
    idx, d2 = ops.golden_rerank(x, store.X, cand, 32,
                                x_norms=store.x_norms, backend=backend)
    # eager oracle: broadcast-subtract distances, top-k
    d2_all = jnp.sum((x[:, None] - store.X[cand]) ** 2, -1)
    neg, pos = jax.lax.top_k(-d2_all, 32)
    assert np.array_equal(np.sort(np.asarray(idx), -1),
                          np.sort(np.asarray(
                              jnp.take_along_axis(cand, pos, -1)), -1))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(-neg),
                               rtol=1e-4, atol=1e-4)


def test_golden_select_matches_eager(gmm_setup):
    store, x = gmm_setup
    cand = jnp.tile(jnp.arange(store.n)[None], (x.shape[0], 1))
    for backend in BACKENDS:
        idx = golden_select(store, x, cand, 24, backend=backend)
        d2 = jnp.sum((x[:, None] - store.X[None]) ** 2, -1)
        ref = jax.lax.top_k(-d2, 24)[1]
        assert np.array_equal(np.sort(np.asarray(idx), -1),
                              np.sort(np.asarray(ref), -1))


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_support_aggregate_parity(gmm_setup, backend):
    store, x = gmm_setup
    b = x.shape[0]
    idx = jnp.argsort(jax.random.normal(jax.random.PRNGKey(2),
                                        (b, store.n)), -1)[:, :40]
    d2 = jnp.sum((x[:, None] - store.X[idx]) ** 2, -1)
    lg = -d2 / 0.7
    out = ops.golden_support_aggregate(store.X, idx, lg, backend=backend)
    w = jax.nn.softmax(lg, -1)
    eager = jnp.einsum("bk,bkd->bd", w, store.X[idx])
    np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_scan_parity(gmm_setup, backend):
    store, x = gmm_setup
    den = OptimalDenoiser(store, SCH, backend=backend)
    t = 300
    out = den(x, t)
    lg = den.logits(x, t)
    eager = jnp.einsum("bn,nd->bd", jax.nn.softmax(lg, -1), store.X)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("storage", [None, jnp.bfloat16])
def test_golddiff_call_end_to_end_parity(image_setup, backend, storage):
    store, x = image_setup
    cfg = GoldDiffConfig()
    gd = GoldDiff(OptimalDenoiser(store, SCH), cfg, backend=backend,
                  storage_dtype=storage)
    for t in (800, 300):
        out = np.asarray(gd(x, t), np.float32)
        eager = np.asarray(_eager_step(store, SCH, cfg, x, t))
        tol = 5e-2 if storage == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(out, eager, rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("storage", [None, jnp.bfloat16])
def test_call_masked_end_to_end_parity(gmm_setup, backend, storage):
    store, x = gmm_setup
    gd = GoldDiff(OptimalDenoiser(store, SCH), backend=backend,
                  storage_dtype=storage)
    ref = GoldDiff(OptimalDenoiser(store, SCH))      # xla fp32 baseline
    for t in (900, 400, 50):
        out = np.asarray(gd.call_masked(x, jnp.asarray(t)), np.float32)
        base = np.asarray(ref.call_masked(x, jnp.asarray(t)), np.float32)
        tol = 5e-2 if storage == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(out, base, rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
def test_on_support_parity(gmm_setup, backend):
    """Explicit support= path (the plug-in hook) across backends."""
    store, x = gmm_setup
    den = OptimalDenoiser(store, SCH, backend=backend)
    idx = jnp.argsort(jax.random.normal(jax.random.PRNGKey(3),
                                        (x.shape[0], store.n)), -1)[:, :30]
    t = 200
    out = den(x, t, support=idx)
    a = float(SCH.a[t])
    sig2 = float(SCH.sigma_np(t)) ** 2
    q = x / a
    d2 = jnp.sum((q[:, None] - store.X[idx]) ** 2, -1)
    w = jax.nn.softmax(-d2 / (2 * sig2), -1)
    eager = jnp.einsum("bk,bkd->bd", w, store.X[idx])
    np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                               rtol=1e-4, atol=1e-4)


def test_engine_program_cache_reuse(gmm_setup):
    """One compiled program per (kind, t, shape, dtype, backend)."""
    store, x = gmm_setup
    eng = GoldDiffEngine(store, SCH, GoldDiffConfig(), backend="xla")
    eng.denoise(x, 500)
    n0 = len(eng._programs)
    eng.denoise(x, 500)                               # hit
    assert len(eng._programs) == n0
    eng.denoise(x, 100)                               # new t -> new program
    eng.denoise(x[:2], 500)                           # new shape -> new program
    assert len(eng._programs) == n0 + 2


def test_engine_rejects_unknown_backend(gmm_setup):
    store, _ = gmm_setup
    with pytest.raises(ValueError):
        GoldDiffEngine(store, SCH, backend="cuda")


def test_masked_distances_computed_once(gmm_setup, monkeypatch):
    """The masked path must call the exact-distance op exactly once per
    step (the seed computed candidate distances twice)."""
    store, x = gmm_setup
    gd = GoldDiff(OptimalDenoiser(store, SCH))
    calls = {"n": 0}
    orig = ops.support_distances

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr("repro.core.engine.ops.support_distances", counting)
    gd.call_masked(x, jnp.asarray(300))
    assert calls["n"] == 1, calls
