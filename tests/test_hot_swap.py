"""Zero-downtime hot-swap: the engine's epoch machinery (operands as
arguments, not baked constants) and the serving runtime's swap protocol
(probe -> flip -> GC, in-flight waves pinned to their admission epoch).
The slow subprocess test is the acceptance guard: a mid-request swap
under ``jax.log_compiles`` with zero compiles and exactly-once
delivery (the CI chaos job runs it by file, so -m filters don't
apply).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GoldDiffConfig, GoldDiffEngine, make_schedule
from repro.data import gmm
from repro.index import IngestConfig, StoreLifecycle, build_index
from repro.index.schedule import ProbeSchedule
from repro.launch.runtime import (EpochProbeError, RuntimeConfig,
                                  ServeRuntime)
from repro.launch.serve import Request, ServeEngine

REPO = Path(__file__).resolve().parent.parent


def grow(lc, b, seed):
    """Append ``b`` fresh rows and commit: the next epoch's view."""
    rows = np.random.default_rng(seed).normal(
        size=(b, lc.dim)).astype(np.float32)
    lc.append(rows)
    lc.commit()
    return lc.view()


@pytest.fixture(scope="module")
def swap_env(tmp_path_factory):
    root = tmp_path_factory.mktemp("swap_store")
    store = gmm(512, dim=16, seed=3)._replace(labels=None)
    index = build_index(store, num_clusters=8)
    lc = StoreLifecycle.create(str(root), store, index, IngestConfig())
    ds0, ix0 = lc.view()
    eng = GoldDiffEngine(ds0, make_schedule("ddpm_linear", 1000),
                         GoldDiffConfig(), index=ix0, index_mode="always",
                         probe_schedule=ProbeSchedule())
    return {"lc": lc, "eng": eng, "ds0": ds0, "ix0": ix0}


# -- engine-level epoch machinery ---------------------------------------------

def test_epoch_swap_sequence(swap_env):
    """The whole engine-side lifecycle in admission order: install a
    grown epoch, flip, serve it with ZERO new compiles, pin back to the
    old epoch bit-identically, then retire."""
    eng, lc = swap_env["eng"], swap_env["lc"]
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 16)).astype(np.float32))
    y0 = np.asarray(eng.denoise(x, 300))           # compiles once
    assert np.isfinite(y0).all()

    ds1, ix1 = grow(lc, 48, seed=42)
    builds = eng._builds
    eng.install_epoch(1, ds1, ix1)
    eng.set_serving_epoch(1)
    y1 = np.asarray(eng.denoise(x, 300))
    assert eng._builds == builds                    # zero-compile swap
    assert np.isfinite(y1).all()
    assert not np.array_equal(y0, y1)               # new rows are live

    with eng.at_epoch(0):                           # in-flight pinning
        y0_again = np.asarray(eng.denoise(x, 300))
    assert eng._builds == builds
    np.testing.assert_array_equal(y0, y0_again)

    with pytest.raises(ValueError, match="serving"):
        eng.retire_epoch(1)
    eng.retire_epoch(0)
    assert sorted(eng._epochs) == [1]
    with pytest.raises(KeyError):
        eng.set_serving_epoch(99)


def test_install_rejects_shape_mismatch(swap_env):
    eng = swap_env["eng"]
    other = gmm(256, dim=16, seed=9)._replace(labels=None)
    with pytest.raises(ValueError, match="cannot hot-swap"):
        eng.install_epoch(7, other, build_index(other, num_clusters=8))
    assert 7 not in eng._epochs


def test_swap_compat_reports_reasons(swap_env):
    eng, ds0 = swap_env["eng"], swap_env["ds0"]
    assert eng.swap_compat(ds0, swap_env["ix0"]) is None
    assert "indexed-ness" in eng.swap_compat(ds0, None)
    other_ix = build_index(swap_env["ds0"], num_clusters=4)
    assert "num_clusters" in eng.swap_compat(ds0, other_ix)


# -- runtime-level swap protocol ----------------------------------------------

@pytest.fixture(scope="module")
def serve_env(tmp_path_factory):
    root = tmp_path_factory.mktemp("swap_serve")
    store = gmm(512, dim=16, seed=3)._replace(labels=None)
    index = build_index(store, num_clusters=8)
    lc = StoreLifecycle.create(str(root), store, index, IngestConfig())
    ds, ix = lc.view()
    eng = ServeEngine(ds, num_steps=6, max_batch=4, index=ix,
                      index_mode="always")
    rt = ServeRuntime(eng, RuntimeConfig(backoff_base_s=0.001,
                                         backoff_max_s=0.005,
                                         breaker_cooldown_s=0.2))
    rt.warmup()
    return {"lc": lc, "rt": rt}


def _serve_one(rt, rid, seed):
    t = rt.submit(Request(rid, 1, seed=seed))
    rt.run_until_idle()
    assert t.status == "done"
    return np.asarray(t.images)


def test_runtime_hot_swap_zero_compiles(serve_env):
    rt, lc = serve_env["rt"], serve_env["lc"]
    y_pre = _serve_one(rt, 0, seed=5)
    before = rt.engine.serving_epoch
    ds, ix = grow(lc, 32, seed=50)
    epoch = rt.hot_swap(ds, ix)
    assert epoch == before + 1
    h = rt.health()
    assert h["serving_epoch"] == epoch
    assert h["epochs_resident"] == 1                # old epoch GC'd
    assert h["compiles_post_warmup"] == 0           # the headline number
    assert rt.counters["hot_swaps"] >= 1
    y_post = _serve_one(rt, 1, seed=5)
    assert np.isfinite(y_post).all()
    assert not np.array_equal(y_pre, y_post)        # new store is live
    assert rt.health()["compiles_post_warmup"] == 0


def test_inflight_wave_finishes_on_admission_epoch(serve_env):
    """A wave admitted before the swap completes on the OLD epoch:
    exactly-once delivery, bit-identical to a no-swap baseline."""
    rt, lc = serve_env["rt"], serve_env["lc"]
    assert rt.eng.plan.num_buckets >= 2             # multi-segment plan
    y_base = _serve_one(rt, 10, seed=77)            # no-swap baseline

    t = rt.submit(Request(11, 1, seed=77))
    assert rt.pump()                                # run exactly one seam
    assert t.status in ("queued", "running")        # still in flight
    ds, ix = grow(lc, 16, seed=60)
    rt.hot_swap(ds, ix)                             # swap mid-request
    rt.run_until_idle()
    assert t.status == "done"
    np.testing.assert_array_equal(np.asarray(t.images), y_base)
    assert rt.health()["compiles_post_warmup"] == 0
    assert rt.health()["epochs_resident"] == 1      # old epoch GC'd now

    y_new = _serve_one(rt, 12, seed=77)             # admitted post-swap
    assert not np.array_equal(y_new, y_base)


def test_probe_quarantines_poisoned_epoch(serve_env):
    """A candidate epoch that produces non-finite output NEVER becomes
    the serving epoch: the probe quarantines it and serving continues
    on the old store uninterrupted."""
    rt, lc = serve_env["rt"], serve_env["lc"]
    before = rt.engine.serving_epoch
    y_pre = _serve_one(rt, 20, seed=8)
    ds, ix = lc.view()
    poisoned = ds._replace(X=jnp.full_like(ds.X, jnp.nan))
    with pytest.raises(EpochProbeError):
        rt.hot_swap(poisoned, ix)
    assert rt.engine.serving_epoch == before        # flip never happened
    assert rt.counters["epoch_quarantined"] == 1
    assert rt.health()["epochs_resident"] == 1      # candidate retired
    y_post = _serve_one(rt, 21, seed=8)
    np.testing.assert_array_equal(y_pre, y_post)    # service undisturbed
    assert rt.health()["compiles_post_warmup"] == 0


def test_hot_swap_rejects_serving_epoch_id(serve_env):
    rt, lc = serve_env["rt"], serve_env["lc"]
    ds, ix = lc.view()
    with pytest.raises(ValueError, match="serving"):
        rt.hot_swap(ds, ix, epoch=rt.engine.serving_epoch)


@pytest.mark.slow
def test_seam_swap_log_compiles_guard_subprocess():
    """The acceptance guard: a hot-swap between a live wave's plan
    seams must be invisible to the compiler (jax.log_compiles captures
    NOTHING after warmup) and deliver every ticket exactly once."""
    code = r"""
import io, logging, tempfile
import jax, numpy as np
from repro.data import gmm
from repro.index import IngestConfig, StoreLifecycle, build_index
from repro.launch.runtime import RuntimeConfig, ServeRuntime
from repro.launch.serve import Request, ServeEngine

root = tempfile.mkdtemp(prefix="seam_swap_")
store = gmm(512, dim=16, seed=3)._replace(labels=None)
lc = StoreLifecycle.create(root, store, build_index(store, num_clusters=8),
                           IngestConfig())
ds, ix = lc.view()
eng = ServeEngine(ds, num_steps=6, max_batch=4, index=ix,
                  index_mode="always")
rt = ServeRuntime(eng, RuntimeConfig())
rt.warmup()

log = io.StringIO()
handler = logging.StreamHandler(log)
logging.getLogger("jax").addHandler(handler)
with jax.log_compiles(True):
    tickets = [rt.submit(Request(0, 2, seed=1)),
               rt.submit(Request(1, 1, seed=2))]
    rt.pump()                            # one seam on the old epoch
    lc.append(np.random.default_rng(0).normal(
        size=(32, 16)).astype(np.float32))
    lc.commit()
    rt.hot_swap(*lc.view())              # swap with waves in flight
    tickets.append(rt.submit(Request(2, 1, seed=3)))
    rt.run_until_idle()
logging.getLogger("jax").removeHandler(handler)

done = [t.status == "done" and np.isfinite(t.images).all()
        for t in tickets]
compiled = [ln for ln in log.getvalue().splitlines()
            if "Compiling" in ln and "jit(" in ln]
print("statuses:", [t.status for t in tickets])
print("post-warmup compiles:", compiled[:5])
print("health:", {k: rt.health()[k] for k in
                  ("serving_epoch", "epochs_resident",
                   "compiles_post_warmup")})
ok = (all(done) and not compiled
      and rt.health()["compiles_post_warmup"] == 0
      and rt.health()["serving_epoch"] == 1)
print("PASS" if ok else "FAIL")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, cwd=str(REPO), env=env)
    assert "PASS" in r.stdout, r.stdout + r.stderr
