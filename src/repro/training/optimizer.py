"""AdamW + cosine schedule + global-norm clipping, pure JAX (no optax).

Optimizer state is kept in f32 regardless of param dtype (bf16 params
keep an f32 master copy), sharded identically to the parameters
(ZeRO-style: the FSDP rules shard the 'embed' axis of both).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict
    master: dict       # f32 master copy of the params


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    # force a copy: astype(f32) of an f32 param aliases it, and params +
    # master are donated separately by train_step (double-donation error)
    master = jax.tree.map(lambda x: jnp.array(x, jnp.float32, copy=True),
                          params)
    return AdamWState(jnp.zeros((), jnp.int32), f32(params), f32(params), master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master, new_master.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_ma = treedef.flatten_up_to(state.master)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(*args) for args in zip(flat_g, flat_m, flat_v, flat_ma, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_ma = treedef.unflatten([o[2] for o in out])
    new_p = treedef.unflatten([o[3] for o in out])
    return new_p, AdamWState(step, new_m, new_v, new_ma), \
        {"grad_norm": gnorm, "lr": lr}
