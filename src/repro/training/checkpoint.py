"""Flat-file checkpointing for param/optimizer pytrees (no orbax offline).

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` holding the
flattened key paths, dtypes, and per-array sha256 checksums.  Writes go
through the shared crash-safe artifact writer (``repro.utils.atomic``:
tmp + fsync + rename, manifest last) — the same implementation the
golden-store persistence uses — so a torn or bit-rotted checkpoint
raises a typed :class:`CheckpointCorruptionError` at restore instead of
silently loading garbage weights.  Restores onto host then (optionally)
device_put with the caller's shardings.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import atomic

CKPT_FORMAT = "training-checkpoint"
CKPT_FORMAT_VERSION = 1


class CheckpointCorruptionError(atomic.ArtifactCorruptionError):
    """Checkpoint bytes disagree with their manifest."""


class CheckpointVersionError(atomic.ArtifactVersionError):
    """Checkpoint written by an incompatible format version."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str | pathlib.Path, step: int, tree) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    atomic.save_arrays(str(d / "arrays.npz"), _flatten(tree),
                       fmt=CKPT_FORMAT, version=CKPT_FORMAT_VERSION,
                       meta={"step": int(step)},
                       manifest_path=str(d / "manifest.json"))
    return d


def latest_step(directory: str | pathlib.Path) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore(directory: str | pathlib.Path, step: int, like_tree):
    d = pathlib.Path(directory) / f"step_{step:08d}"
    data, _ = atomic.load_arrays(
        str(d / "arrays.npz"), fmt=CKPT_FORMAT,
        version=CKPT_FORMAT_VERSION,
        manifest_path=str(d / "manifest.json"),
        corruption_exc=CheckpointCorruptionError,
        version_exc=CheckpointVersionError)
    flat_like = _flatten(like_tree)
    if set(data) != set(flat_like):
        raise CheckpointCorruptionError(
            f"{d}: checkpoint/tree key mismatch "
            f"(missing: {sorted(set(flat_like) - set(data)) or '-'}, "
            f"unexpected: {sorted(set(data) - set(flat_like)) or '-'})")
    leaves, treedef = jax.tree.flatten(like_tree)
    keys = list(flat_like.keys())
    restored = [jnp.asarray(data[k]).astype(l.dtype)
                for k, l in zip(keys, leaves)]
    return treedef.unflatten(restored)
