"""Flat-file checkpointing for param/optimizer pytrees (no orbax offline).

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` holding the
flattened key paths and dtypes.  Restores onto host then (optionally)
device_put with the caller's shardings.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str | pathlib.Path, step: int, tree) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(d / "arrays.npz", **flat)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()}
    (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return d


def latest_step(directory: str | pathlib.Path) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore(directory: str | pathlib.Path, step: int, like_tree):
    d = pathlib.Path(directory) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    flat_like = _flatten(like_tree)
    assert set(data.files) == set(flat_like), "checkpoint/tree key mismatch"
    leaves, treedef = jax.tree.flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    restored = [jnp.asarray(data[k]).astype(l.dtype)
                for k, l in zip(keys, leaves)]
    return treedef.unflatten(restored)
