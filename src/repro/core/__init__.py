"""Core analytical-diffusion library (the paper's primary contribution)."""
from repro.core.dataset import DatasetStore, make_store, downsample_proxy
from repro.core.denoisers import (DENOISERS, OptimalDenoiser, PCADenoiser,
                                  PatchDenoiser, WienerDenoiser, make_denoiser)
from repro.core.engine import GoldDiffEngine
from repro.core.golddiff import GoldDiff, GoldDiffConfig, schedule_sizes
from repro.core.plan import (BucketCaps, PlanBucket, TrajectoryPlan,
                             build_plan)
from repro.core.sampler import (sample, sample_plan, sample_scan,
                                denoise_trajectory)
from repro.core.schedules import Schedule, make_schedule, sampling_timesteps

__all__ = [
    "DatasetStore", "make_store", "downsample_proxy",
    "DENOISERS", "OptimalDenoiser", "PCADenoiser", "PatchDenoiser",
    "WienerDenoiser", "make_denoiser",
    "GoldDiff", "GoldDiffConfig", "GoldDiffEngine", "schedule_sizes",
    "BucketCaps", "PlanBucket", "TrajectoryPlan", "build_plan",
    "sample", "sample_plan", "sample_scan", "denoise_trajectory",
    "Schedule", "make_schedule", "sampling_timesteps",
]
