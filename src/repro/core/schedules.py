"""Diffusion forward-process schedules.

Every schedule is expressed in the generic affine form

    x_t = a_t * x_0 + b_t * eps,   eps ~ N(0, I)

so that VP (a_t = sqrt(alpha_bar), b_t = sqrt(1 - alpha_bar)) and VE
(a_t = 1, b_t = sigma_t) are handled uniformly.  The analytical denoiser
only ever consumes the *rescaled query* ``x_t / a_t`` and the
noise-to-signal ratio ``sigma_t = b_t / a_t`` (paper Eq. 2 with
``sigma_t^2 = (1 - alpha_bar)/alpha_bar``), which both exist for every
schedule in this form.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A discretized forward process with ``num_steps + 1`` grid points.

    ``a[t]``/``b[t]`` are indexed by integer timestep t in [0, num_steps],
    t = 0 is (almost) clean data, t = num_steps is (almost) pure noise.
    """

    name: str
    a: np.ndarray  # signal coefficient, shape [T+1]
    b: np.ndarray  # noise coefficient, shape [T+1]

    @property
    def num_steps(self) -> int:
        return len(self.a) - 1

    def sigma(self, t) -> Array:
        """Noise-to-signal ratio sigma_t = b_t / a_t (paper's sigma_t)."""
        a = jnp.asarray(self.a)[t]
        b = jnp.asarray(self.b)[t]
        return b / a

    def g(self, t) -> Array:
        """Normalized noise level g(sigma_t) in [0, 1] (paper Eq. 4/6).

        Log-linear normalization between the smallest and largest sigma on
        the grid: g = 1 at max noise, g = 0 at min noise.
        """
        sig = jnp.log(self.sigma(jnp.arange(1, self.num_steps + 1)))
        lo, hi = jnp.min(sig), jnp.max(sig)
        t = jnp.clip(jnp.asarray(t), 1, self.num_steps)
        val = (jnp.log(self.sigma(t)) - lo) / (hi - lo)
        return jnp.clip(val, 0.0, 1.0)

    def sigma_np(self, t) -> np.ndarray:
        """Host-side (numpy) sigma_t — safe to call inside jit traces with
        a concrete integer t (the jnp variant would produce tracers)."""
        return self.b[t] / self.a[t]

    def g_np(self, t) -> float:
        sig = np.log(self.b[1:] / self.a[1:])
        lo, hi = sig.min(), sig.max()
        t = int(np.clip(t, 1, self.num_steps))
        return float(np.clip((np.log(self.sigma_np(t)) - lo) / (hi - lo),
                             0.0, 1.0))

    def add_noise(self, x0: Array, eps: Array, t) -> Array:
        a = jnp.asarray(self.a, x0.dtype)[t]
        b = jnp.asarray(self.b, x0.dtype)[t]
        a = jnp.reshape(a, (-1,) + (1,) * (x0.ndim - 1)) if jnp.ndim(t) else a
        b = jnp.reshape(b, (-1,) + (1,) * (x0.ndim - 1)) if jnp.ndim(t) else b
        return a * x0 + b * eps

    def ddim_step(self, x_t: Array, x0_hat: Array, t: int, t_prev: int,
                  eta: float = 0.0, noise: Array | None = None) -> Array:
        """Deterministic (eta=0) or stochastic DDIM update t -> t_prev."""
        a_t = float(self.a[t]); b_t = float(self.b[t])
        a_p = float(self.a[t_prev]); b_p = float(self.b[t_prev])
        eps_hat = (x_t - a_t * x0_hat) / b_t
        if eta == 0.0 or noise is None:
            return a_p * x0_hat + b_p * eps_hat
        # VP-style stochastic interpolation.
        sig = eta * b_p / b_t * jnp.sqrt(jnp.maximum(b_t**2 - (a_t * b_p / a_p) ** 2, 0.0)) / b_t
        dir_coeff = jnp.sqrt(jnp.maximum(b_p**2 - sig**2, 0.0))
        return a_p * x0_hat + dir_coeff * eps_hat + sig * noise


def ddpm_linear(num_steps: int = 1000, beta_start: float = 1e-4,
                beta_end: float = 2e-2) -> Schedule:
    betas = np.linspace(beta_start, beta_end, num_steps)
    alpha_bar = np.cumprod(1.0 - betas)
    a = np.concatenate([[1.0], np.sqrt(alpha_bar)])
    b = np.concatenate([[0.0 + 1e-4], np.sqrt(1.0 - alpha_bar)])
    return Schedule("ddpm_linear", a, b)


def cosine(num_steps: int = 1000, s: float = 8e-3) -> Schedule:
    t = np.arange(num_steps + 1) / num_steps
    f = np.cos((t + s) / (1 + s) * np.pi / 2) ** 2
    alpha_bar = np.clip(f / f[0], 1e-8, 1.0)
    return Schedule("cosine", np.sqrt(alpha_bar),
                    np.sqrt(np.maximum(1.0 - alpha_bar, 1e-8)))


def edm_vp(num_steps: int = 1000, beta_d: float = 19.9, beta_min: float = 0.1) -> Schedule:
    """EDM's VP parameterization (Karras et al. 2022, Table 1)."""
    t = np.linspace(1e-3, 1.0, num_steps + 1)
    log_abar = -0.5 * (0.5 * beta_d * t**2 + beta_min * t)
    a = np.exp(log_abar)
    b = np.sqrt(np.maximum(1.0 - a**2, 1e-8))
    return Schedule("edm_vp", a, b)


def edm_ve(num_steps: int = 1000, sigma_min: float = 2e-2,
           sigma_max: float = 100.0) -> Schedule:
    """VE: x_t = x_0 + sigma_t eps with geometric sigma grid; a_t = 1."""
    sig = np.concatenate([[sigma_min * 0.5],
                          np.geomspace(sigma_min, sigma_max, num_steps)])
    return Schedule("edm_ve", np.ones(num_steps + 1), sig)


SCHEDULES: dict[str, Callable[..., Schedule]] = {
    "ddpm_linear": ddpm_linear,
    "cosine": cosine,
    "edm_vp": edm_vp,
    "edm_ve": edm_ve,
}


def make_schedule(name: str, num_steps: int = 1000, **kw) -> Schedule:
    return SCHEDULES[name](num_steps=num_steps, **kw)


def sampling_timesteps(schedule: Schedule, num_sampling_steps: int) -> np.ndarray:
    """Evenly spaced (in index space) decreasing grid incl. endpoints."""
    T = schedule.num_steps
    ts = np.unique(np.linspace(0, T, num_sampling_steps + 1).round().astype(int))
    return ts[::-1]  # T ... 0
