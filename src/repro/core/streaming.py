"""Streaming (online) softmax aggregation.

Two estimators from the paper (Sec. 3.2 / Tab. 6):

* ``streaming_softmax_mean`` — the *unbiased* online softmax of
  FlashAttention (Dao et al., 2022): a running (max, denominator,
  accumulator) triple is updated chunk by chunk; the result is exactly
  ``softmax(logits) @ values`` for any chunking.  This is what GoldDiff
  applies on the golden subset.

* ``weighted_streaming_softmax_mean`` — the *biased* WSS used by the PCA
  denoiser (Lukoianov et al., 2025): each chunk computes a local softmax
  mean and chunks are then combined with weights proportional to
  ``n_c * exp(mean logit of chunk)`` (batch-level averaging).  Relative to
  the exact softmax this systematically *flattens* the weight
  distribution across chunks — the smoothing bias the paper identifies.

Both operate on logits/values that may be given all at once (we chunk with
``lax.scan`` for O(chunk) memory) and both expose a mergeable partial state
(log-sum-exp merge) so that dataset shards on different devices can be
combined exactly (used by ``repro.distributed.retrieval``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG_INF = -1e30


class SoftmaxState(NamedTuple):
    """Partial state of an online softmax: running max, denom, accum."""

    m: Array      # [...]        running max of logits
    l: Array      # [...]        sum of exp(logit - m)
    acc: Array    # [..., D]     sum of exp(logit - m) * value


def init_state(batch_shape: tuple[int, ...], dim: int, dtype=jnp.float32) -> SoftmaxState:
    return SoftmaxState(
        m=jnp.full(batch_shape, NEG_INF, dtype),
        l=jnp.zeros(batch_shape, dtype),
        acc=jnp.zeros(batch_shape + (dim,), dtype),
    )


def update_state(state: SoftmaxState, logits: Array, values: Array,
                 mask: Array | None = None) -> SoftmaxState:
    """Fold one chunk into the state.

    logits: [..., C]; values: [..., C, D] or [C, D]; mask: [..., C] bool.
    """
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    m_chunk = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(state.m, m_chunk)
    # Guard: if everything so far is masked, keep scale finite.
    scale_old = jnp.exp(state.m - m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_new = state.l * scale_old + jnp.sum(p, axis=-1)
    acc_new = state.acc * scale_old[..., None] + p @ values \
        if values.ndim == 2 else state.acc * scale_old[..., None] + jnp.einsum(
            "...c,...cd->...d", p, values)
    return SoftmaxState(m_new, l_new, acc_new)


def merge_states(a: SoftmaxState, b: SoftmaxState) -> SoftmaxState:
    """Exact log-sum-exp merge of two partial states (associative)."""
    m = jnp.maximum(a.m, b.m)
    sa = jnp.exp(a.m - m)
    sb = jnp.exp(b.m - m)
    return SoftmaxState(m, a.l * sa + b.l * sb,
                        a.acc * sa[..., None] + b.acc * sb[..., None])


def finalize(state: SoftmaxState) -> Array:
    return state.acc / jnp.maximum(state.l, 1e-30)[..., None]


def streaming_softmax_mean(logits: Array, values: Array, chunk: int = 4096,
                           mask: Array | None = None) -> Array:
    """Exact softmax(logits) @ values with O(chunk) working set.

    logits: [..., N]; values: [N, D]; returns [..., D].
    """
    n = logits.shape[-1]
    d = values.shape[-1]
    chunk = min(chunk, n)
    num = n // chunk
    rem = n - num * chunk
    batch_shape = logits.shape[:-1]
    state = init_state(batch_shape, d, jnp.float32)

    if num > 0:
        lg = logits[..., : num * chunk].reshape(batch_shape + (num, chunk))
        vals = values[: num * chunk].reshape(num, chunk, d)
        msk = None
        if mask is not None:
            msk = mask[..., : num * chunk].reshape(batch_shape + (num, chunk))

        def body(st, i):
            m_i = None if msk is None else jnp.take(msk, i, axis=len(batch_shape))
            return update_state(
                st, jnp.take(lg, i, axis=len(batch_shape)).astype(jnp.float32),
                vals[i].astype(jnp.float32), m_i), None

        state, _ = jax.lax.scan(body, state, jnp.arange(num))
    if rem:
        m_r = None if mask is None else mask[..., num * chunk:]
        state = update_state(state, logits[..., num * chunk:].astype(jnp.float32),
                             values[num * chunk:].astype(jnp.float32), m_r)
    return finalize(state)


def weighted_streaming_softmax_mean(logits: Array, values: Array,
                                    chunk: int = 4096) -> Array:
    """Biased WSS (PCA-style batch-level averaging).

    Each chunk c contributes its local softmax mean mu_c; chunks are
    combined with weights w_c ∝ n_c * exp(mean_c(logits)).  Using the
    *mean* logit instead of the log-sum-exp flattens inter-chunk
    competition — the smoothing bias of Sec. 3.2.

    When ``n % chunk != 0`` the tail remainder is folded into the last
    chunk (one larger chunk) rather than dropped; the size factor n_c in
    the chunk weights then matters and is carried as ``log n_c``.
    """
    n = logits.shape[-1]
    d = values.shape[-1]
    batch = logits.shape[:-1]
    chunk = min(chunk, n)
    num = max(n // chunk, 1)
    rem = n - num * chunk
    lg32 = logits.astype(jnp.float32)
    vals32 = values.astype(jnp.float32)
    if rem == 0:
        lg = lg32.reshape(batch + (num, chunk))
        vals = vals32.reshape(num, chunk, d)
        # local softmax mean per chunk: [..., num, D]
        p = jax.nn.softmax(lg, axis=-1)
        mu = jnp.einsum("...nc,ncd->...nd", p, vals)
        # chunk weights from mean logit (the bias): [..., num]
        wc = jax.nn.softmax(jnp.mean(lg, axis=-1), axis=-1)
        return jnp.einsum("...n,...nd->...d", wc, mu)
    # ragged tail: num-1 equal chunks + one final chunk of (chunk + rem)
    s = (num - 1) * chunk
    mus, mls, counts = [], [], []
    if s:
        lg_h = lg32[..., :s].reshape(batch + (num - 1, chunk))
        vals_h = vals32[:s].reshape(num - 1, chunk, d)
        p = jax.nn.softmax(lg_h, axis=-1)
        mus.append(jnp.einsum("...nc,ncd->...nd", p, vals_h))
        mls.append(jnp.mean(lg_h, axis=-1))
        counts.extend([chunk] * (num - 1))
    lg_t = lg32[..., s:]
    p_t = jax.nn.softmax(lg_t, axis=-1)
    mus.append(jnp.einsum("...c,cd->...d", p_t, vals32[s:])[..., None, :])
    mls.append(jnp.mean(lg_t, axis=-1)[..., None])
    counts.append(n - s)
    mu = jnp.concatenate(mus, axis=-2)
    ml = jnp.concatenate(mls, axis=-1)
    log_nc = jnp.log(jnp.asarray(counts, jnp.float32))
    wc = jax.nn.softmax(ml + log_nc, axis=-1)
    return jnp.einsum("...n,...nd->...d", wc, mu)


def wss_combine(logits: Array, values: Array, chunk: int = 64) -> Array:
    """Biased WSS over per-query support sets.

    logits: [..., K]; values: [..., K, D] (aligned).  Same bias model as
    ``weighted_streaming_softmax_mean`` (chunk-local softmax means combined
    by mean-logit weights) but for gathered golden subsets.
    """
    k = logits.shape[-1]
    d = values.shape[-1]
    chunk = max(1, min(chunk, k))
    nc = k // chunk
    rem = k - nc * chunk
    lg32 = logits.astype(jnp.float32)
    vals32 = values.astype(jnp.float32)

    def _chunk_stats(lg, vals):
        p = jax.nn.softmax(lg, axis=-1)
        mu = jnp.einsum("...nc,...ncd->...nd", p, vals)
        return mu, jnp.mean(lg, axis=-1)

    if rem == 0:
        lg = lg32.reshape(logits.shape[:-1] + (nc, chunk))
        vals = vals32.reshape(values.shape[:-2] + (nc, chunk, d))
        mu, ml = _chunk_stats(lg, vals)
        wc = jax.nn.softmax(ml, axis=-1)
        return jnp.einsum("...n,...nd->...d", wc, mu)
    # tail remainder folded into one final larger chunk (same fix as
    # weighted_streaming_softmax_mean; weights carry log n_c)
    s = (nc - 1) * chunk
    mus, mls, counts = [], [], []
    if s:
        mu, ml = _chunk_stats(
            lg32[..., :s].reshape(logits.shape[:-1] + (nc - 1, chunk)),
            vals32[..., :s, :].reshape(values.shape[:-2] + (nc - 1, chunk, d)))
        mus.append(mu)
        mls.append(ml)
        counts.extend([chunk] * (nc - 1))
    mu_t, ml_t = _chunk_stats(lg32[..., s:][..., None, :],
                              vals32[..., s:, :][..., None, :, :])
    mus.append(mu_t)
    mls.append(ml_t)
    counts.append(k - s)
    mu = jnp.concatenate(mus, axis=-2)
    ml = jnp.concatenate(mls, axis=-1)
    wc = jax.nn.softmax(ml + jnp.log(jnp.asarray(counts, jnp.float32)), -1)
    return jnp.einsum("...n,...nd->...d", wc, mu)


def softmax_mean_reference(logits: Array, values: Array,
                           mask: Array | None = None) -> Array:
    """Naive one-shot reference (for tests)."""
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("...n,nd->...d", w, values.astype(jnp.float32))
