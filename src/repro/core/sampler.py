"""Reverse-process samplers driving any analytical (or neural) denoiser.

* ``sample``        — per-step Python loop (each step may have its own
  static (m_t, k_t) program; this is the mode the benchmarks time).
* ``sample_scan``   — single ``lax.scan`` program using a scan-compatible
  denoiser body (e.g. ``GoldDiff.call_masked`` or a neural net).
* ``sample_plan``   — chained per-bucket ``lax.scan`` segments driven by a
  ``repro.core.plan.TrajectoryPlan``: one compiled program per shape
  bucket (typically 3-4), each padded only to its bucket's
  (m_cap, k_cap, nprobe_cap), so serving keeps ~all of static mode's
  FLOP savings without static mode's program-per-step compile cost.
  This is what runs under pjit in the serving engine.
* ``sample_conditional`` — class-conditional generation by restricting the
  dataset store to one class (paper Tab. 3, conditional columns).

All samplers implement DDIM (Song et al., 2020a; eta=0 deterministic) over
an evenly spaced sub-grid of the schedule, 10 steps by default (paper
Sec. 4.1), with x0-prediction clipping for stability.

``x_init`` (optional on every sampler) replaces the internal terminal-
noise draw with a caller-supplied x_T — the serving engine uses it to
give each co-batched request its own per-row noise stream.  When it is
supplied the sampler still consumes the same PRNG splits, so trajectories
with and without it stay comparable.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule, sampling_timesteps
from repro.obs import trace as obs_trace

Array = jnp.ndarray


def _clip(x0: Array, clip_value: float | None) -> Array:
    return x0 if clip_value is None else jnp.clip(x0, -clip_value, clip_value)


def _init_noise(schedule: Schedule, t0: int, shape: tuple, key: jax.Array,
                x_init: Array | None) -> Array:
    # For VP schedules a_T ~ 0 so x_T ~ b_T * eps; the general init is
    # a_T * E[x0] + b_T eps ~= b_T eps (data is standardized).
    if x_init is not None:
        return jnp.asarray(x_init)
    return float(schedule.b[t0]) * jax.random.normal(key, shape)


def sample(denoiser: Callable, schedule: Schedule, shape: tuple,
           rng: jax.Array, num_steps: int = 10, eta: float = 0.0,
           clip_value: float | None = 3.0,
           trace: bool = False, x_init: Array | None = None):
    """Per-step-jit DDIM sampling.  Returns x0 (and the trajectory if asked)."""
    ts = sampling_timesteps(schedule, num_steps)
    rng, init = jax.random.split(rng)
    x = _init_noise(schedule, int(ts[0]), shape, init, x_init)
    traj = []
    for t, t_prev in zip(ts[:-1], ts[1:]):
        x0_hat = _clip(denoiser(x, int(t)), clip_value)
        noise = None
        if eta > 0:
            rng, sub = jax.random.split(rng)
            noise = jax.random.normal(sub, shape)
        x = schedule.ddim_step(x, x0_hat, int(t), int(t_prev), eta, noise)
        if trace:
            traj.append(x0_hat)
    if trace:
        return x, jnp.stack(traj)
    return x


def sample_scan(denoise_masked: Callable, schedule: Schedule, shape: tuple,
                rng: jax.Array, num_steps: int = 10,
                clip_value: float | None = 3.0,
                x_init: Array | None = None) -> Array:
    """Single-program DDIM with a traced-timestep denoiser body.

    Deterministic DDIM only (the eta=0 update is fused into the scan
    body): unlike :func:`sample` there is **no** ``eta`` parameter, and
    passing one is a ``TypeError`` rather than a silently ignored
    mismatch.  Stochastic (eta > 0) trajectories need the per-step
    sampler.
    """
    ts_np = sampling_timesteps(schedule, num_steps)
    ts = jnp.asarray(ts_np)
    a = jnp.asarray(schedule.a)
    b = jnp.asarray(schedule.b)
    rng, init = jax.random.split(rng)       # match sample()'s key schedule
    x = _init_noise(schedule, int(ts_np[0]), shape, init, x_init)

    def body(x, i):
        t, t_prev = ts[i], ts[i + 1]
        x0_hat = _clip(denoise_masked(x, t), clip_value)
        eps_hat = (x - a[t] * x0_hat) / b[t]
        return a[t_prev] * x0_hat + b[t_prev] * eps_hat, None

    x, _ = jax.lax.scan(body, x, jnp.arange(len(ts) - 1))
    return x


def plan_segment(denoise_masked: Callable, schedule: Schedule, plan, bucket,
                 clip_value: float | None = 3.0) -> Callable:
    """One plan bucket's ``lax.scan`` segment as a standalone x -> x fn.

    Module-level (rather than a closure inside :func:`sample_plan`) so
    the serving runtime can execute, retry, and re-enter *individual*
    segments — its admission / deadline-expiry boundaries are exactly
    these bucket seams.  ``sample_plan`` chains the same functions, so
    a trajectory stitched segment-by-segment from the same compiled
    programs is bit-identical to one ``sample_plan`` call.
    """
    ts = jnp.asarray(plan.ts)
    a = jnp.asarray(schedule.a)
    b = jnp.asarray(schedule.b)

    def segment(x):
        def body(x, i):
            t, t_prev = ts[i], ts[i + 1]
            x0_hat = _clip(denoise_masked(x, t, bucket.caps), clip_value)
            eps_hat = (x - a[t] * x0_hat) / b[t]
            return a[t_prev] * x0_hat + b[t_prev] * eps_hat, None
        out, _ = jax.lax.scan(body, x,
                              jnp.arange(bucket.start, bucket.stop))
        return out
    return segment


def plan_segment_key(plan, bucket, shape: tuple, dtype_str: str,
                     clip_value: float | None) -> tuple:
    """The program-cache key of one plan segment (shared between
    ``sample_plan``'s warmup/execution paths and the serving runtime —
    one definition, so precompiled entries are always cache hits)."""
    return ("plan_seg", bucket.start, bucket.stop, bucket.caps.sig(),
            tuple(plan.ts), shape, dtype_str,
            None if clip_value is None else float(clip_value))


def plan_segment_mixed(denoise_masked: Callable, schedule: Schedule, plan,
                       bucket, clip_value: float | None = 3.0) -> Callable:
    """A plan segment that advances only a *subset* of its rows.

    ``segment(x, pos)`` runs the same ``lax.scan`` body as
    :func:`plan_segment` — same bucket caps, same scalar traced ``t`` —
    but each row ``r`` carries a grid cursor ``pos[r]`` (int32) and only
    rows whose cursor sits at this bucket's entry seam
    (``pos[r] == bucket.start``) take the DDIM update; all other rows
    pass through untouched (``jnp.where`` on the scan carry).  This is
    the continuous-batching plug-in point: the serving runtime co-batches
    requests at *different* trajectory cursors in one wave, and because
    every engine op is row-independent the active rows here are
    **bit-identical** to the same rows run through the plain
    :func:`plan_segment` program (verified by the mixed-cursor parity
    suite), so mid-trajectory admission is invisible to each request.

    Admission happens only at bucket seams, so active rows are always
    exactly at ``bucket.start`` — per-row activity masking over the
    bucket scan is fully general here and ``t`` stays scalar (all active
    rows share every scan index).  Frozen rows still flow through the
    denoiser (their lanes are computed and discarded), which is what
    keeps the program count bounded: one mixed program per
    (plan bucket x batch bucket), all warmed by
    ``ServeRuntime.warmup``.
    """
    ts = jnp.asarray(plan.ts)
    a = jnp.asarray(schedule.a)
    b = jnp.asarray(schedule.b)

    def segment(x, pos):
        active = pos == bucket.start

        def body(x, i):
            t, t_prev = ts[i], ts[i + 1]
            x0_hat = _clip(denoise_masked(x, t, bucket.caps), clip_value)
            eps_hat = (x - a[t] * x0_hat) / b[t]
            x_next = a[t_prev] * x0_hat + b[t_prev] * eps_hat
            return jnp.where(active[:, None], x_next, x), None
        out, _ = jax.lax.scan(body, x,
                              jnp.arange(bucket.start, bucket.stop))
        return out
    return segment


def plan_segment_mixed_key(plan, bucket, shape: tuple, dtype_str: str,
                           clip_value: float | None) -> tuple:
    """Program-cache key of a mixed-cursor segment — same anatomy as
    :func:`plan_segment_key` under its own kind tag, so plain and mixed
    programs for one bucket coexist in the cache and both get warmed."""
    return ("plan_seg_mix", bucket.start, bucket.stop, bucket.caps.sig(),
            tuple(plan.ts), shape, dtype_str,
            None if clip_value is None else float(clip_value))


def sample_plan(denoise_masked: Callable, schedule: Schedule, shape: tuple,
                rng: jax.Array, plan, clip_value: float | None = 3.0,
                x_init: Array | None = None,
                program_cache: Callable | None = None,
                compile_only: bool = False,
                jitter: Callable | None = None) -> Array | None:
    """Bucketed DDIM: one ``lax.scan`` segment per plan bucket.

    ``denoise_masked`` must accept ``(x, t, caps)`` (e.g.
    ``GoldDiff.call_masked`` / ``GoldDiffEngine.denoise_masked``);
    ``plan`` is a ``repro.core.plan.TrajectoryPlan`` built for this
    schedule.  The PRNG key schedule and the DDIM update are
    bit-identical to :func:`sample_scan` — only the program
    partitioning differs — so plan outputs match scan outputs to fp32
    reduction order (and static-mode outputs too, since each bucket's
    masks reproduce the per-step static shapes).

    ``program_cache(key, build)`` (e.g. ``GoldDiffEngine.program``)
    memoizes the per-bucket compiled segments: with it, a trajectory
    compiles ``plan.num_buckets`` programs per batch shape the first
    time and zero afterwards.  Without it the segments re-trace per
    call (fine for one-off sampling).  Deterministic DDIM only, like
    :func:`sample_scan`.

    ``compile_only=True`` populates the cache by AOT-lowering each
    segment for a fp32 ``shape`` input (``jit(...).lower().compile()``)
    without executing any trajectory — the serving engine's
    ``warmup()`` path — and returns None.  The cached entries are the
    compiled executables, so subsequent real calls (same shape/dtype
    key) run without touching the compiler.

    ``jitter`` (e.g. ``GoldDiffEngine.jitter``) replaces the plain
    ``jax.jit`` wrapping of each segment with the engine's
    operands-as-arguments wrapper, which is what makes the compiled
    segments *epoch-portable*: after a same-shape store hot-swap the
    identical executables keep running against the new operands with
    zero recompiles.  Omit it for denoisers with no engine behind them.
    """
    def make_segment(bucket):
        return plan_segment(denoise_masked, schedule, plan, bucket,
                            clip_value)

    def seg_key(bucket, shp, dtype_str):
        return plan_segment_key(plan, bucket, shp, dtype_str, clip_value)

    if compile_only:
        if program_cache is None:
            raise ValueError("compile_only needs a program_cache to "
                             "hold the compiled segments")
        spec = jax.ShapeDtypeStruct(shape, jnp.float32)
        for bucket in plan.buckets:
            seg = make_segment(bucket)

            if jitter is not None:
                build = (lambda s=seg: jitter(s, aot_specs=(spec,)))
            else:
                def build(s=seg):
                    compiled = jax.jit(s).lower(spec).compile()
                    return lambda xx, _c=compiled: _c(xx)

            program_cache(seg_key(bucket, shape, "float32"), build)
        return None

    rng, init = jax.random.split(rng)       # match sample()'s key schedule
    x = _init_noise(schedule, int(plan.ts[0]), shape, init, x_init)
    tr = obs_trace.tracer()
    jj = jitter if jitter is not None else jax.jit
    for bi, bucket in enumerate(plan.buckets):
        seg = make_segment(bucket)
        if program_cache is None:
            fn = seg
        else:
            fn = program_cache(seg_key(bucket, x.shape, str(x.dtype)),
                               lambda s=seg: jj(s))
        if not tr.enabled:
            x = fn(x)
            continue
        with tr.span("plan.segment", bucket=bi, start=bucket.start,
                     stop=bucket.stop, caps=bucket.caps.sig(),
                     shape=tuple(x.shape)):
            x = fn(x)
            jax.block_until_ready(x)
    return x


def sample_conditional(make_denoiser_for_class: Callable[[int], Callable],
                       schedule: Schedule, shape: tuple, rng: jax.Array,
                       class_id: int, **kw) -> Array:
    return sample(make_denoiser_for_class(class_id), schedule, shape, rng, **kw)


def denoise_trajectory(denoiser: Callable, schedule: Schedule, x_T: Array,
                       num_steps: int = 10, clip_value: float | None = 3.0):
    """Deterministic DDIM from a *given* terminal noise (paired comparisons:
    the paper generates all methods from the same initial noise, Fig. 4)."""
    ts = sampling_timesteps(schedule, num_steps)
    x = x_T
    xs = [x]
    for t, t_prev in zip(ts[:-1], ts[1:]):
        x0_hat = _clip(denoiser(x, int(t)), clip_value)
        x = schedule.ddim_step(x, x0_hat, int(t), int(t_prev))
        xs.append(x)
    return x, xs
