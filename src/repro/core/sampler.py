"""Reverse-process samplers driving any analytical (or neural) denoiser.

* ``sample``        — per-step Python loop (each step may have its own
  static (m_t, k_t) program; this is the mode the benchmarks time).
* ``sample_scan``   — single ``lax.scan`` program using a scan-compatible
  denoiser body (e.g. ``GoldDiff.call_masked`` or a neural net); this is
  what runs under pjit in the serving engine.
* ``sample_conditional`` — class-conditional generation by restricting the
  dataset store to one class (paper Tab. 3, conditional columns).

All samplers implement DDIM (Song et al., 2020a; eta=0 deterministic) over
an evenly spaced sub-grid of the schedule, 10 steps by default (paper
Sec. 4.1), with x0-prediction clipping for stability.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule, sampling_timesteps

Array = jnp.ndarray


def _clip(x0: Array, clip_value: float | None) -> Array:
    return x0 if clip_value is None else jnp.clip(x0, -clip_value, clip_value)


def sample(denoiser: Callable, schedule: Schedule, shape: tuple,
           rng: jax.Array, num_steps: int = 10, eta: float = 0.0,
           clip_value: float | None = 3.0,
           trace: bool = False):
    """Per-step-jit DDIM sampling.  Returns x0 (and the trajectory if asked)."""
    ts = sampling_timesteps(schedule, num_steps)
    rng, init = jax.random.split(rng)
    t0 = int(ts[0])
    x = float(schedule.b[t0]) * jax.random.normal(init, shape)
    # For VP schedules a_T ~ 0 so x_T ~ b_T * eps; the general init is
    # a_T * E[x0] + b_T eps ~= b_T eps (data is standardized).
    traj = []
    for t, t_prev in zip(ts[:-1], ts[1:]):
        x0_hat = _clip(denoiser(x, int(t)), clip_value)
        noise = None
        if eta > 0:
            rng, sub = jax.random.split(rng)
            noise = jax.random.normal(sub, shape)
        x = schedule.ddim_step(x, x0_hat, int(t), int(t_prev), eta, noise)
        if trace:
            traj.append(x0_hat)
    if trace:
        return x, jnp.stack(traj)
    return x


def sample_scan(denoise_masked: Callable, schedule: Schedule, shape: tuple,
                rng: jax.Array, num_steps: int = 10,
                clip_value: float | None = 3.0) -> Array:
    """Single-program DDIM with a traced-timestep denoiser body."""
    ts = jnp.asarray(sampling_timesteps(schedule, num_steps))
    a = jnp.asarray(schedule.a)
    b = jnp.asarray(schedule.b)
    t0 = int(ts[0])
    rng, init = jax.random.split(rng)       # match sample()'s key schedule
    x = float(schedule.b[t0]) * jax.random.normal(init, shape)

    def body(x, i):
        t, t_prev = ts[i], ts[i + 1]
        x0_hat = _clip(denoise_masked(x, t), clip_value)
        eps_hat = (x - a[t] * x0_hat) / b[t]
        return a[t_prev] * x0_hat + b[t_prev] * eps_hat, None

    x, _ = jax.lax.scan(body, x, jnp.arange(len(ts) - 1))
    return x


def sample_conditional(make_denoiser_for_class: Callable[[int], Callable],
                       schedule: Schedule, shape: tuple, rng: jax.Array,
                       class_id: int, **kw) -> Array:
    return sample(make_denoiser_for_class(class_id), schedule, shape, rng, **kw)


def denoise_trajectory(denoiser: Callable, schedule: Schedule, x_T: Array,
                       num_steps: int = 10, clip_value: float | None = 3.0):
    """Deterministic DDIM from a *given* terminal noise (paired comparisons:
    the paper generates all methods from the same initial noise, Fig. 4)."""
    ts = sampling_timesteps(schedule, num_steps)
    x = x_T
    xs = [x]
    for t, t_prev in zip(ts[:-1], ts[1:]):
        x0_hat = _clip(denoiser(x, int(t)), clip_value)
        x = schedule.ddim_step(x, x0_hat, int(t), int(t_prev))
        xs.append(x)
    return x, xs
