"""Backend-dispatched GoldDiff execution engine.

``GoldDiffEngine`` owns the entire coarse -> fine -> aggregate pipeline
(paper Sec. 3.4) and routes every stage through the kernel layer
(``repro.kernels.ops``), replacing the seed's ad-hoc per-class
``_programs`` dicts and inline jnp hot loops:

* **coarse screening** — proxy distances via ``ops.pdist`` (tiled
  matmul form with precomputed norms) instead of an inline broadcast
  expression;
* **precision re-ranking** — ``ops.golden_rerank`` returns top-k
  indices *and* their exact distances, so the aggregation softmax
  reuses selection distances (the seed recomputed them — and regathered
  the rows — a second time);
* **aggregation** — ``ops.golden_support_aggregate`` (streaming online
  softmax on Pallas backends; scatter + GEMM on the XLA backend) and
  ``ops.golden_aggregate`` for full scans.

Engine features:

* **program cache** — compiled programs keyed on
  ``(kind, t, shape, dtype, backend)``; each timestep has static
  (m_t, k_t) so one XLA program per step (true FLOP savings, the
  paper's complexity table), while ``denoise_masked`` is a single
  scan/pjit-compatible program padded to (m_max, k_max).
* **per-timestep schedule constants** — a_t, sigma_t^2, (m_t, k_t)
  precomputed host-side once per t.
* **bf16 storage with fp32 accumulation** — ``storage_dtype=bfloat16``
  keeps the dataset (and proxy) operands in bf16 for bandwidth while
  row norms stay fp32 (computed from the fp32 master copy) and every
  distance/softmax/accumulation runs in fp32.
* **uniform backends** — ``xla`` (CPU tests, benchmarks, the multi-pod
  dry-run), ``pallas_interpret`` (kernel-body validation on CPU), and
  ``pallas`` (real TPUs) all execute the same pipeline; parity is
  asserted in ``tests/test_engine.py``.

Backend/strategy matrix::

    backend           screening distances     aggregation
    ----------------  ----------------------  --------------------------
    xla               dense GEMM + lookup     scatter + GEMM
    pallas_interpret  gather + tiled kernel   gather + streaming kernel
    pallas            gather + tiled kernel   gather + streaming kernel

(The xla strategy exists because XLA:CPU row gathers run ~50x slower
per element than GEMM; on TPU the tiled VMEM kernels win.)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.core.schedules import Schedule
from repro.kernels import ops

Array = jnp.ndarray
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class GoldDiffConfig:
    """Subset-size schedules as fractions of N (paper defaults, Sec. 4.1)."""

    m_min_frac: float = 1 / 10   # = k_max (paper: random N/10 matches full)
    m_max_frac: float = 1 / 4
    k_min_frac: float = 1 / 20
    k_max_frac: float = 1 / 10
    proxy_factor: int = 4

    def sizes(self, n: int) -> tuple[int, int, int, int]:
        m_min = max(1, int(n * self.m_min_frac))
        m_max = max(m_min, int(n * self.m_max_frac))
        k_min = max(1, int(n * self.k_min_frac))
        k_max = max(k_min, int(n * self.k_max_frac))
        k_max = min(k_max, m_min)  # golden set always fits the candidate set
        return m_min, m_max, k_min, k_max


def schedule_sizes(cfg: GoldDiffConfig, schedule: Schedule, t: int,
                   n: int) -> tuple[int, int]:
    """(m_t, k_t) for integer timestep t (static mode; Eqs. 4/6)."""
    g = schedule.g_np(t)
    m_min, m_max, k_min, k_max = cfg.sizes(n)
    m_t = int(math.floor(m_min + (m_max - m_min) * (1.0 - g)))
    k_t = int(math.floor(k_min + (k_max - k_min) * g))
    return max(1, min(m_t, n)), max(1, min(k_t, m_t, n))


class GoldDiffEngine:
    """Compiled-program cache + kernel routing for the GoldDiff pipeline."""

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 cfg: GoldDiffConfig | None = None, backend: str = "xla",
                 storage_dtype=None):
        if backend not in ops.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {ops.BACKENDS}")
        self.store = store
        self.schedule = schedule
        self.cfg = cfg or GoldDiffConfig()
        self.backend = backend
        self.storage_dtype = storage_dtype
        # Dataset-side operands, optionally in low-precision storage.
        X, proxy = store.X, store.proxy
        if storage_dtype is not None and X.dtype != storage_dtype:
            X = X.astype(storage_dtype)
            proxy = proxy.astype(storage_dtype)
        self.X = X
        self.proxy = proxy
        # Norms always fp32, from the master copy (exact even under bf16).
        self.x_norms = store.x_norms.astype(jnp.float32)
        self.proxy_norms = store.proxy_norms.astype(jnp.float32)
        # Per-timestep schedule constants, computed host-side exactly once.
        self._consts: dict[int, tuple[float, float]] = {}
        self._sizes: dict[int, tuple[int, int]] = {}
        self._programs: dict = {}

    # -- precomputed per-timestep constants ----------------------------------
    def sizes(self, t: int) -> tuple[int, int]:
        if t not in self._sizes:
            self._sizes[t] = schedule_sizes(self.cfg, self.schedule, t,
                                            self.store.n)
        return self._sizes[t]

    def constants(self, t: int) -> tuple[float, float]:
        """(a_t, sigma_t^2) as host floats for static-t programs."""
        if t not in self._consts:
            a = float(self.schedule.a[t])
            sig2 = float(self.schedule.sigma_np(t)) ** 2
            self._consts[t] = (a, sig2)
        return self._consts[t]

    # -- program cache -------------------------------------------------------
    def program(self, key, build):
        """Compiled-program cache keyed on (kind, t, shape, dtype, backend)."""
        if key not in self._programs:
            self._programs[key] = build()
        return self._programs[key]

    def _key(self, kind: str, t, x_t: Array):
        return (kind, t, x_t.shape, str(x_t.dtype), self.backend)

    # -- pipeline stages (traceable bodies) ----------------------------------
    def coarse(self, q: Array, m: int) -> Array:
        """Top-m candidates by proxy distance via ops.pdist; [B, m]."""
        q_img = q.reshape(q.shape[:-1] + tuple(self.store.image_shape))
        qp = downsample_proxy(q_img, self.cfg.proxy_factor)
        if self.storage_dtype is not None:
            qp = qp.astype(self.storage_dtype)
        d2 = ops.pdist(qp, self.proxy, x_norms=self.proxy_norms,
                       backend=self.backend)
        return jax.lax.top_k(-d2, m)[1]

    def _select_body(self, q: Array, t: int) -> tuple[Array, Array]:
        """(idx, d2) of the golden support for a rescaled query (static t)."""
        m_t, k_t = self.sizes(t)
        cand = self.coarse(q, m_t)
        return ops.golden_rerank(q, self.X, cand, k_t, x_norms=self.x_norms,
                                 backend=self.backend)

    def _denoise_body(self, x_t: Array, t: int) -> Array:
        """Fused static step: coarse -> rerank -> aggregate, distances
        computed exactly once."""
        a, sig2 = self.constants(t)
        q = x_t / a
        idx, d2 = self._select_body(q, t)
        lg = -d2 / (2.0 * sig2)
        out = ops.golden_support_aggregate(self.X, idx, lg,
                                           backend=self.backend)
        return out.astype(x_t.dtype)

    # -- public entry points -------------------------------------------------
    def select(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Golden support S_t for each query; [B, k_t] (static shapes)."""
        t = int(t)
        a, _ = self.constants(t)
        if not jit:
            return self._select_body(x_t / a, t)[0]
        fn = self.program(self._key("select", t, x_t),
                          lambda: jax.jit(
                              lambda x: self._select_body(x / a, t)[0]))
        return fn(x_t)

    def denoise(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Full GoldDiff step for the Optimal base (unbiased SS on S_t)."""
        t = int(t)
        if not jit:
            return self._denoise_body(x_t, t)
        fn = self.program(self._key("denoise", t, x_t),
                          lambda: jax.jit(
                              lambda x: self._denoise_body(x, t)))
        return fn(x_t)

    def denoise_masked(self, x_t: Array, t: Array) -> Array:
        """Scan/pjit-compatible step: shapes padded to (m_max, k_max),
        sizes enter only through masks, ``t`` may be traced.

        Exact candidate distances are computed exactly once (over m_max)
        and the selected ones are reused for the aggregation softmax.
        """
        n = self.store.n
        m_min, m_max, k_min, k_max = self.cfg.sizes(n)
        g = self.schedule.g(t)
        m_t = jnp.floor(m_min + (m_max - m_min) * (1.0 - g)).astype(jnp.int32)
        k_t = jnp.floor(k_min + (k_max - k_min) * g).astype(jnp.int32)
        a = jnp.asarray(self.schedule.a)[t]
        sig = jnp.asarray(self.schedule.b)[t] / a
        q = x_t / a
        cand = self.coarse(q, m_max)                        # top-m sorted
        d2 = ops.support_distances(q, self.X, cand, x_norms=self.x_norms,
                                   backend=self.backend)
        cand_mask = jnp.arange(m_max)[None, :] < m_t
        d2 = jnp.where(cand_mask, d2, jnp.inf)
        neg, pos = jax.lax.top_k(-d2, k_max)
        idx = jnp.take_along_axis(cand, pos, axis=-1)
        # selection distances (neg == -d2) reused for the softmax
        # (k_max <= m_min <= m_t, so every selected candidate is valid
        # and the distances are finite)
        lg = neg / (2.0 * sig * sig)
        k_mask = jnp.arange(k_max)[None, :] < k_t
        lg = jnp.where(k_mask, lg, NEG_INF)
        out = ops.golden_support_aggregate(self.X, idx, lg,
                                           backend=self.backend)
        return out.astype(x_t.dtype)

    def full_scan(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Exact posterior mean over the whole store (Eq. 2) via ops."""
        t = int(t)
        a, sig2 = self.constants(t)
        body = lambda x: ops.golden_aggregate(
            x / a, self.X, sig2, x_norms=self.x_norms,
            backend=self.backend).astype(x_t.dtype)
        if not jit:
            return body(x_t)
        fn = self.program(self._key("full_scan", t, x_t),
                          lambda: jax.jit(body))
        return fn(x_t)
