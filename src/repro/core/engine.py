"""Backend-dispatched GoldDiff execution engine.

``GoldDiffEngine`` owns the entire coarse -> fine -> aggregate pipeline
(paper Sec. 3.4) and routes every stage through the kernel layer
(``repro.kernels.ops``), replacing the seed's ad-hoc per-class
``_programs`` dicts and inline jnp hot loops:

* **coarse screening** — proxy distances via ``ops.pdist`` (tiled
  matmul form with precomputed norms) instead of an inline broadcast
  expression;
* **precision re-ranking** — ``ops.golden_rerank`` returns top-k
  indices *and* their exact distances, so the aggregation softmax
  reuses selection distances (the seed recomputed them — and regathered
  the rows — a second time);
* **aggregation** — ``ops.golden_support_aggregate`` (streaming online
  softmax on Pallas backends; scatter + GEMM on the XLA backend) and
  ``ops.golden_aggregate`` for full scans.

Engine features:

* **program cache** — compiled programs keyed on
  ``(kind, t, shape, dtype, backend)``; each timestep has static
  (m_t, k_t) so one XLA program per step (true FLOP savings, the
  paper's complexity table), while ``denoise_masked`` is a
  scan/pjit-compatible program padded to (m_max, k_max) — or, given a
  trajectory-plan bucket's ``caps`` (``repro.core.plan``), padded only
  to that bucket's (m_cap, k_cap, nprobe_cap), which is how
  ``sampler.sample_plan`` serves a whole trajectory with 3-4 compiled
  programs at near-static FLOPs.
* **per-timestep schedule constants** — a_t, sigma_t^2, (m_t, k_t)
  precomputed host-side once per t.
* **bf16 storage with fp32 accumulation** — ``storage_dtype=bfloat16``
  keeps the dataset (and proxy) operands in bf16 for bandwidth while
  row norms stay fp32 (computed from the fp32 master copy) and every
  distance/softmax/accumulation runs in fp32.
* **uniform backends** — ``xla`` (CPU tests, benchmarks, the multi-pod
  dry-run), ``pallas_interpret`` (kernel-body validation on CPU), and
  ``pallas`` (real TPUs) all execute the same pipeline; parity is
  asserted in ``tests/test_engine.py``.

Backend/strategy matrix::

    backend           screening distances     aggregation
    ----------------  ----------------------  --------------------------
    xla + dense       dense GEMM + lookup     scatter + GEMM
    xla + gather      row gather + einsum     row gather + einsum
    pallas_interpret  gather + tiled kernel   gather + streaming kernel
    pallas            gather + tiled kernel   gather + streaming kernel

The xla *strategy* (gather vs dense) is selected per platform at engine
build time: XLA:CPU row gathers run ~50x slower per element than GEMM,
so dense wins whenever the touched rows are a sizable fraction of N,
but the gather form wins below the platform's crossover fraction
(``GATHER_CROSSOVER_FRAC``, measured ~10% of N on CPU; pass
``strategy="measure"`` to probe the live device instead of using the
table).  On TPU the tiled VMEM kernels always gather.

**Streamed exact screening** (``screen=``): the exact coarse stage and
the full scan route through ``ops.screen_topm`` / the streaming LSE
(``kernels/screen.py``) — a fused tiled pdist with a running top-m
(or online-softmax) carry that reads the store exactly once at
O(B * (m + tile)) peak memory instead of materializing [B, N].
``screen="auto"`` keeps the materialized form while the [B, N] buffer
fits the platform budget (``SCREEN_MATERIALIZE_BYTES``; on CPU the one
big GEMM + top_k is ~1.6x faster when it fits) and streams beyond it,
which makes screening and full-scan baselines runnable at N where the
dense matrix cannot be allocated at all.  ``screen_tile`` is part of
every streamed program's cache key.  The same policy applies per shard
inside the sharded entry points (the local [B, n_loc] screen streams
by the same rule).

**Golden Index** (``index=...``): coarse screening routes through the
IVF-clustered ``repro.index.GoldenIndex`` — a tiled centroid scan plus
a gather of only the probed clusters' rows (``ops.ivf_screen``) — with
the probe count nprobe_t driven by the time-aware
``repro.index.ProbeSchedule`` (wide at low SNR, a handful of clusters
at high SNR) plus an occupancy floor (probed windows always hold
>= k_t real rows).  Only the proxy side lives in cluster-sorted order
(reusing the index's own arrays); candidates map through
``index.perm`` into ordinary dataset ids before the re-rank, so the
[N, D] store is never duplicated.  Per-timestep, the engine falls back
to exact dense screening when the scheduled probes would touch more
rows than the platform's gather/GEMM crossover (``index_mode="auto"``;
``"always"`` forces the index, e.g. for recall tests).  Program-cache
keys extend with (nprobe_t, padded candidate count) so indexed and
exact programs never collide.

**Epoch hot-swap** (``install_epoch`` / ``set_serving_epoch`` /
``at_epoch``): every compiled body takes the store/index device arrays
as a real jit argument (:class:`StoreOperands`, threaded by
:meth:`GoldDiffEngine.jitter`) instead of closing over them, so the
operands are *data*, not baked executable constants.  Installing a new
epoch with the same shapes — what the appendable store lifecycle
(``repro.index.ingest``) guarantees across appends — reuses every
compiled program unchanged: a live service grows its golden store with
**zero post-warmup compiles**.  ``at_epoch`` pins a thread's dispatches
to one epoch, which is how the serving runtime lets in-flight waves
finish on the epoch they were admitted under while new waves start on
the swapped one.  Shapes that do change (a capacity rebuild) need a
fresh engine, warmed before cutover (``swap_compat`` names the
mismatch).

**Sharded execution** (``mesh=..., shard_axis=...``): the golden store
— and, when indexed, the global index's cluster-sorted rows, split at
CSR window boundaries (``repro.index.shard``) — is data-sharded across
the devices of one mesh axis, and every public entry point
(``denoise``, ``denoise_masked``, ``select``, ``full_scan``) runs the
same coarse -> fine -> aggregate pipeline under ``jax.jit`` +
``shard_map``:

* shard-local coarse screening (exact ``ops.pdist`` over local rows, or
  ``ops.ivf_screen_local`` over the shard's windows of the *globally
  probed* index), with a cross-shard top-m threshold restricting the
  union of candidates to exactly the single-host candidate set;
* shard-local exact re-rank (``ops.support_distances``, the same
  gather/dense strategy machinery as single-host);
* a cross-shard **two-stage top-k**: local top-k (index, distance)
  pairs are all-gathered — k floats+ints per shard, never data rows —
  and the global k-th distance thresholds each shard's golden members
  (``sharding.crossshard_kth``);
* shard-local unnormalized softmax partials
  (``ops.golden_partial_aggregate``) merged exactly with a log-sum-exp
  ``psum`` (``sharding.lse_merge_mean``) into one
  golden-support aggregate.

Because the candidate partition equals the single-host candidate set
row-for-row (both exact and indexed modes), sharded outputs match the
single-host engine to fp32 reduction order — asserted on emulated
8-device CPU meshes in ``tests/test_sharded_engine.py``.  Program-cache
keys extend with the (shard_axis, n_shards) mesh shape.  The standalone
``distributed_golden_denoise`` composes the same primitives, so there
is one screening implementation in the repo.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.core.plan import (full_scan_costs, fused_step_costs,
                             step_stage_costs)
from repro.core.schedules import Schedule
from repro.distributed.sharding import (gather_global_topk, lse_merge_mean,
                                        shard_map_compat)
from repro.index.schedule import ProbeSchedule
from repro.index.shard import shard_layout
from repro.index.store import GoldenIndex
from repro.kernels import ops, ref
from repro.obs import trace as obs_trace

Array = jnp.ndarray
NEG_INF = -1e30

# Gather/GEMM crossover: the gather-form candidate math beats the dense
# [B, N] GEMM once the touched rows drop below this fraction of N
# (measured on XLA:CPU in PR 2; GPU/TPU entries are conservative tables
# to be refined on real hardware — pass strategy="measure" to probe).
GATHER_CROSSOVER_FRAC = {"cpu": 0.10, "gpu": 0.35, "tpu": 0.50}

# Streamed-vs-materialized screening crossover: the one-pass tiled
# screen (``ops.screen_topm`` / the streaming full-scan LSE) caps peak
# live memory at O(B * (m + tile)), but its running-merge scan
# serializes work that the materialized form hands XLA as one big GEMM
# + top_k.  Re-measured at the PR-10 scan tile (SCAN_TILE=16384;
# N=65536, B=32): streamed 33/64/204 ms at m=512/1638/6553 vs
# materialized 20/40/130 ms — a ~1.6x gap (down from ~2-3x at
# tile=4096), still ~13x less temp memory (benchmarks/
# screen_speedup.py).  A two-level hierarchical merge (per-tile top-m
# + tree reduce, ``screen_topm_scan(hier=True)``) measured ~3-6x
# SLOWER than the carry on XLA:CPU — its TopK custom call fast-paths
# the carry's sorted-prefix input — so the crossover below is
# unchanged: materialize while the [B, N] buffer fits.
# ``screen="auto"`` therefore streams only once the [B, N] fp32 buffer
# would cross this per-platform budget (i.e. exactly when the dense
# path stops being allocatable/cheap); "streamed"/"materialized" force
# either form.  GPU/TPU budgets are conservative HBM-headroom guesses
# to refine on real hardware.
SCREEN_MATERIALIZE_BYTES = {"cpu": 1 << 31, "gpu": 1 << 30, "tpu": 1 << 28}


class StoreOperands(NamedTuple):
    """The engine's device operands for ONE store/index epoch.

    Every compiled body receives this pytree as a real jit *argument*
    (threaded by :meth:`GoldDiffEngine.jitter`) instead of closing over
    engine attributes — closure constants get baked into the XLA
    executable, which is exactly what hot-swapping a grown golden store
    must avoid.  Because the appendable store lifecycle
    (``repro.index.ingest``) keeps shapes static across appends, a new
    epoch with the same shapes reuses every compiled program as-is:
    zero post-warmup compiles on an epoch swap.

    Index fields are ``None`` on unindexed engines (None is empty pytree
    structure, so indexed/unindexed programs cannot collide).
    """

    X: Array                        # [N, D] dataset rows (storage dtype)
    proxy: Array                    # [N, dp] proxy rows (storage dtype)
    x_norms: Array                  # [N] fp32 ||x||^2
    proxy_norms: Array              # [N] fp32 ||proxy||^2
    proxy_sorted: Array | None = None        # [N, dp] cluster-sorted
    proxy_norms_sorted: Array | None = None  # [N] (+inf marks pad slots)
    perm: Array | None = None       # [N] sorted row -> dataset id
    offsets: Array | None = None    # [C+1] CSR window boundaries
    centroids: Array | None = None  # [C, dp]
    centroid_norms: Array | None = None      # [C] (+inf on spare windows)


def measure_crossover(x: Array, x_norms: Array, batch: int = 8,
                      rows: int = 2048, repeats: int = 3) -> float:
    """Probe the live device for the gather/GEMM crossover fraction.

    Times the dense [B, N] GEMM + lookup form against the gather +
    einsum form for ``rows`` touched rows, and extrapolates the touched
    fraction at which they break even (gather cost is ~linear in rows,
    dense cost ~constant).  A coarse estimate is fine here: it only
    picks a strategy, both of which are exact.
    """
    n = x.shape[0]
    rows = min(rows, n)
    q = jnp.zeros((batch, x.shape[1]), x.dtype)
    idx = jnp.tile((jnp.arange(rows) * 997) % n, (batch, 1))
    dense = jax.jit(lambda q, i: jnp.take_along_axis(
        ref.pdist_ref(q, x, x_norms=x_norms), i, -1))
    gather = jax.jit(lambda q, i: ref.support_sqdist_ref(
        q, x[i], x_norms[i]))

    def best(fn):
        jax.block_until_ready(fn(q, idx))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, idx))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_dense, t_gather = best(dense), best(gather)
    return float(np.clip((t_dense / t_gather) * (rows / n), 1e-3, 1.0))


@dataclasses.dataclass(frozen=True)
class GoldDiffConfig:
    """Subset-size schedules as fractions of N (paper defaults, Sec. 4.1)."""

    m_min_frac: float = 1 / 10   # = k_max (paper: random N/10 matches full)
    m_max_frac: float = 1 / 4
    k_min_frac: float = 1 / 20
    k_max_frac: float = 1 / 10
    proxy_factor: int = 4

    def sizes(self, n: int) -> tuple[int, int, int, int]:
        m_min = max(1, int(n * self.m_min_frac))
        m_max = max(m_min, int(n * self.m_max_frac))
        k_min = max(1, int(n * self.k_min_frac))
        k_max = max(k_min, int(n * self.k_max_frac))
        k_max = min(k_max, m_min)  # golden set always fits the candidate set
        return m_min, m_max, k_min, k_max


def schedule_sizes(cfg: GoldDiffConfig, schedule: Schedule, t: int,
                   n: int) -> tuple[int, int]:
    """(m_t, k_t) for integer timestep t (static mode; Eqs. 4/6)."""
    g = schedule.g_np(t)
    m_min, m_max, k_min, k_max = cfg.sizes(n)
    m_t = int(math.floor(m_min + (m_max - m_min) * (1.0 - g)))
    k_t = int(math.floor(k_min + (k_max - k_min) * g))
    return max(1, min(m_t, n)), max(1, min(k_t, m_t, n))


class GoldDiffEngine:
    """Compiled-program cache + kernel routing for the GoldDiff pipeline."""

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 cfg: GoldDiffConfig | None = None, backend: str = "xla",
                 storage_dtype=None, index: GoldenIndex | None = None,
                 probe_schedule: ProbeSchedule | None = None,
                 strategy: str = "auto", index_mode: str = "auto",
                 mesh=None, shard_axis: str = "data",
                 screen: str = "auto", screen_tile: int | None = None,
                 fused: str | bool = "auto", batch_axis: str | None = None):
        if backend not in ops.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {ops.BACKENDS}")
        if strategy not in ("auto", "measure", "gather", "dense"):
            raise ValueError(f"unknown strategy {strategy!r}")
        if screen not in ("auto", "streamed", "materialized"):
            raise ValueError(f"unknown screen mode {screen!r}")
        if index_mode not in ("auto", "always"):
            raise ValueError(f"unknown index_mode {index_mode!r}")
        if fused not in ("auto", True, False):
            raise ValueError(f"unknown fused mode {fused!r}; expected "
                             f"'auto', True or False")
        if mesh is not None and shard_axis not in mesh.axis_names:
            raise ValueError(f"shard_axis {shard_axis!r} not in mesh axes "
                             f"{mesh.axis_names}")
        if batch_axis is not None:
            if mesh is None:
                raise ValueError("batch_axis requires a mesh")
            if batch_axis not in mesh.axis_names:
                raise ValueError(f"batch_axis {batch_axis!r} not in mesh "
                                 f"axes {mesh.axis_names}")
            if batch_axis == shard_axis:
                raise ValueError("batch_axis must differ from shard_axis "
                                 f"({shard_axis!r})")
        self.store = store
        self.schedule = schedule
        self.cfg = cfg or GoldDiffConfig()
        self.backend = backend
        self.storage_dtype = storage_dtype
        n = store.n
        # -- Golden Index (clustered, time-aware coarse screening)
        if index is not None and index.n != n:
            raise ValueError(f"index built for N={index.n}, store has N={n}")
        self.index = index
        self.index_mode = index_mode
        self.probe_schedule = probe_schedule or ProbeSchedule()
        if index is not None:
            # ascending-occupancy cumsum: worst-case row count held by
            # any P probed windows (the nprobe occupancy floor).  Host
            # constant — ``install_epoch`` requires identical offsets,
            # so it stays valid across epoch swaps.
            self._occ_cum = np.cumsum(np.sort(np.diff(
                np.asarray(index.offsets))))
        self._nprobe: dict[int, int] = {}
        # -- epoch-swappable store operands (see StoreOperands): the
        # construction store/index become epoch 0.  ``self.X`` etc. are
        # *properties* resolving through the current epoch (or, inside a
        # traced body, through the operands ``jitter`` threaded in).
        self._tls = threading.local()
        self._epochs: dict[int, StoreOperands] = {
            0: self._make_operands(store, index)}
        self._serving_epoch = 0
        # -- streamed-vs-materialized exact screening (build-time policy)
        self.screen = screen
        # None -> per-path default (SCAN_TILE for lax.scan, the VMEM
        # block for Pallas); an explicit int forces both
        self.screen_tile = None if screen_tile is None else int(screen_tile)
        # -- per-platform gather-vs-dense strategy (build-time selection)
        platform = jax.default_backend()
        self._screen_budget = SCREEN_MATERIALIZE_BYTES.get(platform, 1 << 31)
        if strategy == "measure":
            self.crossover_frac = measure_crossover(self.X, self.x_norms)
        else:
            self.crossover_frac = GATHER_CROSSOVER_FRAC.get(platform, 0.10)
        if strategy in ("gather", "dense"):
            self.strategy = strategy
        else:
            # the fine stage touches m_t <= m_max rows per query
            m_max_frac = self.cfg.sizes(n)[1] / n
            self.strategy = ("gather" if m_max_frac <= self.crossover_frac
                             else "dense")
        # -- fused single-pass step (kernels/fused_step.py) policy
        self.fused = fused
        # -- sharded execution (data-sharded store over one mesh axis;
        # optionally batch-sharded queries over a second axis)
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.batch_axis = batch_axis
        if mesh is not None:
            self.n_shards = int(mesh.shape[shard_axis])
            self.batch_shards = (1 if batch_axis is None
                                 else int(mesh.shape[batch_axis]))
            self._layout = shard_layout(store, mesh, shard_axis, index=index,
                                        storage_dtype=storage_dtype)
        else:
            self.n_shards = 1
            self.batch_shards = 1
            self._layout = None
        # Per-timestep schedule constants, computed host-side exactly once.
        self._consts: dict[int, tuple[float, float]] = {}
        self._sizes: dict[int, tuple[int, int]] = {}
        self._stage_costs: dict = {}
        self._programs: dict = {}
        # monotonic build counter: the serving runtime diffs it across a
        # segment dispatch to detect post-warmup compiles (a cache-size
        # delta misses evict-then-rebuild recompile storms)
        self._builds = 0

    # -- epoch-swappable store operands ---------------------------------------
    def _make_operands(self, store: DatasetStore,
                       index: GoldenIndex | None) -> StoreOperands:
        """Device operands for one (store, index) epoch.

        Dataset-side operands optionally drop to low-precision storage;
        norms always stay fp32, computed from the master copy (exact
        even under bf16).  Only the PROXY side lives in cluster-sorted
        order (the index already materializes it); X is addressed
        through ``perm`` — one [B, R] int gather — instead of
        duplicating the whole [N, D] store in sorted order.
        """
        sd = self.storage_dtype
        X, proxy = store.X, store.proxy
        if sd is not None and X.dtype != sd:
            X = X.astype(sd)
            proxy = proxy.astype(sd)
        kw = {}
        if index is not None:
            ps = index.proxy_sorted
            if sd is not None and ps.dtype != sd:
                ps = ps.astype(sd)
            kw = dict(proxy_sorted=ps,
                      proxy_norms_sorted=index.proxy_norms_sorted
                      .astype(jnp.float32),
                      perm=index.perm, offsets=index.offsets,
                      centroids=index.centroids,
                      centroid_norms=index.centroid_norms)
        return StoreOperands(X=X, proxy=proxy,
                             x_norms=store.x_norms.astype(jnp.float32),
                             proxy_norms=store.proxy_norms
                             .astype(jnp.float32), **kw)

    def _operands(self) -> StoreOperands:
        """Operand resolution order: the pytree bound by an in-flight
        ``jitter`` trace (tracers), else the pinned/serving epoch."""
        bound = getattr(self._tls, "bound", None)
        if bound is not None:
            return bound
        return self._epochs[self.call_epoch]

    @property
    def call_epoch(self) -> int:
        """Epoch the *next* dispatch resolves operands from: the epoch
        pinned by an enclosing :meth:`at_epoch` (how in-flight serving
        waves finish on the epoch they were admitted under), else the
        serving epoch."""
        pinned = getattr(self._tls, "pinned", None)
        return self._serving_epoch if pinned is None else pinned

    @property
    def serving_epoch(self) -> int:
        return self._serving_epoch

    # operand views (read-only; resolve per-epoch, or to tracers inside
    # a jitter-traced body)
    @property
    def X(self) -> Array:
        return self._operands().X

    @property
    def proxy(self) -> Array:
        return self._operands().proxy

    @property
    def x_norms(self) -> Array:
        return self._operands().x_norms

    @property
    def proxy_norms(self) -> Array:
        return self._operands().proxy_norms

    @property
    def proxy_sorted(self) -> Array:
        return self._operands().proxy_sorted

    @property
    def proxy_norms_sorted(self) -> Array:
        return self._operands().proxy_norms_sorted

    @property
    def index_perm(self) -> Array:
        return self._operands().perm

    def swap_compat(self, store: DatasetStore,
                    index: GoldenIndex | None) -> str | None:
        """Can ``(store, index)`` hot-swap into this engine's compiled
        programs?  Returns None when compatible, else a human-readable
        reason.

        Compatibility = every *static* ingredient of a compiled program
        (and of the host-side per-timestep constants) is unchanged:
        array shapes, indexed-ness, cluster count, padded probe width,
        and the CSR offsets themselves (they feed the static nprobe
        occupancy floor).  The appendable store lifecycle
        (``repro.index.ingest``) is built to preserve all of these
        across appends; a capacity rebuild changes them and needs a
        fresh engine (warmed before cutover by the caller).
        """
        if self.mesh is not None:
            return ("sharded engines do not hot-swap (the mesh layout "
                    "bakes per-shard arrays; rebuild the engine)")
        if (store.n, store.dim) != (self.store.n, self.store.dim):
            return (f"store shape ({store.n}, {store.dim}) != engine's "
                    f"({self.store.n}, {self.store.dim})")
        if (index is None) != (self.index is None):
            return "indexed-ness differs from the engine's"
        if index is not None:
            if index.num_clusters != self.index.num_clusters:
                return (f"num_clusters {index.num_clusters} != "
                        f"{self.index.num_clusters}")
            if index.max_cluster != self.index.max_cluster:
                return (f"max_cluster {index.max_cluster} != "
                        f"{self.index.max_cluster}")
            if not np.array_equal(np.asarray(index.offsets),
                                  np.asarray(self.index.offsets)):
                return ("CSR offsets differ (the static nprobe "
                        "occupancy floor depends on them)")
        return None

    def install_epoch(self, epoch: int, store: DatasetStore,
                      index: GoldenIndex | None = None) -> None:
        """Install ``(store, index)`` as a standby epoch.

        Shapes must match the construction epoch (``swap_compat``) —
        same shapes means every already-compiled program serves the new
        operands unmodified, so the swap costs zero compiles.  The
        serving epoch is unchanged until :meth:`set_serving_epoch`.
        """
        reason = self.swap_compat(store, index)
        if reason is not None:
            raise ValueError(f"epoch {epoch} cannot hot-swap: {reason}")
        self._epochs[int(epoch)] = self._make_operands(store, index)

    def set_serving_epoch(self, epoch: int) -> None:
        if int(epoch) not in self._epochs:
            raise KeyError(f"epoch {epoch} is not installed "
                           f"(have {sorted(self._epochs)})")
        self._serving_epoch = int(epoch)

    def retire_epoch(self, epoch: int) -> None:
        """Drop a standby epoch's operands (frees device memory)."""
        if int(epoch) == self._serving_epoch:
            raise ValueError(f"cannot retire the serving epoch {epoch}")
        self._epochs.pop(int(epoch), None)

    @contextlib.contextmanager
    def at_epoch(self, epoch: int):
        """Pin dispatches in this thread to ``epoch``'s operands (the
        serving runtime wraps each wave's segment in this, so in-flight
        waves finish on the epoch they were admitted under)."""
        prev = getattr(self._tls, "pinned", None)
        self._tls.pinned = int(epoch)
        try:
            yield
        finally:
            self._tls.pinned = prev

    def current_operands(self) -> StoreOperands:
        return self._epochs[self.call_epoch]

    @staticmethod
    def _ops_sig(ops_: StoreOperands) -> tuple:
        return tuple(None if a is None else (tuple(a.shape), str(a.dtype))
                     for a in ops_)

    def jitter(self, fn, aot_specs: tuple | None = None):
        """Epoch-aware ``jax.jit``: compile ``fn`` with the store
        operands threaded as real arguments, not baked constants.

        The returned callable has ``fn``'s own signature; at each call
        it resolves the current (or ``at_epoch``-pinned) epoch's
        operands and passes them positionally, so one compiled
        executable serves every installed epoch with the same shapes.
        Inside the traced body the engine's operand properties resolve
        to the threaded tracers (thread-local bind), which is why the
        pipeline-stage methods need no signature changes.

        ``aot_specs`` (a tuple of ``ShapeDtypeStruct``) AOT-lowers for
        those input avals immediately — the serving warmup path.  AOT
        executables are cached per operand-shape signature; an epoch
        whose shapes were never lowered falls back to a fresh compile,
        counted in ``_builds`` so the post-warmup recompile guard stays
        honest.  Sharded engines return plain ``jax.jit`` (their
        operands live in the mesh layout; they do not hot-swap).
        """
        if self.mesh is not None:
            return jax.jit(fn)

        def traced(ops_, *args):
            self._tls.bound = ops_
            try:
                return fn(*args)
            finally:
                self._tls.bound = None

        jf = jax.jit(traced)
        if aot_specs is None:
            return lambda *args: jf(self.current_operands(), *args)
        ops0 = self.current_operands()
        execs = {self._ops_sig(ops0): jf.lower(ops0, *aot_specs).compile()}

        def call(*args):
            ops_ = self.current_operands()
            sig = self._ops_sig(ops_)
            compiled = execs.get(sig)
            if compiled is None:         # changed-shape epoch: honest
                self._builds += 1        # post-warmup compile accounting
                compiled = jf.lower(ops_, *aot_specs).compile()
                execs[sig] = compiled
            return compiled(ops_, *args)

        return call

    # -- precomputed per-timestep constants ----------------------------------
    def sizes(self, t: int) -> tuple[int, int]:
        if t not in self._sizes:
            self._sizes[t] = schedule_sizes(self.cfg, self.schedule, t,
                                            self.store.n)
        return self._sizes[t]

    def constants(self, t: int) -> tuple[float, float]:
        """(a_t, sigma_t^2) as host floats for static-t programs."""
        if t not in self._consts:
            a = float(self.schedule.a[t])
            sig2 = float(self.schedule.sigma_np(t)) ** 2
            self._consts[t] = (a, sig2)
        return self._consts[t]

    def nprobe(self, t: int) -> int:
        """Scheduled probe count nprobe_t for a static timestep.

        Beyond the ProbeSchedule value, an **occupancy floor** is
        enforced: even the nprobe_t *smallest* windows must hold k_t
        real rows, so the golden support can always be filled with
        valid candidates and ``select()`` never returns padding ids.
        """
        if t not in self._nprobe:
            m_t, k_t = self.sizes(t)
            p = self.probe_schedule.nprobe(
                self.schedule.g_np(t), m_t, self.store.n,
                self.index.num_clusters)
            need = int(np.searchsorted(self._occ_cum, k_t) + 1)
            self._nprobe[t] = min(max(p, need), self.index.num_clusters)
        return self._nprobe[t]

    def padded_m(self, t: int) -> int:
        """Indexed candidate count: the probed capacity nprobe_t * L.

        IVF-Flat convention: *everything probed is re-ranked* — the
        time-aware candidate budget is nprobe_t itself (the capacity
        floor keeps it >= safety * m_t), and skipping the coarse top-m
        select over the gathered rows is what makes the indexed stage
        fast on every backend.
        """
        return self.nprobe(t) * self.index.max_cluster

    def use_index(self, t: int) -> bool:
        """Route coarse screening through the index at this timestep?

        ``auto`` falls back to the exact dense scan whenever the probed
        rows would exceed the platform's gather/GEMM crossover fraction
        of N — indexed screening degrades to exact screening, never to
        a slower program.
        """
        if self.index is None:
            return False
        if self.index_mode == "always":
            return True
        touched = self.nprobe(t) * self.index.max_cluster
        return touched <= self.crossover_frac * self.store.n

    def strategy_for(self, t: int) -> str:
        """Per-step candidate-math strategy.

        Indexed steps always gather: their candidate set is the probed
        capacity (small by the use_index rule), and the dense form's
        [B, N] GEMM would nullify the index's sublinear coarse stage.
        Exact steps keep the build-time platform selection (sized for
        the non-indexed m_max).
        """
        return "gather" if self.use_index(t) else self.strategy

    def use_fused(self, t: int) -> bool:
        """Route this static step through the fused single-pass kernel
        (``ops.fused_step``; program kind ``"fused_step"``)?

        Indexed steps never fuse — the IVF gather path's sublinear
        coarse stage is the whole point of the index, and the one-pass
        streaming kernel reads every store row.  ``True`` forces fusion
        on every exact step; ``auto`` fuses exactly where the staged
        pipeline pays for dense [B, N]-shaped work anyway: when the
        per-step strategy is "dense" (single-host), or on any exact
        sharded step (the fused sharded form additionally overlaps the
        cross-shard collectives with shard-local compute).  On
        gather-strategy steps (m_t far below the platform crossover)
        the staged re-rank touches only m_t rows, which a full-store
        streaming pass cannot beat, so ``auto`` leaves them staged.
        """
        if self.fused is False:
            return False
        if self.use_index(t):
            return False
        if self.fused is True:
            return True
        if self.mesh is not None:
            return True
        return self.strategy_for(t) == "dense"

    def _fused_masked(self, use_ix: bool) -> bool:
        """Masked-path fused decision.  The masked path is ONE program
        (per caps bucket), so the choice is global over the bucket —
        same rule as :meth:`use_fused` with the build-time strategy."""
        if self.fused is False or use_ix:
            return False
        if self.fused is True:
            return True
        if self.mesh is not None:
            return True
        return self.strategy == "dense"

    def use_stream(self, batch: int, n: int | None = None) -> bool:
        """Stream the exact screen / full scan at this (batch, store) size?

        ``auto`` streams exactly when the materialized [B, N] fp32
        distance/logits buffer would cross the platform's budget
        (``SCREEN_MATERIALIZE_BYTES``) — the streamed form is then the
        only one that allocates, at O(B * (m + tile)) live memory.  ``n``
        overrides the store size (the sharded bodies pass their local
        row count).
        """
        if self.screen != "auto":
            return self.screen == "streamed"
        n = self.store.n if n is None else n
        return 4 * int(batch) * int(n) > self._screen_budget

    # -- program cache -------------------------------------------------------
    def program(self, key, build):
        """Compiled-program cache keyed on (kind, t, shape, dtype,
        backend, strategy) (+ (nprobe_t, padded candidate count) when
        the step is indexed).

        This lookup is the engine's *dispatch seam*: when a fault hook
        is installed (``ops.set_dispatch_hook``, see
        ``repro.launch.faults``) it may evict cache entries before the
        hit/miss check (simulated recompile storms) and wrap the
        returned callable per dispatch (injected NaNs / latency /
        raised executor errors).  The cache itself always stores the
        unwrapped callable, and with no hook installed the raw cached
        object is returned — identity, zero overhead, zero recompiles
        (the CI recompile guard covers the warm path).
        """
        hook = ops.dispatch_hook()
        if hook is not None:
            hook.on_program(self, key)
        if key not in self._programs:
            self._programs[key] = build()
            self._builds += 1
        fn = self._programs[key]
        if hook is not None:
            return hook.wrap(key, fn)
        return fn

    def _index_sig(self, t: int) -> tuple:
        """(nprobe_t, padded candidate count) — keeps indexed and exact
        programs for the same (t, shape) from colliding in the cache."""
        if not self.use_index(t):
            return ()
        return (self.nprobe(t), self.padded_m(t))

    def _key(self, kind: str, t, x_t: Array, extra: tuple = ()):
        mesh_sig = () if self.mesh is None else \
            (("mesh", self.shard_axis, self.n_shards,
              self.batch_axis, self.batch_shards),)
        # streamed screening programs tile the store, so the tile size
        # is part of the compiled program's identity; sharded programs
        # stream by their LOCAL row count (what the shard bodies see)
        n_sig = None if self.mesh is None else self._layout.n_loc
        screen_sig = (("screen", "streamed", self.screen_tile)
                      if self.use_stream(x_t.shape[0], n_sig)
                      else ("screen", "materialized"),)
        return (kind, t, x_t.shape, str(x_t.dtype), self.backend,
                self.strategy_for(t)) + mesh_sig + screen_sig + tuple(extra)

    # -- pipeline stages (traceable bodies) ----------------------------------
    def _proxy_query(self, q: Array) -> Array:
        q_img = q.reshape(q.shape[:-1] + tuple(self.store.image_shape))
        qp = downsample_proxy(q_img, self.cfg.proxy_factor)
        if self.storage_dtype is not None:
            qp = qp.astype(self.storage_dtype)
        return qp

    def coarse(self, q: Array, m: int) -> Array:
        """Top-m candidates by exact proxy distance; [B, m].

        Routed through ``ops.screen_topm``: one pass over the proxy
        store either way, materializing the [B, N] distance matrix only
        below the streamed-vs-materialized crossover (``use_stream``).
        """
        return ops.screen_topm(self._proxy_query(q), self.proxy, m,
                               x_norms=self.proxy_norms,
                               tile=self.screen_tile,
                               stream=self.use_stream(q.shape[0]),
                               backend=self.backend)[0]

    def coarse_indexed(self, q: Array, m: int, nprobe_max: int,
                       nprobe=None) -> tuple[Array, Array]:
        """Candidates via the Golden Index; O(C d + nprobe L) in the
        capacity mode the engine uses (``m = nprobe_max * L``: every
        probed row feeds the exact re-rank, no proxy pass needed).

        Returns ``(pos, d2)`` with positions in **cluster-sorted** row
        space (+inf ``d2`` marks slots beyond the probed capacity).
        """
        o = self._operands()
        return ops.ivf_screen(self._proxy_query(q), o.proxy_sorted,
                              o.proxy_norms_sorted, o.offsets,
                              o.centroids, o.centroid_norms, m,
                              nprobe_max, self.index.max_cluster,
                              nprobe=nprobe, backend=self.backend)

    def _select_body(self, q: Array, t: int) -> tuple[Array, Array]:
        """(idx, d2) of the golden support for a rescaled query (static
        t).  ``idx`` are dataset row ids on both paths (indexed
        candidates map through ``index.perm`` before the re-rank)."""
        m_t, k_t = self.sizes(t)
        if self.use_index(t):
            mp = self.padded_m(t)
            pos, pd2 = self.coarse_indexed(q, mp, self.nprobe(t))
            cand = self.index_perm[pos]
            return ops.golden_rerank(q, self.X, cand, min(k_t, mp),
                                     x_norms=self.x_norms,
                                     backend=self.backend,
                                     strategy="gather",
                                     valid=jnp.isfinite(pd2))
        cand = self.coarse(q, m_t)
        return ops.golden_rerank(q, self.X, cand, k_t, x_norms=self.x_norms,
                                 backend=self.backend,
                                 strategy=self.strategy)

    def _select_ids_body(self, q: Array, t: int) -> Array:
        """Golden support as dataset row ids.

        The nprobe occupancy floor guarantees the probed windows hold
        >= k_t real rows, so these are always valid candidates."""
        return self._select_body(q, t)[0]

    def _denoise_body(self, x_t: Array, t: int) -> Array:
        """Fused static step: coarse -> rerank -> aggregate, distances
        computed exactly once."""
        a, sig2 = self.constants(t)
        q = x_t / a
        idx, d2 = self._select_body(q, t)
        # +inf distances (capacity-padded slots) clamp to NEG_INF logits
        lg = jnp.maximum(-d2 / (2.0 * sig2), NEG_INF)
        out = ops.golden_support_aggregate(self.X, idx, lg,
                                           backend=self.backend,
                                           strategy=self.strategy_for(t))
        return out.astype(x_t.dtype)

    def _fused_body(self, x_t: Array, t: int) -> Array:
        """Fused single-pass static step (``ops.fused_step``): coarse
        screen, exact re-rank and aggregation in one program; the
        streaming forms never materialize a [B, N] distance matrix or
        a [B, m, D] candidate tensor."""
        a, sig2 = self.constants(t)
        m_t, k_t = self.sizes(t)
        q = x_t / a
        out = ops.fused_step(q, self._proxy_query(q), self.X, self.proxy,
                             m_t, k_t, sig2, x_norms=self.x_norms,
                             proxy_norms=self.proxy_norms,
                             backend=self.backend, strategy=self.strategy,
                             stream=self.use_stream(x_t.shape[0]),
                             tile=self.screen_tile)
        return out.astype(x_t.dtype)

    # -- sharded (mesh / shard_map) pipeline ---------------------------------
    def _shard_mapped(self, local, n_extra_rep: int = 0):
        """shard_map ``local`` over the layout's stacked per-shard arrays.

        The returned callable takes ``(x_t, *extra_replicated)``; the
        store (and index routing) arrays are threaded as explicit
        shard_map operands with ``P(shard_axis)`` specs — the query and
        the (small) centroid table are replicated.
        """
        L = self._layout
        row = [L.X, L.x_norms, L.proxy, L.proxy_norms, L.ids]
        rep = []
        if L.indexed:
            row += [L.offsets, L.wrange]
            rep = [L.centroids, L.centroid_norms]
        sp = PartitionSpec(self.shard_axis)
        # 2D (batch x store) mesh: the query batch (and the output)
        # shard over ``batch_axis`` while the store stays sharded over
        # ``shard_axis``; every cross-shard collective names only
        # shard_axis, so it runs independently per batch group.
        bsp = (PartitionSpec() if self.batch_axis is None
               else PartitionSpec(self.batch_axis))
        in_specs = (sp,) * len(row) + (bsp,) + \
            (PartitionSpec(),) * (n_extra_rep + len(rep))
        mapped = shard_map_compat(local, self.mesh, in_specs, bsp)

        def call(x_t, *extra):
            if self.batch_shards > 1 and x_t.shape[0] % self.batch_shards:
                raise ValueError(
                    f"batch {x_t.shape[0]} does not divide over "
                    f"batch_axis {self.batch_axis!r} "
                    f"(size {self.batch_shards})")
            return mapped(*row, x_t, *extra, *rep)

        return call

    def _unpack_local(self, args, n_extra: int = 0):
        """Split a shard_map body's operands back into named pieces
        (squeezing the leading size-1 shard dim off the sharded ones)."""
        L = self._layout
        args = list(args)
        X, xn, pr, pn, ids = (z[0] for z in args[:5])
        i = 5
        offs = wr = cents = cnorms = None
        if L.indexed:
            offs, wr = args[5][0], args[6][0]
            i = 7
        x_t = args[i]
        extra = tuple(args[i + 1: i + 1 + n_extra])
        if L.indexed:
            cents, cnorms = args[i + 1 + n_extra], args[i + 2 + n_extra]
        return (X, xn, pr, pn, ids, offs, wr, cents, cnorms, x_t) + extra

    def _sharded_static(self, kind: str, t: int):
        """Build the shard_map'd program for a static timestep.

        Shard-local coarse screen (exact or indexed) -> shard-local
        exact re-rank -> cross-shard two-stage top-k -> LSE-merged
        golden aggregate.  The surviving candidate partition equals the
        single-host candidate set row-for-row, so the result matches
        the single-host program to fp32 reduction order.
        """
        # deferred: retrieval module-imports repro.core.dataset, so a
        # top-level import would cycle when repro.distributed is the
        # first package imported
        from repro.distributed.retrieval import (golden_local_topk,
                                                 local_coarse_exact,
                                                 merged_golden_mean)

        L, ax = self._layout, self.shard_axis
        a, sig2 = self.constants(t)
        m_t, k_t = self.sizes(t)
        m_cap = min(m_t, L.n_loc)
        use_ix = self.use_index(t)
        if use_ix:
            p_t = self.nprobe(t)
            w_cap = min(p_t, L.w_max)
            k_cap = max(1, min(k_t, w_cap * L.max_cluster))
            strategy = "gather"
        else:
            k_cap = max(1, min(k_t, m_cap))
            strategy = self.strategy
        backend = self.backend

        def local(*args):
            (X, xn, pr, pn, ids, offs, wr, cents, cnorms,
             x_t) = self._unpack_local(args)
            q = x_t / a
            qp = self._proxy_query(q)
            if use_ix:
                cand, pd2 = ops.ivf_screen_local(
                    qp, offs, cents, cnorms, wr[0], wr[1], p_t,
                    L.max_cluster, w_cap, L.n_loc, backend=backend)
                valid = jnp.isfinite(pd2)
            else:
                cand, valid = local_coarse_exact(
                    qp, pr, pn, m_cap, m_t, m_t, ax, backend=backend,
                    stream=self.use_stream(x_t.shape[0], L.n_loc),
                    tile=self.screen_tile)
            idx, neg, kth = golden_local_topk(X, xn, q, cand, valid, k_cap,
                                              k_t, k_t, ax, backend=backend,
                                              strategy=strategy)
            if kind == "select":
                return gather_global_topk(ids[idx], neg, k_t, ax)
            out = merged_golden_mean(X, idx, neg, kth, sig2, ax,
                                     strategy=strategy)
            return out.astype(x_t.dtype)

        return self._shard_mapped(local)

    def _sharded_fused_static(self, t: int):
        """Sharded fused static step: same math as
        :meth:`_sharded_static` (bitwise — the fused local step reuses
        the identical kernel ops) with the cross-shard collectives
        issued ahead of the shard-local compute they overlap
        (``distributed/retrieval.fused_local_step``)."""
        from repro.distributed.retrieval import fused_local_step

        L, ax = self._layout, self.shard_axis
        a, sig2 = self.constants(t)
        m_t, k_t = self.sizes(t)
        m_cap = min(m_t, L.n_loc)
        k_cap = max(1, min(k_t, m_cap))
        strategy = self.strategy
        backend = self.backend

        def local(*args):
            (X, xn, pr, pn, ids, offs, wr, cents, cnorms,
             x_t) = self._unpack_local(args)
            q = x_t / a
            qp = self._proxy_query(q)
            out = fused_local_step(
                X, xn, q, qp, pr, pn, m_cap, m_t, m_t, k_cap, k_t, k_t,
                sig2, ax, backend=backend, strategy=strategy,
                stream=self.use_stream(x_t.shape[0], L.n_loc),
                tile=self.screen_tile)
            return out.astype(x_t.dtype)

        return self._shard_mapped(local)

    def _sharded_masked_body(self, x_t: Array, t: Array,
                             caps=None) -> Array:
        """Scan/pjit-compatible sharded step (one program, traced t).

        Mirrors ``denoise_masked`` exactly — same (m_t, k_t) masks,
        per-bucket caps, probe schedule, and occupancy floor — with
        the k_t cut applied through the cross-shard threshold instead
        of a positional mask (the same set, up to distance ties).
        """
        from repro.distributed.retrieval import (fused_local_step,
                                                 golden_local_topk,
                                                 local_coarse_exact,
                                                 merged_golden_mean)

        L, ax = self._layout, self.shard_axis
        n = self.store.n
        m_min, m_max, k_min, k_max = self.cfg.sizes(n)
        m_cap, k_cap, p_cap, use_ix = self._masked_caps(caps)
        fused = self._fused_masked(use_ix)
        m_loc = min(m_cap, L.n_loc)
        if use_ix:
            p_pad = p_cap
            w_cap = min(p_pad, L.w_max)
            k_loc = max(1, min(k_cap, w_cap * L.max_cluster))
            strategy = "gather"
        else:
            k_loc = max(1, min(k_cap, m_loc))
            strategy = self.strategy
        backend = self.backend

        def local(*args):
            (X, xn, pr, pn, ids, offs, wr, cents, cnorms, x_t,
             tt) = self._unpack_local(args, n_extra=1)
            g = self.schedule.g(tt)
            m_t = jnp.floor(m_min + (m_max - m_min) * (1.0 - g)) \
                .astype(jnp.int32)
            k_t = jnp.floor(k_min + (k_max - k_min) * g).astype(jnp.int32)
            m_t = jnp.minimum(m_t, m_cap)
            k_t = jnp.minimum(k_t, k_cap)
            a = jnp.asarray(self.schedule.a)[tt]
            sig = jnp.asarray(self.schedule.b)[tt] / a
            q = x_t / a
            qp = self._proxy_query(q)
            if fused:
                out = fused_local_step(
                    X, xn, q, qp, pr, pn, m_loc, m_cap, m_t, k_loc,
                    k_cap, k_t, sig * sig, ax, backend=backend,
                    strategy=strategy,
                    stream=self.use_stream(x_t.shape[0], L.n_loc),
                    tile=self.screen_tile)
                return out.astype(x_t.dtype)
            if use_ix:
                nprobe_t = self._masked_nprobe_t(g, m_t, k_t, p_pad)
                cand, pd2 = ops.ivf_screen_local(
                    qp, offs, cents, cnorms, wr[0], wr[1], p_pad,
                    L.max_cluster, w_cap, L.n_loc, nprobe=nprobe_t,
                    backend=backend)
                valid = jnp.isfinite(pd2)
            else:
                cand, valid = local_coarse_exact(
                    qp, pr, pn, m_loc, m_cap, m_t, ax, backend=backend,
                    stream=self.use_stream(x_t.shape[0], L.n_loc),
                    tile=self.screen_tile)
            idx, neg, kth = golden_local_topk(X, xn, q, cand, valid, k_loc,
                                              k_cap, k_t, ax,
                                              backend=backend,
                                              strategy=strategy)
            out = merged_golden_mean(X, idx, neg, kth, sig * sig, ax,
                                     strategy=strategy)
            return out.astype(x_t.dtype)

        return self._shard_mapped(local, n_extra_rep=1)(
            x_t, jnp.asarray(t, jnp.int32))

    def _sharded_full_scan(self, t: int):
        """Exact posterior mean over the sharded store: local partial
        softmax states (dense or tile-streamed), one LSE merge."""
        L, ax = self._layout, self.shard_axis
        a, sig2 = self.constants(t)

        def local(*args):
            (X, xn, pr, pn, ids, offs, wr, cents, cnorms,
             x_t) = self._unpack_local(args)
            q = x_t / a
            acc, m_l, l_l = ops.golden_full_partial(
                q, X, sig2, x_norms=xn,
                stream=self.use_stream(x_t.shape[0], L.n_loc),
                tile=self.screen_tile)
            return lse_merge_mean(acc, m_l, l_l, ax).astype(x_t.dtype)

        return self._shard_mapped(local)

    # -- observability (spans around host-level dispatches) -------------------
    def stage_costs(self, kind: str, t: int, batch: int) -> dict:
        """Cached analytic per-stage FLOPs/bytes (``core.plan``'s
        accounting) for one entry-point dispatch.  ``select`` drops the
        aggregate stage (it stops at the golden support)."""
        key = (kind, int(t), int(batch))
        if key not in self._stage_costs:
            if kind == "full_scan":
                costs = full_scan_costs(self, batch)
            elif kind == "fused_step":
                costs = fused_step_costs(self, t, batch)
            else:
                costs = step_stage_costs(self, t, batch)
                if kind == "select":
                    costs = {s: c for s, c in costs.items()
                             if s != "aggregate"}
            self._stage_costs[key] = costs
        return self._stage_costs[key]

    def _traced(self, kind: str, t: int, x_t: Array, fn, compiled: bool):
        """Run ``fn(x_t)`` inside an ``engine.<kind>`` span.

        Only reached when the current tracer is enabled (callers branch
        on ``tracer().enabled`` first, so the disabled path stays
        bit-identical with zero extra work).  Stage point events carry
        the analytic FLOPs/bytes tags; the dispatch blocks inside the
        span so the recorded duration is wall-clock, not enqueue time.
        """
        tr = obs_trace.tracer()
        with tr.span(f"engine.{kind}", t=int(t), backend=self.backend,
                     shape=tuple(x_t.shape), compile=bool(compiled),
                     indexed=bool(self.use_index(t))):
            for stage, c in self.stage_costs(kind, t, x_t.shape[0]).items():
                tr.event(f"stage.{stage}", t=int(t), flops=c["flops"],
                         bytes=c["bytes"])
            out = fn(x_t)
            jax.block_until_ready(out)
        return out

    # -- public entry points -------------------------------------------------
    def select(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Golden support S_t for each query; [B, k_t] (static shapes).

        Always returns dataset row ids (indexed steps map back through
        ``index.perm``).
        """
        t = int(t)
        a, _ = self.constants(t)
        if self.mesh is not None:
            body = lambda: self._sharded_static("select", t)
        else:
            body = lambda: lambda x: self._select_ids_body(x / a, t)
        if not jit:
            return body()(x_t)
        b0 = self._builds
        fn = self.program(self._key("select", t, x_t, self._index_sig(t)),
                          lambda: self.jitter(body()))
        if not obs_trace.tracer().enabled:
            return fn(x_t)
        return self._traced("select", t, x_t, fn, self._builds > b0)

    def denoise(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Full GoldDiff step for the Optimal base (unbiased SS on S_t)."""
        t = int(t)
        fused = self.use_fused(t)
        kind = "fused_step" if fused else "denoise"
        if self.mesh is not None:
            body = (lambda: self._sharded_fused_static(t)) if fused \
                else (lambda: self._sharded_static("denoise", t))
        elif fused:
            body = lambda: lambda x: self._fused_body(x, t)
        else:
            body = lambda: lambda x: self._denoise_body(x, t)
        if not jit:
            return body()(x_t)
        b0 = self._builds
        fn = self.program(self._key(kind, t, x_t, self._index_sig(t)),
                          lambda: self.jitter(body()))
        if not obs_trace.tracer().enabled:
            return fn(x_t)
        return self._traced(kind, t, x_t, fn, self._builds > b0)

    # -- masked (scan/pjit-compatible) path -----------------------------------
    def _masked_nprobe_pad(self) -> int:
        """Worst-case nprobe_t over the whole t grid (static pad for the
        single masked program)."""
        if not hasattr(self, "_nprobe_pad"):
            T = self.schedule.num_steps
            self._nprobe_pad = max(self.nprobe(t) for t in range(1, T + 1))
        return self._nprobe_pad

    def _use_index_masked(self) -> bool:
        """The masked path is ONE program, so the indexed/exact decision
        is global: index only when even the worst-case probe width stays
        below the gather/GEMM crossover.

        ``index_mode="always"`` bypasses that guard: with a wide
        schedule (the default ProbeSchedule has f_hi = 1.0) the single
        program then pays worst-case probes — near the whole store —
        at EVERY step.  That mode exists for correctness testing; for
        performance use "auto", or a capped schedule (see
        ``benchmarks.index_speedup.SCALE_PROBES``)."""
        if self.index is None:
            return False
        if self.index_mode == "always":
            return True
        touched = self._masked_nprobe_pad() * self.index.max_cluster
        return touched <= self.crossover_frac * self.store.n

    def _masked_caps(self, caps) -> tuple[int, int, int, bool]:
        """Resolve a plan bucket's ``caps`` (or None for the legacy
        one-program-per-trajectory mode) into the masked program's
        static pads ``(m_cap, k_cap, nprobe_cap, use_index)``.

        ``caps=None`` pads to the worst case over the whole schedule —
        exactly the single masked program PR 4 served — while a
        ``plan.BucketCaps`` pads only to the bucket's own maxima, which
        is how ``sample_plan`` keeps static mode's FLOP savings at a
        handful of compiled programs (``core/plan.py``).
        """
        n = self.store.n
        _, m_max, _, k_max = self.cfg.sizes(n)
        if caps is None:
            use_ix = self._use_index_masked()
            return (m_max, k_max,
                    self._masked_nprobe_pad() if use_ix else 0, use_ix)
        use_ix = bool(caps.indexed) and self.index is not None
        return (min(int(caps.m_cap), n), int(caps.k_cap),
                int(caps.nprobe_cap), use_ix)

    def _masked_nprobe_t(self, g, m_t, k_t, p_cap: int):
        """Traced probe count for the masked/plan path.

        Mirrors :meth:`nprobe` exactly — the occupancy floor is
        evaluated at the *traced* k_t (``jnp.searchsorted`` over the
        ascending-occupancy cumsum), so on-grid steps probe the same
        windows as their static programs — then clips at the bucket's
        static pad ``p_cap`` (probes beyond the pad have no gather
        lanes to land in).
        """
        c = self.index.num_clusters
        nprobe_t = self.probe_schedule.nprobe_jnp(g, m_t, self.store.n, c)
        need = jnp.searchsorted(jnp.asarray(self._occ_cum, jnp.int32),
                                k_t.astype(jnp.int32)) + 1
        nprobe_t = jnp.maximum(nprobe_t, jnp.minimum(need, c))
        return jnp.clip(nprobe_t, 1, p_cap)

    def denoise_masked(self, x_t: Array, t: Array, caps=None) -> Array:
        """Scan/pjit-compatible step: shapes padded to the caps — the
        global (m_max, k_max) / worst-case probe width by default, or a
        plan bucket's ``caps`` (``plan.BucketCaps``) — sizes enter only
        through masks, ``t`` may be traced.

        Exact candidate distances are computed exactly once (over the
        padded candidate count) and the selected ones are reused for the
        aggregation softmax.
        """
        if self.mesh is not None:
            return self._sharded_masked_body(x_t, t, caps)
        n = self.store.n
        m_min, m_max, k_min, k_max = self.cfg.sizes(n)
        m_cap, k_cap, p_cap, use_ix = self._masked_caps(caps)
        g = self.schedule.g(t)
        m_t = jnp.floor(m_min + (m_max - m_min) * (1.0 - g)).astype(jnp.int32)
        k_t = jnp.floor(k_min + (k_max - k_min) * g).astype(jnp.int32)
        m_t = jnp.minimum(m_t, m_cap)
        k_t = jnp.minimum(k_t, k_cap)
        a = jnp.asarray(self.schedule.a)[t]
        sig = jnp.asarray(self.schedule.b)[t] / a
        q = x_t / a
        if self._fused_masked(use_ix):
            # fused single-pass masked step: the traced (m_t, k_t)
            # masks enter the fused epilogue (same +inf / NEG_INF
            # semantics as the staged masks below)
            out = ops.fused_step(
                q, self._proxy_query(q), self.X, self.proxy,
                m_cap, min(k_cap, m_cap), sig * sig,
                x_norms=self.x_norms, proxy_norms=self.proxy_norms,
                backend=self.backend, strategy=self.strategy,
                stream=self.use_stream(x_t.shape[0]),
                tile=self.screen_tile, m_t=m_t, k_t=k_t)
            return out.astype(x_t.dtype)
        if use_ix:
            # probe width varies with the traced t through the mask; the
            # gather is padded to the bucket's (or the grid's) worst
            # case.  All probed rows are candidates (IVF-Flat), so the
            # time-aware candidate budget is nprobe_t, not the m_t mask.
            p_pad = p_cap
            m_pad = p_pad * self.index.max_cluster
            nprobe_t = self._masked_nprobe_t(g, m_t, k_t, p_pad)
            pos, pd2 = self.coarse_indexed(q, m_pad, p_pad, nprobe=nprobe_t)
            cand = self.index_perm[pos]
            cand_mask = jnp.isfinite(pd2)
            strategy = "gather"          # dense [B, N] math would void
        else:                            # the index's sublinear coarse
            m_pad = m_cap
            cand = self.coarse(q, m_pad)                    # top-m sorted
            cand_mask = jnp.arange(m_pad)[None, :] < m_t
            strategy = self.strategy
        k_pad = min(k_cap, m_pad)
        d2 = ops.support_distances(q, self.X, cand, x_norms=self.x_norms,
                                   backend=self.backend,
                                   strategy=strategy)
        d2 = jnp.where(cand_mask, d2, jnp.inf)
        neg, pos = jax.lax.top_k(-d2, k_pad)
        idx = jnp.take_along_axis(cand, pos, axis=-1)
        # selection distances (neg == -d2) reused for the softmax
        # (k_max <= m_min <= m_t, so in the exact path every selected
        # candidate is valid; indexed capacity-padded slots carry -inf
        # and clamp to NEG_INF -> zero weight)
        lg = jnp.maximum(neg / (2.0 * sig * sig), NEG_INF)
        k_mask = jnp.arange(k_pad)[None, :] < k_t
        lg = jnp.where(k_mask, lg, NEG_INF)
        out = ops.golden_support_aggregate(self.X, idx, lg,
                                           backend=self.backend,
                                           strategy=strategy)
        return out.astype(x_t.dtype)

    def full_scan(self, x_t: Array, t: int, jit: bool = True) -> Array:
        """Exact posterior mean over the whole store (Eq. 2) via ops."""
        t = int(t)
        a, sig2 = self.constants(t)
        if self.mesh is not None:
            body = self._sharded_full_scan(t)
        else:
            body = lambda x: ops.golden_aggregate(
                x / a, self.X, sig2, x_norms=self.x_norms,
                backend=self.backend, stream=self.use_stream(x.shape[0]),
                tile=self.screen_tile).astype(x_t.dtype)
        if not jit:
            return body(x_t)
        b0 = self._builds
        fn = self.program(self._key("full_scan", t, x_t),
                          lambda: self.jitter(body))
        if not obs_trace.tracer().enabled:
            return fn(x_t)
        return self._traced("full_scan", t, x_t, fn, self._builds > b0)
