"""GoldDiff: Dynamic Time-Aware Golden Subset selection (paper Sec. 3.4).

Coarse-to-fine, training-free, plug-and-play:

1. *Adaptive coarse screening* — proxy distances in the downsampled space
   (``DatasetStore.proxy``) pick a candidate set C_t of size

       m_t = floor(m_min + (m_max - m_min) * (1 - g(sigma_t)))      (Eq. 4)

   (monotonically *increasing* as noise decreases: recall safety margin).

2. *Precision golden selection* — exact distances inside C_t pick the
   golden support S_t of size

       k_t = floor(k_min + (k_max - k_min) * g(sigma_t))            (Eq. 6)

   (monotonically *decreasing*: posterior progressive concentration).

3. The base denoiser is evaluated with ``support=S_t`` using the unbiased
   streaming softmax (Sec. 3.2).

Execution is delegated to :class:`repro.core.engine.GoldDiffEngine`,
which routes every stage through the kernel layer
(``repro.kernels.ops``: tiled ``pdist`` screening, ``golden_rerank``
returning indices + distances, streaming ``golden_support_aggregate``)
and caches one compiled program per (timestep, shape, backend, dtype).

Two execution modes:

* ``static`` — each timestep uses its integer (m_t, k_t); separate XLA
  programs per step, true FLOP savings (matches the paper's complexity
  table; used by the benchmarks).
* ``masked`` — a single program padded to (m_max, k_max) with validity
  masks, suitable for ``lax.scan``-based samplers / pjit.  Exact
  candidate distances are computed exactly once per step and reused for
  the aggregation softmax.

Note: Eq. 5 in the paper writes the exact re-ranking distance as
``||x_t - x_i||``; we use the rescaled ``||x_t/a_t - x_i||`` which induces
the same ordering as the true logits (it differs only by the global 1/a_t
factor on the query), so the selected set equals the top-k *by posterior
weight* — the quantity Theorem 1 bounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.core.denoisers import OptimalDenoiser
from repro.core.engine import (GoldDiffConfig, GoldDiffEngine,
                               schedule_sizes)
from repro.core.schedules import Schedule
from repro.kernels import ops

Array = jnp.ndarray

__all__ = ["GoldDiff", "GoldDiffConfig", "GoldDiffEngine", "schedule_sizes",
           "coarse_screen", "golden_select"]


def coarse_screen(store: DatasetStore, q: Array, m: int, proxy_factor: int,
                  backend: str = "xla") -> Array:
    """Top-m candidate indices by proxy distance.  q: [B, D] -> [B, m].

    Routed through ``ops.pdist`` (tiled matmul form, precomputed norms).
    """
    q_img = q.reshape(q.shape[:-1] + tuple(store.image_shape))
    qp = downsample_proxy(q_img, proxy_factor)
    d2 = ops.pdist(qp, store.proxy, x_norms=store.proxy_norms,
                   backend=backend)
    return jax.lax.top_k(-d2, m)[1]


def golden_select(store: DatasetStore, q: Array, cand: Array, k: int,
                  backend: str = "xla") -> Array:
    """Exact re-ranking inside the candidate set (Eq. 5). Returns [B, k].

    Matmul-form distances via ``ops.golden_rerank`` — no [B, m, D]
    broadcast-subtract temporaries.
    """
    idx, _ = ops.golden_rerank(q, store.X, cand, k, x_norms=store.x_norms,
                               backend=backend)
    return idx


class GoldDiff:
    """Plug-and-play wrapper: GoldDiff(base_denoiser) (paper Tab. 5).

    ``backend`` / ``storage_dtype`` / ``strategy`` configure the
    execution engine (see :class:`GoldDiffEngine`); ``backend=None``
    (default) inherits the base denoiser's backend so the fused path and
    the explicit ``support=`` path run the same kernels.  ``xla`` is the
    fast path on CPU, ``pallas`` lowers the TPU kernels.  Pass
    ``index=repro.index.build_index(store)`` to route coarse screening
    through the clustered Golden Index (sublinear in N; probe width set
    by ``probe_schedule``).  Pass ``mesh=``/``shard_axis=`` to
    data-shard the golden store (and the index) across a mesh axis:
    selection and aggregation then run under shard_map with a
    cross-shard two-stage top-k + log-sum-exp merge (see
    :class:`GoldDiffEngine`).  ``screen=``/``screen_tile=`` control the
    streamed-vs-materialized exact screening crossover (one-pass tiled
    top-m at O(B (m + tile)) memory vs the dense [B, N] matrix).
    ``fused="auto"|True|False`` routes eligible steps through the
    single-pass fused step kernel (``kernels/fused_step.py``: screen +
    re-rank + aggregate in one program, no [B, m, D] candidate
    materialization); ``batch_axis=`` shards the *query* batch over a
    second mesh axis (2D batch x store mesh).
    """

    def __init__(self, base, cfg: GoldDiffConfig | None = None,
                 jit_steps: bool = True, backend: str | None = None,
                 storage_dtype=None, index=None, probe_schedule=None,
                 strategy: str = "auto", index_mode: str = "auto",
                 mesh=None, shard_axis: str = "data",
                 screen: str = "auto", screen_tile: int | None = None,
                 fused: str | bool = "auto", batch_axis: str | None = None):
        self.base = base
        self.cfg = cfg or GoldDiffConfig()
        self.store: DatasetStore = base.store
        self.schedule: Schedule = base.schedule
        # GoldDiff always aggregates with the *unbiased* streaming softmax.
        if getattr(base, "weighting", "ss") == "wss":
            base.weighting = "ss"
        self.name = f"golddiff+{base.name}"
        self.jit_steps = jit_steps
        if backend is None:
            backend = getattr(base, "backend", "xla")
        engine_kw = {} if screen_tile is None else \
            {"screen_tile": screen_tile}
        self.engine = GoldDiffEngine(self.store, self.schedule, self.cfg,
                                     backend=backend,
                                     storage_dtype=storage_dtype,
                                     index=index,
                                     probe_schedule=probe_schedule,
                                     strategy=strategy,
                                     index_mode=index_mode,
                                     mesh=mesh, shard_axis=shard_axis,
                                     screen=screen, fused=fused,
                                     batch_axis=batch_axis, **engine_kw)

    @property
    def backend(self) -> str:
        return self.engine.backend

    # -- static mode ---------------------------------------------------------
    def select(self, x_t: Array, t: int) -> Array:
        """Golden support S_t for each query; [B, k_t] (static shapes)."""
        return self.engine.select(x_t, int(t), jit=self.jit_steps)

    def __call__(self, x_t: Array, t: int, support: Array | None = None) -> Array:
        if support is not None:
            return self.base(x_t, t, support=support)
        t = int(t)
        if isinstance(self.base, OptimalDenoiser):
            # fused engine path: selection distances reused for the
            # aggregation softmax, one compiled program per step
            return self.engine.denoise(x_t, t, jit=self.jit_steps)
        # patch-family bases compute their own (feature-space) logits on
        # the golden support; only the selection runs through the engine
        if not self.jit_steps:
            return self.base(x_t, t, support=self.select(x_t, t))
        # patch-based bases build numpy feature caches lazily; force
        # them OUTSIDE the traced program
        if hasattr(self.base, "_dataset_features"):
            self.base._dataset_features(self.base.patch_size(t))
        if self.engine.mesh is not None:
            # sharded selection is its own shard_map program; the base's
            # feature-space logits then run on the replicated support
            return self.base(x_t, t, support=self.select(x_t, t))
        a, _ = self.engine.constants(t)
        fn = self.engine.program(
            self.engine._key(("wrap", self.base.name), t, x_t,
                             self.engine._index_sig(t)),
            lambda: self.engine.jitter(lambda x: self.base(
                x, t, support=self.engine._select_ids_body(x / a, t))))
        return fn(x_t)

    # -- masked (scan-compatible) mode ----------------------------------------
    def call_masked(self, x_t: Array, t: Array, caps=None) -> Array:
        """One-program variant: shapes padded to (m_max, k_max), sizes masked.

        ``t`` may be a traced integer array; m_t/k_t enter only through
        masks, so this body is safe inside ``lax.scan`` / pjit.  (Optimal
        base only: patch bases need static patch sizes -> static mode.)
        ``caps`` (a ``plan.BucketCaps``) pads to one trajectory-plan
        bucket's shapes instead of the global worst case — the body
        ``sampler.sample_plan`` scans per bucket.
        """
        return self.engine.denoise_masked(x_t, t, caps)
