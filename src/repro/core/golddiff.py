"""GoldDiff: Dynamic Time-Aware Golden Subset selection (paper Sec. 3.4).

Coarse-to-fine, training-free, plug-and-play:

1. *Adaptive coarse screening* — proxy distances in the downsampled space
   (``DatasetStore.proxy``) pick a candidate set C_t of size

       m_t = floor(m_min + (m_max - m_min) * (1 - g(sigma_t)))      (Eq. 4)

   (monotonically *increasing* as noise decreases: recall safety margin).

2. *Precision golden selection* — exact distances inside C_t pick the
   golden support S_t of size

       k_t = floor(k_min + (k_max - k_min) * g(sigma_t))            (Eq. 6)

   (monotonically *decreasing*: posterior progressive concentration).

3. The base denoiser is evaluated with ``support=S_t`` using the unbiased
   streaming softmax (Sec. 3.2).

Two execution modes:

* ``static`` — each timestep uses its integer (m_t, k_t); separate XLA
  programs per step, true FLOP savings (matches the paper's complexity
  table; used by the benchmarks).
* ``masked`` — a single program padded to (m_max, k_max) with validity
  masks, suitable for ``lax.scan``-based samplers / pjit.

Note: Eq. 5 in the paper writes the exact re-ranking distance as
``||x_t - x_i||``; we use the rescaled ``||x_t/a_t - x_i||`` which induces
the same ordering as the true logits (it differs only by the global 1/a_t
factor on the query), so the selected set equals the top-k *by posterior
weight* — the quantity Theorem 1 bounds.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import streaming
from repro.core.dataset import DatasetStore, downsample_proxy
from repro.core.schedules import Schedule

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class GoldDiffConfig:
    """Subset-size schedules as fractions of N (paper defaults, Sec. 4.1)."""

    m_min_frac: float = 1 / 10   # = k_max (paper: random N/10 matches full)
    m_max_frac: float = 1 / 4
    k_min_frac: float = 1 / 20
    k_max_frac: float = 1 / 10
    proxy_factor: int = 4

    def sizes(self, n: int) -> tuple[int, int, int, int]:
        m_min = max(1, int(n * self.m_min_frac))
        m_max = max(m_min, int(n * self.m_max_frac))
        k_min = max(1, int(n * self.k_min_frac))
        k_max = max(k_min, int(n * self.k_max_frac))
        k_max = min(k_max, m_min)  # golden set always fits the candidate set
        return m_min, m_max, k_min, k_max


def schedule_sizes(cfg: GoldDiffConfig, schedule: Schedule, t: int,
                   n: int) -> tuple[int, int]:
    """(m_t, k_t) for integer timestep t (static mode)."""
    g = schedule.g_np(t)
    m_min, m_max, k_min, k_max = cfg.sizes(n)
    m_t = int(math.floor(m_min + (m_max - m_min) * (1.0 - g)))
    k_t = int(math.floor(k_min + (k_max - k_min) * g))
    return max(1, min(m_t, n)), max(1, min(k_t, m_t, n))


def coarse_screen(store: DatasetStore, q: Array, m: int,
                  proxy_factor: int) -> Array:
    """Top-m candidate indices by proxy distance.  q: [B, D] -> [B, m]."""
    img_shape = store.image_shape
    q_img = q.reshape(q.shape[:-1] + tuple(img_shape))
    qp = downsample_proxy(q_img, proxy_factor)                 # [B, d]
    d2 = (jnp.sum(qp * qp, -1, keepdims=True) + store.proxy_norms[None, :]
          - 2.0 * qp @ store.proxy.T)
    _, idx = jax.lax.top_k(-d2, m)
    return idx


def golden_select(store: DatasetStore, q: Array, cand: Array, k: int) -> Array:
    """Exact re-ranking inside the candidate set (Eq. 5). Returns [B, k]."""
    xs = store.X[cand]                                          # [B, m, D]
    d2 = jnp.sum((q[:, None, :] - xs) ** 2, axis=-1)            # [B, m]
    _, pos = jax.lax.top_k(-d2, k)
    return jnp.take_along_axis(cand, pos, axis=-1)


class GoldDiff:
    """Plug-and-play wrapper: GoldDiff(base_denoiser) (paper Tab. 5)."""

    def __init__(self, base, cfg: GoldDiffConfig | None = None,
                 jit_steps: bool = True):
        self.base = base
        self.cfg = cfg or GoldDiffConfig()
        self.store: DatasetStore = base.store
        self.schedule: Schedule = base.schedule
        # GoldDiff always aggregates with the *unbiased* streaming softmax.
        if getattr(base, "weighting", "ss") == "wss":
            base.weighting = "ss"
        self.name = f"golddiff+{base.name}"
        # Per-timestep jit cache: the golden path is many small gather/
        # einsum ops whose eager dispatch overhead would swamp the FLOP
        # savings; each t has static (m_t, k_t) so one program per step.
        self.jit_steps = jit_steps
        self._programs: dict = {}

    # -- static mode ---------------------------------------------------------
    def select(self, x_t: Array, t: int) -> Array:
        """Golden support S_t for each query; [B, k_t] (static shapes)."""
        m_t, k_t = schedule_sizes(self.cfg, self.schedule, t, self.store.n)
        a = float(self.schedule.a[t])
        q = x_t / a
        cand = coarse_screen(self.store, q, m_t, self.cfg.proxy_factor)
        return golden_select(self.store, q, cand, k_t)

    def __call__(self, x_t: Array, t: int, support: Array | None = None) -> Array:
        if support is not None:
            return self.base(x_t, t, support=support)
        t = int(t)
        if not self.jit_steps:
            return self.base(x_t, t, support=self.select(x_t, t))
        key = (t, x_t.shape)
        if key not in self._programs:
            # patch-based bases build numpy feature caches lazily; force
            # them OUTSIDE the traced program
            if hasattr(self.base, "_dataset_features"):
                self.base._dataset_features(self.base.patch_size(t))
            self._programs[key] = jax.jit(
                lambda x: self.base(x, t, support=self.select(x, t)))
        return self._programs[key](x_t)

    # -- masked (scan-compatible) mode ----------------------------------------
    def call_masked(self, x_t: Array, t: Array) -> Array:
        """One-program variant: shapes padded to (m_max, k_max), sizes masked.

        ``t`` may be a traced integer array; m_t/k_t enter only through
        masks, so this body is safe inside ``lax.scan`` / pjit.
        """
        n = self.store.n
        m_min, m_max, k_min, k_max = self.cfg.sizes(n)
        g = self.schedule.g(t)
        m_t = jnp.floor(m_min + (m_max - m_min) * (1.0 - g)).astype(jnp.int32)
        k_t = jnp.floor(k_min + (k_max - k_min) * g).astype(jnp.int32)
        a = jnp.asarray(self.schedule.a)[t]
        q = x_t / a
        cand = coarse_screen(self.store, q, m_max, self.cfg.proxy_factor)
        cand_mask = jnp.arange(m_max)[None, :] < m_t             # top-m sorted
        xs = self.store.X[cand]
        d2 = jnp.sum((q[:, None, :] - xs) ** 2, axis=-1)
        d2 = jnp.where(cand_mask, d2, jnp.inf)
        _, pos = jax.lax.top_k(-d2, k_max)
        idx = jnp.take_along_axis(cand, pos, axis=-1)
        k_mask = jnp.arange(k_max)[None, :] < k_t
        return self._base_masked(x_t, t, idx, k_mask)

    def _base_masked(self, x_t: Array, t: Array, idx: Array, mask: Array) -> Array:
        # Masked traced-t path for the Optimal base (the scan sampler's
        # target).  Patch bases need static patch sizes -> static mode only.
        a = jnp.asarray(self.schedule.a)[t]
        sig = jnp.asarray(self.schedule.b)[t] / a
        q = x_t / a
        xs = self.store.X[idx]
        d2 = jnp.sum((q[:, None, :] - xs) ** 2, axis=-1)
        lg = -d2 / (2.0 * sig * sig)
        lg = jnp.where(mask, lg, streaming.NEG_INF)
        w = jax.nn.softmax(lg, axis=-1)
        return jnp.einsum("bk,bkd->bd", w, xs)
