"""Analytical denoisers (the paper's baseline hierarchy, Sec. 4.1).

Every denoiser maps a batch of noisy points ``x_t: [B, D]`` at integer
timestep ``t`` to the posterior-mean estimate ``x0_hat: [B, D]``:

* ``OptimalDenoiser``  — exact empirical-Bayes posterior mean (Eq. 2),
  O(N D) full scan (De Bortoli, 2022).
* ``WienerDenoiser``   — linear-MMSE estimator from dataset mean/covariance
  (Wiener, 1949); O(D^2) but independent of N at sampling time.
* ``PatchDenoiser``    — Kamb & Ganguli (2024) style per-pixel patch
  posterior with a timestep-dependent patch size p_t.
* ``PCADenoiser``      — Lukoianov et al. (2025): patch features projected
  onto a rank-r PCA basis; default *biased* WSS weighting (the smoothing
  bias of Sec. 3.2).

All support an optional per-query golden ``support`` (integer indices
``[B, k]``): when given, the posterior is computed *only* over those
training points — this is the hook GoldDiff plugs into (Tab. 5
"orthogonality": GoldDiff + {Optimal, Kamb, PCA}).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streaming
from repro.core.dataset import DatasetStore, pairwise_sq_dists
from repro.core.schedules import Schedule
from repro.kernels import ops

Array = jnp.ndarray
Weighting = Literal["ss", "wss"]


# ---------------------------------------------------------------------------
# Optimal (full-scan empirical Bayes, Eq. 2)
# ---------------------------------------------------------------------------

class OptimalDenoiser:
    """Exact posterior mean over the training set (or a golden support).

    The unbiased (``ss``) paths route through ``repro.kernels.ops``
    (full scans via the streaming-softmax ``golden_aggregate`` kernel,
    supports via matmul-form ``support_distances`` +
    ``golden_support_aggregate``); ``backend`` selects
    xla / pallas_interpret / pallas uniformly.  The biased ``wss``
    weighting keeps the chunked streaming estimators (the bias model of
    Sec. 3.2 is chunk-structured by definition).
    """

    name = "optimal"

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 chunk: int = 8192, weighting: Weighting = "ss",
                 backend: str = "xla"):
        self.store = store
        self.schedule = schedule
        self.chunk = chunk
        self.weighting = weighting
        self.backend = backend

    def logits(self, x_t: Array, t: int) -> Array:
        """Full-scan logits l_i = -||x_t/a_t - x_i||^2 / (2 sigma_t^2); [B,N]."""
        a = float(self.schedule.a[t])
        sig2 = float(self.schedule.sigma_np(t)) ** 2
        q = x_t / a
        d2 = pairwise_sq_dists(q, self.store.X, self.store.x_norms)
        return -d2 / (2.0 * sig2)

    def __call__(self, x_t: Array, t: int, support: Array | None = None) -> Array:
        if support is not None:
            return self._on_support(x_t, t, support)
        if self.weighting == "wss":
            return streaming.weighted_streaming_softmax_mean(
                self.logits(x_t, t), self.store.X, self.chunk)
        a = float(self.schedule.a[t])
        sig2 = float(self.schedule.sigma_np(t)) ** 2
        return ops.golden_aggregate(x_t / a, self.store.X, sig2,
                                    x_norms=self.store.x_norms,
                                    backend=self.backend).astype(x_t.dtype)

    def _on_support(self, x_t: Array, t: int, idx: Array,
                    mask: Array | None = None) -> Array:
        a = float(self.schedule.a[t])
        sig2 = float(self.schedule.sigma_np(t)) ** 2
        q = x_t / a                                # [B, D]
        d2 = ops.support_distances(q, self.store.X, idx,
                                   x_norms=self.store.x_norms,
                                   backend=self.backend)
        lg = -d2 / (2.0 * sig2)
        if mask is not None:
            lg = jnp.where(mask, lg, streaming.NEG_INF)
        if self.weighting == "wss":
            return streaming.wss_combine(lg, self.store.X[idx])
        return ops.golden_support_aggregate(
            self.store.X, idx, lg, backend=self.backend).astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Wiener (linear MMSE; N enters only through precomputed statistics)
# ---------------------------------------------------------------------------

class WienerDenoiser:
    """x0_hat = mu + Sigma a (a^2 Sigma + b^2 I)^-1 (x_t - a mu).

    Sigma is represented through the SVD of the centered data matrix, so the
    inverse is exact and rank-limited (never materializes the D x D matrix
    unless rank == D).
    """

    name = "wiener"

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 rank: int | None = None):
        self.store = store
        self.schedule = schedule
        x = np.asarray(store.X, np.float64)
        self.mu = jnp.asarray(x.mean(0), jnp.float32)
        xc = x - x.mean(0)
        r = min(x.shape) if rank is None else min(rank, min(x.shape))
        # economical SVD on the smaller Gram side
        _, s, vt = np.linalg.svd(xc, full_matrices=False)
        self.V = jnp.asarray(vt[:r].T, jnp.float32)          # [D, r]
        self.lam = jnp.asarray((s[:r] ** 2) / x.shape[0], jnp.float32)

    def __call__(self, x_t: Array, t: int, support: Array | None = None) -> Array:
        # support is meaningless for a statistics-only estimator (paper
        # excludes Wiener from the orthogonality study for this reason).
        a = float(self.schedule.a[t])
        b = float(self.schedule.b[t])
        z = x_t - a * self.mu
        coeff = (a * self.lam) / (a * a * self.lam + b * b)   # [r]
        proj = z @ self.V                                     # [B, r]
        return self.mu + (proj * coeff) @ self.V.T


# ---------------------------------------------------------------------------
# Patch-based (Kamb & Ganguli) and PCA (Lukoianov et al.)
# ---------------------------------------------------------------------------

def _box_patch_dist(qf: Array, xf: Array, patch: int) -> Array:
    """Per-pixel patch squared distance between query/data feature maps.

    qf: [B, H, W, C], xf: [Nc, H, W, C] -> [B, Nc, H, W]
    (sum over a patch x patch window of per-pixel squared diffs, SAME pad).
    """
    diff2 = jnp.sum((qf[:, None] - xf[None]) ** 2, axis=-1)   # [B,Nc,H,W]
    if patch <= 1:
        return diff2
    return jax.lax.reduce_window(
        diff2, 0.0, jax.lax.add,
        window_dimensions=(1, 1, patch, patch),
        window_strides=(1, 1, 1, 1), padding="SAME")


class PatchDenoiser:
    """Kamb-style per-pixel patch posterior.

    Each pixel is denoised with its own softmax over the training set where
    the logit compares the local patch around that pixel.  Patch size p_t
    follows the paper's heuristic receptive-field schedule: large when the
    noise dominates (global averaging), small near the data manifold
    (locality -> generalization).
    """

    name = "kamb"
    default_weighting: Weighting = "ss"

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 patch_min: int = 3, patch_max: int = 11, chunk: int = 128,
                 weighting: Weighting | None = None):
        if len(store.image_shape) != 3:
            raise ValueError("patch denoisers need [H, W, C] data")
        self.store = store
        self.schedule = schedule
        self.patch_min = patch_min
        self.patch_max = patch_max
        self.chunk = chunk
        self.weighting = weighting or self.default_weighting
        self.h, self.w, self.c = store.image_shape

    # -- hooks overridden by PCADenoiser ------------------------------------
    def features(self, imgs: Array, patch: int) -> Array:
        """Feature map whose per-pixel L2 distance defines the patch logit."""
        return imgs

    def _chunk_features(self, s: int, e: int, ximg: Array, patch: int) -> Array:
        return self.features(ximg, patch)

    def feature_dist(self, qf: Array, xf: Array, patch: int) -> Array:
        return _box_patch_dist(qf, xf, patch)

    # ------------------------------------------------------------------------
    def patch_size(self, t: int) -> int:
        g = self.schedule.g_np(t)
        p = int(round(self.patch_min + (self.patch_max - self.patch_min) * g))
        return p | 1  # odd

    def _imgs(self, flat: Array) -> Array:
        return flat.reshape(flat.shape[:-1] + (self.h, self.w, self.c))

    def __call__(self, x_t: Array, t: int, support: Array | None = None,
                 mask: Array | None = None) -> Array:
        a = float(self.schedule.a[t])
        sig2 = float(self.schedule.sigma_np(t)) ** 2
        patch = self.patch_size(t)
        q = self._imgs(x_t / a)                                 # [B,H,W,C]
        qf = self.features(q, patch)
        b = q.shape[0]
        d = self.h * self.w * self.c

        if support is not None:
            return self._on_support(q, qf, t, support, patch, sig2, mask)

        # full scan, chunked over the dataset with online softmax per pixel
        n = self.store.n
        state = streaming.init_state((b, self.h * self.w), self.c)
        chunk = min(self.chunk, n)
        for s in range(0, n, chunk):
            e = min(s + chunk, n)
            ximg = self._imgs(self.store.X[s:e])
            xf = self._chunk_features(s, e, ximg, patch)
            dist = self.feature_dist(qf, xf, patch)             # [B,nc,H,W]
            lg = (-dist / (2.0 * sig2)).reshape(b, e - s, -1)
            lg = jnp.moveaxis(lg, 1, -1)                        # [B,HW,nc]
            vals = jnp.moveaxis(ximg.reshape(e - s, -1, self.c), 0, 1)  # [HW,nc,C]
            state = streaming.update_state(state, lg, vals)
        out = streaming.finalize(state)                          # [B,HW,C]
        return out.reshape(b, d)

    def _on_support(self, q: Array, qf: Array, t: int, idx: Array,
                    patch: int, sig2: float, mask: Array | None) -> Array:
        bsz = q.shape[0]

        def one(qi, qfi, ids, mi):
            ximg = self._imgs(self.store.X[ids])                 # [k,H,W,C]
            xf = self.features(ximg, patch)
            dist = self.feature_dist(qfi[None], xf, patch)[0]    # [k,H,W]
            lg = -dist / (2.0 * sig2)
            if mi is not None:
                lg = jnp.where(mi[:, None, None], lg, streaming.NEG_INF)
            if self.weighting == "wss":
                k = lg.shape[0]
                lgp = jnp.moveaxis(lg.reshape(k, -1), 0, -1)     # [HW,k]
                vals = jnp.moveaxis(ximg.reshape(k, -1, self.c), 0, 1)
                out = streaming.wss_combine(lgp, vals)           # [HW,C]
                return out.reshape(self.h, self.w, self.c)
            w = jax.nn.softmax(lg, axis=0)                       # [k,H,W]
            return jnp.einsum("khw,khwc->hwc", w, ximg)

        m_arg = mask if mask is not None else jnp.ones(idx.shape, bool)
        out = jax.vmap(lambda a_, b_, c_, d_: one(a_, b_, c_, d_))(
            q, qf, idx, m_arg)
        return out.reshape(bsz, -1)


class PCADenoiser(PatchDenoiser):
    """Lukoianov et al.: patch features projected on a rank-r PCA basis.

    Patch extraction + projection is a single convolution with the PCA
    filters, so the per-pixel distance runs in the r-dim subspace
    (O(N p_t D) -> O(N r D / p^2) distance work).  Default weighting is the
    *biased* WSS the original method uses; GoldDiff swaps it for the
    unbiased SS on the golden support (Sec. 3.2).
    """

    name = "pca"
    default_weighting: Weighting = "wss"

    def __init__(self, store: DatasetStore, schedule: Schedule,
                 rank: int = 8, num_fit_patches: int = 4096, seed: int = 0,
                 **kw):
        super().__init__(store, schedule, **kw)
        self.rank = rank
        self.num_fit_patches = num_fit_patches
        self.seed = seed
        self._bases: dict[int, Array] = {}

    def _dataset_features(self, patch: int) -> Array:
        """Cached PCA feature maps of the WHOLE dataset for this patch size.

        Features are query-independent, so the golden-support path gathers
        precomputed features instead of re-running the projection conv per
        query (the fix for the 2.4x slowdown first measured in Tab. 2).
        """
        key = ("feat", patch)
        if key not in self._bases:
            imgs = self._imgs(self.store.X)
            chunks = []
            step = max(1, 4096 // max(self.h // 8, 1))
            for s in range(0, self.store.n, step):
                chunks.append(self.features(imgs[s:s + step], patch))
            self._bases[key] = jnp.concatenate(chunks, axis=0)
        return self._bases[key]

    def _on_support(self, q, qf, t, idx, patch, sig2, mask):
        bsz = q.shape[0]
        feats = self._dataset_features(patch)                # [N,H,W,r]

        def one(qfi, ids, mi):
            xf = feats[ids]                                  # [k,H,W,r]
            dist = jnp.sum((qfi[None] - xf) ** 2, axis=-1)   # [k,H,W]
            lg = -dist / (2.0 * sig2)
            if mi is not None:
                lg = jnp.where(mi[:, None, None], lg, streaming.NEG_INF)
            ximg = self._imgs(self.store.X[ids])
            if self.weighting == "wss":
                k = lg.shape[0]
                lgp = jnp.moveaxis(lg.reshape(k, -1), 0, -1)
                vals = jnp.moveaxis(ximg.reshape(k, -1, self.c), 0, 1)
                return streaming.wss_combine(lgp, vals).reshape(
                    self.h, self.w, self.c)
            w = jax.nn.softmax(lg, axis=0)
            return jnp.einsum("khw,khwc->hwc", w, ximg)

        m_arg = mask if mask is not None else jnp.ones(idx.shape, bool)
        out = jax.vmap(one)(qf, idx, m_arg)
        return out.reshape(bsz, -1)

    def _basis(self, patch: int) -> Array:
        """PCA filters [patch, patch, C, r] fit on random training patches."""
        if patch in self._bases:
            return self._bases[patch]
        rng = np.random.default_rng(self.seed + patch)
        x = np.asarray(self.store.X).reshape(-1, self.h, self.w, self.c)
        n = x.shape[0]
        cnt = min(self.num_fit_patches, 16384)
        ii = rng.integers(0, n, cnt)
        hh = rng.integers(0, max(self.h - patch, 0) + 1, cnt)
        ww = rng.integers(0, max(self.w - patch, 0) + 1, cnt)
        patches = np.stack([x[i, a:a + patch, b:b + patch, :]
                            for i, a, b in zip(ii, hh, ww)])
        flat = patches.reshape(cnt, -1)
        flat = flat - flat.mean(0)
        r = min(self.rank, flat.shape[1])
        _, _, vt = np.linalg.svd(flat, full_matrices=False)
        basis = vt[:r].T.reshape(patch, patch, self.c, r)
        self._bases[patch] = jnp.asarray(basis, jnp.float32)
        return self._bases[patch]

    def features(self, imgs: Array, patch: int) -> Array:
        basis = self._basis(patch)
        return jax.lax.conv_general_dilated(
            imgs, basis, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    def feature_dist(self, qf: Array, xf: Array, patch: int) -> Array:
        # distance already lives in the projected patch space; no box sum
        return jnp.sum((qf[:, None] - xf[None]) ** 2, axis=-1)

    def _chunk_features(self, s: int, e: int, ximg: Array, patch: int) -> Array:
        return self._dataset_features(patch)[s:e]


DENOISERS = {
    "optimal": OptimalDenoiser,
    "wiener": WienerDenoiser,
    "kamb": PatchDenoiser,
    "pca": PCADenoiser,
}


def make_denoiser(name: str, store: DatasetStore, schedule: Schedule, **kw):
    return DENOISERS[name](store, schedule, **kw)
