"""Trajectory plans: bucketed shape compilation for the DDIM grid.

The paper's Posterior Progressive Concentration makes per-step compute
budgets shrink/grow along the trajectory — k_t halves and m_t grows as
noise falls (Eqs. 4/6), and the probe width nprobe_t tracks g(sigma_t)
the same way.  Serving previously had to pick one of two bad corners:

* **static mode** keeps the paper's FLOP savings exactly (each step's
  program is shaped to its own (m_t, k_t, nprobe_t)) but compiles one
  XLA program *per timestep* — 10+ programs per batch shape, cold-start
  poison for a serving engine;
* **masked mode** compiles ONE scan/pjit-compatible program but pads
  every step to the worst case (m_max, k_max, nprobe_pad), paying
  max-shape candidate/support FLOPs at all timesteps.

A :class:`TrajectoryPlan` is the middle of that trade-off: the DDIM
step grid is partitioned into a handful of contiguous **shape buckets**
by a greedy merge over the per-step shapes — adjacent steps coalesce
while the bucket's padded-FLOP overhead (running every member step at
the bucket's caps vs at its own exact shape) stays under ``threshold``
(default 15%).  Each bucket carries static caps
``(m_cap, k_cap, nprobe_cap)``; the engine's masked step accepts those
caps (``denoise_masked(x, t, caps=...)``) so every bucket is one
compiled program, and ``sampler.sample_plan`` chains the buckets as
per-bucket ``lax.scan`` segments.  Typically 3-4 programs recover ~all
of static mode's FLOP savings (gated at <= 1.2x in ``check_bench``).

Buckets never straddle an indexed/exact screening boundary: a step the
engine would route through the Golden Index (``engine.use_index(t)``)
cannot share a program with an exact-screening step, because the two
compile different coarse stages.  Within a bucket the traced masks
reproduce the static per-step shapes exactly (the top-m_cap list masked
to m_t equals the static top-m_t list, and likewise for k and nprobe),
so plan-vs-static output parity is fp32 reduction order, not a recall
bound (``tests/test_plan.py``).

FLOP accounting is the candidate/support work the caps actually pad —
per query and step, ``(candidate_rows + k) * D`` with
``candidate_rows = m`` (exact) or ``nprobe * L`` (indexed) — i.e. the
exact re-rank plus the support aggregation.  The coarse proxy pass is
excluded: it is cap-independent (exact mode reads all N rows either
way; indexed probing is already counted through nprobe * L).
"""
from __future__ import annotations

import dataclasses

from repro.core.schedules import sampling_timesteps

__all__ = ["BucketCaps", "PlanBucket", "TrajectoryPlan", "build_plan",
           "step_shapes", "step_stage_costs", "fused_step_costs",
           "full_scan_costs"]


@dataclasses.dataclass(frozen=True)
class BucketCaps:
    """Static pad shapes for one bucket's compiled masked program.

    Hashable (frozen) so it can extend compiled-program cache keys.
    ``nprobe_cap``/``indexed`` route the coarse stage: an indexed
    bucket pads the probe gather to ``nprobe_cap`` windows, an exact
    bucket pads the candidate list to ``m_cap`` rows.
    """

    m_cap: int
    k_cap: int
    nprobe_cap: int = 0
    indexed: bool = False

    def sig(self) -> tuple:
        """Cache-key signature."""
        return (self.m_cap, self.k_cap, self.nprobe_cap, self.indexed)


@dataclasses.dataclass(frozen=True)
class StepShape:
    """Exact per-step shapes (the static-mode program for step ``t``)."""

    t: int            # schedule timestep this DDIM step denoises at
    m_t: int
    k_t: int
    nprobe_t: int     # 0 when the step screens exactly
    indexed: bool

    def flops(self, dim: int, max_cluster: int) -> float:
        """Candidate/support FLOPs per query at these exact shapes."""
        cand = self.nprobe_t * max_cluster if self.indexed else self.m_t
        return float((cand + self.k_t) * dim)

    def flops_at(self, caps: BucketCaps, dim: int, max_cluster: int) -> float:
        """Candidate/support FLOPs per query when run padded to ``caps``."""
        cand = caps.nprobe_cap * max_cluster if caps.indexed else caps.m_cap
        return float((cand + caps.k_cap) * dim)


@dataclasses.dataclass(frozen=True)
class PlanBucket:
    """A contiguous run of DDIM steps sharing one compiled program.

    ``start``/``stop`` index the *step* grid (position i denoises at
    ``plan.ts[i]`` and lands on ``plan.ts[i + 1]``), stop exclusive.
    """

    start: int
    stop: int
    caps: BucketCaps
    padded_flops: float   # per query, summed over member steps, at caps
    exact_flops: float    # per query, summed over member steps, exact

    @property
    def num_steps(self) -> int:
        return self.stop - self.start

    @property
    def overhead(self) -> float:
        """Padded-over-exact FLOP overhead (0.0 == no padding waste)."""
        return self.padded_flops / self.exact_flops - 1.0


@dataclasses.dataclass(frozen=True)
class TrajectoryPlan:
    """A bucketed partition of one DDIM trajectory.

    ``ts`` is the full sampling grid (descending, ``num_steps + 1``
    points, as ``sampling_timesteps`` returns it); ``steps[i]`` holds
    the exact shapes of the step denoising at ``ts[i]``.
    """

    ts: tuple
    steps: tuple
    buckets: tuple
    threshold: float

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def padded_flops(self) -> float:
        """Per-query candidate/support FLOPs the plan actually pays."""
        return sum(b.padded_flops for b in self.buckets)

    @property
    def exact_flops(self) -> float:
        """Per-query candidate/support FLOPs of per-step static mode."""
        return sum(b.exact_flops for b in self.buckets)

    @property
    def overhead(self) -> float:
        """Whole-trajectory padded-FLOP overhead vs static mode."""
        return self.padded_flops / self.exact_flops - 1.0

    def describe(self) -> str:
        """Human-readable bucket table (one line per bucket)."""
        lines = [f"TrajectoryPlan: {self.num_steps} steps -> "
                 f"{self.num_buckets} buckets, "
                 f"padded-FLOP overhead {100 * self.overhead:.1f}% "
                 f"(threshold {100 * self.threshold:.0f}%/bucket)"]
        for b in self.buckets:
            t_hi = int(self.ts[b.start])
            t_lo = int(self.ts[b.stop - 1])
            cap = (f"nprobe<={b.caps.nprobe_cap}" if b.caps.indexed
                   else f"m<={b.caps.m_cap}")
            lines.append(
                f"  steps [{b.start}, {b.stop}) t {t_hi}..{t_lo}: "
                f"{cap} k<={b.caps.k_cap} "
                f"overhead {100 * b.overhead:.1f}%")
        return "\n".join(lines)


def _elem_size(engine) -> int:
    """Bytes per stored element (bf16 storage halves operand traffic)."""
    try:
        return int(engine.X.dtype.itemsize)
    except AttributeError:               # pragma: no cover - duck-typed
        return 4


def step_stage_costs(engine, t: int, batch: int = 1) -> dict:
    """Analytic per-stage FLOPs/bytes of one GoldDiff step at static ``t``.

    Returns ``{stage: {"flops": float, "bytes": float}}`` with stages
    ``screen`` *or* ``ivf_screen`` (by ``engine.use_index(t)``), then
    ``rerank`` and ``aggregate`` — the operand-traffic/arithmetic model
    the roofline benchmark and the engine's stage spans share.  The
    conventions (documented so cells stay comparable across PRs):

    * matmul-form distances count 2*rows*dim FLOPs per query (one
      multiply-add per element);
    * bytes are *analytic operand traffic*: stored rows at the storage
      dtype width, norms/logits/outputs at fp32 — an optimistic
      read-each-operand-once model, so ``achieved <= peak`` holds with
      slack on cached re-reads;
    * the dense (scatter+GEMM) strategy reads the full store per stage,
      the gather strategy reads only the touched rows (exactly the
      crossover the engine picks strategies by).
    """
    b = float(batch)
    n = float(engine.store.n)
    dim = float(engine.store.dim)
    dp = float(engine.proxy.shape[1])
    esz = float(_elem_size(engine))
    m_t, k_t = engine.sizes(t)
    costs = {}
    if engine.use_index(t):
        ix = engine.index
        c = float(ix.num_clusters)
        cand = float(engine.nprobe(t) * ix.max_cluster)
        costs["ivf_screen"] = {
            # centroid scan GEMM + probed-window proxy distances; like
            # the exact screen, shared operands (centroids, probed
            # proxy rows) count ONCE per batch — the read-each-operand-
            # once convention — while per-query outputs scale with b
            "flops": 2.0 * b * c * dp + 2.0 * b * cand * dp,
            "bytes": c * dp * 4.0 + min(n, cand) * dp * esz
            + b * cand * 8.0 + b * dp * 4.0}
    else:
        cand = float(m_t)
        out_b = (b * n * 4.0
                 if not engine.use_stream(int(batch)) else b * m_t * 8.0)
        costs["screen"] = {"flops": 2.0 * b * n * dp,
                           "bytes": n * dp * esz + b * dp * 4.0 + out_b}
    if engine.strategy_for(t) == "dense":
        costs["rerank"] = {"flops": 2.0 * b * n * dim,
                           "bytes": n * dim * esz + b * n * 4.0}
        costs["aggregate"] = {"flops": 2.0 * b * n * dim,
                              "bytes": n * dim * esz + b * n * 4.0}
    else:
        costs["rerank"] = {"flops": 2.0 * b * cand * dim,
                           "bytes": b * cand * (dim * esz + 8.0)}
        costs["aggregate"] = {"flops": 2.0 * b * k_t * dim,
                              "bytes": b * k_t * (dim * esz + 4.0)}
    return costs


def fused_step_costs(engine, t: int, batch: int = 1) -> dict:
    """Analytic FLOPs/bytes of the fused single-pass step kind.

    One stage (``fused_step``): the fused program streams the proxy and
    dataset stores exactly once — coarse screen, exact re-rank, and the
    top-k epilogue in one pass — so the byte model reads each operand
    ONCE (n rows of proxy + X at storage width, queries/outputs at
    fp32, plus the [B, m] carry and the k golden rows the epilogue
    gathers).  FLOPs are the two per-tile GEMMs over all N rows plus
    the gather-form aggregate over k.  Deliberately an undercount of
    any real schedule (re-reads, spills), keeping ``achieved <= peak``
    meaningful in the roofline cell.
    """
    b = float(batch)
    n = float(engine.store.n)
    dim = float(engine.store.dim)
    dp = float(engine.proxy.shape[1])
    esz = float(_elem_size(engine))
    m_t, k_t = engine.sizes(t)
    flops = 2.0 * b * n * dp + 2.0 * b * n * dim + 2.0 * b * k_t * dim
    byts = (n * (dp + dim) * esz            # one streaming store pass
            + 2.0 * n * 4.0                 # fp32 row norms (both sides)
            + b * (dp + dim) * 4.0          # queries
            + b * m_t * 12.0                # [B, m] carry (neg, idx, d2)
            + b * k_t * (dim * esz + 8.0)   # epilogue golden-row gather
            + b * dim * 4.0)                # output
    return {"fused_step": {"flops": flops, "bytes": byts}}


def full_scan_costs(engine, batch: int = 1) -> dict:
    """Analytic FLOPs/bytes of the exact posterior mean (Eq. 2)."""
    b = float(batch)
    n = float(engine.store.n)
    dim = float(engine.store.dim)
    esz = float(_elem_size(engine))
    # distance GEMM + softmax-weighted aggregation GEMM over all N rows
    return {"full_scan": {"flops": 4.0 * b * n * dim,
                          "bytes": 2.0 * n * dim * esz + b * n * 8.0
                          + 2.0 * b * dim * 4.0}}


def step_shapes(engine, num_steps: int = 10) -> tuple:
    """Exact static-mode shapes for every step of the DDIM grid.

    ``engine`` is a ``GoldDiffEngine`` (duck-typed: ``sizes``,
    ``use_index``, ``nprobe`` and the schedule are all that is read).
    """
    ts = sampling_timesteps(engine.schedule, num_steps)
    steps = []
    for t in ts[:-1]:
        t = int(t)
        m_t, k_t = engine.sizes(t)
        indexed = bool(engine.use_index(t))
        nprobe_t = engine.nprobe(t) if indexed else 0
        steps.append(StepShape(t, m_t, k_t, nprobe_t, indexed))
    return tuple(ts.tolist()), tuple(steps)


def _caps_of(steps, lo: int, hi: int) -> BucketCaps:
    """Elementwise-max caps over steps[lo:hi] (all same ``indexed``)."""
    seg = steps[lo:hi]
    return BucketCaps(m_cap=max(s.m_t for s in seg),
                      k_cap=max(s.k_t for s in seg),
                      nprobe_cap=max(s.nprobe_t for s in seg),
                      indexed=seg[0].indexed)


def _bucket(steps, lo: int, hi: int, dim: int, max_cluster: int
            ) -> PlanBucket:
    caps = _caps_of(steps, lo, hi)
    padded = sum(s.flops_at(caps, dim, max_cluster) for s in steps[lo:hi])
    exact = sum(s.flops(dim, max_cluster) for s in steps[lo:hi])
    return PlanBucket(lo, hi, caps, padded, exact)


def build_plan(engine, num_steps: int = 10, threshold: float = 0.15,
               max_buckets: int | None = None) -> TrajectoryPlan:
    """Partition the DDIM grid into shape buckets by greedy merging.

    Every step starts as its own bucket (zero overhead == static mode);
    adjacent buckets with the same indexed/exact routing then merge
    greedily — always the pair whose merged bucket has the lowest
    padded-FLOP overhead — while that overhead stays ``<= threshold``.
    ``threshold=0`` therefore reproduces static mode (one bucket per
    distinct shape), ``threshold=inf`` reproduces masked mode (one
    bucket per routing region).  ``max_buckets`` keeps merging past the
    threshold (still lowest-overhead-first) until the bucket count
    fits, which is how ``--buckets N`` on the serving CLIs forces a
    program budget — except that indexed/exact routing edges always
    split buckets, so the floor is the number of routing regions (one
    region when the whole grid routes the same way).
    """
    ts, steps = step_shapes(engine, num_steps)
    if not steps:
        raise ValueError("empty sampling grid")
    dim = int(engine.store.dim)
    mc = int(engine.index.max_cluster) if engine.index is not None else 0
    buckets = [_bucket(steps, i, i + 1, dim, mc) for i in range(len(steps))]

    def merged(i: int) -> PlanBucket | None:
        a, b = buckets[i], buckets[i + 1]
        if a.caps.indexed != b.caps.indexed:
            return None                    # never straddle a routing edge
        return _bucket(steps, a.start, b.stop, dim, mc)

    def best_merge():
        cands = [(m.overhead, i, m) for i in range(len(buckets) - 1)
                 if (m := merged(i)) is not None]
        return min(cands, default=None)

    while len(buckets) > 1:
        cand = best_merge()
        if cand is None:
            break
        ov, i, m = cand
        if ov > threshold and (max_buckets is None
                               or len(buckets) <= max_buckets):
            break
        buckets[i: i + 2] = [m]
    return TrajectoryPlan(ts=ts, steps=steps, buckets=tuple(buckets),
                          threshold=float(threshold))
