"""Theorem 1 (posterior truncation error bound) and concentration diagnostics.

    || f_D(x_t) - f_S(x_t) ||_2  <=  2 R (N - k) exp(-Delta_k)        (Eq. 7)

with R = max_i ||x_i||_2 and Delta_k = l_(1) - l_(k+1) the Logit Gap.
Also the diagnostics behind Fig. 1 / Fig. 3a: posterior entropy and the
participation ratio (effective golden-support size), which exhibit the
Posterior Progressive Concentration phenomenon.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def logit_gap(logits: Array, k: int) -> Array:
    """Delta_k = l_(1) - l_(k+1) along the last axis (sorted descending)."""
    top = jax.lax.top_k(logits, min(k + 1, logits.shape[-1]))[0]
    return top[..., 0] - top[..., -1]


def theorem1_bound(logits: Array, k: int, radius: float) -> Array:
    """Upper bound 2 R (N - k) exp(-Delta_k); logits: [..., N]."""
    n = logits.shape[-1]
    if k >= n:
        return jnp.zeros(logits.shape[:-1])
    return 2.0 * radius * (n - k) * jnp.exp(-logit_gap(logits, k))


def truncation_error(logits: Array, values: Array, k: int) -> Array:
    """Measured || f_D - f_topk ||_2 (the quantity Theorem 1 bounds)."""
    w_full = jax.nn.softmax(logits, axis=-1)
    f_full = jnp.einsum("...n,nd->...d", w_full, values)
    top_lg, top_idx = jax.lax.top_k(logits, k)
    w_k = jax.nn.softmax(top_lg, axis=-1)
    f_k = jnp.einsum("...k,...kd->...d", w_k, values[top_idx])
    return jnp.linalg.norm(f_full - f_k, axis=-1)


def posterior_entropy(logits: Array) -> Array:
    """H(w) in nats; N-point uniform has entropy log N."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def participation_ratio(logits: Array) -> Array:
    """1 / sum_i w_i^2 — the effective number of contributing samples.

    = N for a uniform posterior, -> 1 on full collapse.  This is the
    quantitative form of the 'golden support size' in Fig. 1.
    """
    w = jax.nn.softmax(logits, axis=-1)
    return 1.0 / jnp.sum(w * w, axis=-1)


def data_radius(x: Array) -> float:
    return float(jnp.max(jnp.linalg.norm(x, axis=-1)))
