"""In-memory dataset store consumed by the analytical denoisers.

The store keeps the training set in flattened form ``X: [N, D]`` together
with the low-dimensional proxy embedding ``proxy: [N, d]`` used by
GoldDiff's coarse screening (paper Sec. 3.4: 4x spatial downsample) and
precomputed squared norms (so pairwise distances become a single matmul).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


class DatasetStore(NamedTuple):
    X: Array                    # [N, D] flattened training points
    proxy: Array                # [N, d] proxy-space embedding (d << D)
    x_norms: Array              # [N]    ||x_i||^2
    proxy_norms: Array          # [N]    ||proxy_i||^2
    image_shape: tuple          # e.g. (32, 32, 3) or (2,) for 2-D toys
    labels: Array | None = None  # [N] int class ids (conditional generation)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[1]


def downsample_proxy(x_img: Array, factor: int = 4) -> Array:
    """Paper's proxy: spatially average-pooled image, flattened.

    ``x_img``: [..., H, W, C].  Falls back to identity for non-image data
    (ndim < 3 trailing dims) or tiny spatial dims.
    """
    if x_img.ndim < 3 or x_img.shape[-2] < factor or x_img.shape[-3] < factor:
        return x_img.reshape(x_img.shape[: x_img.ndim - 1] + (-1,)) \
            if x_img.ndim >= 2 else x_img
    h, w, c = x_img.shape[-3:]
    hh, ww = h // factor, w // factor
    lead = x_img.shape[:-3]
    v = x_img[..., : hh * factor, : ww * factor, :]
    v = v.reshape(lead + (hh, factor, ww, factor, c)).mean(axis=(-4, -2))
    return v.reshape(lead + (hh * ww * c,))


def make_store(x: np.ndarray | Array, image_shape: tuple,
               labels: np.ndarray | None = None,
               proxy_factor: int = 4, dtype=jnp.float32) -> DatasetStore:
    """Build a DatasetStore from raw data of shape [N, *image_shape]."""
    x = jnp.asarray(x, dtype)
    n = x.shape[0]
    ximg = x.reshape((n,) + tuple(image_shape))
    proxy = downsample_proxy(ximg, proxy_factor)
    flat = x.reshape(n, -1)
    return DatasetStore(
        X=flat,
        proxy=proxy,
        x_norms=jnp.sum(flat * flat, axis=-1),
        proxy_norms=jnp.sum(proxy * proxy, axis=-1),
        image_shape=tuple(image_shape),
        labels=None if labels is None else jnp.asarray(labels),
    )


def restrict(store: DatasetStore, idx: Array) -> DatasetStore:
    """Materialize the sub-store at integer indices ``idx`` (e.g. one class)."""
    return DatasetStore(
        X=store.X[idx], proxy=store.proxy[idx], x_norms=store.x_norms[idx],
        proxy_norms=store.proxy_norms[idx], image_shape=store.image_shape,
        labels=None if store.labels is None else store.labels[idx],
    )


def pairwise_sq_dists(q: Array, x: Array, x_norms: Array | None = None) -> Array:
    """||q - x_i||^2 for q: [B, D], x: [N, D] -> [B, N] via the matmul form."""
    if x_norms is None:
        x_norms = jnp.sum(x * x, axis=-1)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    d2 = qn + x_norms[None, :] - 2.0 * q @ x.T
    return jnp.maximum(d2, 0.0)
