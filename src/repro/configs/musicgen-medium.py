"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

Transformer backbone only; the EnCodec conv codec + text conditioner is a
stub per the carve-out — input_specs() provides precomputed conditioning
frame embeddings.  kv = heads = 24 (MHA).  Adaptation (DESIGN §8):
MusicGen's sinusoidal positions are replaced with RoPE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=1e4,
    frontend="audio",
    frontend_tokens=512,         # conditioning frames prepended
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="arXiv:2306.05284 (MusicGen-medium)",
)
