"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
on alternate layers [arXiv:2403.19887].

Adaptation note (DESIGN §8): Jamba's Mamba-1 mixers are implemented as
Mamba-2 SSD blocks (TPU-native chunked form, same interface); state size
128 per the SSD parameterization rather than Mamba-1's 16.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    # 1 attention per 8 layers (1:7 Mamba:attention interleave)
    pattern=("M", "M", "M", "A", "M", "M", "M", "M"),
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=0.0,              # Jamba uses no positional encoding
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="arXiv:2403.19887 (Jamba v0.1)",
)
