"""qwen2-7b [dense] — GQA kv=4, QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="arXiv:2407.10671 (Qwen2-7B)",
)
