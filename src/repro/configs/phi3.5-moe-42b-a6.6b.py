"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    moe_every=1,
    rope_theta=1e4,
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
