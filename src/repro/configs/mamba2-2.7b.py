"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060].  Pure mixer stack: d_ff = 0 (no MLP blocks).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("M",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,             # d_inner = 5120 -> 80 SSD heads
    ssm_conv=4,
    rope_theta=0.0,
    source="arXiv:2405.21060 (Mamba-2 2.7B)",
)
