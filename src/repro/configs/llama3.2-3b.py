"""llama3.2-3b [dense] — small llama3, GQA kv=8 [hf:meta-llama/Llama-3.2-1B family]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B (family scaling per assignment)",
)
