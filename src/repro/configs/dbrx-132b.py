"""dbrx-132b [moe] — 16 experts top-4 fine-grained, GQA kv=8
[hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    moe_every=1,
    rope_theta=5e5,
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="hf:databricks/dbrx-base",
)
