"""Architecture registry.

Config files are named exactly after the assigned arch ids
(``qwen2.5-32b.py`` etc. — dots/dashes in filenames, loaded via
importlib), each exposing a ``CONFIG: ModelConfig`` with its public-pool
citation in ``CONFIG.source``.  ``repro.configs.golddiff`` holds the
paper-side (analytical diffusion) presets.
"""
from __future__ import annotations

import importlib.util
import pathlib

from repro.models.config import ModelConfig

_DIR = pathlib.Path(__file__).parent

ARCH_IDS = [
    "qwen2.5-32b",
    "mamba2-2.7b",
    "qwen2-7b",
    "phi3.5-moe-42b-a6.6b",
    "jamba-v0.1-52b",
    "llama3.2-3b",
    "dbrx-132b",
    "internvl2-1b",
    "musicgen-medium",
    "starcoder2-3b",
]

_CACHE: dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch in _CACHE:
        return _CACHE[arch]
    path = _DIR / f"{arch}.py"
    if not path.exists():
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    spec = importlib.util.spec_from_file_location(f"repro_config_{arch}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    cfg = mod.CONFIG
    assert cfg.name == arch, f"{path} CONFIG.name={cfg.name!r} != {arch!r}"
    _CACHE[arch] = cfg
    return cfg


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_smoke_config(arch: str) -> ModelConfig:
    return get_config(arch).reduced()
