"""starcoder2-3b [dense] — GQA kv=2, RoPE [arXiv:2402.19173]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=1e4,
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="arXiv:2402.19173 (StarCoder2-3B)",
)
