"""internvl2-1b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

This config is the LANGUAGE backbone; the InternViT vision encoder +
projector is a stub per the carve-out — input_specs() provides
precomputed patch embeddings [B, frontend_tokens, d_model].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=False,
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=1024,        # ViT patch embeddings prepended
    attn_kind_decode="golden",
    golden_blocks=64,
    golden_block_size=128,
    source="arXiv:2404.16821 (InternVL2-1B; Qwen2-0.5B-style LM backbone)",
)
