"""Paper-side presets: dataset x denoiser x GoldDiff hyperparameters.

Paper defaults (Sec. 4.1): m_min = k_max = N/10, m_max = N/4,
k_min = N/20, 10 DDIM steps, proxy = 4x spatial downsample.
"""
from __future__ import annotations

import dataclasses

from repro.core.golddiff import GoldDiffConfig


@dataclasses.dataclass(frozen=True)
class ExperimentPreset:
    dataset: str
    dataset_kw: dict
    schedule: str = "ddpm_linear"
    num_steps: int = 10            # sampling steps (paper default)
    base_denoiser: str = "pca"
    golddiff: GoldDiffConfig = GoldDiffConfig()


PRESETS = {
    "moons": ExperimentPreset("moons", {"n": 2000}, base_denoiser="optimal"),
    "mnist": ExperimentPreset("mnist_like", {"n": 4096}),
    "fashion": ExperimentPreset("mnist_like", {"n": 4096, "seed": 7}),
    "cifar10": ExperimentPreset("cifar_like", {"n": 8192}),
    "celeba": ExperimentPreset("celeba_like", {"n": 4096}),
    "afhq": ExperimentPreset("afhq_like", {"n": 4096}),
    "imagenet": ExperimentPreset("imagenet_like",
                                 {"n": 20000, "num_classes": 1000}),
}
