"""The immutable GoldenIndex store (IVF layout over the proxy space).

Layout: dataset rows are *permuted into cluster-sorted order* so every
cluster's rows are contiguous — the probed-cluster window in
``ops.ivf_screen`` is then ``offsets[c] + arange(L)`` per probe, pure
index arithmetic.  Only the proxy arrays are materialized in sorted
order (here, once); the engine maps candidate positions through
``perm`` back to ordinary dataset ids before the exact re-rank, so the
big [N, D] store is never duplicated.

``max_cluster`` (the padded per-probe row count L) is a host ``int`` so
it can shape static programs; everything else is a device array.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # annotation-only: repro.index must not pull in
    from repro.core.dataset import DatasetStore  # repro.core (cycle)

Array = jnp.ndarray


class GoldenIndex(NamedTuple):
    centroids: Array           # [C, dp] fp32 cluster centers (proxy space)
    centroid_norms: Array      # [C]     ||c||^2 (fp32)
    perm: Array                # [N] int32: sorted row r is dataset row perm[r]
    offsets: Array             # [C+1] int32 CSR cluster boundaries
    proxy_sorted: Array        # [N, dp] proxy rows in cluster-sorted order
    proxy_norms_sorted: Array  # [N]     ||proxy||^2, sorted (keeps +inf pads)
    max_cluster: int           # L: largest cluster size (static pad width)

    @property
    def num_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def n(self) -> int:
        return self.perm.shape[0]


def default_num_clusters(n: int) -> int:
    """sqrt-N rule: C ~ sqrt(N) balances the centroid scan (O(C d)) with
    the probed-row term (O(nprobe * N/C * d))."""
    return int(np.clip(round(np.sqrt(n)), 4, n))


def build_index(store: DatasetStore, num_clusters: int | None = None,
                key: Array | None = None, iters: int = 25,
                balance: float = 1.5) -> GoldenIndex:
    """Cluster the proxy embedding and lay out the CSR index.

    Deterministic under a fixed ``key`` (defaults to PRNGKey(0)).

    ``balance`` caps the padded probe width: any cluster larger than
    ``ceil(balance * N / C)`` is split into consecutive CSR *windows*
    that share (duplicate) its centroid — the standard balanced-IVF
    chunking.  Probing then pays ``nprobe * L`` for L near the mean
    cluster size instead of the max, which matters because every probed
    window is padded to ``max_cluster`` for static shapes.  Windows of a
    split cluster tie on centroid distance, so wide clusters simply
    consume several adjacent probe slots.
    """
    # deferred: build <-> store <-> engine would otherwise cycle at
    # module import time (engine imports the sharded-layout machinery,
    # which imports this module)
    from repro.index.build import kmeans

    n = store.n
    c = int(np.clip(num_clusters or default_num_clusters(n), 1, n))
    key = jax.random.PRNGKey(0) if key is None else key
    cents, assign = kmeans(key, store.proxy, c, iters=iters)
    assign_np = np.asarray(assign)
    perm = np.argsort(assign_np, kind="stable").astype(np.int32)
    counts = np.bincount(assign_np, minlength=c)
    cents_np = np.asarray(cents, np.float32)
    cap = max(1, int(np.ceil(balance * n / c)))
    # split oversized clusters into <=cap windows (duplicated centroids)
    win_cents, win_sizes = [], []
    for ci in range(c):
        size = int(counts[ci])
        pieces = max(1, -(-size // cap))
        base = size // pieces
        rem = size - base * pieces
        for p in range(pieces):
            win_cents.append(cents_np[ci])
            win_sizes.append(base + (1 if p < rem else 0))
    offsets = np.concatenate(
        [[0], np.cumsum(win_sizes)]).astype(np.int32)
    cents = jnp.asarray(np.stack(win_cents), jnp.float32)
    return GoldenIndex(
        centroids=cents,
        centroid_norms=jnp.sum(cents * cents, -1),
        perm=jnp.asarray(perm),
        offsets=jnp.asarray(offsets),
        proxy_sorted=store.proxy[perm],
        # gather (not recompute) so +inf markers on padded/masked rows
        # survive into the sorted view and keep excluding those rows
        proxy_norms_sorted=store.proxy_norms[perm].astype(jnp.float32),
        max_cluster=int(max(win_sizes)),
    )


def screening_recall(pos, d2, perm, exact_ids) -> float:
    """recall@m of indexed screening vs exact screening (host-side).

    Fraction of the exact top-m candidate ids (``exact_ids`` [B, m])
    present among the *selectable* indexed candidates — positions
    ``pos`` whose ``d2`` is finite; capacity-padding slots are masked
    +inf downstream and must not inflate recall — mapped through
    ``perm`` to dataset ids, averaged over the batch.  Shared by
    ``tests/test_index.py`` and ``benchmarks/index_speedup.py`` so the
    gated metric and the tested metric cannot drift apart.
    """
    pos = np.asarray(pos)
    fin = np.isfinite(np.asarray(d2))
    perm = np.asarray(perm)
    exact = np.asarray(exact_ids)
    m = exact.shape[1]
    return float(np.mean([
        len(set(perm[pos[b][fin[b]]]) & set(exact[b])) / m
        for b in range(exact.shape[0])]))


def save_index(index: GoldenIndex, path: str) -> None:
    np.savez(path, **{f: np.asarray(getattr(index, f))
                      for f in GoldenIndex._fields})


def load_index(path: str) -> GoldenIndex:
    with np.load(path) as z:
        fields = {f: z[f] for f in GoldenIndex._fields}
    fields["max_cluster"] = int(fields["max_cluster"])
    return GoldenIndex(**{f: v if f == "max_cluster" else jnp.asarray(v)
                          for f, v in fields.items()})
