"""The immutable GoldenIndex store (IVF layout over the proxy space).

Layout: dataset rows are *permuted into cluster-sorted order* so every
cluster's rows are contiguous — the probed-cluster window in
``ops.ivf_screen`` is then ``offsets[c] + arange(L)`` per probe, pure
index arithmetic.  Only the proxy arrays are materialized in sorted
order (here, once); the engine maps candidate positions through
``perm`` back to ordinary dataset ids before the exact re-rank, so the
big [N, D] store is never duplicated.

``max_cluster`` (the padded per-probe row count L) is a host ``int`` so
it can shape static programs; everything else is a device array.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # annotation-only: repro.index must not pull in
    from repro.core.dataset import DatasetStore  # repro.core (cycle)

Array = jnp.ndarray


class GoldenIndex(NamedTuple):
    centroids: Array           # [C, dp] fp32 cluster centers (proxy space)
    centroid_norms: Array      # [C]     ||c||^2 (fp32)
    perm: Array                # [N] int32: sorted row r is dataset row perm[r]
    offsets: Array             # [C+1] int32 CSR cluster boundaries
    proxy_sorted: Array        # [N, dp] proxy rows in cluster-sorted order
    proxy_norms_sorted: Array  # [N]     ||proxy||^2, sorted (keeps +inf pads)
    max_cluster: int           # L: largest cluster size (static pad width)

    @property
    def num_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def n(self) -> int:
        return self.perm.shape[0]


def default_num_clusters(n: int) -> int:
    """sqrt-N rule: C ~ sqrt(N) balances the centroid scan (O(C d)) with
    the probed-row term (O(nprobe * N/C * d))."""
    return int(np.clip(round(np.sqrt(n)), 4, n))


def build_index(store: DatasetStore, num_clusters: int | None = None,
                key: Array | None = None, iters: int = 25,
                balance: float = 1.5) -> GoldenIndex:
    """Cluster the proxy embedding and lay out the CSR index.

    Deterministic under a fixed ``key`` (defaults to PRNGKey(0)).

    ``balance`` caps the padded probe width: any cluster larger than
    ``ceil(balance * N / C)`` is split into consecutive CSR *windows*
    that share (duplicate) its centroid — the standard balanced-IVF
    chunking.  Probing then pays ``nprobe * L`` for L near the mean
    cluster size instead of the max, which matters because every probed
    window is padded to ``max_cluster`` for static shapes.  Windows of a
    split cluster tie on centroid distance, so wide clusters simply
    consume several adjacent probe slots.
    """
    # deferred: build <-> store <-> engine would otherwise cycle at
    # module import time (engine imports the sharded-layout machinery,
    # which imports this module)
    from repro.index.build import kmeans

    n = store.n
    c = int(np.clip(num_clusters or default_num_clusters(n), 1, n))
    key = jax.random.PRNGKey(0) if key is None else key
    cents, assign = kmeans(key, store.proxy, c, iters=iters)
    assign_np = np.asarray(assign)
    perm = np.argsort(assign_np, kind="stable").astype(np.int32)
    counts = np.bincount(assign_np, minlength=c)
    cents_np = np.asarray(cents, np.float32)
    cap = max(1, int(np.ceil(balance * n / c)))
    # split oversized clusters into <=cap windows (duplicated centroids)
    win_cents, win_sizes = [], []
    for ci in range(c):
        size = int(counts[ci])
        pieces = max(1, -(-size // cap))
        base = size // pieces
        rem = size - base * pieces
        for p in range(pieces):
            win_cents.append(cents_np[ci])
            win_sizes.append(base + (1 if p < rem else 0))
    offsets = np.concatenate(
        [[0], np.cumsum(win_sizes)]).astype(np.int32)
    cents = jnp.asarray(np.stack(win_cents), jnp.float32)
    return GoldenIndex(
        centroids=cents,
        centroid_norms=jnp.sum(cents * cents, -1),
        perm=jnp.asarray(perm),
        offsets=jnp.asarray(offsets),
        proxy_sorted=store.proxy[perm],
        # gather (not recompute) so +inf markers on padded/masked rows
        # survive into the sorted view and keep excluding those rows
        proxy_norms_sorted=store.proxy_norms[perm].astype(jnp.float32),
        max_cluster=int(max(win_sizes)),
    )


def screening_recall(pos, d2, perm, exact_ids) -> float:
    """recall@m of indexed screening vs exact screening (host-side).

    Fraction of the exact top-m candidate ids (``exact_ids`` [B, m])
    present among the *selectable* indexed candidates — positions
    ``pos`` whose ``d2`` is finite; capacity-padding slots are masked
    +inf downstream and must not inflate recall — mapped through
    ``perm`` to dataset ids, averaged over the batch.  Shared by
    ``tests/test_index.py`` and ``benchmarks/index_speedup.py`` so the
    gated metric and the tested metric cannot drift apart.
    """
    pos = np.asarray(pos)
    fin = np.isfinite(np.asarray(d2))
    perm = np.asarray(perm)
    exact = np.asarray(exact_ids)
    m = exact.shape[1]
    return float(np.mean([
        len(set(perm[pos[b][fin[b]]]) & set(exact[b])) / m
        for b in range(exact.shape[0])]))


# -- persistence (atomic, versioned, checksummed) ----------------------------

INDEX_FORMAT = "golden-index"
INDEX_FORMAT_VERSION = 1

_ARRAY_FIELDS = tuple(f for f in GoldenIndex._fields if f != "max_cluster")


class StoreError(Exception):
    """Base class for golden-store persistence/lifecycle failures."""


class StoreCorruptionError(StoreError):
    """On-disk store bytes are damaged or internally inconsistent
    (truncation, bit-flip, torn write, broken CSR invariants)."""


class StoreVersionError(StoreError):
    """On-disk store was written by an incompatible format version."""


class StoreCapacityError(StoreError):
    """An append exceeded the capacity-padded layout (no free slot /
    no spare window left) — a full rebuild is required to grow."""


def validate_index(fields: dict[str, np.ndarray], max_cluster: int) -> None:
    """Validate GoldenIndex array invariants; raise StoreCorruptionError.

    Checks presence already happened (the manifest layer); this is the
    *semantic* layer: dtypes the kernels require, CSR well-formedness
    (offsets sorted, spanning exactly the sorted rows, no window wider
    than ``max_cluster``), and the permutation being a bijection over
    the selectable (finite proxy-norm) rows — capacity-padding slots
    (+inf norms) only need in-range values, they are masked out of
    every selection downstream.
    """
    cents = fields["centroids"]
    cnorm = fields["centroid_norms"]
    perm = fields["perm"]
    offsets = fields["offsets"]
    ps = fields["proxy_sorted"]
    pns = fields["proxy_norms_sorted"]

    def bad(msg: str):
        raise StoreCorruptionError(f"golden index invalid: {msg}")

    for name, arr, nd in (("centroids", cents, 2), ("centroid_norms",
                          cnorm, 1), ("perm", perm, 1), ("offsets",
                          offsets, 1), ("proxy_sorted", ps, 2),
                          ("proxy_norms_sorted", pns, 1)):
        if arr.ndim != nd:
            bad(f"{name} must be {nd}-D, got shape {arr.shape}")
    for name, arr in (("perm", perm), ("offsets", offsets)):
        if not np.issubdtype(arr.dtype, np.integer):
            bad(f"{name} must be an integer array, got {arr.dtype}")
    n = perm.shape[0]
    c = cents.shape[0]
    if cnorm.shape[0] != c:
        bad(f"centroid_norms has {cnorm.shape[0]} entries for "
            f"{c} centroids")
    if ps.shape != (n, cents.shape[1]):
        bad(f"proxy_sorted shape {ps.shape} != ({n}, {cents.shape[1]})")
    if pns.shape[0] != n:
        bad(f"proxy_norms_sorted has {pns.shape[0]} entries for {n} rows")
    if offsets.shape[0] != c + 1:
        bad(f"offsets has {offsets.shape[0]} entries for {c} windows "
            f"(want C+1 = {c + 1})")
    if n and (offsets[0] != 0 or offsets[-1] != n):
        bad(f"offsets must span [0, {n}], got "
            f"[{int(offsets[0])}, {int(offsets[-1])}]")
    sizes = np.diff(offsets.astype(np.int64))
    if (sizes < 0).any():
        w = int(np.argmax(sizes < 0))
        bad(f"offsets not sorted (window {w} has negative size "
            f"{int(sizes[w])})")
    if int(max_cluster) < (int(sizes.max()) if sizes.size else 0):
        bad(f"max_cluster {int(max_cluster)} < widest window "
            f"{int(sizes.max())}")
    if n and ((perm < 0).any() or (perm >= n).any()):
        bad(f"perm has out-of-range entries (valid range [0, {n}))")
    if np.isnan(cnorm).any() or np.isnan(pns).any():
        bad("NaN in centroid_norms / proxy_norms_sorted (norms must be "
            "finite, or +inf on padding slots)")
    # bijection over selectable rows: every real (finite-norm) slot maps
    # to a distinct dataset id.  On immutable indexes every slot is real,
    # so this is the full-permutation check.
    real = np.isfinite(pns)
    real_ids = perm[real]
    if real_ids.size != np.unique(real_ids).size:
        bad("perm is not a bijection: duplicate dataset ids among "
            "selectable rows")


def save_index(index: GoldenIndex, path: str) -> None:
    """Atomic, checksummed save: ``<path>`` (npz) + a JSON manifest
    sidecar ``<path>.manifest.json`` (format version, shape/dtype
    schema, per-array sha256).  See ``repro.utils.atomic``."""
    from repro.utils import atomic
    arrays = {f: np.asarray(getattr(index, f)) for f in _ARRAY_FIELDS}
    atomic.save_arrays(path, arrays, fmt=INDEX_FORMAT,
                       version=INDEX_FORMAT_VERSION,
                       meta={"max_cluster": int(index.max_cluster)})


def load_index(path: str) -> GoldenIndex:
    """Validated load: manifest/version/checksum checks, then the CSR
    and permutation invariants — all BEFORE construction, so damage
    surfaces as a typed :class:`StoreCorruptionError` /
    :class:`StoreVersionError` instead of NaNs (or an obscure
    AttributeError) deep inside an engine build."""
    from repro.utils import atomic
    arrays, meta = atomic.load_arrays(
        path, fmt=INDEX_FORMAT, version=INDEX_FORMAT_VERSION,
        corruption_exc=StoreCorruptionError,
        version_exc=StoreVersionError)
    missing = sorted(set(_ARRAY_FIELDS) - set(arrays))
    if missing:
        raise StoreCorruptionError(
            f"{path}: manifest is missing required index array(s): "
            f"{missing}")
    if "max_cluster" not in meta:
        raise StoreCorruptionError(f"{path}: manifest meta is missing "
                                   f"'max_cluster'")
    max_cluster = int(meta["max_cluster"])
    validate_index(arrays, max_cluster)
    return GoldenIndex(max_cluster=max_cluster,
                       **{f: jnp.asarray(arrays[f]) for f in _ARRAY_FIELDS})
