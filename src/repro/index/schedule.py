"""Time-aware probe schedule: how many clusters to visit at noise sigma_t.

Posterior Progressive Concentration (paper Eqs. 4/6) drives the probe
count exactly the way it drives (m_t, k_t): the normalized noise level
g(sigma_t) in [0, 1] interpolates between two probed fractions of the
C clusters,

    nprobe_t = ceil(C * (f_lo + (f_hi - f_lo) * g(sigma_t)))

wide at low SNR (g -> 1: the posterior is diffuse, probes approach the
whole index — and per the Gaussian-score regime the coarse stage is
forgiving there, so width costs recall nothing) and a handful of
clusters at high SNR (g -> 0: the golden support has collapsed onto a
local neighborhood that a few nearest clusters cover).

Two safety terms keep recall honest:

* **capacity floor** — probed clusters must plausibly *hold* the
  paper's candidate budget m_t, so nprobe_t is floored at
  ``ceil(safety * m_t * C / N)`` (safety > 1 buys slack for cluster
  imbalance and boundary misses);
* **min_probes** — an absolute minimum number of clusters.

When the floor pushes nprobe_t past the platform's gather/GEMM
crossover the engine falls back to exact dense screening for that
timestep (see ``GoldDiffEngine``) — the index degrades to exact
screening, never to silent recall loss.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ProbeSchedule:
    """nprobe_t = clip(max(snr_term, capacity_floor, min_probes), 1, C)."""

    f_lo: float = 1 / 16     # probed fraction of clusters at g = 0 (high SNR)
    f_hi: float = 1.0        # probed fraction at g = 1 (low SNR)
    safety: float = 2.0      # capacity floor: probed rows >= safety * m_t
    min_probes: int = 4

    def nprobe(self, g: float, m_t: int, n: int, num_clusters: int) -> int:
        """Host-side probe count for a static timestep."""
        c = num_clusters
        snr = math.ceil(c * (self.f_lo + (self.f_hi - self.f_lo) * g))
        cap = math.ceil(self.safety * m_t * c / n)
        return int(min(max(snr, cap, self.min_probes, 1), c))

    def nprobe_jnp(self, g: Array, m_t: Array, n: int,
                   num_clusters: int) -> Array:
        """Traced mirror of :meth:`nprobe` for the masked (scan/pjit)
        path, where g and m_t come from a traced timestep."""
        c = num_clusters
        snr = jnp.ceil(c * (self.f_lo + (self.f_hi - self.f_lo) * g))
        cap = jnp.ceil(self.safety * m_t.astype(jnp.float32) * c / n)
        lo = jnp.maximum(jnp.maximum(snr, cap), float(self.min_probes))
        return jnp.clip(lo, 1, c).astype(jnp.int32)
