"""Per-shard layout of the golden store (and its index) over a mesh axis.

The sharded ``GoldDiffEngine`` partitions ONE dataset — and, when
indexed, ONE global ``GoldenIndex`` — across the devices of a mesh
axis, so multi-device screening is an *equality-preserving* re-layout
of the single-host pipeline rather than an approximation:

* **exact mode** (no index): rows are chunked contiguously in dataset
  order; padded tail rows carry +inf norms and are never screened in.
* **indexed mode**: the global index's cluster-sorted rows are
  partitioned at CSR *window* boundaries, balanced by row count.  Each
  shard holds the contiguous window-id range ``wrange = [w_lo, w_hi)``,
  those windows' rows (proxy AND the [n_loc, D] store rows, both in
  cluster-sorted order), and window offsets rebased to shard-local row
  positions.  The (small) centroid table is replicated so every shard
  can run the identical global probe selection
  (``ops.ivf_screen_local``); a probed window then belongs to exactly
  one shard, so the union of shard-local candidate lanes equals the
  single-host probe set row-for-row.

All per-shard arrays are stacked on a leading shard axis and placed
with ``NamedSharding(mesh, P(axis))``: inside ``shard_map`` each shard
sees exactly its own slab (leading dim 1, squeezed by the caller).
``ids`` maps shard-local row positions back to dataset row ids, which
is how ``select()`` keeps returning ordinary dataset indices.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if TYPE_CHECKING:  # annotation-only: importing repro.core here would
    from repro.core.dataset import DatasetStore      # cycle via engine
    from repro.index.store import GoldenIndex

Array = jnp.ndarray


class ShardedLayout(NamedTuple):
    """Stacked per-shard golden store (+ optional index routing)."""

    X: Array                   # [S, n_loc, D] store rows (sorted if indexed)
    x_norms: Array             # [S, n_loc] fp32 (+inf on padding)
    proxy: Array               # [S, n_loc, dp] (cluster-sorted if indexed)
    proxy_norms: Array         # [S, n_loc] fp32 (+inf on padding)
    ids: Array                 # [S, n_loc] int32 dataset row ids (0 on pad)
    offsets: Array | None      # [S, W + 1] int32 local window offsets
    wrange: Array | None       # [S, 2] int32 owned window ids [w_lo, w_hi)
    centroids: Array | None    # [C, dp] replicated global window centroids
    centroid_norms: Array | None  # [C] replicated
    n_loc: int                 # static per-shard row count (padded)
    w_max: int                 # static max windows owned by any shard
    max_cluster: int           # L: padded per-window row count
    n_shards: int

    @property
    def indexed(self) -> bool:
        return self.offsets is not None


def partition_windows(offsets: np.ndarray, n_shards: int) -> np.ndarray:
    """Cut points (window ids, length S+1) balancing rows per shard.

    Greedy: shard s takes the windows up to the first boundary at or
    past ``(s + 1) / S`` of the rows.  Monotone by construction; shards
    past the last window come out empty (valid, just idle) when there
    are fewer windows than shards.
    """
    n = int(offsets[-1])
    cuts = [0]
    for s in range(1, n_shards):
        target = round(n * s / n_shards)
        w = int(np.searchsorted(offsets, target, side="left"))
        cuts.append(int(np.clip(w, cuts[-1], len(offsets) - 1)))
    cuts.append(len(offsets) - 1)
    return np.asarray(cuts, np.int64)


def shard_layout(store: DatasetStore, mesh: Mesh, axis: str = "data",
                 index: GoldenIndex | None = None,
                 storage_dtype=None) -> ShardedLayout:
    """Build the stacked per-shard layout (host-side, at engine build)."""
    n_sh = int(mesh.shape[axis])
    n = store.n
    X = np.asarray(store.X)
    proxy = np.asarray(store.proxy)
    xn = np.asarray(store.x_norms, np.float32)
    pn = np.asarray(store.proxy_norms, np.float32)

    if index is None:
        order = np.arange(n)
        n_loc = -(-n // n_sh)
        row_cuts = np.minimum(np.arange(n_sh + 1) * n_loc, n)
        w_max = 0
        offs_parts = wrange = None
    else:
        if index.n != n:
            raise ValueError(f"index built for N={index.n}, store N={n}")
        order = np.asarray(index.perm)
        offsets = np.asarray(index.offsets, np.int64)
        cuts = partition_windows(offsets, n_sh)
        row_cuts = offsets[cuts]
        w_max = int(np.max(np.diff(cuts)))
        n_loc = int(np.max(np.diff(row_cuts)))
        offs_parts = []
        for s in range(n_sh):
            o = offsets[cuts[s]: cuts[s + 1] + 1] - offsets[cuts[s]]
            offs_parts.append(np.pad(o, (0, w_max + 1 - len(o)),
                                     mode="edge" if len(o) else "constant"))
        wrange = np.stack([cuts[:-1], cuts[1:]], axis=1).astype(np.int32)

    def stack_rows(a, fill=0.0):
        out = np.full((n_sh, n_loc) + a.shape[1:], fill, a.dtype)
        for s in range(n_sh):
            rows = order[row_cuts[s]: row_cuts[s + 1]]
            out[s, : len(rows)] = a[rows]
        return out

    ids = np.zeros((n_sh, n_loc), np.int32)
    for s in range(n_sh):
        rows = order[row_cuts[s]: row_cuts[s + 1]]
        ids[s, : len(rows)] = rows

    Xs, ps = stack_rows(X), stack_rows(proxy)
    if storage_dtype is not None:
        Xs = Xs.astype(storage_dtype)
        ps = ps.astype(storage_dtype)
    sh = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    return ShardedLayout(
        X=put(Xs),
        x_norms=put(stack_rows(xn, fill=np.inf)),
        proxy=put(ps),
        proxy_norms=put(stack_rows(pn, fill=np.inf)),
        ids=put(ids),
        offsets=None if index is None else put(
            np.stack(offs_parts).astype(np.int32)),
        wrange=None if index is None else put(wrange),
        centroids=None if index is None else jax.device_put(
            index.centroids, rep),
        centroid_norms=None if index is None else jax.device_put(
            index.centroid_norms, rep),
        n_loc=int(n_loc),
        w_max=w_max,
        max_cluster=0 if index is None else index.max_cluster,
        n_shards=n_sh,
    )
