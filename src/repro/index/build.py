"""JAX k-means over the proxy embedding (the Golden Index builder).

k-means++ seeding (Arthur & Vassilvitskii, 2007) followed by batched
Lloyd iterations, all in the matmul distance form the kernel layer uses
(``||p - c||^2 = ||p||^2 + ||c||^2 - 2 p.c``), so the builder is a
sequence of [N, C] GEMMs — fast on every backend and deterministic under
a fixed PRNG key (tested in ``tests/test_index.py``).

Empty clusters are re-seeded each Lloyd iteration to the point farthest
from its assigned centroid, which doubles as a crude balance heuristic:
oversized clusters with distant outliers donate a point that becomes a
new centroid, splitting them on the next assignment pass.  Balance
matters because the IVF gather pads every probed cluster to the max
cluster size L (static shapes), so the probed-row cost is
``nprobe * L`` rather than ``nprobe * N/C``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ref import pdist_ref

Array = jnp.ndarray


def _sq_dists(p: Array, c: Array) -> Array:
    """[N, C] squared distances — the kernel layer's reference math."""
    return pdist_ref(p, c)


@functools.partial(jax.jit, static_argnames=("k",))
def kmeans_plusplus(key: Array, points: Array, k: int) -> Array:
    """k-means++ seeding: [N, d] -> [k, d] initial centroids.

    Sequential by construction (each seed conditions on the previous
    ones) but each step is a single [N] distance update, so the whole
    pass is O(k N d).
    """
    n, d = points.shape
    p32 = points.astype(jnp.float32)
    first = jax.random.randint(key, (), 0, n)
    cents = jnp.zeros((k, d), jnp.float32).at[0].set(p32[first])
    min_d2 = jnp.sum((p32 - p32[first]) ** 2, -1)

    def step(i, carry):
        cents, min_d2 = carry
        ki = jax.random.fold_in(key, i)
        # sample proportional to the current squared distance (the ++
        # rule); gumbel-max over log-probs keeps it jit-friendly
        logits = jnp.log(jnp.maximum(min_d2, 1e-30))
        nxt = jax.random.categorical(ki, logits)
        c = p32[nxt]
        cents = cents.at[i].set(c)
        min_d2 = jnp.minimum(min_d2, jnp.sum((p32 - c) ** 2, -1))
        return cents, min_d2

    cents, _ = jax.lax.fori_loop(1, k, step, (cents, min_d2))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: Array, points: Array, k: int, iters: int = 25
           ) -> tuple[Array, Array]:
    """Batched Lloyd iterations.  [N, d] -> (centroids [k, d], assign [N]).

    Deterministic under a fixed ``key``; empty clusters are re-seeded to
    the globally farthest point from its centroid.
    """
    n = points.shape[0]
    p32 = points.astype(jnp.float32)
    cents0 = kmeans_plusplus(key, points, k)

    def lloyd(_, cents):
        d2 = _sq_dists(p32, cents)
        assign = jnp.argmin(d2, -1)
        counts = jnp.zeros((k,), jnp.float32).at[assign].add(1.0)
        sums = jnp.zeros_like(cents).at[assign].add(p32)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # re-seed empty clusters, each to a DISTINCT far point (the e-th
        # empty cluster takes the e-th farthest-from-its-centroid point,
        # splitting crowded clusters on the next pass; a shared seed
        # would leave all but one of them empty again)
        empty = counts == 0.0
        far = jax.lax.top_k(jnp.min(d2, -1), k)[1]          # [k] farthest
        rank = jnp.clip(jnp.cumsum(empty) - 1, 0, k - 1)    # e per cluster
        new = jnp.where(empty[:, None], p32[far[rank]], new)
        return new

    cents = jax.lax.fori_loop(0, iters, lloyd, cents0)
    assign = jnp.argmin(_sq_dists(p32, cents), -1).astype(jnp.int32)
    return cents, assign
