"""Durable, appendable golden-store lifecycle (epochs + journal).

The immutable :class:`~repro.index.store.GoldenIndex` couples
*availability* to dataset size: growing the store means a full k-means
rebuild (seconds at N=65k) with serving downtime.  This module makes the
store **appendable with static shapes** so a live service can grow its
golden store and hot-swap it into a running engine with zero recompiles:

Capacity-padded layout
    Every CSR window gets a uniform capacity ``L_cap = ceil(slack *
    max_cluster)`` plus a pool of *spare* windows; ``offsets`` is the
    constant ``arange(W+1) * L_cap``.  Empty slots carry ``+inf``
    proxy/row norms (the repo-wide padding convention: +inf distance =>
    never screened in, NEG_INF logit => zero aggregate weight), and
    spare windows carry ``+inf`` centroid norms so probes rank them
    last.  Appends fill slots **in place** — array shapes, ``n``,
    ``max_cluster``, and ``num_clusters`` never change, so every engine
    program-cache key (and compiled executable) stays valid across
    appends.

Occupancy-triggered local re-clustering
    When a row lands in a full window, only that window is re-clustered:
    a deterministic (RNG-free) 2-means splits its rows between the
    window and one spare, updating the two centroids.  Everything else
    is untouched.  With no spare left the row falls back to the nearest
    window with free capacity (graceful recall degradation instead of
    failure); the layout is exhausted only when every slot is full
    (:class:`~repro.index.store.StoreCapacityError`).

Durability: epoch directories + an append journal
    Disk layout under ``root``::

        CURRENT                    # atomic pointer: "epoch_00000002"
        epoch_00000002/arrays.npz  # checksummed via repro.utils.atomic
        epoch_00000002/arrays.npz.manifest.json
        journal.bin                # framed, CRC'd, fsync'd appends

    ``append()`` journals the raw rows (header: base epoch, sequence
    number, CRC) with an fsync *before* touching memory;  ``commit()``
    writes a new epoch directory, atomically flips ``CURRENT`` (the
    commit point), then truncates the journal.  ``open()`` loads the
    CURRENT epoch (validated: version, checksums, CSR/permutation
    invariants) and replays the journal's valid prefix — frames from
    other epochs or with out-of-order sequence numbers are skipped, so
    recovery is idempotent across every crash window (pre-``CURRENT``
    flip, post-flip pre-truncate, torn journal tail).  Re-application is
    bit-deterministic (pure numpy, no RNG), so a recovered store is
    bit-identical to the pre-crash in-memory state.

``view()`` exposes the current state as an ordinary ``(DatasetStore,
GoldenIndex)`` pair — the engine stays unaware of the lifecycle; the
serving runtime swaps views at plan-bucket seams
(``ServeRuntime.hot_swap``).
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from typing import TYPE_CHECKING

import numpy as np

from repro.index.store import (GoldenIndex, StoreCapacityError,
                               StoreCorruptionError, StoreError,
                               StoreVersionError, validate_index)
from repro.utils import atomic

if TYPE_CHECKING:                        # deferred: repro.core imports
    from repro.core.dataset import DatasetStore   # cycle via engine

EPOCH_FORMAT = "golden-store-epoch"
EPOCH_FORMAT_VERSION = 1

CURRENT_FILE = "CURRENT"
JOURNAL_FILE = "journal.bin"
JOURNAL_MAGIC = b"GJRNL001"
FRAME_MAGIC = b"FRME"
# frame header: magic, base_epoch, seq, n_rows, dim, payload crc32
_FRAME_HDR = struct.Struct("<4sQQIII")

_ARRAYS = ("X", "proxy", "x_norms", "proxy_norms", "proxy_sorted",
           "proxy_norms_sorted", "perm", "offsets", "centroids",
           "centroid_norms", "sizes")


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Capacity-padding knobs (fixed at ``create`` time, persisted)."""

    slack: float = 1.5       # window capacity = ceil(slack * max_cluster)
    spare_frac: float = 0.125  # spare windows as a fraction of base windows
    recluster_iters: int = 8   # Lloyd iterations of the local 2-means


def _epoch_name(epoch: int) -> str:
    return f"epoch_{int(epoch):08d}"


def _proxy_rows(rows: np.ndarray, image_shape: tuple,
                proxy_factor: int) -> np.ndarray:
    """Numpy proxy embedding of flattened rows (same pooling as
    ``repro.core.dataset.downsample_proxy``; numpy-only so journal
    replay never depends on device state)."""
    from repro.core.dataset import downsample_proxy
    img = rows.reshape((rows.shape[0],) + tuple(image_shape))
    return np.asarray(downsample_proxy(img, proxy_factor),
                      np.float32).reshape(rows.shape[0], -1)


class StoreLifecycle:
    """Appendable, crash-safe golden store rooted at a directory.

    Construct with :meth:`create` (from an immutable store + index) or
    :meth:`open` (recover from disk).  All mutable state is host numpy;
    :meth:`view` materializes device views for the engine.
    """

    def __init__(self, root: str, arrays: dict[str, np.ndarray],
                 meta: dict, epoch: int,
                 quarantined: list[tuple[str, str]] | None = None):
        self.root = os.fspath(root)
        self._X = arrays["X"]
        self._proxy = arrays["proxy"]
        self._xn = arrays["x_norms"]
        self._pn = arrays["proxy_norms"]
        self._ps = arrays["proxy_sorted"]
        self._pns = arrays["proxy_norms_sorted"]
        self._perm = arrays["perm"]
        self._offsets = arrays["offsets"]
        self._cent = arrays["centroids"]
        self._cnorm = arrays["centroid_norms"]
        self._sizes = arrays["sizes"]
        self.image_shape = tuple(meta["image_shape"])
        self.proxy_factor = int(meta["proxy_factor"])
        self.capacity = int(meta["capacity"])          # L_cap per window
        self.recluster_iters = int(meta.get("recluster_iters", 8))
        self._n_rows = int(meta["n_rows"])
        self._seq = int(meta["seq"])                   # next frame seq
        self._epoch = int(epoch)                       # durable epoch id
        self._epoch_seq = self._seq
        self._epoch_n_rows = self._n_rows
        self.quarantined = list(quarantined or [])
        self.replayed_frames = 0

    # -- derived geometry ----------------------------------------------------
    @property
    def num_windows(self) -> int:
        return self._cent.shape[0]

    @property
    def n_capacity(self) -> int:
        return self._perm.shape[0]

    @property
    def n_rows(self) -> int:
        return self._n_rows

    @property
    def epoch(self) -> int:
        """Last *durable* epoch id (what a crash recovers to, modulo
        the journal)."""
        return self._epoch

    @property
    def pending_rows(self) -> int:
        """Rows appended (journaled) since the last durable epoch."""
        return self._n_rows - self._epoch_n_rows

    @property
    def dim(self) -> int:
        return self._X.shape[1]

    # -- construction --------------------------------------------------------
    @classmethod
    def create(cls, root: str, store: "DatasetStore", index: GoldenIndex,
               config: IngestConfig | None = None,
               proxy_factor: int = 4) -> "StoreLifecycle":
        """Lay out a capacity-padded copy of ``(store, index)`` under
        ``root`` and commit it as epoch 0."""
        cfg = config or IngestConfig()
        if store.labels is not None:
            raise ValueError("StoreLifecycle does not carry labels yet "
                             "(conditional stores are a follow-on)")
        if index.n != store.n:
            raise ValueError(f"index built for N={index.n}, store has "
                             f"N={store.n}")
        n, d = store.n, store.dim
        dp = index.centroids.shape[1]
        w_base = index.num_clusters
        l_cap = max(2, int(np.ceil(cfg.slack * index.max_cluster)))
        w_spare = max(1, int(np.ceil(cfg.spare_frac * w_base)))
        w = w_base + w_spare
        n_cap = w * l_cap
        if n > n_cap:                    # cannot happen with slack >= 1
            raise StoreCapacityError(f"capacity {n_cap} < existing rows "
                                     f"{n}")
        arr = {
            "X": np.zeros((n_cap, d), np.float32),
            "proxy": np.zeros((n_cap, dp), np.float32),
            "x_norms": np.full(n_cap, np.inf, np.float32),
            "proxy_norms": np.full(n_cap, np.inf, np.float32),
            "proxy_sorted": np.zeros((n_cap, dp), np.float32),
            "proxy_norms_sorted": np.full(n_cap, np.inf, np.float32),
            "perm": np.zeros(n_cap, np.int32),
            "offsets": (np.arange(w + 1, dtype=np.int64)
                        * l_cap).astype(np.int32),
            "centroids": np.zeros((w, dp), np.float32),
            "centroid_norms": np.full(w, np.inf, np.float32),
            "sizes": np.zeros(w, np.int32),
        }
        arr["X"][:n] = np.asarray(store.X, np.float32)
        arr["proxy"][:n] = np.asarray(store.proxy, np.float32)
        arr["x_norms"][:n] = np.asarray(store.x_norms, np.float32)
        arr["proxy_norms"][:n] = np.asarray(store.proxy_norms, np.float32)
        arr["centroids"][:w_base] = np.asarray(index.centroids, np.float32)
        arr["centroid_norms"][:w_base] = np.asarray(index.centroid_norms,
                                                    np.float32)
        off = np.asarray(index.offsets, np.int64)
        perm = np.asarray(index.perm, np.int32)
        ps = np.asarray(index.proxy_sorted, np.float32)
        pns = np.asarray(index.proxy_norms_sorted, np.float32)
        for wi in range(w_base):
            size = int(off[wi + 1] - off[wi])
            dst = wi * l_cap
            arr["proxy_sorted"][dst:dst + size] = ps[off[wi]:off[wi + 1]]
            arr["proxy_norms_sorted"][dst:dst + size] = \
                pns[off[wi]:off[wi + 1]]
            arr["perm"][dst:dst + size] = perm[off[wi]:off[wi + 1]]
            arr["sizes"][wi] = size
        meta = {"image_shape": list(store.image_shape),
                "proxy_factor": int(proxy_factor),
                "capacity": l_cap,
                "recluster_iters": int(cfg.recluster_iters),
                "n_rows": n, "seq": 0}
        os.makedirs(root, exist_ok=True)
        lc = cls(root, arr, meta, epoch=0)
        lc._write_epoch(0)
        atomic.atomic_write_text(os.path.join(root, CURRENT_FILE),
                                 _epoch_name(0) + "\n")
        lc._reset_journal()
        return lc

    @classmethod
    def open(cls, root: str, fallback: bool = True) -> "StoreLifecycle":
        """Recover from disk: load the CURRENT epoch (validated), then
        replay the journal's valid prefix.

        ``fallback=True`` quarantines a damaged CURRENT epoch and walks
        back to the newest loadable one (recorded in ``quarantined``);
        with no survivor — or with ``fallback=False`` — the typed
        load error propagates.
        """
        root = os.fspath(root)
        cur_path = os.path.join(root, CURRENT_FILE)
        if not os.path.exists(cur_path):
            raise StoreError(f"{root}: not a store-lifecycle root "
                             f"(no {CURRENT_FILE})")
        current = open(cur_path).read().strip()
        candidates = [current]
        if fallback:
            others = sorted((p for p in os.listdir(root)
                             if p.startswith("epoch_") and p != current),
                            reverse=True)
            candidates += others
        quarantined: list[tuple[str, str]] = []
        last_err: StoreError | None = None
        for name in candidates:
            try:
                lc = cls._load_epoch(root, name, quarantined)
                lc._replay_journal()
                return lc
            except (StoreCorruptionError, StoreVersionError) as e:
                quarantined.append((name, str(e)))
                last_err = e
        raise last_err if last_err is not None else \
            StoreError(f"{root}: no loadable epoch")

    @classmethod
    def _load_epoch(cls, root: str, name: str,
                    quarantined: list) -> "StoreLifecycle":
        try:
            epoch = int(name.split("_", 1)[1])
        except (IndexError, ValueError):
            raise StoreCorruptionError(f"{root}: malformed epoch name "
                                       f"{name!r} in {CURRENT_FILE}")
        npz = os.path.join(root, name, "arrays.npz")
        if not os.path.exists(npz):
            raise StoreCorruptionError(f"{npz}: epoch directory missing "
                                       f"or incomplete")
        arrays, meta = atomic.load_arrays(
            npz, fmt=EPOCH_FORMAT, version=EPOCH_FORMAT_VERSION,
            corruption_exc=StoreCorruptionError,
            version_exc=StoreVersionError)
        missing = sorted(set(_ARRAYS) - set(arrays))
        if missing:
            raise StoreCorruptionError(f"{npz}: missing epoch array(s): "
                                       f"{missing}")
        for key in ("image_shape", "proxy_factor", "capacity", "n_rows",
                    "seq"):
            if key not in meta:
                raise StoreCorruptionError(f"{npz}: manifest meta is "
                                           f"missing {key!r}")
        validate_index({f: arrays[f] for f in
                        ("centroids", "centroid_norms", "perm", "offsets",
                         "proxy_sorted", "proxy_norms_sorted")},
                       int(meta["capacity"]))
        n_rows = int(meta["n_rows"])
        n_cap = arrays["perm"].shape[0]
        if not 0 <= n_rows <= n_cap:
            raise StoreCorruptionError(f"{npz}: n_rows {n_rows} outside "
                                       f"[0, {n_cap}]")
        if np.isfinite(arrays["x_norms"][n_rows:]).any():
            raise StoreCorruptionError(f"{npz}: finite x_norms beyond "
                                       f"n_rows={n_rows} (row-count "
                                       f"mismatch)")
        sizes = arrays["sizes"]
        if int(sizes.sum()) != n_rows:
            raise StoreCorruptionError(
                f"{npz}: window occupancy {int(sizes.sum())} != n_rows "
                f"{n_rows}")
        return cls(root, arrays, meta, epoch=epoch,
                   quarantined=list(quarantined))

    # -- journal -------------------------------------------------------------
    def _journal_path(self) -> str:
        return os.path.join(self.root, JOURNAL_FILE)

    def _reset_journal(self) -> None:
        atomic.atomic_write_bytes(self._journal_path(), JOURNAL_MAGIC)

    def _read_journal(self):
        """Yield ``(epoch, seq, rows)`` for the journal's valid prefix;
        returns the byte offset where validity ends."""
        path = self._journal_path()
        frames = []
        end = len(JOURNAL_MAGIC)
        if not os.path.exists(path):
            return frames, 0
        with open(path, "rb") as f:
            data = f.read()
        if data[:len(JOURNAL_MAGIC)] != JOURNAL_MAGIC:
            return frames, 0                      # foreign file: rewrite
        pos = len(JOURNAL_MAGIC)
        while pos + _FRAME_HDR.size <= len(data):
            magic, epoch, seq, n, dim, crc = _FRAME_HDR.unpack_from(
                data, pos)
            if magic != FRAME_MAGIC or dim != self.dim:
                break
            payload = data[pos + _FRAME_HDR.size:
                           pos + _FRAME_HDR.size + n * dim * 4]
            if len(payload) != n * dim * 4:
                break                             # torn tail
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                break                             # corrupt tail
            rows = np.frombuffer(payload, np.float32).reshape(n, dim)
            frames.append((epoch, seq, rows))
            pos += _FRAME_HDR.size + len(payload)
            end = pos
        return frames, end

    def _replay_journal(self) -> None:
        """Apply the journal's valid prefix on top of the loaded epoch
        (idempotent: frames from other epochs or out-of-sequence are
        skipped), then truncate any invalid tail."""
        frames, end = self._read_journal()
        for epoch, seq, rows in frames:
            if epoch != self._epoch or seq != self._seq:
                continue                          # stale or gapped frame
            self._apply_rows(rows)
            self._seq += 1
            self.replayed_frames += 1
        path = self._journal_path()
        if not os.path.exists(path) or end == 0:
            self._reset_journal()
        else:
            size = os.path.getsize(path)
            if size > end:                        # torn tail: drop it
                with open(path, "r+b") as f:
                    f.truncate(end)
                    f.flush()
                    os.fsync(f.fileno())

    def _journal_append(self, rows: np.ndarray) -> None:
        payload = np.ascontiguousarray(rows, np.float32).tobytes()
        hdr = _FRAME_HDR.pack(FRAME_MAGIC, self._epoch, self._seq,
                              rows.shape[0], rows.shape[1],
                              zlib.crc32(payload) & 0xFFFFFFFF)
        with open(self._journal_path(), "ab") as f:
            f.write(hdr + payload)
            f.flush()
            os.fsync(f.fileno())

    # -- append --------------------------------------------------------------
    def append(self, rows: np.ndarray) -> int:
        """Durably append flattened rows ``[b, D]``; returns the frame's
        sequence number.

        The journal write (fsync'd) happens before any in-memory
        mutation, so a crash at any later point replays this append
        bit-identically on restart.  Raises
        :class:`~repro.index.store.StoreCapacityError` — *before*
        journaling — when the rows don't fit the capacity-padded
        layout.
        """
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.dim:
            raise ValueError(f"append rows must be [b, {self.dim}], got "
                             f"{rows.shape}")
        if self._n_rows + rows.shape[0] > self.n_capacity:
            raise StoreCapacityError(
                f"append of {rows.shape[0]} rows exceeds capacity "
                f"{self.n_capacity} (have {self._n_rows}); rebuild with "
                f"more slack/spares to grow further")
        seq = self._seq
        self._journal_append(rows)
        self._apply_rows(rows)
        self._seq += 1
        return seq

    def _apply_rows(self, rows: np.ndarray) -> None:
        """Pure-numpy, RNG-free application of one append frame (the
        same code path at append time and journal replay)."""
        prox = _proxy_rows(rows, self.image_shape, self.proxy_factor)
        l_cap = self.capacity
        for i in range(rows.shape[0]):
            p = prox[i]
            nid = self._n_rows
            d2 = (self._cnorm - 2.0 * (self._cent @ p)
                  + float(p @ p))
            w = int(np.argmin(d2))
            if self._sizes[w] >= l_cap:
                spare = np.flatnonzero(~np.isfinite(self._cnorm)
                                       & (self._sizes == 0))
                if spare.size:
                    self._recluster(w, int(spare[0]))
                    d2w = ((self._cnorm[[w, int(spare[0])]]
                            - 2.0 * (self._cent[[w, int(spare[0])]] @ p))
                           + float(p @ p))
                    pair = [w, int(spare[0])]
                    order = np.argsort(d2w, kind="stable")
                    w = next(pair[int(j)] for j in order
                             if self._sizes[pair[int(j)]] < l_cap)
                else:
                    # no spare windows left: nearest window with a free
                    # slot (graceful recall degradation, never a crash)
                    free = self._sizes < l_cap
                    d2 = np.where(free & np.isfinite(self._cnorm), d2,
                                  np.inf)
                    if not np.isfinite(d2).any():
                        d2 = np.where(free, 0.0, np.inf)
                    w = int(np.argmin(d2))
            slot = w * l_cap + int(self._sizes[w])
            self._perm[slot] = nid
            self._ps[slot] = p
            self._pns[slot] = float(p @ p)
            self._sizes[w] += 1
            self._X[nid] = rows[i]
            self._xn[nid] = float(rows[i] @ rows[i])
            self._proxy[nid] = p
            self._pn[nid] = float(p @ p)
            self._n_rows += 1

    def _recluster(self, w: int, s: int) -> None:
        """Deterministic local 2-means: split window ``w``'s rows
        between ``w`` and the spare ``s`` (centroids updated, all other
        windows untouched)."""
        l_cap = self.capacity
        lo = w * l_cap
        size = int(self._sizes[w])
        pts = self._ps[lo:lo + size].copy()
        perm = self._perm[lo:lo + size].copy()
        pns = self._pns[lo:lo + size].copy()
        c1 = pts.mean(0)
        d1 = ((pts - c1) ** 2).sum(-1)
        c2 = pts[int(np.argmax(d1))].copy()
        side = None
        for _ in range(max(1, self.recluster_iters)):
            d1 = ((pts - c1) ** 2).sum(-1)
            d2 = ((pts - c2) ** 2).sum(-1)
            new_side = d2 < d1                    # ties stay with c1
            if side is not None and (new_side == side).all():
                break
            side = new_side
            if side.any():
                c2 = pts[side].mean(0)
            if (~side).any():
                c1 = pts[~side].mean(0)
        # degenerate split (all identical points): halve by position so
        # the overflowing window actually frees slots
        if side is None or not side.any() or not (~side).any():
            side = np.zeros(size, bool)
            side[size // 2:] = True
            c1 = pts[~side].mean(0)
            c2 = pts[side].mean(0)
        for win, mask, c in ((w, ~side, c1), (s, side, c2)):
            base = win * l_cap
            cnt = int(mask.sum())
            self._ps[base:base + cnt] = pts[mask]
            self._perm[base:base + cnt] = perm[mask]
            self._pns[base:base + cnt] = pns[mask]
            # cleared tail slots: deterministic padding (bit-identical
            # replay depends on it)
            self._ps[base + cnt:base + l_cap] = 0.0
            self._perm[base + cnt:base + l_cap] = 0
            self._pns[base + cnt:base + l_cap] = np.inf
            self._sizes[win] = cnt
            self._cent[win] = c
            self._cnorm[win] = float(c @ c)

    # -- commit (durable epoch) ----------------------------------------------
    def _arrays(self) -> dict[str, np.ndarray]:
        return {"X": self._X, "proxy": self._proxy, "x_norms": self._xn,
                "proxy_norms": self._pn, "proxy_sorted": self._ps,
                "proxy_norms_sorted": self._pns, "perm": self._perm,
                "offsets": self._offsets, "centroids": self._cent,
                "centroid_norms": self._cnorm, "sizes": self._sizes}

    def _write_epoch(self, epoch: int) -> None:
        d = os.path.join(self.root, _epoch_name(epoch))
        os.makedirs(d, exist_ok=True)
        atomic.save_arrays(
            os.path.join(d, "arrays.npz"), self._arrays(),
            fmt=EPOCH_FORMAT, version=EPOCH_FORMAT_VERSION,
            meta={"image_shape": list(self.image_shape),
                  "proxy_factor": self.proxy_factor,
                  "capacity": self.capacity,
                  "recluster_iters": self.recluster_iters,
                  "n_rows": self._n_rows, "seq": self._seq,
                  "epoch": int(epoch)})

    def commit(self, kill=None) -> int:
        """Fold journaled appends into a new durable epoch.

        Stages (``kill`` is a test hook called with the stage name
        after each one — raising from it simulates a crash exactly
        there):

        1. ``"epoch_written"`` — the new epoch directory is durable,
           ``CURRENT`` still points at the old epoch.  Recovery loads
           the OLD epoch and replays the journal: state preserved.
        2. ``"current_flipped"`` — ``CURRENT`` atomically points at the
           new epoch; the journal still holds the old frames.  Recovery
           loads the NEW epoch and *skips* the stale frames (epoch tag
           mismatch): state preserved.
        3. ``"journal_truncated"`` — old frames garbage-collected.
        """
        if self.pending_rows == 0 and self._seq == self._epoch_seq:
            return self._epoch
        new = self._epoch + 1
        self._write_epoch(new)
        if kill is not None:
            kill("epoch_written")
        atomic.atomic_write_text(os.path.join(self.root, CURRENT_FILE),
                                 _epoch_name(new) + "\n")
        if kill is not None:
            kill("current_flipped")
        self._epoch = new
        self._epoch_seq = self._seq
        self._epoch_n_rows = self._n_rows
        self._reset_journal()
        if kill is not None:
            kill("journal_truncated")
        return new

    # -- engine-facing views -------------------------------------------------
    def view(self):
        """Current state as an ordinary ``(DatasetStore, GoldenIndex)``
        pair (device COPIES — ``jnp.array``, never ``jnp.asarray``: on
        CPU the latter can zero-copy alias these live mutable buffers,
        and a later ``append`` would silently mutate an installed
        engine epoch behind the zero-copy)."""
        import jax.numpy as jnp

        from repro.core.dataset import DatasetStore
        store = DatasetStore(
            X=jnp.array(self._X), proxy=jnp.array(self._proxy),
            x_norms=jnp.array(self._xn),
            proxy_norms=jnp.array(self._pn),
            image_shape=self.image_shape, labels=None)
        index = GoldenIndex(
            centroids=jnp.array(self._cent),
            centroid_norms=jnp.array(self._cnorm),
            perm=jnp.array(self._perm),
            offsets=jnp.array(self._offsets),
            proxy_sorted=jnp.array(self._ps),
            proxy_norms_sorted=jnp.array(self._pns),
            max_cluster=self.capacity)
        return store, index
