"""Golden Index: clustered, time-aware retrieval for coarse screening.

The paper's headline claim is that inference cost decouples from the
dataset size N, yet the plain GoldDiff pipeline still scans the *whole*
proxy store at every step (``ops.pdist`` is O(N d)).  This package makes
the coarse stage sublinear with an IVF-style clustered index:

* :mod:`repro.index.build`    — JAX k-means (k-means++ seeding, batched
  Lloyd iterations) over the proxy embedding;
* :mod:`repro.index.store`    — the immutable :class:`GoldenIndex`
  (centroids, cluster-sorted row permutation, CSR offsets, per-cluster
  norms; ``save_index``/``load_index`` via npz);
* :mod:`repro.index.schedule` — the time-aware probe schedule
  :class:`ProbeSchedule` (how many clusters ``nprobe_t`` to visit at
  noise level sigma_t).

Why a *time-aware* probe count works — Posterior Progressive
Concentration (paper Eqs. 4/6): the posterior over training points
collapses onto a local neighborhood of the query as the SNR rises
(g(sigma_t) -> 0), which is exactly the regime where a handful of
nearby clusters contains the entire golden support, so
``nprobe_t ~ f_lo * C`` suffices.  At low SNR (g -> 1) the posterior is
diffuse and probes are widest (``nprobe_t -> f_hi * C``) — and the
Gaussian-score regime (Wang & Vastola) makes the coarse stage forgiving
there: any wide candidate set yields nearly the same posterior mean.  A
recall-safety floor additionally guarantees that the probed clusters'
total row capacity covers the paper's candidate budget m_t (Eq. 4) with
slack, so indexed screening degrades to exact screening rather than
silently losing recall when m_t is a large fraction of N.

Per-step coarse cost drops from O(N d) to O(C d + nprobe_t L) in the
engine's IVF-Flat capacity mode (L = padded cluster width): a centroid
scan plus CSR window enumeration — every probed row feeds the exact
re-rank directly, so no per-row proxy pass survives in the coarse
stage.  ``GoldDiffEngine(index=...)`` routes the coarse stage through
this package on all three backends (xla / pallas_interpret / pallas);
:mod:`repro.index.shard` partitions one global index across the devices
of a mesh axis at CSR window boundaries, which is how
``GoldDiffEngine(mesh=...)`` keeps sharded indexed screening equal to
the single-host probe set (not merely close to it).
"""
from repro.index.build import kmeans, kmeans_plusplus
from repro.index.ingest import IngestConfig, StoreLifecycle
from repro.index.schedule import ProbeSchedule
from repro.index.shard import ShardedLayout, partition_windows, shard_layout
from repro.index.store import (GoldenIndex, StoreCapacityError,
                               StoreCorruptionError, StoreError,
                               StoreVersionError, build_index, load_index,
                               save_index, screening_recall, validate_index)

__all__ = ["GoldenIndex", "build_index", "save_index", "load_index",
           "kmeans", "kmeans_plusplus", "ProbeSchedule",
           "ShardedLayout", "partition_windows", "shard_layout",
           "screening_recall", "validate_index", "StoreError",
           "StoreCorruptionError", "StoreVersionError",
           "StoreCapacityError", "IngestConfig", "StoreLifecycle"]
