"""Zero-dependency observability layer: tracing, metrics, quality.

* ``repro.obs.trace``   — ``Tracer`` (nestable spans, bounded ring
  buffer), the process-global current tracer (``set_tracer`` /
  ``tracer()``, off by default via ``NULL_TRACER``), and ``TraceHook``
  for the program-dispatch seam.
* ``repro.obs.metrics`` — typed ``Counter``/``Gauge``/``Histogram``
  (bounded reservoir quantiles) in a ``MetricsRegistry``
  (process-global default: ``REGISTRY``), exported as JSON snapshots
  and Prometheus text.
* ``repro.obs.quality`` — ``QualityMonitor``: sampled online
  screening-recall proxy, the concentration curve (k_t/N and probe
  occupancy vs t), finite-guard/degradation rates.

``trace`` and ``metrics`` import nothing from the rest of the repo, so
any layer (kernels included) may import them without cycles;
``quality`` sits above the index layer and is re-exported lazily.
"""
from __future__ import annotations

from repro.obs import metrics, trace
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, Tracer, TraceHook,
                             install_dispatch_tracing, set_tracer, tracer,
                             uninstall_dispatch_tracing)

__all__ = ["metrics", "trace", "REGISTRY", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "NULL_TRACER", "Tracer", "TraceHook",
           "install_dispatch_tracing", "set_tracer", "tracer",
           "uninstall_dispatch_tracing", "QualityMonitor"]


def __getattr__(name):
    # lazy: quality imports the index layer, which imports core — keep
    # ``repro.core.engine -> repro.obs`` cycle-free
    if name == "QualityMonitor":
        from repro.obs.quality import QualityMonitor
        return QualityMonitor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
