"""Typed metrics: counters, gauges, bounded-quantile histograms.

The serving runtime previously kept an *unbounded* Python list of
request latencies just to compute p50/p99 in ``health()`` — O(traffic)
memory on a process meant to run for weeks.  :class:`Histogram` replaces
it with **reservoir sampling** (Vitter's algorithm R with a
deterministic counter-based splitmix64 stream, the same generator
family as ``launch.faults`` — no global RNG state, reproducible across
runs): O(reservoir) memory forever, exact quantiles while
``count <= reservoir``, and an unbiased uniform sample of the whole
stream beyond it (p50/p99 regression-tested against exact percentiles
in ``tests/test_obs.py``).

All metrics live in a :class:`MetricsRegistry`; :data:`REGISTRY` is the
process-global default (``scripts/obs_dump.py`` and the serving
``health()``/``prometheus()`` exporters read it), and tests build
private registries so they never see each other's state.  Two export
formats:

* ``registry.snapshot()`` — plain-JSON dict (name -> typed cell);
* ``registry.prometheus()`` — Prometheus text exposition format
  (counters/gauges as samples, histograms as summaries with
  ``quantile`` labels + ``_sum``/``_count``).

Metric updates take a per-registry lock only on *creation*; increments
and observations are single-bytecode-ish operations safe under the
GIL, matching how the runtime's own counters dict already behaves.
"""
from __future__ import annotations

import math
import re
import threading

_M64 = (1 << 64) - 1


def _splitmix64(z: int) -> int:
    z = (z + 0x9E3779B97F4A7C15) & _M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


def _unit(seed: int, n: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, counter)."""
    return _splitmix64((seed * 0xD1B54A32D192ED03
                        + n * 0x8CB92BA72F3D8DD7) & _M64) / 2.0 ** 64


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


class Counter:
    """Monotone counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def cell(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def cell(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Bounded-quantile histogram over a fixed-size reservoir.

    ``observe(v)`` is O(1); ``quantile(q)`` sorts the reservoir
    (O(R log R), an exporter-path cost).  While ``count <= reservoir``
    the sample IS the stream, so quantiles are exact; beyond it,
    algorithm R keeps each seen value with probability R/count —
    a uniform sample, so quantile error concentrates as O(1/sqrt(R)).
    """

    kind = "histogram"
    QUANTILES = (0.5, 0.95, 0.99)

    def __init__(self, name: str, help: str = "", reservoir: int = 1024,
                 seed: int = 0):
        if reservoir < 1:
            raise ValueError(f"reservoir must be >= 1, got {reservoir}")
        self.name = name
        self.help = help
        self.reservoir = int(reservoir)
        self.seed = seed
        self._sample: list[float] = []
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        n = self.count
        self.count = n + 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if n < self.reservoir:
            self._sample.append(v)
        else:
            j = int(_unit(self.seed, n) * (n + 1))
            if j < self.reservoir:
                self._sample[j] = v

    def quantile(self, q: float) -> float:
        if not self._sample:
            return 0.0
        s = sorted(self._sample)
        # linear interpolation between closest ranks (numpy default)
        pos = q * (len(s) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    def cell(self) -> dict:
        c = {"type": "histogram", "count": self.count, "sum": self.sum,
             "min": self.min if self.count else 0.0,
             "max": self.max if self.count else 0.0}
        for q in self.QUANTILES:
            c[f"p{int(q * 100)}"] = self.quantile(q)
        return c


class MetricsRegistry:
    """Named metric store with idempotent typed constructors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, requested "
                                f"{cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", reservoir: int = 1024,
                  seed: int = 0) -> Histogram:
        return self._get(Histogram, name, help, reservoir=reservoir,
                         seed=seed)

    def register(self, metric) -> None:
        """Adopt an externally constructed metric (last-wins on name
        collisions — e.g. a fresh ``ServeRuntime`` re-registering its
        private latency histogram replaces a stale predecessor's)."""
        with self._lock:
            self._metrics[metric.name] = metric

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON dict of every metric (name -> typed cell)."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.cell() for name, m in items}

    def prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines = []
        for name, m in items:
            pn = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pn} summary")
                for q in Histogram.QUANTILES:
                    lines.append(f'{pn}{{quantile="{q}"}} '
                                 f"{m.quantile(q):.9g}")
                lines.append(f"{pn}_sum {m.sum:.9g}")
                lines.append(f"{pn}_count {m.count}")
            else:
                lines.append(f"# TYPE {pn} {m.kind}")
                lines.append(f"{pn} {m.value:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")


REGISTRY = MetricsRegistry()
