"""Tracing: nestable spans over a fixed-size ring buffer of events.

The stack's observability tentpole needs a tracer that is *free when
off* and *cheap when on*:

* **off** — the module-level current tracer defaults to
  :data:`NULL_TRACER`, a shared constant whose ``enabled`` flag is
  ``False`` and whose ``span``/``event`` methods are no-ops returning a
  shared no-op context.  Every instrumented call site branches on
  ``tracer().enabled`` *before* doing any tag computation, so the
  disabled path is one global read + one attribute check — no event
  objects, no clock reads, no extra dispatches, identical program-cache
  keys, bit-identical outputs (``tests/test_obs.py`` pins this).
* **on** — events land in a preallocated ring buffer by monotonically
  increasing sequence number (an integer index modulo capacity; under
  the GIL the append is a single list-slot store, so concurrent
  emitters never block each other — "lock-free" in the
  no-locks-on-the-hot-path sense).  The buffer holds the most recent
  ``capacity`` events; ``seq`` stays globally monotone so drops are
  detectable.

Event schema (one dict per event — the *unified* schema; the fault
injector emits onto the same stream, see ``repro.launch.faults``):

  ``{"seq": int, "ts": float, "kind": "begin"|"end"|"point",
     "name": str, "span": int, "parent": int | None, "tags": dict}``

``span`` is the owning span's id for begin/end pairs (and the enclosing
span for points; 0 = top level); ``parent`` is the enclosing span's id.
``end`` events carry ``tags["dur"]`` (seconds).  The clock is
injectable (``Tracer(clock=...)``) so span ordering/duration tests run
deterministically under a fake clock.

Span taxonomy (see README "Observability"):

  ``engine.denoise|select|full_scan|fused_step``  one per engine entry
      dispatch (``fused_step`` when the fused="auto" policy routes the
      step through the single-pass fused program)
  ``stage.screen|ivf_screen|rerank|aggregate|full_scan|fused_step``
      point events carrying analytic ``flops``/``bytes`` tags
      (``core.plan``; fused steps emit one whole-step stage event)
  ``dispatch.<kind>``  one per program-cache dispatch (TraceHook)
  ``plan.segment``     one per trajectory-plan bucket execution
  ``wave.segment``     one per serving-runtime segment (+ ``wave.*`` /
      ``request.*`` lifecycle points)
  ``fault.<kind>``     injected faults, inline (launch.faults)
"""
from __future__ import annotations

import json
import time


class _NullSpan:
    """Shared no-op context manager (the disabled-tracer span)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span handle: closes with an ``end`` event carrying ``dur``."""

    __slots__ = ("tracer", "name", "sid", "parent", "t0")

    def __init__(self, tracer, name, sid, parent, t0):
        self.tracer = tracer
        self.name = name
        self.sid = sid
        self.parent = parent
        self.t0 = t0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.tracer._end_span(self)
        return False


class Tracer:
    """Nestable spans + point events over a bounded ring buffer."""

    enabled = True

    def __init__(self, capacity: int = 4096, clock=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self._buf: list = [None] * self.capacity
        self._seq = 0                     # next sequence number (monotone)
        self._next_span = 1               # span id 0 = top level
        self._stack: list[int] = []       # open span ids (nesting)

    # -- emission -------------------------------------------------------------
    def _emit(self, kind: str, name: str, span: int, parent, tags: dict):
        seq = self._seq
        self._seq = seq + 1
        self._buf[seq % self.capacity] = {
            "seq": seq, "ts": self.clock(), "kind": kind, "name": name,
            "span": span, "parent": parent, "tags": tags}

    def span(self, name: str, **tags):
        """Open a nested span; use as ``with tr.span("engine.denoise",
        t=400):``.  The matching ``end`` event records ``dur``."""
        parent = self._stack[-1] if self._stack else 0
        sid = self._next_span
        self._next_span += 1
        t0 = self.clock()
        self._emit("begin", name, sid, parent, tags)
        self._stack.append(sid)
        return _Span(self, name, sid, parent, t0)

    def _end_span(self, s: _Span):
        if self._stack and self._stack[-1] == s.sid:
            self._stack.pop()
        elif s.sid in self._stack:        # tolerate mis-nested exits
            self._stack.remove(s.sid)
        self._emit("end", s.name, s.sid, s.parent,
                   {"dur": self.clock() - s.t0})

    def event(self, name: str, **tags):
        """Point event inside the current span (0 = top level)."""
        span = self._stack[-1] if self._stack else 0
        self._emit("point", name, span,
                   self._stack[-2] if len(self._stack) > 1 else None, tags)

    # -- reading --------------------------------------------------------------
    def events(self) -> list[dict]:
        """Buffered events in sequence order (oldest surviving first)."""
        n = min(self._seq, self.capacity)
        start = self._seq - n
        return [self._buf[(start + i) % self.capacity] for i in range(n)]

    @property
    def dropped(self) -> int:
        """Events evicted by ring wrap (total emitted - buffered)."""
        return max(0, self._seq - self.capacity)

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._seq = 0
        self._next_span = 1
        self._stack = []

    def dump(self, path: str) -> int:
        """Write buffered events as JSON lines; returns the count."""
        evs = self.events()
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e, default=str) + "\n")
        return len(evs)


class NullTracer(Tracer):
    """The disabled tracer: every operation is a no-op constant."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def span(self, name: str, **tags):
        return _NULL_SPAN

    def event(self, name: str, **tags):
        return None

    def _emit(self, *a, **kw):
        return None


NULL_TRACER = NullTracer()

_TRACER: Tracer = NULL_TRACER


def set_tracer(tr: Tracer | None) -> Tracer:
    """Install ``tr`` (or NULL_TRACER for ``None``) as the process-wide
    current tracer; returns the previous one so callers can restore."""
    global _TRACER
    prev = _TRACER
    _TRACER = NULL_TRACER if tr is None else tr
    return prev


def tracer() -> Tracer:
    """The current tracer (NULL_TRACER when tracing is off)."""
    return _TRACER


class TraceHook:
    """Dispatch-seam hook: spans every compiled-program dispatch.

    Installed at ``ops.set_dispatch_hook`` (the same seam the fault
    injector uses).  ``inner`` chains to a previously installed hook —
    typically the :class:`repro.launch.faults.FaultInjector` — so
    tracing and fault injection compose; the injector's wrapped
    callable runs *inside* the trace span, so injected latency/errors
    are attributed to the dispatch that suffered them.

    Each dispatch emits a ``dispatch.<kind>`` span tagged with the full
    cache key and ``compile`` (True exactly when this lookup built the
    program — detected pre-lookup via ``key in engine._programs``).
    ``registry`` (optional, a ``repro.obs.metrics.MetricsRegistry``)
    additionally counts dispatches and compiles per program kind.
    """

    def __init__(self, tr: Tracer, inner=None, registry=None):
        self.tracer = tr
        self.inner = inner
        self.registry = registry
        self._last_compile = False

    def on_program(self, engine, key) -> None:
        if self.inner is not None:
            self.inner.on_program(engine, key)   # may evict (recompile)
        # ``program()`` calls on_program then wrap back-to-back for the
        # same key, so one pending flag is enough (no interleaving)
        self._last_compile = key not in engine._programs

    def wrap(self, key, fn):
        if self.inner is not None:
            fn = self.inner.wrap(key, fn)
        tr = self.tracer
        if not tr.enabled and self.registry is None:
            return fn
        kind = key[0] if isinstance(key, tuple) and key else str(key)
        compiled = bool(self._last_compile)
        if self.registry is not None:
            self.registry.counter(f"golddiff_dispatch_total_{kind}").inc()
            if compiled:
                self.registry.counter("golddiff_compiles_total").inc()
        if not tr.enabled:
            return fn

        def traced(*args, **kw):
            with tr.span(f"dispatch.{kind}", key=repr(key),
                         compile=compiled):
                return fn(*args, **kw)

        return traced


def install_dispatch_tracing(tr: Tracer, registry=None) -> TraceHook:
    """Wrap the current dispatch hook (e.g. an installed fault
    injector) in a :class:`TraceHook` and install it.  Returns the hook
    so callers can pass it to :func:`uninstall_dispatch_tracing`."""
    from repro.kernels import ops   # deferred: keep obs import-light
    hook = TraceHook(tr, inner=ops.dispatch_hook(), registry=registry)
    ops.set_dispatch_hook(hook)
    return hook


def uninstall_dispatch_tracing(hook: TraceHook | None = None) -> None:
    """Restore the hook that was active before tracing was installed."""
    from repro.kernels import ops
    cur = ops.dispatch_hook()
    if isinstance(cur, TraceHook):
        ops.set_dispatch_hook(cur.inner)
    elif hook is not None and cur is hook:   # pragma: no cover - defensive
        ops.set_dispatch_hook(hook.inner)
