"""Online quality monitors: screening recall, concentration, guards.

The paper's speed/quality contract is checked offline by tier-2
benchmarks; this module checks it *online*, at serve time, at a
configurable sample rate so the hot path stays unperturbed:

* **streaming screening-recall proxy** — on a sampled subset of
  segment seams, run BOTH the indexed coarse screen and the exact
  top-m screen on the first ``probe_rows`` rows of the live wave state
  and record their overlap (``repro.index.store.screening_recall``,
  the same metric the tier-2 gate uses).  This is the quantity that
  silently degrades when ``ProbeSchedule`` narrows at high SNR.
* **concentration curve** — per executed timestep: the golden-subset
  fraction k_t/N and the probe-occupancy fraction (rows the coarse
  stage touches / N), as per-t gauges (the curve, readable straight
  off a Prometheus scrape) plus aggregate histograms.  This is the
  paper's Posterior Progressive Concentration made observable in
  production.
* **guard rates** — finite-guard trips and degraded-rung entries as
  counters (the runtime drives them), alongside its breaker
  dwell-time accounting.

Probe decisions draw from the same deterministic counter-based
splitmix stream as the metrics reservoir: a given ``seed`` + call
order reproduces the same probe points, independent of wall clock.

Probe programs are cached in the engine's own compiled-program cache
under ``"obs_screen_*"`` kinds — NOT in the fault injector's default
target set (a monitor that can be faulted measures the injector, not
the system) — and :meth:`QualityMonitor.warmup` precompiles them, so
enabling monitors does not break the zero-post-warmup-compile guard.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.obs import metrics as _metrics


class QualityMonitor:
    """Sampled online quality telemetry for one ``GoldDiffEngine``.

    ``sample_rate`` is the per-opportunity probability of running the
    (two extra dispatches) recall probe; concentration recording is
    analytic host arithmetic and runs on every reported step.
    """

    def __init__(self, engine, registry: _metrics.MetricsRegistry | None
                 = None, sample_rate: float = 0.25, probe_rows: int = 2,
                 seed: int = 0):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], got "
                             f"{sample_rate}")
        self.engine = engine
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self.sample_rate = float(sample_rate)
        self.probe_rows = int(probe_rows)
        self.seed = seed
        self._probe_n = 0                # sampling-decision counter
        r = self.registry
        self.recall_hist = r.histogram(
            "golddiff_screen_recall_proxy",
            "sampled indexed-vs-exact screening recall at segment seams")
        self.recall_last = r.gauge(
            "golddiff_screen_recall_last",
            "most recent screening-recall probe value")
        self.subset_hist = r.histogram(
            "golddiff_subset_frac",
            "golden-subset fraction k_t/N per executed step")
        self.occupancy_hist = r.histogram(
            "golddiff_probe_occupancy",
            "fraction of store rows touched by the coarse stage per step")
        self.steps = r.counter("golddiff_steps_total",
                               "executed denoise steps observed")
        self.probes = r.counter("golddiff_recall_probes_total",
                                "screening-recall probes executed")
        self.finite_trips = r.counter(
            "golddiff_finite_trips_total",
            "rows replaced by the Gaussian fallback after a finite-guard "
            "trip")
        self.degrades = r.counter("golddiff_degraded_waves_total",
                                  "waves served on a non-primary rung")

    # -- concentration (analytic, host-side) ----------------------------------
    def _touched_frac(self, t: int) -> float:
        eng = self.engine
        n = eng.store.n
        if eng.use_index(t):
            return min(1.0, eng.nprobe(t) * eng.index.max_cluster / n)
        return 1.0                       # exact screen reads every row

    def record_step(self, t: int) -> None:
        """Record the concentration curve for one executed timestep."""
        t = int(t)
        eng = self.engine
        n = eng.store.n
        m_t, k_t = eng.sizes(t)
        occ = self._touched_frac(t)
        self.steps.inc()
        self.subset_hist.observe(k_t / n)
        self.occupancy_hist.observe(occ)
        r = self.registry
        r.gauge(f"golddiff_k_frac_t{t}",
                "golden-subset fraction k_t/N at this timestep"
                ).set(k_t / n)
        r.gauge(f"golddiff_occupancy_t{t}",
                "coarse-stage touched fraction at this timestep").set(occ)
        if eng.use_index(t):
            r.gauge(f"golddiff_nprobe_t{t}",
                    "scheduled probe count at this timestep"
                    ).set(eng.nprobe(t))

    # -- guard / degradation hooks (driven by the runtime) --------------------
    def on_finite_trips(self, n: int) -> None:
        self.finite_trips.inc(n)

    def on_degrade(self) -> None:
        self.degrades.inc()

    # -- recall probe ---------------------------------------------------------
    def _probe_programs(self, t: int, rows: int):
        """(exact, indexed) compiled probe screens for static ``t`` over
        a ``[rows, D]`` query — cached under obs-only program kinds."""
        eng = self.engine
        m_t, _ = eng.sizes(t)
        mp, npb = eng.padded_m(t), eng.nprobe(t)
        shape = (rows, eng.store.dim)
        exact = eng.program(
            ("obs_screen_exact", t, shape, m_t, eng.backend),
            lambda: jax.jit(lambda q: eng.coarse(q, m_t)))
        ivf = eng.program(
            ("obs_screen_ivf", t, shape, mp, npb, eng.backend),
            lambda: jax.jit(lambda q: eng.coarse_indexed(q, mp, npb)))
        return exact, ivf

    def probe_recall(self, x, t: int) -> float | None:
        """Indexed-vs-exact screening recall on the first ``probe_rows``
        rows of ``x`` (current state at timestep ``t``).  Returns None
        when the step screens exactly (nothing to proxy).  Probes always
        run at exactly ``probe_rows`` rows (short inputs are tiled) so
        the probe-program shapes are static and :meth:`warmup` covers
        every post-warmup probe."""
        t = int(t)
        eng = self.engine
        if not eng.use_index(t) or x.shape[0] == 0:
            return None
        from repro.index.store import screening_recall
        rows = max(1, self.probe_rows)
        a, _ = eng.constants(t)
        q = np.asarray(x[:rows], np.float32)
        if q.shape[0] < rows:
            reps = -(-rows // q.shape[0])
            q = np.tile(q, (reps, 1))[:rows]
        q = q / a
        exact_fn, ivf_fn = self._probe_programs(t, rows)
        exact_ids = jax.block_until_ready(exact_fn(q))
        pos, pd2 = jax.block_until_ready(ivf_fn(q))
        rec = screening_recall(pos, pd2, eng.index.perm, exact_ids)
        self.probes.inc()
        self.recall_hist.observe(rec)
        self.recall_last.set(rec)
        return rec

    def maybe_probe_recall(self, x, t: int) -> float | None:
        """Sampled :meth:`probe_recall` (deterministic decision stream)."""
        n = self._probe_n
        self._probe_n = n + 1
        if self.sample_rate <= 0.0 \
                or _metrics._unit(self.seed, n) >= self.sample_rate:
            return None
        return self.probe_recall(x, t)

    # -- summary --------------------------------------------------------------
    def health(self) -> dict:
        """Flat summary for ``ServeRuntime.health()`` (JSON-friendly)."""
        return {
            "screen_recall_last": self.recall_last.value,
            "screen_recall_p50": self.recall_hist.quantile(0.5),
            "subset_frac_p50": self.subset_hist.quantile(0.5),
            "probe_occupancy_p50": self.occupancy_hist.quantile(0.5),
            "n_recall_probes": self.probes.value,
            "n_steps_observed": self.steps.value,
        }

    # -- warmup ---------------------------------------------------------------
    def warmup(self, ts, rows: int | None = None) -> int:
        """Precompile the probe programs for every indexed timestep in
        ``ts`` (zero post-warmup compiles even with monitors on).
        Returns the number of timesteps warmed."""
        eng = self.engine
        rows = self.probe_rows if rows is None else int(rows)
        warmed = 0
        q = np.zeros((max(1, rows), eng.store.dim), np.float32)
        for t in sorted({int(t) for t in ts}):
            if not eng.use_index(t):
                continue
            exact_fn, ivf_fn = self._probe_programs(t, q.shape[0])
            jax.block_until_ready(exact_fn(q))
            jax.block_until_ready(ivf_fn(q))
            warmed += 1
        return warmed
