"""Crash-safe artifact persistence: atomic writes + checksummed manifests.

One implementation shared by the golden-store persistence
(``repro.index.store``, ``repro.index.ingest``) and the training
checkpointer (``repro.training.checkpoint``), so the write protocol and
the validation rules cannot drift apart.

Write protocol (per file): write to ``<name>.tmp.<pid>`` in the SAME
directory, flush + ``os.fsync``, then ``os.replace`` over the final
name and fsync the directory.  A crash at any point leaves either the
old file or the new file — never a torn one — and stray ``.tmp.*``
files are ignored by every reader.

Array artifacts are an ``.npz`` plus a JSON *manifest* recording the
format name, an integer ``format_version``, and per-array
shape/dtype/sha256.  ``load_arrays`` validates all of it BEFORE any
caller constructs objects from the data, raising the caller's typed
error classes (so ``repro.index.store`` surfaces
``StoreCorruptionError``/``StoreVersionError`` and the checkpointer its
own) instead of an obscure downstream failure or — worse — silently
wrong numerics.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import zipfile

import numpy as np


class ArtifactError(Exception):
    """Base class for persistence failures (missing / unreadable)."""


class ArtifactCorruptionError(ArtifactError):
    """Artifact bytes disagree with their manifest (torn write,
    truncation, bit-flip, checksum mismatch, schema mismatch)."""


class ArtifactVersionError(ArtifactError):
    """Artifact was written by an incompatible format version."""


def sha256_hex(data: bytes | np.ndarray) -> str:
    if isinstance(data, np.ndarray):
        data = np.ascontiguousarray(data).tobytes()
    return hashlib.sha256(data).hexdigest()


def fsync_dir(path: str) -> None:
    """fsync a directory so a rename inside it is durable (POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` atomically (tmp + fsync + replace)."""
    path = os.fspath(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(path) or ".")


def atomic_write_text(path: str, text: str) -> None:
    atomic_write_bytes(path, text.encode("utf-8"))


def atomic_write_json(path: str, obj) -> None:
    atomic_write_bytes(path, json.dumps(obj, indent=1,
                                        sort_keys=True).encode("utf-8"))


def _manifest_path(npz_path: str) -> str:
    return os.fspath(npz_path) + ".manifest.json"


def save_arrays(npz_path: str, arrays: dict[str, np.ndarray],
                fmt: str, version: int, meta: dict | None = None,
                manifest_path: str | None = None) -> str:
    """Atomically write ``arrays`` as npz + a checksummed manifest.

    The npz lands first, the manifest second — the manifest is the
    per-artifact commit marker, so a crash between the two writes is
    *detected* at load (checksum mismatch), never silently served.
    Returns the manifest path.
    """
    npz_path = os.fspath(npz_path)
    manifest_path = manifest_path or _manifest_path(npz_path)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    atomic_write_bytes(npz_path, buf.getvalue())
    manifest = {
        "format": fmt,
        "format_version": int(version),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": sha256_hex(v)}
                   for k, v in sorted(arrays.items())},
        "meta": dict(meta or {}),
    }
    atomic_write_json(manifest_path, manifest)
    return manifest_path


def load_arrays(npz_path: str, fmt: str, version: int,
                manifest_path: str | None = None,
                corruption_exc: type[Exception] = ArtifactCorruptionError,
                version_exc: type[Exception] = ArtifactVersionError,
                ) -> tuple[dict[str, np.ndarray], dict]:
    """Load + validate an npz/manifest pair written by ``save_arrays``.

    Validates, in order: manifest presence and well-formedness, format
    name, format version, npz readability, array presence (both
    directions), per-array shape/dtype, and per-array sha256.  Raises
    ``version_exc`` for version mismatches and ``corruption_exc`` for
    everything else, always with a message naming the offending piece.
    Returns ``(arrays, meta)``.
    """
    npz_path = os.fspath(npz_path)
    manifest_path = manifest_path or _manifest_path(npz_path)
    if not os.path.exists(manifest_path):
        raise corruption_exc(f"{npz_path}: missing manifest "
                             f"{os.path.basename(manifest_path)} (not "
                             f"written by save_arrays, or a torn write)")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise corruption_exc(f"{manifest_path}: unreadable manifest "
                             f"({e})") from e
    if not isinstance(manifest, dict) or \
            not isinstance(manifest.get("arrays"), dict):
        raise corruption_exc(f"{manifest_path}: malformed manifest "
                             f"(expected an object with an 'arrays' map)")
    if manifest.get("format") != fmt:
        raise corruption_exc(
            f"{manifest_path}: format {manifest.get('format')!r} != "
            f"expected {fmt!r}")
    got_ver = manifest.get("format_version")
    if got_ver != int(version):
        raise version_exc(
            f"{manifest_path}: format_version {got_ver!r} is not the "
            f"supported version {version} — refusing to load")
    try:
        with np.load(npz_path) as z:
            arrays = {k: np.array(z[k]) for k in z.files}
    except (OSError, ValueError, zipfile.BadZipFile, KeyError,
            EOFError) as e:
        raise corruption_exc(f"{npz_path}: unreadable npz ({e})") from e
    spec = manifest["arrays"]
    missing = sorted(set(spec) - set(arrays))
    extra = sorted(set(arrays) - set(spec))
    if missing or extra:
        raise corruption_exc(
            f"{npz_path}: array set mismatch vs manifest "
            f"(missing: {missing or '-'}, unexpected: {extra or '-'})")
    for name in sorted(spec):
        want, have = spec[name], arrays[name]
        if not isinstance(want, dict):
            raise corruption_exc(f"{manifest_path}: malformed entry for "
                                 f"array {name!r}")
        if list(have.shape) != list(want.get("shape", [])):
            raise corruption_exc(
                f"{npz_path}: array {name!r} shape {list(have.shape)} != "
                f"manifest {want.get('shape')}")
        if str(have.dtype) != want.get("dtype"):
            raise corruption_exc(
                f"{npz_path}: array {name!r} dtype {have.dtype} != "
                f"manifest {want.get('dtype')}")
        digest = sha256_hex(have)
        if digest != want.get("sha256"):
            raise corruption_exc(
                f"{npz_path}: array {name!r} checksum mismatch "
                f"(sha256 {digest[:12]}… != manifest "
                f"{str(want.get('sha256'))[:12]}… — torn write or "
                f"bit-rot)")
    meta = manifest.get("meta")
    return arrays, dict(meta) if isinstance(meta, dict) else {}
