"""Small dependency-free utilities shared across the stack.

:mod:`repro.utils.atomic` — the single crash-safe artifact writer
(tmp + fsync + rename, sha256-checksummed JSON manifests) used by both
``repro.index.store`` persistence and ``repro.training.checkpoint``.
"""
from repro.utils.atomic import (ArtifactCorruptionError, ArtifactError,
                                ArtifactVersionError, atomic_write_bytes,
                                atomic_write_json, atomic_write_text,
                                load_arrays, save_arrays, sha256_hex)

__all__ = ["ArtifactError", "ArtifactCorruptionError",
           "ArtifactVersionError", "atomic_write_bytes",
           "atomic_write_json", "atomic_write_text", "load_arrays",
           "save_arrays", "sha256_hex"]
