"""Pallas TPU kernel: streaming-softmax weighted aggregation (Eq. 2).

The per-step hot loop of the analytical denoiser: a *single-query-class
attention* over the (golden) support where keys == values == training
points.  FlashAttention-style online softmax: the dataset streams through
VMEM in MXU-aligned tiles while a (max, denom, accumulator) carry lives in
scratch; logits come from the matmul distance form.  This is the
TPU-native replacement for the paper's CUDA streaming softmax (DESIGN §3).

out[b] = sum_i softmax_i( -(||q_b||^2 + ||x_i||^2 - 2 q_b.x_i) / (2 s2) ) x_i
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

NEG_INF = -1e30
DEFAULT_BQ = 8
DEFAULT_BN = 512


def _agg_kernel(q_ref, x_ref, qn_ref, xn_ref, out_ref,
                m_ref, l_ref, acc_ref, *, inv_two_sigma2: float, nn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    x = x_ref[...]
    dot = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = qn_ref[...] + xn_ref[...] - 2.0 * dot          # [bq, bn]
    # real rows clamp at the finite NEG_INF floor (extreme sigma -> a
    # uniform aggregate, never NaN); padded rows (d2 = +inf from the
    # +inf-norm pad) keep a hard -inf so they stay weightless even in
    # the all-clamped degenerate case
    logits = jnp.where(d2 == jnp.inf, -jnp.inf,
                       jnp.maximum(-d2 * inv_two_sigma2, NEG_INF))

    m_prev = m_ref[...]                                  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                          # [bq, bn]
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
        p, x.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nn - 1)
    def _emit():
        out_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sigma2", "bq", "bn", "interpret"))
def golden_aggregate(q: jnp.ndarray, x: jnp.ndarray, sigma2: float,
                     x_norms: jnp.ndarray | None = None,
                     bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                     interpret: bool = True) -> jnp.ndarray:
    """Full-scan empirical-Bayes posterior mean.  q: [B, D], x: [N, D] -> [B, D].

    ``q`` must already be the rescaled query ``x_t / a_t``; ``sigma2`` is the
    noise-to-signal ratio sigma_t^2 (static: one program per timestep, the
    per-step-jit execution mode of DESIGN §3).
    """
    b, d = q.shape
    n = x.shape[0]
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    q_norms = jnp.sum(q.astype(jnp.float32) ** 2, -1)

    bq = min(bq, b)
    bn = min(bn, n)
    pb = (-b) % bq
    pn = (-n) % bn
    qp = jnp.pad(q, ((0, pb), (0, 0)))
    xp = jnp.pad(x, ((0, pn), (0, 0)))
    qn = jnp.pad(q_norms, (0, pb)).reshape(-1, 1)
    # +inf norm on padded rows -> -inf logits -> zero weight
    xn = jnp.pad(x_norms, (0, pn), constant_values=jnp.inf).reshape(1, -1)
    nb, nn = (b + pb) // bq, (n + pn) // bn

    out = pl.pallas_call(
        functools.partial(_agg_kernel,
                          inv_two_sigma2=ref.finite_inv_two_sigma2(sigma2),
                          nn=nn),
        grid=(nb, nn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pb, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # weighted accumulator
        ],
        interpret=interpret,
    )(qp, xp, qn, xn)
    return out[:b]
