"""Pallas TPU kernel: fused exact re-ranking distances over candidate tiles.

GoldDiff's precision stage (paper Eq. 5).  The seed implementation
materialized two ``[B, m, D]`` broadcast-subtract temporaries
(``(q[:, None] - xs) ** 2`` and its square); here distances are computed
in the MXU-friendly matmul form over gathered candidate tiles

    ||q_b - x_c||^2 = ||q_b||^2 + ||x_c||^2 - 2 q_b . x_c

with dataset row norms *gathered* (O(B m) scalars, precomputed once per
dataset in ``DatasetStore``) instead of recomputed, and fp32
accumulation regardless of the storage dtype.  The kernel body per
(query-tile, candidate-tile) is a single batched (bq x D) . (bq x bm x D)
contraction plus rank-1 adds — no [B, m, D] temporaries.

The ops-layer ``golden_rerank`` wrapper adds the top-k and returns the
selected indices *and their distances*, so downstream aggregation reuses
selection distances instead of recomputing them (the seed computed exact
candidate distances twice per masked step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8
DEFAULT_BM = 128


def _sqdist_kernel(q_ref, xs_ref, xn_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    xs = xs_ref[...]
    qn = jnp.sum(q * q, -1, keepdims=True)                     # [bq, 1]
    dot = jax.lax.dot_general(                                 # [bq, bm]
        q, xs, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    # +inf norms (masked/padded rows) propagate to +inf distances
    out_ref[...] = jnp.maximum(qn + xn_ref[...] - 2.0 * dot, 0.0)


@functools.partial(jax.jit, static_argnames=("bq", "bm", "interpret"))
def support_sqdist(q: jnp.ndarray, xs: jnp.ndarray, x_norms: jnp.ndarray,
                   bq: int = DEFAULT_BQ, bm: int = DEFAULT_BM,
                   interpret: bool = True) -> jnp.ndarray:
    """Exact distances to per-query gathered rows, tiled matmul form.

    q: [B, D], xs: [B, M, D] (gathered candidate rows), x_norms: [B, M]
    (gathered ``||x||^2``) -> [B, M] fp32.

    interpret=True on CPU (validation); False lowers for real TPUs.
    """
    b, d = q.shape
    m = xs.shape[1]
    bq = min(bq, b)
    bm = min(bm, m)
    pb = (-b) % bq
    pm = (-m) % bm
    qp = jnp.pad(q, ((0, pb), (0, 0)))
    xsp = jnp.pad(xs, ((0, pb), (0, pm), (0, 0)))
    xnp = jnp.pad(x_norms.astype(jnp.float32), ((0, pb), (0, pm)))
    grid = ((b + pb) // bq, (m + pm) // bm)

    out = pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bm, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + pb, m + pm), jnp.float32),
        interpret=interpret,
    )(qp, xsp, xnp)
    return out[:b, :m]
