"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

This module is the single entry point the core library uses for the
GoldDiff hot path — coarse screening (``pdist``), exact re-ranking
(``golden_rerank``), and golden aggregation (``golden_support_aggregate``
for supports, ``golden_aggregate`` for full scans) — plus the attention
kernels.  ``repro.core.engine.GoldDiffEngine`` routes every stage
through these wrappers so the same code path serves CPU tests, the
multi-pod dry-run, and real TPUs.

``backend``:
  * "pallas"            — lower the TPU kernel (real hardware)
  * "pallas_interpret"  — execute the kernel body in Python on CPU
                          (correctness validation; the tests use this)
  * "xla"               — pure-jnp reference math (CPU benchmarks and
                          the multi-pod dry-run, which compiles for the
                          CPU backend where Pallas TPU kernels cannot
                          lower)

Strategy note (measured on XLA:CPU): row gathers run ~50x slower per
element than GEMM, so the "xla" backend computes re-rank distances in
the *dense* form (one [B, N] GEMM + O(B m) scalar lookups) and
aggregates by scattering the k softmax weights into [B, N] and doing a
second GEMM — ~10x faster end-to-end than gathering [B, m, D] rows on
CPU.  The Pallas backends use the tiled gather kernels, the right shape
for TPU (MXU matmuls over VMEM tiles, DMA gathers).  Both paths compute
the same math with fp32 accumulation; parity is asserted in
``tests/test_engine.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.golden_aggregate import golden_aggregate as _agg
from repro.kernels.golden_attention import (golden_attention_decode as _gattn,
                                            select_golden_blocks)
from repro.kernels.golden_rerank import support_sqdist as _sqd
from repro.kernels.golden_support_aggregate import (
    golden_support_aggregate as _sagg)
from repro.kernels.pdist import pdist as _pdist

DEFAULT_BACKEND = "pallas_interpret"
BACKENDS = ("pallas", "pallas_interpret", "xla")


def pdist(q, x, q_norms=None, x_norms=None, backend: str = DEFAULT_BACKEND,
          **kw):
    """Pairwise squared distances [B, N] (tiled matmul form, fp32)."""
    if backend == "xla":
        return ref.pdist_ref(q, x, q_norms, x_norms)
    return _pdist(q, x, q_norms, x_norms, interpret=(backend != "pallas"),
                  **kw)


def support_sqdist(q, xs, x_norms, backend: str = DEFAULT_BACKEND, **kw):
    """Distances to per-query gathered rows: [B, M, D] -> [B, M] fp32."""
    if backend == "xla":
        return ref.support_sqdist_ref(q, xs, x_norms)
    return _sqd(q, xs, x_norms, interpret=(backend != "pallas"), **kw)


def support_distances(q, x, idx, x_norms=None,
                      backend: str = DEFAULT_BACKEND, **kw):
    """Exact distances q -> x[idx] with no [B, m, D] subtract temporaries.

    xla: dense form (one [B, N] GEMM + scalar lookup — no row gathers).
    pallas*: row gather + tiled matmul-form kernel.
    """
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    if backend == "xla":
        d2_all = ref.pdist_ref(q, x, x_norms=x_norms)
        return jnp.take_along_axis(d2_all, idx, axis=-1)
    return support_sqdist(q, x[idx], x_norms[idx], backend=backend, **kw)


def golden_rerank(q, x, cand, k: int, x_norms=None,
                  backend: str = DEFAULT_BACKEND, **kw):
    """Exact re-rank inside the candidate set (paper Eq. 5).

    Returns ``(idx, d2)``: top-k dataset indices [B, k] AND their exact
    squared distances [B, k] (sorted ascending), so the caller reuses
    selection distances for the aggregation softmax instead of
    recomputing them.
    """
    d2 = support_distances(q, x, cand, x_norms, backend=backend, **kw)
    neg, pos = jax.lax.top_k(-d2, k)
    return jnp.take_along_axis(cand, pos, axis=-1), -neg


def golden_support_aggregate(x, idx, logits, backend: str = DEFAULT_BACKEND,
                             **kw):
    """softmax(logits)-weighted mean of x[idx] per query -> [B, D] fp32.

    ``logits`` come from re-ranking distances (masking is the caller's
    job: NEG_INF entries get zero weight).  xla: scatter + GEMM;
    pallas*: gather + streaming online-softmax kernel.
    """
    if backend == "xla":
        return ref.scatter_aggregate_ref(x, idx, logits)
    return _sagg(x[idx], logits, interpret=(backend != "pallas"), **kw)


def golden_aggregate(q, x, sigma2: float, x_norms=None,
                     backend: str = DEFAULT_BACKEND, **kw):
    """Full-scan posterior mean (Eq. 2) via streaming softmax."""
    if backend == "xla":
        return ref.golden_aggregate_ref(q, x, sigma2, x_norms)
    return _agg(q, x, float(sigma2), x_norms=x_norms,
                interpret=(backend != "pallas"), **kw)


def golden_attention_decode(q, k, v, block_idx, valid, block_size: int = 128,
                            backend: str = DEFAULT_BACKEND):
    if backend == "xla":
        return ref.golden_attention_decode_ref(q, k, v, block_idx, valid,
                                               block_size)
    return _gattn(q, k, v, block_idx, valid, block_size=block_size,
                  interpret=(backend != "pallas"))


def flash_attention(q, k, v, causal: bool = True,
                    backend: str = DEFAULT_BACKEND, **kw):
    if backend == "xla":
        return ref.flash_attention_ref(q, k, v, causal)
    return _flash(q, k, v, causal=causal, interpret=(backend != "pallas"),
                  **kw)


__all__ = ["pdist", "support_sqdist", "support_distances", "golden_rerank",
           "golden_support_aggregate", "golden_aggregate",
           "golden_attention_decode", "select_golden_blocks",
           "flash_attention", "DEFAULT_BACKEND", "BACKENDS"]
