"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

This module is the single entry point the core library uses for the
GoldDiff hot path — coarse screening (``screen_topm``: fused tiled
pdist + running top-m, or the materialized ``pdist`` form below the
crossover), exact re-ranking (``golden_rerank``), and golden
aggregation (``golden_support_aggregate`` for supports,
``golden_aggregate`` for full scans, streamable) — plus the attention
kernels.  ``repro.core.engine.GoldDiffEngine`` routes every stage
through these wrappers so the same code path serves CPU tests, the
multi-pod dry-run, and real TPUs.

``backend``:
  * "pallas"            — lower the TPU kernel (real hardware)
  * "pallas_interpret"  — execute the kernel body in Python on CPU
                          (correctness validation; the tests use this)
  * "xla"               — pure-jnp reference math (CPU benchmarks and
                          the multi-pod dry-run, which compiles for the
                          CPU backend where Pallas TPU kernels cannot
                          lower)

Strategy note (measured on XLA:CPU): row gathers run ~50x slower per
element than GEMM, so by default the "xla" backend computes re-rank
distances in the *dense* form (one [B, N] GEMM + O(B m) scalar lookups)
and aggregates by scattering the k softmax weights into [B, N] and
doing a second GEMM — ~10x faster end-to-end than gathering [B, m, D]
rows on CPU *when m is a sizable fraction of N*.  The crossover flips
once the touched rows drop below ~10% of N on CPU (much higher on
GPU/TPU), which is exactly the regime the Golden Index creates, so
``support_distances`` / ``golden_support_aggregate`` accept an explicit
``strategy`` ("dense" | "gather") that ``GoldDiffEngine`` selects per
platform at build time instead of hard-coding by backend.  The Pallas
backends always use the tiled gather kernels, the right shape for TPU
(MXU matmuls over VMEM tiles, DMA gathers).  All paths compute the same
math with fp32 accumulation; parity is asserted in
``tests/test_engine.py`` / ``tests/test_index.py``.

``ivf_screen`` + ``centroid_scan`` are the indexed (sublinear) coarse
stage over a ``repro.index.GoldenIndex`` layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.centroid_scan import centroid_scan as _cscan
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_step import (fused_candidates_pallas,
                                      fused_candidates_scan, fused_posterior)
from repro.kernels.golden_aggregate import golden_aggregate as _agg
from repro.kernels.golden_attention import (golden_attention_decode as _gattn,
                                            select_golden_blocks)
from repro.kernels.golden_rerank import support_sqdist as _sqd
from repro.kernels.golden_support_aggregate import (
    golden_support_aggregate as _sagg)
from repro.kernels.pdist import pdist as _pdist
from repro.kernels.screen import (DEFAULT_TILE, SCAN_TILE,
                                  full_scan_partial_stream,
                                  full_scan_stream, screen_topm_pallas,
                                  screen_topm_scan)

DEFAULT_BACKEND = "pallas_interpret"
BACKENDS = ("pallas", "pallas_interpret", "xla")

# -- fault-injection dispatch seam -------------------------------------------
# The engine's compiled-program cache (``GoldDiffEngine.program``)
# consults this module-level hook on every lookup.  With no hook
# installed (the production default) the cache returns its raw
# callables — identity, zero overhead, zero recompiles (guarded by the
# CI recompile job).  ``repro.launch.faults`` installs a deterministic
# injector here for chaos tests and the resilience benchmark; nothing
# else should ever set it.
_DISPATCH_HOOK = None


def set_dispatch_hook(hook):
    """Install (or clear, with ``None``) the dispatch fault hook.

    A hook object must provide ``on_program(engine, key)`` (called on
    every cache lookup, before the hit/miss check — it may evict) and
    ``wrap(key, fn) -> fn`` (called on every dispatch — it may return
    ``fn`` unchanged or a fault-wrapped callable).  Returns the
    previously installed hook so callers can restore it.
    """
    global _DISPATCH_HOOK
    prev = _DISPATCH_HOOK
    _DISPATCH_HOOK = hook
    return prev


def dispatch_hook():
    """The currently installed dispatch fault hook (``None`` = off)."""
    return _DISPATCH_HOOK


def pdist(q, x, q_norms=None, x_norms=None, backend: str = DEFAULT_BACKEND,
          **kw):
    """Pairwise squared distances [B, N] (tiled matmul form, fp32)."""
    if backend == "xla":
        return ref.pdist_ref(q, x, q_norms, x_norms)
    return _pdist(q, x, q_norms, x_norms, interpret=(backend != "pallas"),
                  **kw)


def screen_topm(q, x, m: int, q_norms=None, x_norms=None,
                tile: int | None = None, stream: bool = True,
                backend: str = DEFAULT_BACKEND, **kw):
    """Exact top-m rows of x by squared distance, read exactly once.

    The streaming coarse screen (``kernels.screen``): tiled matmul-form
    distances + a running top-m carry, peak memory O(B * (m + tile))
    instead of the materialized O(B * N).  Returns ``(idx, d2)``
    [B, m] with ``d2`` ascending; ``m > N`` surplus slots carry
    ``d2 = +inf`` and clamped in-range indices.  The result equals
    ``lax.top_k(-pdist(q, x), m)`` including tie order.

    ``stream=False`` keeps the materialized form — the full [B, N]
    distance matrix (tiled ``pdist`` kernel on pallas backends) plus
    one wide ``lax.top_k`` — which is the right shape below the
    engine's streamed-vs-materialized crossover, where one big GEMM
    beats the scan's per-tile merge overhead (measured ~1.6x on
    XLA:CPU at the scan-path default tile; see
    ``benchmarks/screen_speedup.py``).  ``tile=None`` resolves per
    path: ``SCAN_TILE`` for the lax.scan fallback, ``DEFAULT_TILE``
    for the Pallas VMEM block.
    """
    if not stream:
        if backend == "xla":
            return ref.screen_topm_ref(q, x, m, q_norms, x_norms)
        return ref.materialized_topm(
            pdist(q, x, q_norms, x_norms, backend=backend), m)
    if backend == "xla":
        return screen_topm_scan(q, x, m, q_norms, x_norms, tile=tile)
    return screen_topm_pallas(q, x, m, q_norms, x_norms,
                              bn=DEFAULT_TILE if tile is None else tile,
                              interpret=(backend != "pallas"), **kw)


def support_sqdist(q, xs, x_norms, backend: str = DEFAULT_BACKEND, **kw):
    """Distances to per-query gathered rows: [B, M, D] -> [B, M] fp32."""
    if backend == "xla":
        return ref.support_sqdist_ref(q, xs, x_norms)
    return _sqd(q, xs, x_norms, interpret=(backend != "pallas"), **kw)


def support_distances(q, x, idx, x_norms=None,
                      backend: str = DEFAULT_BACKEND,
                      strategy: str | None = None, **kw):
    """Exact distances q -> x[idx] with no [B, m, D] subtract temporaries.

    ``strategy`` picks the candidate-math form on the xla backend:
    "dense" (one [B, N] GEMM + scalar lookup — no row gathers) or
    "gather" ([B, m, D] row gather + matmul-form distances, sublinear in
    N).  ``None`` keeps the historical per-backend default ("dense" on
    xla).  The pallas backends always gather — tiled VMEM kernels are
    the TPU shape regardless.
    """
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    if backend == "xla":
        if (strategy or "dense") == "dense":
            d2_all = ref.pdist_ref(q, x, x_norms=x_norms)
            return jnp.take_along_axis(d2_all, idx, axis=-1)
        return ref.support_sqdist_ref(q, x[idx], x_norms[idx])
    return support_sqdist(q, x[idx], x_norms[idx], backend=backend, **kw)


def golden_rerank(q, x, cand, k: int, x_norms=None,
                  backend: str = DEFAULT_BACKEND,
                  strategy: str | None = None, valid=None, **kw):
    """Exact re-rank inside the candidate set (paper Eq. 5).

    Returns ``(idx, d2)``: top-k dataset indices [B, k] AND their exact
    squared distances [B, k] (sorted ascending), so the caller reuses
    selection distances for the aggregation softmax instead of
    recomputing them.  ``valid`` (bool [B, m], optional) masks padded
    candidate slots (e.g. clipped rows from a capacity-padded
    ``ivf_screen``) to +inf so they are selected last and weightless.
    """
    d2 = support_distances(q, x, cand, x_norms, backend=backend,
                           strategy=strategy, **kw)
    if valid is not None:
        d2 = jnp.where(valid, d2, jnp.inf)
    neg, pos = jax.lax.top_k(-d2, k)
    return jnp.take_along_axis(cand, pos, axis=-1), -neg


def fused_step(q, qp, x, proxy, m: int, k: int, sigma2,
               x_norms=None, proxy_norms=None,
               backend: str = DEFAULT_BACKEND, strategy: str | None = None,
               stream: bool = True, tile: int | None = None,
               m_t=None, k_t=None, **kw):
    """One fused GoldDiff denoise step: posterior mean in a single pass.

    Coarse screen + exact re-rank + softmax aggregation fused
    (``kernels.fused_step``): store tiles stream through once carrying
    a running proxy top-m with the exact distances threaded along, and
    the epilogue aggregates only the k selected golden rows — no
    [B, N] re-rank matrix, no [B, m, D] candidate materialization, no
    second read of the store.  ``q`` [B, D] are rescaled queries
    (``x_t / a``), ``qp`` [B, dp] their proxy projections; returns the
    posterior mean [B, D] fp32.

    ``strategy`` picks the epilogue's aggregation form (and, with
    ``stream=False``, the re-rank form) exactly as in the staged ops:
    "gather" keeps everything sublinear in N (the streaming story);
    "dense" keeps the scatter + GEMM aggregate dense-strategy engines
    already use — the identical op the staged body runs, so fused and
    staged stay op-compatible per strategy.  ``stream=False`` (xla
    only) keeps the materialized candidate form below the
    streamed-screen byte crossover.  The pallas backends always stream
    (the megakernel is the TPU shape).  ``sigma2`` may be traced;
    ``m_t`` / ``k_t`` (optional traced scalars) mask scheduled sizes
    for the caps-aware masked path.  Fused-vs-staged outputs agree at
    fp32 reduction order (~1e-7 relative; the candidate *sets* are
    bit-identical, see the kernel module docstring).
    """
    interpret = backend != "pallas"
    if backend != "xla":
        idx, d2 = fused_candidates_pallas(
            qp, q, proxy, x, m, proxy_norms, x_norms,
            bn=DEFAULT_TILE if tile is None else tile,
            interpret=interpret, **kw)
    elif stream:
        idx, d2 = fused_candidates_scan(qp, q, proxy, x, m,
                                        proxy_norms, x_norms, tile=tile)
    else:
        idx, pd2 = screen_topm(qp, proxy, m, x_norms=proxy_norms,
                               stream=False, backend=backend)
        d2 = support_distances(q, x, idx, x_norms, backend=backend,
                               strategy=strategy)
        # surplus slots (m > N) alias clamped rows with finite dense
        # distances; propagate the screen's +inf marker so they stay
        # weightless, matching the streaming forms
        d2 = jnp.where(jnp.isinf(pd2), jnp.inf, d2)
    return fused_posterior(x, idx, d2, k, sigma2, backend=backend,
                           m_t=m_t, k_t=k_t, interpret=interpret,
                           strategy=strategy)


def golden_support_aggregate(x, idx, logits, backend: str = DEFAULT_BACKEND,
                             strategy: str | None = None, **kw):
    """softmax(logits)-weighted mean of x[idx] per query -> [B, D] fp32.

    ``logits`` come from re-ranking distances (masking is the caller's
    job: NEG_INF entries get zero weight).  xla: scatter + GEMM
    (``strategy="dense"``, the default) or row gather + einsum
    (``strategy="gather"``, sublinear in N); pallas*: gather + streaming
    online-softmax kernel.
    """
    if backend == "xla":
        if (strategy or "dense") == "dense":
            return ref.scatter_aggregate_ref(x, idx, logits)
        return ref.golden_support_aggregate_ref(x[idx], logits)
    return _sagg(x[idx], logits, interpret=(backend != "pallas"), **kw)


def golden_partial_aggregate(x, idx, logits, strategy: str | None = None):
    """Unnormalized softmax partial state of x[idx] per query.

    Returns ``(acc [B, D], m [B], l [B])`` — the shard-local half of the
    golden aggregation: partial states from different dataset shards
    combine exactly with ``repro.distributed.sharding.lse_merge_mean``
    (streaming.merge semantics), which is how the sharded
    ``GoldDiffEngine`` and ``distributed_golden_denoise`` produce a
    posterior mean bit-comparable to the single-host softmax.

    ``idx`` indexes rows of the *local* shard ``x``; ``strategy``
    mirrors :func:`golden_support_aggregate` ("dense": scatter + GEMM,
    the XLA:CPU shape; "gather": row gather + einsum, sublinear in the
    shard size).  Pass ``idx=None`` with dense [B, n_loc] logits for
    the full-scan (every-local-row) case.  The body is plain jnp on
    every backend: it runs inside ``shard_map``, where it compiles for
    whatever platform the mesh lives on (the same rationale as the
    standalone distributed path).
    """
    if idx is None:
        lg = logits.astype(jnp.float32)
        m = jnp.max(lg, axis=-1)
        p = jnp.exp(lg - m[:, None])
        return (jax.lax.dot_general(
            p, x.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32), m, jnp.sum(p, axis=-1))
    if (strategy or "gather") == "dense":
        return ref.scatter_partial_aggregate_ref(x, idx, logits)
    return ref.partial_aggregate_ref(x[idx], logits)


def ivf_screen_local(qp, offsets_loc, centroids, centroid_norms, w_lo, w_hi,
                     nprobe_max: int, max_cluster: int, w_cap: int,
                     n_loc: int, nprobe=None,
                     backend: str = DEFAULT_BACKEND):
    """Shard-local lanes of a *globally probed* Golden Index.

    The sharded engine partitions one global ``GoldenIndex`` across
    devices at CSR *window* boundaries (``repro.index.shard``): each
    shard owns the contiguous window ids ``[w_lo, w_hi)`` and their
    cluster-sorted rows.  Every shard runs the identical (replicated,
    O(C d)) centroid scan and top-``nprobe_max`` probe selection — same
    input, same op, so the probe list agrees across shards bit-for-bit
    — then keeps only *its own* probed windows, compacted best-first
    into ``w_cap = min(nprobe_max, windows per shard)`` slots via a
    masked top-k.  The union of lanes across shards is exactly the
    single-host probe set, each lane owned by one shard: this is what
    makes sharded-vs-single-host indexed screening an equality test,
    not a recall bound.

    Capacity mode only (the engine's IVF-Flat convention: every probed
    row feeds the exact re-rank).  Returns ``(pos, d2)``: [B, w_cap *
    max_cluster] positions into the shard's sorted rows, and validity
    markers (0 real, +inf capacity padding / foreign windows).
    ``nprobe`` (defaults to ``nprobe_max``) may be traced — probes
    beyond it are masked, for the scan/pjit-compatible masked path.
    """
    cd2 = centroid_scan(qp, centroids, centroid_norms, backend=backend)
    cneg, probe = jax.lax.top_k(-cd2, nprobe_max)          # [B, P], global
    mine = (probe >= w_lo) & (probe < w_hi)
    if nprobe is not None:
        mine = mine & (jnp.arange(nprobe_max) < nprobe)[None, :]
    score = jnp.where(mine, cneg, -jnp.inf)
    svals, spos = jax.lax.top_k(score, w_cap)              # my probed windows
    win = jnp.take_along_axis(probe, spos, axis=-1)
    wvalid = svals > -jnp.inf
    lw = jnp.clip(win - w_lo, 0, offsets_loc.shape[0] - 2)
    starts = offsets_loc[lw]                               # [B, Wc]
    ends = offsets_loc[lw + 1]
    lane = jnp.arange(max_cluster, dtype=starts.dtype)
    pos = starts[..., None] + lane[None, None, :]          # [B, Wc, L]
    valid = (pos < ends[..., None]) & wvalid[..., None]
    b = qp.shape[0]
    pos = jnp.minimum(pos, n_loc - 1).reshape(b, -1)
    valid = valid.reshape(b, -1)
    return pos, jnp.where(valid, 0.0, jnp.inf)


def centroid_scan(q, centroids, c_norms=None, backend: str = DEFAULT_BACKEND,
                  **kw):
    """Query -> k-means-centroid distances [B, C] (IVF level 1, fp32)."""
    if backend == "xla":
        return ref.pdist_ref(q, centroids, x_norms=c_norms)
    return _cscan(q, centroids, c_norms, interpret=(backend != "pallas"),
                  **kw)


def ivf_screen(qp, proxy_sorted, proxy_norms_sorted, offsets, centroids,
               centroid_norms, m: int, nprobe_max: int, max_cluster: int,
               nprobe=None, backend: str = DEFAULT_BACKEND, **kw):
    """Two-level indexed coarse screening (GoldenIndex layout).

    Level 1: tiled centroid scan + top-``nprobe_max`` probe selection.
    Level 2: gather ONLY the probed clusters' rows (CSR windows padded
    to the static ``max_cluster`` width L) and compute matmul-form
    proxy distances over those ``nprobe_max * L`` rows — O(C d +
    nprobe L d) per query instead of the dense O(N d) scan.

    ``nprobe`` (defaults to ``nprobe_max``) may be a *traced* scalar:
    probes beyond it are masked, which is how the scan/pjit-compatible
    masked engine path varies the probe width inside one program.

    Returns ``(pos, d2)``: candidate rows as positions **in
    cluster-sorted row space** [B, m] plus their proxy distances (slots
    beyond the probed clusters' true rows carry +inf).  Callers map
    positions to dataset ids via ``index.perm``.  When ``m`` equals the
    probed capacity ``nprobe_max * max_cluster`` — the IVF-Flat
    convention of re-ranking *everything probed*, and the engine's
    default — no per-row screening decision remains, so the gather +
    proxy-distance pass AND the top-m select (the two dominant costs of
    the indexed path) are skipped entirely: the returned ``d2`` are
    validity markers (0 for real rows, +inf for capacity padding, which
    is all downstream consumers use them for), the rows come back in
    CSR order, and the coarse stage costs O(C d + nprobe L) — the
    proxy-dim factor moves wholly into the exact re-rank.
    """
    n = proxy_sorted.shape[0]
    cd2 = centroid_scan(qp, centroids, centroid_norms, backend=backend)
    probe = jax.lax.top_k(-cd2, nprobe_max)[1]              # [B, P]
    starts = offsets[probe]                                 # [B, P]
    ends = offsets[probe + 1]
    lane = jnp.arange(max_cluster, dtype=starts.dtype)
    pos = starts[..., None] + lane[None, None, :]           # [B, P, L]
    valid = pos < ends[..., None]
    if nprobe is not None:
        probe_live = jnp.arange(nprobe_max) < nprobe        # [P]
        valid = valid & probe_live[None, :, None]
    b = qp.shape[0]
    pos = jnp.minimum(pos, n - 1).reshape(b, -1)            # [B, R]
    valid = valid.reshape(b, -1)
    if m >= nprobe_max * max_cluster:
        return pos, jnp.where(valid, 0.0, jnp.inf)
    xs = proxy_sorted[pos]                                  # [B, R, dp]
    xn = proxy_norms_sorted[pos]
    d2 = support_sqdist(qp, xs, xn, backend=backend, **kw)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, m)
    return jnp.take_along_axis(pos, sel, axis=-1), -neg


def golden_aggregate(q, x, sigma2: float, x_norms=None,
                     backend: str = DEFAULT_BACKEND, stream: bool = False,
                     tile: int | None = None, **kw):
    """Full-scan posterior mean (Eq. 2) via streaming softmax.

    The pallas backends always stream (online-softmax carry in VMEM
    scratch).  On xla, ``stream=True`` switches from the dense [B, N]
    logits form to the tiled ``lax.scan`` LSE
    (``kernels.screen.full_scan_stream``), which makes full-scan
    baselines runnable at N where the dense matrix cannot be allocated.
    """
    if backend == "xla":
        if stream:
            return full_scan_stream(q, x, float(sigma2), x_norms=x_norms,
                                    tile=DEFAULT_TILE if tile is None
                                    else tile)
        return ref.golden_aggregate_ref(q, x, sigma2, x_norms)
    return _agg(q, x, float(sigma2), x_norms=x_norms,
                interpret=(backend != "pallas"), **kw)


def golden_full_partial(q, x, sigma2: float, x_norms=None,
                        stream: bool = False, tile: int | None = None):
    """Unnormalized softmax state of the FULL local store; (acc, m, l).

    The shard-local half of a full scan: states LSE-merge exactly
    across shards (``sharding.lse_merge_mean``).  ``stream=True`` tiles
    the pass (O(B * tile) live logits) instead of materializing the
    dense [B, n_loc] matrix; both forms clamp logits at the finite
    ``NEG_INF`` sentinel so all-padding rows merge to zero weight, and
    they agree to fp32 reduction order.  Plain jnp on every backend —
    it runs inside ``shard_map``, where it compiles for whatever
    platform the mesh lives on.
    """
    if stream:
        return full_scan_partial_stream(q, x, float(sigma2),
                                        x_norms=x_norms,
                                        tile=DEFAULT_TILE if tile is None
                                        else tile)
    d2 = ref.pdist_ref(q, x, x_norms=x_norms)
    lg = jnp.maximum(-d2 * ref.finite_inv_two_sigma2(sigma2), ref.NEG_INF)
    return golden_partial_aggregate(x, None, lg)


def golden_attention_decode(q, k, v, block_idx, valid, block_size: int = 128,
                            backend: str = DEFAULT_BACKEND):
    if backend == "xla":
        return ref.golden_attention_decode_ref(q, k, v, block_idx, valid,
                                               block_size)
    return _gattn(q, k, v, block_idx, valid, block_size=block_size,
                  interpret=(backend != "pallas"))


def flash_attention(q, k, v, causal: bool = True,
                    backend: str = DEFAULT_BACKEND, **kw):
    if backend == "xla":
        return ref.flash_attention_ref(q, k, v, causal)
    return _flash(q, k, v, causal=causal, interpret=(backend != "pallas"),
                  **kw)


__all__ = ["pdist", "screen_topm", "support_sqdist", "support_distances",
           "golden_rerank", "fused_step", "fused_posterior",
           "golden_support_aggregate",
           "golden_partial_aggregate", "golden_full_partial",
           "golden_aggregate", "centroid_scan", "ivf_screen",
           "ivf_screen_local", "golden_attention_decode",
           "select_golden_blocks", "flash_attention", "DEFAULT_BACKEND",
           "BACKENDS", "DEFAULT_TILE", "SCAN_TILE", "set_dispatch_hook", "dispatch_hook"]
