"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

``backend``:
  * "pallas"            — lower the TPU kernel (real hardware)
  * "pallas_interpret"  — execute the kernel body in Python on CPU
                          (correctness validation; the tests use this)
  * "xla"               — the pure-jnp reference math (used by the
                          multi-pod dry-run, which compiles for the CPU
                          backend where Pallas TPU kernels cannot lower)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.golden_aggregate import golden_aggregate as _agg
from repro.kernels.golden_attention import (golden_attention_decode as _gattn,
                                            select_golden_blocks)
from repro.kernels.pdist import pdist as _pdist

DEFAULT_BACKEND = "pallas_interpret"


def pdist(q, x, backend: str = DEFAULT_BACKEND, **kw):
    if backend == "xla":
        return ref.pdist_ref(q, x)
    return _pdist(q, x, interpret=(backend != "pallas"), **kw)


def golden_aggregate(q, x, sigma2: float, backend: str = DEFAULT_BACKEND, **kw):
    if backend == "xla":
        return ref.golden_aggregate_ref(q, x, sigma2)
    return _agg(q, x, float(sigma2), interpret=(backend != "pallas"), **kw)


def golden_attention_decode(q, k, v, block_idx, valid, block_size: int = 128,
                            backend: str = DEFAULT_BACKEND):
    if backend == "xla":
        return ref.golden_attention_decode_ref(q, k, v, block_idx, valid,
                                               block_size)
    return _gattn(q, k, v, block_idx, valid, block_size=block_size,
                  interpret=(backend != "pallas"))


def flash_attention(q, k, v, causal: bool = True,
                    backend: str = DEFAULT_BACKEND, **kw):
    if backend == "xla":
        return ref.flash_attention_ref(q, k, v, causal)
    return _flash(q, k, v, causal=causal, interpret=(backend != "pallas"),
                  **kw)


__all__ = ["pdist", "golden_aggregate", "golden_attention_decode",
           "select_golden_blocks", "flash_attention"]
