"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def pdist_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(x * x, -1)[None, :]
          - 2.0 * q @ x.T)
    return jnp.maximum(d2, 0.0)


def golden_aggregate_ref(q: jnp.ndarray, x: jnp.ndarray,
                         sigma2: float) -> jnp.ndarray:
    lg = -pdist_ref(q, x) / (2.0 * sigma2)
    w = jax.nn.softmax(lg, axis=-1)
    return (w @ x.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q: [B,Hkv,G,S,dh]; k/v: [B,Hkv,S,dh] — dense softmax attention."""
    dh = q.shape[-1]
    s = q.shape[3]
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def golden_attention_decode_ref(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray, block_idx: jnp.ndarray,
                                valid: jnp.ndarray,
                                block_size: int = 128) -> jnp.ndarray:
    """Gather golden blocks densely, mask invalid, softmax-attend."""
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    kb = block_idx.shape[-1]
    nb = s // block_size
    idx = jnp.clip(block_idx, 0, nb - 1)
    kblk = k.reshape(b, hkv, nb, block_size, dh)
    vblk = v.reshape(b, hkv, nb, block_size, dh)
    kg = jnp.take_along_axis(kblk, idx[..., None, None].repeat(block_size, -2)
                             .repeat(dh, -1), axis=2)           # [B,H,kb,Bs,dh]
    vg = jnp.take_along_axis(vblk, idx[..., None, None].repeat(block_size, -2)
                             .repeat(dh, -1), axis=2)
    kg = kg.reshape(b, hkv, kb * block_size, dh).astype(jnp.float32)
    vg = vg.reshape(b, hkv, kb * block_size, dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), kg)
    scores = scores / (dh ** 0.5)
    mask = jnp.repeat(valid.astype(bool), block_size, axis=-1)   # [B,H,kb*Bs]
    scores = jnp.where(mask[:, :, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, vg).astype(q.dtype)
