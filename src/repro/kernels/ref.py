"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Largest inverse-temperature the aggregation softmax will use: chosen
# so ``-d2 * inv`` stays an ordinary fp32 overflow (clamped at NEG_INF)
# instead of the silent-NaN ``0 * inf`` that an unguarded ``1/(2*0.0)``
# produces.  3e37 < fp32 max, and any sigma2 small enough to hit the
# clamp already drives every finite logit to the NEG_INF floor.
MAX_INV_TWO_SIGMA2 = 3.0e37


def finite_inv_two_sigma2(sigma2) -> float:
    """``1 / (2 sigma2)`` clamped to an fp32-finite inverse temperature.

    Degenerate noise levels (``sigma2 <= 0``, NaN, or denormal) return
    the finite ``MAX_INV_TWO_SIGMA2`` cap instead of raising
    ``ZeroDivisionError`` or overflowing to +inf — callers pair the
    result with a ``NEG_INF`` logit clamp, so the extreme-sigma limit
    degrades to a uniform (data-mean) aggregate, never NaN.
    """
    s = float(sigma2)
    if not s > 0.0:                      # 0, negative, or NaN
        return MAX_INV_TWO_SIGMA2
    inv = 1.0 / (2.0 * s)
    return min(inv, MAX_INV_TWO_SIGMA2)


def pdist_ref(q: jnp.ndarray, x: jnp.ndarray,
              q_norms: jnp.ndarray | None = None,
              x_norms: jnp.ndarray | None = None) -> jnp.ndarray:
    """Matmul-form pairwise squared distances; accepts precomputed row
    norms (e.g. +inf on padded/masked dataset rows -> +inf distance)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = jnp.sum(q * q, -1) if q_norms is None else q_norms.astype(jnp.float32)
    xn = jnp.sum(x * x, -1) if x_norms is None else x_norms.astype(jnp.float32)
    d2 = qn[:, None] + xn[None, :] - 2.0 * q @ x.T
    return jnp.maximum(d2, 0.0)


def materialized_topm(d2: jnp.ndarray, m: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m of a materialized [B, N] distance matrix with the shared
    slot semantics: ``(idx, d2)`` ascending; ``m > N`` surplus slots
    carry ``d2 = +inf`` and an in-range index.  The ONE definition of
    the materialized-screen contract — both ``screen_topm_ref`` and the
    pallas-pdist materialized path of ``ops.screen_topm`` route here.
    """
    n = d2.shape[-1]
    k = min(m, n)
    neg, idx = jax.lax.top_k(-d2, k)
    if m > k:
        pad = ((0, 0), (0, m - k))
        neg = jnp.pad(neg, pad, constant_values=-jnp.inf)
        idx = jnp.pad(idx, pad)
    return idx, -neg


def screen_topm_ref(q: jnp.ndarray, x: jnp.ndarray, m: int,
                    q_norms: jnp.ndarray | None = None,
                    x_norms: jnp.ndarray | None = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materialized top-m oracle: full [B, N] pdist + one ``lax.top_k``.

    This is both the parity oracle for ``kernels.screen`` and the dense
    path the engine keeps below the streamed-vs-materialized crossover.
    """
    return materialized_topm(pdist_ref(q, x, q_norms, x_norms), m)


def support_sqdist_ref(q: jnp.ndarray, xs: jnp.ndarray,
                       x_norms: jnp.ndarray | None = None) -> jnp.ndarray:
    """Distances to per-query gathered rows.  q: [B, D], xs: [B, M, D],
    x_norms: [B, M] -> [B, M] fp32 (matmul form, no [B, M, D] temporaries)."""
    q32 = q.astype(jnp.float32)
    xs32 = xs.astype(jnp.float32)
    xn = (jnp.sum(xs32 * xs32, -1) if x_norms is None
          else x_norms.astype(jnp.float32))
    qn = jnp.sum(q32 * q32, -1, keepdims=True)
    dot = jnp.einsum("bd,bmd->bm", q32, xs32)
    return jnp.maximum(qn + xn - 2.0 * dot, 0.0)


def golden_aggregate_ref(q: jnp.ndarray, x: jnp.ndarray, sigma2: float,
                         x_norms: jnp.ndarray | None = None) -> jnp.ndarray:
    # Logits clamp at the finite NEG_INF sentinel (matching the Pallas
    # kernel and the streamed LSE): an all-clamped row — every distance
    # overflowed at extreme sigma — softmaxes to a uniform (data-mean)
    # aggregate instead of the NaN an all--inf softmax produces.
    inv = finite_inv_two_sigma2(sigma2)
    lg = jnp.maximum(-pdist_ref(q, x, x_norms=x_norms) * inv, NEG_INF)
    w = jax.nn.softmax(lg, axis=-1)
    out = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def scatter_aggregate_ref(x: jnp.ndarray, idx: jnp.ndarray,
                          logits: jnp.ndarray) -> jnp.ndarray:
    """softmax(logits)-weighted mean of x[idx] per query -> [B, D] fp32.

    Dense scatter + GEMM form: on XLA:CPU row gathers run ~50x slower
    per element than GEMM, so scattering the k weights into a [B, N]
    matrix and multiplying by the (contiguous) dataset is much faster
    than gathering [B, k, D] rows.  ``.add`` handles duplicate indices
    exactly (their weights sum, as in the gathered formulation).
    """
    b, n = logits.shape[0], x.shape[0]
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    ws = jnp.zeros((b, n), jnp.float32).at[
        jnp.arange(b)[:, None], idx].add(w)
    return jax.lax.dot_general(ws, x, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def golden_support_aggregate_ref(xs: jnp.ndarray,
                                 logits: jnp.ndarray) -> jnp.ndarray:
    """Gathered-values oracle for the Pallas support-aggregate kernel."""
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bk,bkd->bd", w, xs.astype(jnp.float32))


def partial_aggregate_ref(xs: jnp.ndarray, logits: jnp.ndarray
                          ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Unnormalized softmax partial state over gathered rows.

    Returns ``(acc [B, D], m [B], l [B])``: the exp-weighted sum, the
    max logit, and the partition sum — ``streaming.merge`` semantics, so
    shard-partial states combine exactly with a log-sum-exp merge
    (``sharding.lse_merge_mean``).  All-masked rows (every logit at the
    finite NEG_INF sentinel) yield a NEG_INF max whose merge scale
    underflows to 0, not NaN.
    """
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1)
    p = jnp.exp(lg - m[:, None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bk,bkd->bd", p, xs.astype(jnp.float32))
    return acc, m, l


def scatter_partial_aggregate_ref(x: jnp.ndarray, idx: jnp.ndarray,
                                  logits: jnp.ndarray
                                  ) -> tuple[jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """Dense scatter + GEMM form of :func:`partial_aggregate_ref` (the
    XLA:CPU-fast shape: no [B, k, D] row gathers)."""
    b, n = logits.shape[0], x.shape[0]
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1)
    p = jnp.exp(lg - m[:, None])
    l = jnp.sum(p, axis=-1)
    ws = jnp.zeros((b, n), jnp.float32).at[
        jnp.arange(b)[:, None], idx].add(p)
    acc = jax.lax.dot_general(ws, x, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc, m, l


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q: [B,Hkv,G,S,dh]; k/v: [B,Hkv,S,dh] — dense softmax attention."""
    dh = q.shape[-1]
    s = q.shape[3]
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def golden_attention_decode_ref(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray, block_idx: jnp.ndarray,
                                valid: jnp.ndarray,
                                block_size: int = 128) -> jnp.ndarray:
    """Gather golden blocks densely, mask invalid, softmax-attend."""
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    kb = block_idx.shape[-1]
    nb = s // block_size
    idx = jnp.clip(block_idx, 0, nb - 1)
    kblk = k.reshape(b, hkv, nb, block_size, dh)
    vblk = v.reshape(b, hkv, nb, block_size, dh)
    kg = jnp.take_along_axis(kblk, idx[..., None, None].repeat(block_size, -2)
                             .repeat(dh, -1), axis=2)           # [B,H,kb,Bs,dh]
    vg = jnp.take_along_axis(vblk, idx[..., None, None].repeat(block_size, -2)
                             .repeat(dh, -1), axis=2)
    kg = kg.reshape(b, hkv, kb * block_size, dh).astype(jnp.float32)
    vg = vg.reshape(b, hkv, kb * block_size, dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), kg)
    scores = scores / (dh ** 0.5)
    mask = jnp.repeat(valid.astype(bool), block_size, axis=-1)   # [B,H,kb*Bs]
    scores = jnp.where(mask[:, :, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, vg).astype(q.dtype)
