"""Pallas TPU kernel: tiled query -> centroid distances (IVF level 1).

First stage of indexed coarse screening: distances from each query's
proxy embedding to the C k-means centroids, in the MXU matmul form

    ||q - c||^2 = ||q||^2 + ||c||^2 - 2 q . c

with centroid norms precomputed once at index build (GoldenIndex).  The
centroid table is tiny (C ~ sqrt(N)), so unlike ``pdist`` — whose N
axis streams through VMEM in 512-wide tiles — the whole centroid tile
usually fits in one block; the default bc=128 keeps the lane dimension
MXU-aligned while letting multi-thousand-cluster indexes still tile.
Padded centroids carry +inf norms so their distances are +inf and the
probe top-k never selects them.  fp32 accumulation regardless of the
query/centroid storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8
DEFAULT_BC = 128


def _centroid_kernel(q_ref, c_ref, qn_ref, cn_ref, out_ref):
    q = q_ref[...]
    c = c_ref[...]
    acc = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = qn_ref[...] + cn_ref[...] - 2.0 * acc
    out_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("bq", "bc", "interpret"))
def centroid_scan(q: jnp.ndarray, centroids: jnp.ndarray,
                  c_norms: jnp.ndarray | None = None,
                  bq: int = DEFAULT_BQ, bc: int = DEFAULT_BC,
                  interpret: bool = True) -> jnp.ndarray:
    """||q_i - c_j||^2 for q: [B, d], centroids: [C, d] -> [B, C] fp32.

    interpret=True on CPU (validation); False lowers for real TPUs.
    """
    b, d = q.shape
    c = centroids.shape[0]
    if c_norms is None:
        c_norms = jnp.sum(centroids.astype(jnp.float32) ** 2, -1)
    q_norms = jnp.sum(q.astype(jnp.float32) ** 2, -1)

    bq = min(bq, b)
    bc = min(bc, c)
    pb = (-b) % bq
    pc = (-c) % bc
    qp = jnp.pad(q, ((0, pb), (0, 0)))
    cp = jnp.pad(centroids, ((0, pc), (0, 0)))
    qn = jnp.pad(q_norms, (0, pb)).reshape(-1, 1)
    # +inf norms on padded centroids -> +inf distance -> never probed
    cn = jnp.pad(c_norms.astype(jnp.float32), (0, pc),
                 constant_values=jnp.inf).reshape(1, -1)
    grid = ((b + pb) // bq, (c + pc) // bc)

    out = pl.pallas_call(
        _centroid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + pb, c + pc), jnp.float32),
        interpret=interpret,
    )(qp, cp, qn, cn)
    return out[:b, :c]
