"""Pallas TPU kernel: golden (top-k block-sparse) decode attention.

The paper's coarse-to-fine golden-subset mechanism transplanted onto the
KV cache (DESIGN §4): the host-side selector scores each query against
*block summaries* (mean-pooled keys per block — the downsample proxy) and
hands this kernel the top-k golden block indices.  The kernel then runs
exact attention only over those blocks, paged-attention style: the block
index array is scalar-prefetched and drives the K/V BlockSpec index maps,
so only golden blocks ever move HBM -> VMEM.

Decode shape: one query token per sequence, GQA with G = Hq/Hkv query
heads sharing each KV head.

    q:   [B, Hkv, G, dh]
    k,v: [B, Hkv, S, dh]   (S = num_blocks * block_size)
    idx: [B, Hkv, kb]      golden block indices (int32)
    valid: [B, Hkv, kb]    1 = real block, 0 = padding
    out: [B, Hkv, G, dh]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _gattn_kernel(idx_ref, valid_ref, q_ref, k_ref, v_ref, out_ref,
                  m_ref, l_ref, acc_ref, *, kb: int, scale: float):
    b, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[b, h, j] == 1)
    def _update():
        q = q_ref[0, 0]                                   # [G, dh]
        k = k_ref[0, 0]                                   # [Bs, dh]
        v = v_ref[0, 0]                                   # [Bs, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, Bs]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        sc = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * sc + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * sc + jax.lax.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kb - 1)
    def _emit():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret"))
def golden_attention_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            block_idx: jnp.ndarray, valid: jnp.ndarray,
                            block_size: int = 128,
                            interpret: bool = True) -> jnp.ndarray:
    """Exact attention over golden blocks only.

    q: [B, Hkv, G, dh]; k/v: [B, Hkv, S, dh]; block_idx/valid: [B, Hkv, kb].
    Returns [B, Hkv, G, dh].
    """
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    kb = block_idx.shape[-1]
    assert s % block_size == 0, "cache length must be block-aligned"
    scale = 1.0 / (dh ** 0.5)
    # clamp padded indices into range (masked out by `valid` anyway)
    block_idx = jnp.clip(block_idx, 0, s // block_size - 1).astype(jnp.int32)

    grid = (b, hkv, kb)
    out = pl.pallas_call(
        functools.partial(_gattn_kernel, kb=kb, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, dh), lambda bb, hh, jj, idx, val: (bb, hh, 0, 0)),
                pl.BlockSpec((1, 1, block_size, dh),
                             lambda bb, hh, jj, idx, val: (bb, hh, idx[bb, hh, jj], 0)),
                pl.BlockSpec((1, 1, block_size, dh),
                             lambda bb, hh, jj, idx, val: (bb, hh, idx[bb, hh, jj], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dh),
                                   lambda bb, hh, jj, idx, val: (bb, hh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(block_idx, valid.astype(jnp.int32), q, k, v)
    return out


def select_golden_blocks(q: jnp.ndarray, k: jnp.ndarray, num_blocks: int,
                         block_size: int = 128) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Coarse screening over block summaries (paper Eq. 4 analogue).

    Scores each (batch, kv-head) query group against mean-pooled keys per
    block; returns (block_idx, valid): [B, Hkv, num_blocks].
    """
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    nb = s // block_size
    summaries = k.reshape(b, hkv, nb, block_size, dh).mean(3)     # [B,Hkv,nb,dh]
    qbar = q.mean(2)                                              # [B,Hkv,dh]
    scores = jnp.einsum("bhd,bhnd->bhn", qbar.astype(jnp.float32),
                        summaries.astype(jnp.float32))
    kb = min(num_blocks, nb)
    _, idx = jax.lax.top_k(scores, kb)
    return idx.astype(jnp.int32), jnp.ones_like(idx, jnp.int32)
