"""Pallas TPU kernel: streaming softmax aggregation over golden supports.

The support-set sibling of ``golden_aggregate`` (which scans the whole
dataset): values here are the per-query *gathered* golden rows ``xs[b]``
(k rows per query, selected upstream by ``golden_rerank``) and the
logits are **reused from selection** rather than recomputed — the fused
step the seed was missing (it regathered ``X[idx]`` and recomputed
``(q - xs)**2`` for the final softmax).

FlashAttention-style online softmax (Dao et al., 2022): the support
streams through VMEM in k-tiles while a (max, denom, accumulator) carry
lives in scratch; the weighted sum per tile is one batched
(bq x bk) . (bq x bk x D) contraction.  fp32 accumulation regardless of
the storage dtype (bf16 values upcast per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 8
DEFAULT_BK = 128


def _sagg_kernel(lg_ref, xs_ref, out_ref, m_ref, l_ref, acc_ref, *, nk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lg = lg_ref[...]                                    # [bq, bk] f32
    xs = xs_ref[...].astype(jnp.float32)                # [bq, bk, d]
    m_prev = m_ref[...]                                 # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(lg, -1, keepdims=True))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.exp(lg - m_new)                             # [bq, bk]
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, -1, keepdims=True)
    acc = jax.lax.dot_general(                          # [bq, d]
        p, xs, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * scale + acc
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        out_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def golden_support_aggregate(xs: jnp.ndarray, logits: jnp.ndarray,
                             bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                             interpret: bool = True) -> jnp.ndarray:
    """softmax(logits)-weighted mean of gathered support rows.

    xs: [B, K, D] (gathered golden rows), logits: [B, K] (validity
    masking — e.g. the scan-compatible k_t mask — is applied by the
    caller as NEG_INF entries) -> [B, D] fp32.
    """
    b, k, d = xs.shape
    bq = min(bq, b)
    bk = min(bk, k)
    pb = (-b) % bq
    pk = (-k) % bk
    xsp = jnp.pad(xs, ((0, pb), (0, pk), (0, 0)))
    # NEG_INF logits on padded columns -> zero weight
    lgp = jnp.pad(logits.astype(jnp.float32), ((0, pb), (0, pk)),
                  constant_values=NEG_INF)
    nb, nk = (b + pb) // bq, (k + pk) // bk

    out = pl.pallas_call(
        functools.partial(_sagg_kernel, nk=nk),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bq, bk, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pb, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # weighted accumulator
        ],
        interpret=interpret,
    )(lgp, xsp)
    return out[:b]
