"""Fused GoldDiff step: screen + re-rank + aggregate in ONE store pass.

The staged engine runs a denoise step as separate programs — coarse
proxy screen, exact re-rank, softmax aggregation — each round-tripping
candidates through HBM (the PR 7 roofline pins the exact screen at
~0.01 of peak bytes/s for exactly this reason).  This module fuses the
step: store tiles stream through once, each tile contributes its proxy
distances AND its exact distances, and a running top-m carry threads
both through the same selection, so by the end of the single pass the
carry holds the staged pipeline's candidate set *with its re-rank
distances already attached*.  A small epilogue (top-k + clamped logits
+ gathered online-softmax aggregate over the k golden rows) turns that
carry straight into the posterior mean — no second read of the store,
no [B, N] re-rank matrix, and no [B, m, D] candidate materialization.
Peak live memory is O(B * (m + tile)) + the k aggregated rows.

Selection math is ``kernels.screen``'s carry-first tie merge extended
with one more threaded operand: the concatenation [carry | tile] is
re-selected by ONE ``lax.top_k`` on the negated proxy distances, and
``take_along_axis`` carries (index, exact d2) pairs along.  Because the
proxy keys and merge order are identical to ``screen_topm_scan``, the
fused candidate list — and therefore the epilogue's top-k input — is
bit-for-bit the staged screen's output; the exact distances are
computed by the same clamped matmul form as ``ref.pdist_ref`` (the d
contraction is unaffected by N tiling), so fused-vs-staged agree to
fp32 *reduction order* (the aggregation sums in gathered instead of
scattered order), ~1e-7.

Two implementations share the math, mirroring ``kernels.screen``:

* ``fused_candidates_pallas`` — Pallas megakernel: one grid pass with
  (values, indices, exact-d2) VMEM scratch carried across the N axis,
  two MXU matmuls (proxy + exact) and one merge per tile.
* ``fused_candidates_scan``   — ``lax.scan`` twin for any XLA backend
  (ragged tails overlap back, re-seen columns masked; no padded copy).

``fused_posterior`` is the shared epilogue; ``ops.fused_step`` is the
dispatching entry point (it also provides the materialized form used
below the streamed-screen byte crossover).

Slot semantics (shared with ``ops.screen_topm``): ``m > N`` surplus
slots carry exact ``d2 = +inf`` and a clamped in-range index, so they
re-rank last and aggregate with exactly zero weight — *not* the
staged dense path's aliased row-0 distances, which only stays correct
because the engine never schedules m > N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.golden_support_aggregate import (
    golden_support_aggregate as _sagg)

NEG_INF = -1e30
DEFAULT_BQ = 8
DEFAULT_TILE = 4096
# Scan-path default tile for the FUSED pass.  Unlike the proxy-only
# screen scan (``screen.SCAN_TILE`` = 16384, dp ~ 49), every fused tile
# carries the exact [B, tile] GEMM over the full D, so the working set
# per tile is ~16x larger and wants to stay cache-resident: measured on
# XLA:CPU at D=784, B=32 the fused step runs 199/303 ms (m=512/1024) at
# tile=2048 vs 597/552 ms at 16384 for N=65536, and 29 vs 43 ms at
# N=4096 — tile=2048 wins at both scales.
FUSED_SCAN_TILE = 2048


def _merge_topm_carry(vals, idx, ex, neg_tile, idx_tile, ex_tile, m: int):
    """Running top-m step threading (index, exact-d2) with the selection.

    Same carry-first concatenation as ``screen._merge_topm`` (ties go to
    the lowest dataset index, matching ``lax.top_k``), with the exact
    distances re-gathered by the same ``sel`` so every carried slot
    keeps its re-rank key.
    """
    cat_v = jnp.concatenate([vals, neg_tile], axis=-1)
    cat_i = jnp.concatenate([idx, idx_tile], axis=-1)
    cat_e = jnp.concatenate([ex, ex_tile], axis=-1)
    new_v, sel = jax.lax.top_k(cat_v, m)
    return (new_v, jnp.take_along_axis(cat_i, sel, axis=-1),
            jnp.take_along_axis(cat_e, sel, axis=-1))


def _tile_d2(q, xt, qn, xnt):
    """Clamped matmul-form squared distances for one tile (fp32)."""
    dot = jax.lax.dot_general(
        q, xt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return jnp.maximum(qn + xnt[None, :] - 2.0 * dot, 0.0)


# -- Pallas megakernel --------------------------------------------------------

def _fused_kernel(qp_ref, xp_ref, q_ref, x_ref, qpn_ref, xpn_ref,
                  qn_ref, xn_ref, idx_out, d2_out,
                  vals_ref, idx_ref, ex_ref, *, m: int, bn: int, nn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)
        ex_ref[...] = jnp.full_like(ex_ref, jnp.inf)

    pdot = jax.lax.dot_general(
        qp_ref[...], xp_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    pd2 = jnp.maximum(qpn_ref[...] + xpn_ref[...] - 2.0 * pdot, 0.0)
    edot = jax.lax.dot_general(
        q_ref[...], x_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ed2 = jnp.maximum(qn_ref[...] + xn_ref[...] - 2.0 * edot, 0.0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, pd2.shape, 1)
    new_v, new_i, new_e = _merge_topm_carry(
        vals_ref[...], idx_ref[...], ex_ref[...], -pd2, cols, ed2, m)
    vals_ref[...] = new_v
    idx_ref[...] = new_i
    ex_ref[...] = new_e

    @pl.when(j == nn - 1)
    def _emit():
        idx_out[...] = idx_ref[...]
        d2_out[...] = ex_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("m", "bq", "bn", "interpret"))
def fused_candidates_pallas(qp: jnp.ndarray, q: jnp.ndarray,
                            proxy: jnp.ndarray, x: jnp.ndarray, m: int,
                            proxy_norms: jnp.ndarray | None = None,
                            x_norms: jnp.ndarray | None = None,
                            bq: int = DEFAULT_BQ, bn: int = DEFAULT_TILE,
                            interpret: bool = True):
    """One-pass screened candidates with exact distances attached.

    qp: [B, dp] proxy queries, q: [B, D] exact queries; proxy: [N, dp],
    x: [N, D] -> ``(idx, d2)`` [B, m]: the proxy top-m candidate list
    (ascending proxy distance, ``lax.top_k`` tie order) with each
    slot's EXACT squared distance.  Surplus slots (m > N) carry
    ``d2 = +inf`` and clamped indices.  interpret=True on CPU.

    N is padded to a block multiple with +inf-norm rows on both stores
    (the sibling-kernel idiom): padded rows screen last AND carry +inf
    exact distance, so they can never acquire aggregation weight.
    """
    b, dp = qp.shape
    d = q.shape[1]
    n = x.shape[0]
    qp32 = qp.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    qp_norms = jnp.sum(qp32 ** 2, -1)
    q_norms = jnp.sum(q32 ** 2, -1)
    if proxy_norms is None:
        proxy_norms = jnp.sum(proxy.astype(jnp.float32) ** 2, -1)
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)

    bq = min(bq, b)
    bn = min(bn, max(n, 1))
    pb = (-b) % bq
    n_pad = max(-(-n // bn), -(-m // bn)) * bn
    qpp = jnp.pad(qp32, ((0, pb), (0, 0)))
    qxp = jnp.pad(q32, ((0, pb), (0, 0)))
    xpp = jnp.pad(proxy, ((0, n_pad - n), (0, 0)))
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    qpn = jnp.pad(qp_norms, (0, pb)).reshape(-1, 1)
    qn = jnp.pad(q_norms, (0, pb)).reshape(-1, 1)
    xpn = jnp.pad(proxy_norms.astype(jnp.float32), (0, n_pad - n),
                  constant_values=jnp.inf).reshape(1, -1)
    xn = jnp.pad(x_norms.astype(jnp.float32), (0, n_pad - n),
                 constant_values=jnp.inf).reshape(1, -1)
    nb, nn = (b + pb) // bq, n_pad // bn

    idx, d2 = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, bn=bn, nn=nn),
        grid=(nb, nn),
        in_specs=[
            pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=(pl.BlockSpec((bq, m), lambda i, j: (i, 0)),
                   pl.BlockSpec((bq, m), lambda i, j: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((b + pb, m), jnp.int32),
                   jax.ShapeDtypeStruct((b + pb, m), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((bq, m), jnp.float32),   # running negated proxy top-m
            pltpu.VMEM((bq, m), jnp.int32),     # their dataset indices
            pltpu.VMEM((bq, m), jnp.float32),   # their exact distances
        ],
        interpret=interpret,
    )(qpp, xpp, qxp, xp, qpn, xpn, qn, xn)
    return jnp.minimum(idx[:b], max(n - 1, 0)), d2[:b]


# -- XLA (lax.scan) twin ------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "tile"))
def fused_candidates_scan(qp: jnp.ndarray, q: jnp.ndarray,
                          proxy: jnp.ndarray, x: jnp.ndarray, m: int,
                          proxy_norms: jnp.ndarray | None = None,
                          x_norms: jnp.ndarray | None = None,
                          tile: int | None = None):
    """Tiled-scan twin of :func:`fused_candidates_pallas` for any backend.

    Same ragged-tail handling as ``screen_topm_scan``: the final tile
    overlaps back (``dynamic_slice`` clamp) with re-seen proxy keys
    masked to -inf, so no padded store copy exists for any N.  Peak
    live memory O(B * (m + tile)); ``tile=None`` picks the fused-pass
    default ``FUSED_SCAN_TILE`` (smaller than the proxy screen's —
    each fused tile carries the full-D exact GEMM).
    """
    n = x.shape[0]
    if tile is None:
        tile = FUSED_SCAN_TILE
    b = qp.shape[0]
    qp32 = qp.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    qpn = jnp.sum(qp32 ** 2, -1)[:, None]
    qn = jnp.sum(q32 ** 2, -1)[:, None]
    if proxy_norms is None:
        proxy_norms = jnp.sum(proxy.astype(jnp.float32) ** 2, -1)
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    proxy_norms = proxy_norms.astype(jnp.float32)
    x_norms = x_norms.astype(jnp.float32)
    tile = min(tile, max(n, 1))

    def body(carry, start):
        vals, idx, ex = carry
        eff = jnp.minimum(start, n - tile)     # ragged tail: overlap back
        xpt = jax.lax.dynamic_slice_in_dim(proxy, eff, tile
                                           ).astype(jnp.float32)
        xpnt = jax.lax.dynamic_slice_in_dim(proxy_norms, eff, tile)
        xt = jax.lax.dynamic_slice_in_dim(x, eff, tile).astype(jnp.float32)
        xnt = jax.lax.dynamic_slice_in_dim(x_norms, eff, tile)
        pd2 = _tile_d2(qp32, xpt, qpn, xpnt)
        ed2 = _tile_d2(q32, xt, qn, xnt)
        cols = eff + jax.lax.broadcasted_iota(jnp.int32, pd2.shape, 1)
        neg = jnp.where(cols >= start, -pd2, -jnp.inf)  # mask re-seen rows
        return _merge_topm_carry(vals, idx, ex, neg, cols, ed2, m), None

    init = (jnp.full((b, m), -jnp.inf, jnp.float32),
            jnp.zeros((b, m), jnp.int32),
            jnp.full((b, m), jnp.inf, jnp.float32))
    (vals, idx, ex), _ = jax.lax.scan(
        body, init,
        jnp.arange(0, -(-n // tile) * tile, tile, dtype=jnp.int32))
    return jnp.minimum(idx, max(n - 1, 0)), ex


# -- shared epilogue ----------------------------------------------------------

def fused_posterior(x: jnp.ndarray, idx: jnp.ndarray, d2: jnp.ndarray,
                    k: int, sigma2, backend: str = "xla",
                    m_t=None, k_t=None, interpret: bool = True,
                    strategy: str | None = None) -> jnp.ndarray:
    """Candidates + exact distances -> posterior mean [B, D] fp32.

    The O(B * (m + k D)) tail of the fused step: exact top-k inside the
    candidate list, clamped logits, and a softmax aggregate over only
    the k golden rows — the store is never re-read densely.

    ``strategy`` picks the xla aggregation form exactly like
    ``ops.golden_support_aggregate``: "gather" (the default — row
    gather + einsum, sublinear in N, the streaming story) or "dense"
    (scatter + [B, N] GEMM — on XLA:CPU the [B, k, D] row gather is
    the slowest op in the whole step, so dense-strategy engines keep
    their scatter form; it is the same op the staged body runs, which
    also keeps fused-vs-staged sharded parity bitwise).

    ``sigma2`` may be a traced scalar (the masked path); ``m_t`` /
    ``k_t`` (optional traced scalars) mask candidate slots at or past
    the scheduled sizes, exactly like the engine's staged masked body:
    slots >= ``m_t`` re-rank at +inf, logit slots >= ``k_t`` clamp to
    the finite ``NEG_INF`` sentinel (an all-masked row degrades to a
    uniform average of its gathered rows, never NaN).
    """
    if m_t is not None:
        live = jnp.arange(d2.shape[-1])[None, :] < m_t
        d2 = jnp.where(live, d2, jnp.inf)
    neg, pos = jax.lax.top_k(-d2, k)
    gid = jnp.take_along_axis(idx, pos, axis=-1)
    lg = jnp.maximum(neg / (2.0 * sigma2), NEG_INF)
    if k_t is not None:
        lg = jnp.where(jnp.arange(k)[None, :] < k_t, lg, NEG_INF)
    if backend == "xla":
        if (strategy or "gather") == "dense":
            return ref.scatter_aggregate_ref(x, gid, lg)
        return ref.golden_support_aggregate_ref(x[gid], lg)
    return _sagg(x[gid], lg, interpret=interpret)
