"""Pallas TPU kernel: causal flash attention (train/prefill hot loop).

GQA layout: q [B, Hkv, G, S, dh], k/v [B, Hkv, S, dh].  Grid =
(B*Hkv, q-tiles, kv-tiles) with the kv dimension innermost sequential;
the online-softmax (max, denom, accum) carry lives in VMEM scratch and
the output tile is emitted on the last kv step.  Tiles above the causal
diagonal are skipped entirely (`pl.when`), so compute is ~S^2/2 not S^2
(the pure-JAX `models.layers.flash_attention` masks but still computes —
this kernel is the TPU-target replacement; DESIGN §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_QC = 256
DEFAULT_KC = 512


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
                  *, qc: int, kc: int, nk: int, scale: float, causal: bool):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip tiles entirely above the causal diagonal
    run = (ki * kc <= qi * qc + qc - 1) if causal else True

    @pl.when(run)
    def _update():
        q = q_ref[0, 0]                                   # [G, qc, dh]
        k = k_ref[0, 0]                                   # [kc, dh]
        v = v_ref[0, 0]
        g = q.shape[0]
        s = jax.lax.dot_general(
            q.reshape(g * qc, -1), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(g, qc, kc) * scale
        if causal:
            qpos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
            kpos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
            s = jnp.where((qpos >= kpos)[None], s, NEG_INF)
        m_prev = m_ref[...]                               # [G, qc]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1))
        sc = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = l_ref[...] * sc + jnp.sum(p, -1)
        pv = jax.lax.dot_general(
            p.reshape(g * qc, kc), v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(g, qc, -1)
        acc_ref[...] = acc_ref[...] * sc[..., None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "qc", "kc", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, qc: int = DEFAULT_QC,
                    kc: int = DEFAULT_KC, interpret: bool = True) -> jnp.ndarray:
    """q: [B, Hkv, G, S, dh]; k/v: [B, Hkv, S, dh] -> [B, Hkv, G, S, dh]."""
    b, hkv, g, s, dh = q.shape
    qc = min(qc, s)
    kc = min(kc, s)
    assert s % qc == 0 and s % kc == 0, "seq must tile evenly"
    nq, nk = s // qc, s // kc
    scale = dh ** -0.5
    bh = b * hkv
    q4 = q.reshape(bh, 1, g, s, dh)
    k4 = k.reshape(bh, 1, s, dh)
    v4 = v.reshape(bh, 1, s, dh)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, qc=qc, kc=kc, nk=nk, scale=scale,
                          causal=causal),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, qc, dh), lambda i, qi, ki: (i, 0, 0, qi, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda i, qi, ki: (i, 0, ki, 0)),
            pl.BlockSpec((1, 1, kc, dh), lambda i, qi, ki: (i, 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, qc, dh),
                               lambda i, qi, ki: (i, 0, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, g, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, qc), jnp.float32),
            pltpu.VMEM((g, qc), jnp.float32),
            pltpu.VMEM((g, qc, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k4, v4)
    return out.reshape(b, hkv, g, s, dh)
