"""Pallas TPU kernel: tiled pairwise squared distances (coarse screening).

The O(N d) proxy-screening term of GoldDiff (paper Tab. 1).  Distances are
computed in the MXU-friendly matmul form

    ||q - x||^2 = ||q||^2 + ||x||^2 - 2 q . x

with row norms precomputed once per dataset (DatasetStore), so the kernel
body is a single (bq x d) @ (d x bn) matmul per tile plus rank-1 adds.
Tiles are MXU-aligned (multiples of 128 on the contracted/output dims);
fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BN = 512


def _pdist_kernel(q_ref, x_ref, qn_ref, xn_ref, out_ref):
    q = q_ref[...]
    x = x_ref[...]
    acc = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = qn_ref[...] + xn_ref[...] - 2.0 * acc
    out_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def pdist(q: jnp.ndarray, x: jnp.ndarray,
          q_norms: jnp.ndarray | None = None,
          x_norms: jnp.ndarray | None = None,
          bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
          interpret: bool = True) -> jnp.ndarray:
    """||q_i - x_j||^2 for q: [B, d], x: [N, d] -> [B, N] (fp32).

    interpret=True on CPU (validation); False lowers for real TPUs.
    """
    b, d = q.shape
    n = x.shape[0]
    if q_norms is None:
        q_norms = jnp.sum(q.astype(jnp.float32) ** 2, -1)
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)

    bq = min(bq, b)
    bn = min(bn, n)
    pb = (-b) % bq
    pn = (-n) % bn
    qp = jnp.pad(q, ((0, pb), (0, 0)))
    xp = jnp.pad(x, ((0, pn), (0, 0)))
    qn = jnp.pad(q_norms, (0, pb)).reshape(-1, 1)
    xn = jnp.pad(x_norms, (0, pn)).reshape(1, -1)
    grid = ((b + pb) // bq, (n + pn) // bn)

    out = pl.pallas_call(
        _pdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(((b + pb), (n + pn)), jnp.float32),
        interpret=interpret,
    )(qp, xp, qn, xn)
    return out[:b, :n]
