"""Streaming one-pass screening: fused tiled pdist + running top-m.

The coarse stage of GoldDiff screens every dataset row, and the
materialized form (``ops.pdist`` -> ``lax.top_k``) allocates the full
``[B, N]`` proxy-distance matrix and sorts all N columns — at
ImageNet-1K scale that buffer IS the memory wall.  This module removes
it: the store streams through in N-tiles, each tile's distances are
computed in the MXU matmul form, and a running top-m carry
(values + indices) is merged per tile, so peak live memory is
O(B * (m + tile)) instead of O(B * N) and the store is read exactly
once.

Merge math (the same two-stage trick as the cross-shard top-k in
``distributed/sharding.py``, applied across tiles instead of shards):
the carry holds the m best negated distances seen so far; each tile
contributes its ``tile`` raw candidates and ONE ``lax.top_k`` over the
``[B, m + tile]`` concatenation re-selects the running top-m.  Because
the carry precedes the tile in the concatenation and tiles scan
left-to-right, ties resolve to the lowest dataset index — exactly
``lax.top_k``'s tie order — so the streamed result equals the
materialized ``lax.top_k(-pdist, m)`` bit-for-bit (per-element distance
dot products reduce over d in the same order regardless of N tiling).

Three implementations share that math:

* ``screen_topm_pallas`` — Pallas TPU kernel: flash-attention-style
  carry of (values, indices) scratch across the N grid axis, one
  matmul + merge per VMEM tile.  (Like the other engine kernels it is
  validated in interpret mode; the in-kernel ``lax.top_k`` lowering on
  real Mosaic is part of the ROADMAP real-TPU item.)
* ``screen_topm_scan``   — XLA fallback: ``lax.scan`` over N-tiles with
  the same carry; compiles for any backend.
* ``ref.screen_topm_ref`` — materialized oracle (pdist + top_k).

``full_scan_partial_stream`` applies the identical tiling to the exact
posterior mean (Eq. 2): an online-softmax (max, denom, accumulator)
carry over N-tiles — the XLA twin of the Pallas
``golden_aggregate`` kernel — so ``full_scan`` baselines run at N where
the dense ``[B, N]`` logits matrix cannot be allocated at all.

Slot semantics (shared with ``ops.ivf_screen``): when ``m`` exceeds the
number of rows, surplus slots carry ``d2 = +inf`` and an in-range
(clamped) index, so downstream gathers stay valid and +inf marks
padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

NEG_INF = -1e30
DEFAULT_BQ = 8
DEFAULT_TILE = 4096
# Scan-path default N-tile.  The lax.scan fallback holds only [B, tile]
# live distances, so it affords a 4x larger tile than the Pallas VMEM
# block — and XLA:CPU wall-clock improves monotonically with tile size
# (fewer merge dispatches, fatter GEMMs): at N=65536, B=32 the carry
# merge measures 42/118/421 ms (m=512/1638/6553) at tile=4096 vs
# 33/64/204 ms at 16384, recovering most of the streamed-vs-
# materialized gap (materialized: 20/40/130 ms where the [B, N] buffer
# fits).  Callers pass ``tile=None`` to get this per-path default.
SCAN_TILE = 16384


def _merge_topm(vals, idx, neg_tile, idx_tile, m: int):
    """One running-top-m step: re-select m from [carry | tile].

    ``vals`` descending negated distances [B, m]; tile operands raw
    [B, tile].  Carry-first concatenation keeps ``lax.top_k`` tie order
    (lowest dataset index wins).
    """
    cat_v = jnp.concatenate([vals, neg_tile], axis=-1)
    cat_i = jnp.concatenate([idx, idx_tile], axis=-1)
    new_v, sel = jax.lax.top_k(cat_v, m)
    return new_v, jnp.take_along_axis(cat_i, sel, axis=-1)


# -- Pallas kernel ------------------------------------------------------------

def _screen_kernel(q_ref, x_ref, qn_ref, xn_ref, idx_out, d2_out,
                   vals_ref, idx_ref, *, m: int, bn: int, nn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    q = q_ref[...]
    x = x_ref[...]
    dot = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn_ref[...] + xn_ref[...] - 2.0 * dot, 0.0)
    base = j * bn
    cols = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    new_v, new_i = _merge_topm(vals_ref[...], idx_ref[...], -d2, cols, m)
    vals_ref[...] = new_v
    idx_ref[...] = new_i

    @pl.when(j == nn - 1)
    def _emit():
        idx_out[...] = idx_ref[...]
        d2_out[...] = -vals_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("m", "bq", "bn", "interpret"))
def screen_topm_pallas(q: jnp.ndarray, x: jnp.ndarray, m: int,
                       q_norms: jnp.ndarray | None = None,
                       x_norms: jnp.ndarray | None = None,
                       bq: int = DEFAULT_BQ, bn: int = DEFAULT_TILE,
                       interpret: bool = True):
    """Streaming top-m over x for q: [B, d], x: [N, d] -> (idx, d2) [B, m].

    ``d2`` ascending fp32; +inf marks slots past the real rows (m > N),
    whose indices are clamped in-range.  interpret=True on CPU.

    Like the sibling Pallas kernels (``pdist``, ``golden_aggregate``)
    the N axis is explicitly padded to a block multiple with +inf-norm
    rows — an HBM-side copy when N % bn != 0, the established idiom
    here.  The XLA scan twin avoids even that (clamped overlapping
    tiles); callers who need strict O(B (m + tile)) memory on ragged N
    use it.
    """
    b, d = q.shape
    n = x.shape[0]
    if q_norms is None:
        q_norms = jnp.sum(q.astype(jnp.float32) ** 2, -1)
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)

    bq = min(bq, b)
    bn = min(bn, max(n, 1))
    pb = (-b) % bq
    # pad N so every tile is full AND the carry always holds m slots
    n_pad = max(-(-n // bn), -(-m // bn)) * bn
    qp = jnp.pad(q, ((0, pb), (0, 0)))
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    qn = jnp.pad(q_norms, (0, pb)).reshape(-1, 1)
    # +inf norms on padded rows -> +inf distance -> selected last
    xn = jnp.pad(x_norms.astype(jnp.float32), (0, n_pad - n),
                 constant_values=jnp.inf).reshape(1, -1)
    nb, nn = (b + pb) // bq, n_pad // bn

    idx, d2 = pl.pallas_call(
        functools.partial(_screen_kernel, m=m, bn=bn, nn=nn),
        grid=(nb, nn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=(pl.BlockSpec((bq, m), lambda i, j: (i, 0)),
                   pl.BlockSpec((bq, m), lambda i, j: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((b + pb, m), jnp.int32),
                   jax.ShapeDtypeStruct((b + pb, m), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((bq, m), jnp.float32),   # running negated top-m
            pltpu.VMEM((bq, m), jnp.int32),     # their dataset indices
        ],
        interpret=interpret,
    )(qp, xp, qn, xn)
    return jnp.minimum(idx[:b], max(n - 1, 0)), d2[:b]


# -- XLA (lax.scan) fallback --------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "tile", "hier"))
def screen_topm_scan(q: jnp.ndarray, x: jnp.ndarray, m: int,
                     q_norms: jnp.ndarray | None = None,
                     x_norms: jnp.ndarray | None = None,
                     tile: int | None = None, hier: bool = False):
    """Tiled-scan twin of :func:`screen_topm_pallas` for any XLA backend.

    Peak live memory O(B * (m + tile)); the [N, d] store is sliced in
    place (``dynamic_slice``), never padded or re-materialized — a
    ragged final tile slides back to ``[N - tile, N)`` (the
    dynamic-slice clamp) and the already-seen overlap columns are
    masked to -inf, so no O(N d) padded copy exists for any N.
    ``tile=None`` picks :data:`SCAN_TILE` (the scan path affords a much
    larger tile than the Pallas VMEM block, and CPU wall-clock improves
    with it — see the constant's comment).

    ``hier=True`` switches the merge to a two-level hierarchical form:
    each tile selects its own top-m independently inside the scan, the
    [nt, B, m] level-0 lists stack as scan outputs, and a log2(nt)-deep
    pairwise tree re-selects the global top-m.  Left-first
    concatenation at every level keeps ``lax.top_k``'s lowest-index tie
    rule, so both forms are bit-identical to the materialized screen.
    It is OFF by default on measurement: XLA:CPU's TopK custom call is
    strongly data-dependent (a descending-sorted prefix — exactly the
    carry-merge's input — runs ~10x faster than random input), and
    ``lax.scan`` serializes on every backend, so removing the merge
    from the carry buys no critical-path win while the independent
    per-tile top-k forfeits the fast path (measured ~3x slower end to
    end on CPU at N=65536).  The flag remains for backends whose
    per-tile top-k vectorizes across tiles.
    """
    n, d = x.shape
    if tile is None:
        tile = SCAN_TILE
    q32 = q.astype(jnp.float32)
    if q_norms is None:
        q_norms = jnp.sum(q32 ** 2, -1)
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    x_norms = x_norms.astype(jnp.float32)
    tile = min(tile, max(n, 1))
    b = q.shape[0]
    qn = q_norms.astype(jnp.float32)[:, None]
    starts = jnp.arange(0, -(-n // tile) * tile, tile, dtype=jnp.int32)
    nt = starts.shape[0]

    def tile_neg(start):
        eff = jnp.minimum(start, n - tile)     # ragged tail: overlap back
        xt = jax.lax.dynamic_slice_in_dim(x, eff, tile).astype(jnp.float32)
        xnt = jax.lax.dynamic_slice_in_dim(x_norms, eff, tile)
        dot = jax.lax.dot_general(
            q32, xt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qn + xnt[None, :] - 2.0 * dot, 0.0)
        cols = eff + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        return jnp.where(cols >= start, -d2, -jnp.inf), cols  # mask re-seen

    if hier and m < tile and nt > 1:
        # Two-level hierarchical merge (opt-in; see docstring): per-tile
        # independent top-m stacked as scan outputs (O(B N m/tile) —
        # strictly below the materialized [B, N] when m < tile), then a
        # log2(nt)-deep pairwise tree re-selects the global top-m.
        def level0(carry, start):
            neg, cols = tile_neg(start)
            v, sel = jax.lax.top_k(neg, m)
            return carry, (v, jnp.take_along_axis(cols, sel, axis=-1))

        _, (vals, idx) = jax.lax.scan(level0, 0, starts)
        while vals.shape[0] > 1:
            if vals.shape[0] % 2:              # odd level: -inf ghost tile
                vals = jnp.concatenate(
                    [vals, jnp.full_like(vals[:1], -jnp.inf)], axis=0)
                idx = jnp.concatenate([idx, jnp.zeros_like(idx[:1])], axis=0)
            cat_v = jnp.concatenate([vals[0::2], vals[1::2]], axis=-1)
            cat_i = jnp.concatenate([idx[0::2], idx[1::2]], axis=-1)
            vals, sel = jax.lax.top_k(cat_v, m)
            idx = jnp.take_along_axis(cat_i, sel, axis=-1)
        vals, idx = vals[0], idx[0]
    else:
        def body(carry, start):
            vals, idx = carry
            neg, cols = tile_neg(start)
            return _merge_topm(vals, idx, neg, cols, m), None

        init = (jnp.full((b, m), -jnp.inf, jnp.float32),
                jnp.zeros((b, m), jnp.int32))
        (vals, idx), _ = jax.lax.scan(body, init, starts)
    return jnp.minimum(idx, max(n - 1, 0)), -vals


# -- streaming full-scan LSE (XLA twin of the golden_aggregate kernel) --------

@functools.partial(jax.jit, static_argnames=("sigma2", "tile"))
def full_scan_partial_stream(q: jnp.ndarray, x: jnp.ndarray, sigma2: float,
                             x_norms: jnp.ndarray | None = None,
                             tile: int = DEFAULT_TILE):
    """Unnormalized softmax state of the FULL store, one tiled pass.

    Returns ``(acc [B, D], m [B], l [B])`` with the same clamped-logit
    (``NEG_INF`` floor) semantics as ``ops.golden_partial_aggregate``'s
    dense full-scan case, so the states LSE-merge exactly across shards
    (``sharding.lse_merge_mean``).  Peak live memory O(B * tile + B * D)
    — the [B, N] logits matrix of the dense form is never built, and
    (like :func:`screen_topm_scan`) a ragged final tile overlaps
    backwards with the re-seen columns masked to exactly zero weight
    instead of padding the store.
    """
    n, d = x.shape
    b = q.shape[0]
    q32 = q.astype(jnp.float32)
    qn = jnp.sum(q32 ** 2, -1)[:, None]
    if x_norms is None:
        x_norms = jnp.sum(x.astype(jnp.float32) ** 2, -1)
    x_norms = x_norms.astype(jnp.float32)
    tile = min(tile, max(n, 1))
    # finite inverse temperature: degenerate sigma2 clamps every logit
    # at NEG_INF (uniform weights -> data mean) instead of the silent
    # 0 * inf NaN / ZeroDivisionError of an unguarded 1 / (2 sigma2)
    inv = ref.finite_inv_two_sigma2(sigma2)

    def body(carry, start):
        m_run, l_run, acc = carry
        eff = jnp.minimum(start, n - tile)     # ragged tail: overlap back
        xt = jax.lax.dynamic_slice_in_dim(x, eff, tile).astype(jnp.float32)
        xnt = jax.lax.dynamic_slice_in_dim(x_norms, eff, tile)
        dot = jax.lax.dot_general(
            q32, xt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qn + xnt[None, :] - 2.0 * dot, 0.0)
        # +inf-norm (padded) rows clamp to the finite NEG_INF sentinel —
        # exp(NEG_INF - m) underflows to exactly 0 for any real logit,
        # matching the dense partial; re-seen overlap columns get a hard
        # -inf so they are zero even in the all-NEG_INF degenerate case
        lg = jnp.maximum(-d2 * inv, NEG_INF)
        cols = eff + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        lg = jnp.where(cols >= start, lg, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(lg, -1))
        scale = jnp.exp(m_run - m_new)
        p = jnp.exp(lg - m_new[:, None])
        l_new = l_run * scale + jnp.sum(p, -1)
        acc_new = acc * scale[:, None] + jax.lax.dot_general(
            p, xt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b,), NEG_INF, jnp.float32),
            jnp.zeros((b,), jnp.float32),
            jnp.zeros((b, d), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(
        body, init,
        jnp.arange(0, -(-n // tile) * tile, tile, dtype=jnp.int32))
    return acc, m_run, l_run


def full_scan_stream(q: jnp.ndarray, x: jnp.ndarray, sigma2: float,
                     x_norms: jnp.ndarray | None = None,
                     tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Streaming exact posterior mean (Eq. 2); [B, D] in q.dtype."""
    acc, _, l = full_scan_partial_stream(q, x, float(sigma2),
                                         x_norms=x_norms, tile=tile)
    return (acc / jnp.maximum(l, 1e-30)[:, None]).astype(q.dtype)
