"""Procedural datasets standing in for the paper's benchmarks.

The container is offline (no MNIST/CIFAR/AFHQ/ImageNet files), so we
generate *procedural* datasets with matched shape and cardinality.  Every
claim the reproduction validates (speedup vs N, golden-subset == full scan,
Theorem 1, progressive concentration, WSS bias) is algorithmic and
dataset-agnostic — see DESIGN.md §7.

Image generator: each class c has a smooth random-Fourier prototype; a
sample is prototype + smooth random deformation field + band-limited
texture + pixel noise, standardized to roughly [-1, 1].  This yields a
manifold with genuine low-frequency structure, so the paper's downsampled
proxy screening (hierarchical consistency of natural images) is exercised
meaningfully rather than trivially.
"""
from __future__ import annotations

import numpy as np

from repro.core.dataset import DatasetStore, make_store


def moons(n: int = 2000, noise: float = 0.08, seed: int = 0) -> DatasetStore:
    """Two interleaved half-circles (the Fig. 1 toy), standardized."""
    rng = np.random.default_rng(seed)
    n2 = n // 2
    th1 = rng.uniform(0, np.pi, n2)
    th2 = rng.uniform(0, np.pi, n - n2)
    a = np.stack([np.cos(th1), np.sin(th1)], -1)
    b = np.stack([1 - np.cos(th2), -np.sin(th2) + 0.5], -1)
    x = np.concatenate([a, b]) + rng.normal(0, noise, (n, 2))
    y = np.concatenate([np.zeros(n2, int), np.ones(n - n2, int)])
    x = (x - x.mean(0)) / x.std(0)
    return make_store(x.astype(np.float32), (2,), labels=y, proxy_factor=1)


def gmm(n: int = 4096, dim: int = 16, num_modes: int = 8,
        spread: float = 0.15, seed: int = 0) -> DatasetStore:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (num_modes, dim))
    y = rng.integers(0, num_modes, n)
    x = centers[y] + rng.normal(0, spread, (n, dim))
    x = (x - x.mean(0)) / (x.std() + 1e-8)
    return make_store(x.astype(np.float32), (dim,), labels=y, proxy_factor=1)


def _fourier_field(rng, h, w, c, max_freq: int, count: int) -> np.ndarray:
    """[count, h, w, c] smooth random fields from low-frequency Fourier modes."""
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w),
                         indexing="ij")
    out = np.zeros((count, h, w, c), np.float32)
    for f in range(1, max_freq + 1):
        for (gy, gx) in ((f, 0), (0, f), (f, f)):
            phase = rng.uniform(0, 2 * np.pi, (count, 1, 1, c))
            amp = rng.normal(0, 1.0 / f, (count, 1, 1, c))
            base = 2 * np.pi * (gy * yy + gx * xx)
            out += amp * np.cos(base[None, :, :, None] + phase)
    return out


def procedural_images(n: int, h: int, w: int, c: int = 3,
                      num_classes: int = 10, seed: int = 0,
                      deform: float = 1.5, texture: float = 0.35,
                      pixel_noise: float = 0.05,
                      batch: int = 4096) -> tuple[np.ndarray, np.ndarray]:
    """Raw arrays (x [n,h,w,c] float32 standardized, labels [n])."""
    rng = np.random.default_rng(seed)
    protos = _fourier_field(rng, h, w, c, max_freq=3, count=num_classes)
    protos /= (np.abs(protos).max(axis=(1, 2, 3), keepdims=True) + 1e-6)
    labels = rng.integers(0, num_classes, n)
    xs = np.empty((n, h, w, c), np.float32)
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    for s in range(0, n, batch):
        e = min(s + batch, n)
        m = e - s
        lab = labels[s:e]
        # smooth per-sample deformation of the prototype (shift field)
        dy = _fourier_field(rng, h, w, 1, 2, m)[..., 0] * deform
        dx = _fourier_field(rng, h, w, 1, 2, m)[..., 0] * deform
        iy = np.clip((yy[None] + dy).round().astype(int), 0, h - 1)
        ix = np.clip((xx[None] + dx).round().astype(int), 0, w - 1)
        base = protos[lab]                                   # [m,h,w,c]
        warped = base[np.arange(m)[:, None, None], iy, ix, :]
        tex = _fourier_field(rng, h, w, c, 6, m) * texture * 0.3
        xs[s:e] = warped + tex + rng.normal(0, pixel_noise, (m, h, w, c))
    xs -= xs.mean()
    xs /= (xs.std() + 1e-8)
    return xs, labels


def image_store(n: int, h: int, w: int, c: int = 3, num_classes: int = 10,
                seed: int = 0, **kw) -> DatasetStore:
    x, y = procedural_images(n, h, w, c, num_classes, seed, **kw)
    return make_store(x.reshape(n, -1), (h, w, c), labels=y)


# Named dataset registry mirroring the paper's benchmark suite ---------------

def mnist_like(n=4096, seed=0):
    return image_store(n, 28, 28, 1, num_classes=10, seed=seed)


def cifar_like(n=8192, seed=0):
    return image_store(n, 32, 32, 3, num_classes=10, seed=seed)


def celeba_like(n=4096, seed=0):
    return image_store(n, 64, 64, 3, num_classes=2, seed=seed)


def afhq_like(n=4096, seed=0):
    return image_store(n, 64, 64, 3, num_classes=3, seed=seed)


def imagenet_like(n=20000, seed=0, num_classes=1000):
    return image_store(n, 64, 64, 3, num_classes=num_classes, seed=seed)


DATASETS = {
    "moons": moons,
    "gmm": gmm,
    "mnist_like": mnist_like,
    "cifar_like": cifar_like,
    "celeba_like": celeba_like,
    "afhq_like": afhq_like,
    "imagenet_like": imagenet_like,
}


def make_dataset(name: str, **kw) -> DatasetStore:
    return DATASETS[name](**kw)
