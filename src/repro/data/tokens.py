"""Deterministic synthetic token pipeline for LLM training/serving paths.

A seeded mixture of order-1 Markov chains over the vocabulary plus copy
spans: enough structure that a ~100M model's loss visibly falls within a
few hundred steps, fully reproducible, zero files.  The pipeline yields
already-sharded global batches (callers pass device_put targets).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_chains: int = 8
    copy_prob: float = 0.15
    seed: int = 0


class TokenPipeline:
    """Stateless-per-step token source: batch(step) is pure in (cfg, step)."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 4096)  # transition table over a head-vocab
        self._v = v
        # sparse-ish row-stochastic transition tables, one per chain
        self.tables = []
        for _ in range(cfg.num_chains):
            logits = rng.gumbel(size=(v, 32))
            cols = rng.integers(0, v, (v, 32))
            self.tables.append((cols, jax.nn.softmax(jnp.asarray(logits), -1)))

    def batch(self, step: int) -> dict[str, Array]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        b, s, v = cfg.global_batch, cfg.seq_len, self._v
        chain = rng.integers(0, cfg.num_chains, b)
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        # vectorized chain walk
        for i in range(b):
            cols, probs = self.tables[chain[i]]
            probs = np.asarray(probs)
            cur = toks[i, 0]
            u = rng.random(s)
            for j in range(1, s + 1):
                p = probs[cur]
                cur = cols[cur, np.searchsorted(np.cumsum(p), u[j - 1])]
                toks[i, j] = cur
        # splice copy spans (long-range structure)
        n_copy = int(cfg.copy_prob * b)
        for i in range(n_copy):
            span = rng.integers(8, min(64, s // 4))
            src = rng.integers(0, s - 2 * span)
            dst = rng.integers(src + span, s - span)
            toks[i, dst:dst + span] = toks[i, src:src + span]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


def fast_batch(cfg: TokenPipelineConfig, step: int) -> dict[str, Array]:
    """Cheap jax-side batch (uniform tokens) for smoke tests/benchmarks."""
    key = jax.random.PRNGKey((cfg.seed << 20) ^ step)
    toks = jax.random.randint(key, (cfg.global_batch, cfg.seq_len + 1), 0,
                              cfg.vocab_size, jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
