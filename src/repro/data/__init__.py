"""Data substrate: procedural datasets + token pipeline."""
from repro.data.synthetic import (DATASETS, afhq_like, celeba_like,
                                  cifar_like, gmm, image_store,
                                  imagenet_like, make_dataset, mnist_like,
                                  moons, procedural_images)
from repro.data.tokens import TokenPipeline, TokenPipelineConfig, fast_batch

__all__ = [
    "DATASETS", "make_dataset", "moons", "gmm", "image_store",
    "mnist_like", "cifar_like", "celeba_like", "afhq_like", "imagenet_like",
    "procedural_images", "TokenPipeline", "TokenPipelineConfig", "fast_batch",
]
