"""Step-function builders shared by train.py / serve.py / dryrun.py."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, use_rules
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.training import optimizer as opt


def make_train_step(cfg: ModelConfig, rules: Rules,
                    opt_cfg: opt.AdamWConfig | None = None,
                    num_microbatches: int = 1,
                    shard_grad_accum: bool = False,
                    zero1_rules: Rules | None = None) -> Callable:
    """One optimizer step; with num_microbatches > 1 the global batch is
    split and gradients are accumulated in f32 over a lax.scan (activation
    memory / num_microbatches at the cost of serialization).

    shard_grad_accum constrains the f32 gradient accumulator to the PARAM
    shardings (FSDP over `data`), so each microbatch's gradient reduction
    lowers to a reduce-scatter instead of a full all-reduce (§Perf)."""
    opt_cfg = opt_cfg or opt.AdamWConfig()

    def _grad_constraint():
        if not shard_grad_accum or rules.mesh is None:
            return lambda g: g
        from repro.models.module import param_shardings
        from repro.models.transformer import model_specs
        shardings = param_shardings(model_specs(cfg), rules)

        def constrain(g):
            return jax.tree.map(
                lambda x, s: x if s is None
                else jax.lax.with_sharding_constraint(x, s), g, shardings)
        return constrain

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            def lf(p, b):
                loss, metrics = T.loss_fn(cfg, p, b)
                return loss, metrics

            if num_microbatches == 1:  # noqa: SIM108
                (loss, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((num_microbatches,
                                         x.shape[0] // num_microbatches)
                                        + x.shape[1:]), batch)

                constrain = _grad_constraint()

                def acc(carry, b):
                    gsum, lsum = carry
                    (l, metrics), g = jax.value_and_grad(
                        lf, has_aux=True)(params, b)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g)
                    gsum = constrain(gsum)
                    return (gsum, lsum + l), metrics

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (gsum, lsum), metrics = jax.lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
                loss = lsum / num_microbatches
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            if zero1_rules is not None:
                # ZeRO-1: params replicated over `data` but optimizer state
                # and the grad reduction sharded over it; grads are
                # reduce-scattered into the optimizer shard, the update runs
                # shard-local, and the fresh params are all-gathered once.
                from repro.models.module import param_shardings
                from repro.models.transformer import model_specs
                specs = model_specs(cfg)
                opt_sh = param_shardings(specs, zero1_rules)
                par_sh = param_shardings(specs, rules)
                grads = jax.tree.map(
                    lambda g, s: g if s is None
                    else jax.lax.with_sharding_constraint(g, s),
                    grads, opt_sh)
                params, opt_state, om = opt.apply_updates(
                    opt_cfg, params, grads, opt_state)
                params = jax.tree.map(
                    lambda p, s: p if s is None
                    else jax.lax.with_sharding_constraint(p, s),
                    params, par_sh)
            else:
                params, opt_state, om = opt.apply_updates(
                    opt_cfg, params, grads, opt_state)
            metrics = dict(metrics, loss=loss, **om)
            return params, opt_state, metrics

    return train_step


def make_loss_step(cfg: ModelConfig, rules: Rules) -> Callable:
    """Forward+backward without optimizer (lighter dry-run variant)."""
    def loss_step(params, batch):
        with use_rules(rules):
            (loss, _), grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
            return loss, grads
    return loss_step


def make_prefill_step(cfg: ModelConfig, rules: Rules) -> Callable:
    def prefill_step(params, batch):
        with use_rules(rules):
            return T.prefill(cfg, params, batch["tokens"],
                             batch.get("embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Rules) -> Callable:
    def serve_step(params, cache, token, pos):
        with use_rules(rules):
            return T.decode_step(cfg, params, cache, token, pos)
    return serve_step
