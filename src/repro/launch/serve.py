"""Batched analytical-diffusion sampling engine (the paper's serving kind).

A request is (dataset/class, num_images, seed); ``ServeEngine`` batches
requests per wave and runs GoldDiff DDIM sampling.  With the Optimal
base the whole trajectory runs through ``sample_scan`` over the masked
(scan/pjit-compatible) ``GoldDiff.call_masked`` body, so serving
compiles ONE program per batch shape — not one program per (step,
request) pair — and a warm engine answers any request at an
already-compiled batch size without touching the compiler.  Patch-family
bases need static per-step patch sizes, so they keep the per-step
static-program sampler.  Under a mesh the golden store is data-sharded
through the engine's shard_map pipeline (``GoldDiff(mesh=...)``).

(Historical note: this class used to be called ``GoldDiffEngine``,
shadowing the unrelated execution engine ``core.engine.GoldDiffEngine``
— it is the *serving* layer on top of that engine.)

  PYTHONPATH=src python -m repro.launch.serve --dataset cifar_like \
      --n 4096 --requests 2 --batch 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Iterable

import jax
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, make_schedule, sample,
                        sample_scan)
from repro.core.denoisers import OptimalDenoiser, make_denoiser
from repro.data import make_dataset


@dataclasses.dataclass
class Request:
    request_id: int
    num_images: int
    seed: int
    class_id: int | None = None


@dataclasses.dataclass
class Result:
    request_id: int
    images: np.ndarray
    latency_s: float


class ServeEngine:
    """Training-free generation service over a fixed dataset store."""

    def __init__(self, dataset: str, dataset_kw: dict | None = None,
                 base: str = "optimal", schedule: str = "ddpm_linear",
                 num_steps: int = 10, gd_cfg: GoldDiffConfig | None = None,
                 max_batch: int = 16, mesh=None):
        self.store = make_dataset(dataset, **(dataset_kw or {}))
        self.schedule = make_schedule(schedule, 1000)
        self.num_steps = num_steps
        self.max_batch = max_batch
        base_den = make_denoiser(base, self.store, self.schedule)
        self.denoiser = GoldDiff(base_den, gd_cfg or GoldDiffConfig(),
                                 mesh=mesh)

    def _scan_compatible(self) -> bool:
        """One-program serving needs the masked body: a GoldDiff over
        the Optimal base (patch bases require static patch sizes)."""
        return (hasattr(self.denoiser, "call_masked")
                and isinstance(getattr(self.denoiser, "base", None),
                               OptimalDenoiser))

    def _sample(self, batch: int, seed: int) -> np.ndarray:
        rng = jax.random.PRNGKey(seed)
        shape = (batch, self.store.dim)
        if self._scan_compatible():
            x = sample_scan(self.denoiser.call_masked, self.schedule, shape,
                            rng, num_steps=self.num_steps)
        else:
            x = sample(self.denoiser, self.schedule, shape, rng,
                       num_steps=self.num_steps)
        return np.asarray(x).reshape((batch,) + self.store.image_shape)

    def serve(self, requests: Iterable[Request]) -> list[Result]:
        """Greedy batching: requests are packed up to max_batch per wave."""
        out: list[Result] = []
        queue = list(requests)
        while queue:
            wave, used = [], 0
            while queue and used + queue[0].num_images <= self.max_batch:
                r = queue.pop(0)
                wave.append(r)
                used += r.num_images
            if not wave:                        # single oversized request
                r = queue.pop(0)
                wave, used = [r], min(r.num_images, self.max_batch)
            t0 = time.time()
            imgs = self._sample(used, seed=wave[0].seed)
            dt = time.time() - t0
            ofs = 0
            for r in wave:
                n = min(r.num_images, used - ofs)
                out.append(Result(r.request_id, imgs[ofs: ofs + n], dt))
                ofs += n
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar_like")
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--base", default="optimal",
                    choices=["optimal", "pca", "kamb"])
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    eng = ServeEngine(args.dataset, {"n": args.n}, base=args.base,
                      num_steps=args.steps, max_batch=args.batch)
    reqs = [Request(i, args.batch, seed=100 + i) for i in range(args.requests)]
    t0 = time.time()
    results = eng.serve(reqs)
    total = time.time() - t0
    for r in results:
        print(f"request {r.request_id}: {r.images.shape} "
              f"batch-latency={r.latency_s:.2f}s "
              f"finite={np.isfinite(r.images).all()}")
    n_img = sum(r.images.shape[0] for r in results)
    print(f"served {n_img} images in {total:.2f}s "
          f"({total/max(n_img,1):.3f}s/image, {args.steps} steps)")


if __name__ == "__main__":
    main()
