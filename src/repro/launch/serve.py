"""Batched analytical-diffusion sampling engine (the paper's serving kind).

A request is (dataset/class, num_images, seed); ``ServeEngine`` batches
requests per wave and runs GoldDiff DDIM sampling.  Three execution
modes, picked by ``mode=`` (``"auto"`` default):

* ``"plan"`` — the default with the Optimal base: the trajectory runs
  through ``sample_plan`` over a ``repro.core.plan.TrajectoryPlan`` —
  chained per-bucket ``lax.scan`` segments whose masked bodies are
  padded only to their bucket's (m_cap, k_cap, nprobe_cap).  A few
  (typically 3-4) compiled programs per batch shape keep ~all of static
  mode's trajectory FLOP savings (the paper's Posterior Progressive
  Concentration), instead of masked mode's worst-case padding or
  static mode's program-per-timestep cold start.
* ``"scan"`` — PR 4's single masked program per batch shape, padded to
  (m_max, k_max) at every step.
* ``"static"`` — per-step static programs (patch-family bases need
  static patch sizes, so they always serve this way).

Batch sizes are bucketed to powers of two up to ``max_batch``: a wave
of 5 requests runs at batch 8 and the padding rows are sliced off, so
the whole serving surface is ``len(batch_buckets) x plan.num_buckets``
programs — all of which ``warmup()`` precompiles before traffic, and
none of which recompile afterwards (guarded in CI by the emulated-mesh
recompile test).

Every request owns its noise stream: row i of request r draws its
terminal noise from ``fold_in(PRNGKey(r.seed), i)``, so a request's
images do not depend on which wave co-batched it (regression-tested in
``tests/test_serve_plan.py``; the pre-plan engine seeded a whole wave
from its first request's seed).

Under a mesh the golden store is data-sharded through the engine's
shard_map pipeline (``GoldDiff(mesh=...)``) in every mode.

(Historical note: this class used to be called ``GoldDiffEngine``,
shadowing the unrelated execution engine ``core.engine.GoldDiffEngine``
— it is the *serving* layer on top of that engine.)

  PYTHONPATH=src python -m repro.launch.serve --dataset cifar_like \
      --n 4096 --requests 2 --batch 8 --buckets 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GoldDiff, GoldDiffConfig, build_plan, make_schedule,
                        sample, sample_plan, sample_scan)
from repro.core.dataset import DatasetStore
from repro.core.denoisers import OptimalDenoiser, make_denoiser
from repro.core.schedules import sampling_timesteps
from repro.data import make_dataset
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class Request:
    request_id: int
    num_images: int
    seed: int
    class_id: int | None = None
    # per-request deadline (seconds from submission), honored by the
    # fault-tolerant runtime (launch/runtime.py); ``ServeEngine.serve``
    # itself is a synchronous batch call and ignores it
    deadline_s: float | None = None


@dataclasses.dataclass
class Result:
    request_id: int
    images: np.ndarray
    latency_s: float


class ServeEngine:
    """Training-free generation service over a fixed dataset store.

    The synchronous batch layer of the serving stack (the async layer
    with admission control, deadlines, and continuous batching is
    :class:`repro.launch.runtime.ServeRuntime`, which wraps a warmed
    instance of this class).  Owns three serving-specific concerns:

    * **batch buckets** — request waves are padded to the next
      power-of-two batch size, so the set of compilable batch shapes
      is logarithmic in ``max_batch``.
    * **per-request noise streams** — every row draws its terminal
      noise from ``fold_in(PRNGKey(request.seed), row)``, making
      outputs bitwise independent of how requests are packed into
      waves (the property continuous batching relies on).
    * **AOT warmup** — ``warmup()`` precompiles every (batch bucket x
      plan bucket x plan variant) program, including the mixed-cursor
      ``plan_seg_mix`` variants, so serving any request mix afterward
      compiles nothing (CI-guarded).

    ``plan_threshold`` / ``max_buckets`` forward to
    :func:`repro.core.plan.build_plan`: lower thresholds give
    finer-grained plans — more seams for the runtime to admit/expire
    at, at the cost of more programs to warm (see docs/SERVING.md).

    ``fused`` forwards to the engine (``GoldDiffEngine(fused=...)``):
    with the fused single-pass step on, ``warmup()`` precompiles the
    *fused* program kinds — the static ``fused_step`` programs and the
    fused-body plan/scan segments — so zero post-warmup compiles holds
    unchanged (the program cache keys the fused kind; the CI recompile
    guard runs with ``fused=True``).
    """

    def __init__(self, dataset: str | DatasetStore,
                 dataset_kw: dict | None = None,
                 base: str = "optimal", schedule: str = "ddpm_linear",
                 num_steps: int = 10, gd_cfg: GoldDiffConfig | None = None,
                 max_batch: int = 16, mesh=None, mode: str = "auto",
                 plan_threshold: float = 0.15,
                 max_buckets: int | None = None,
                 clip_value: float | None = 3.0, index=None,
                 index_mode: str = "auto", fused: str | bool = "auto",
                 batch_axis: str | None = None):
        # a DatasetStore passes through directly — the store-lifecycle
        # path (repro.index.ingest) serves its capacity-padded view
        # without a synthetic-dataset detour
        self.store = (dataset if isinstance(dataset, DatasetStore)
                      else make_dataset(dataset, **(dataset_kw or {})))
        self.schedule = make_schedule(schedule, 1000)
        self.num_steps = num_steps
        self.max_batch = max_batch
        self.clip_value = clip_value
        base_den = make_denoiser(base, self.store, self.schedule)
        self.denoiser = GoldDiff(base_den, gd_cfg or GoldDiffConfig(),
                                 mesh=mesh, index=index,
                                 index_mode=index_mode, fused=fused,
                                 batch_axis=batch_axis)
        # pinned here so baseline subclasses may swap ``denoiser`` (e.g.
        # unwrap to the full-scan base) and keep the program cache
        self._engine = self.denoiser.engine
        if mode == "auto":
            mode = "plan" if self._scan_compatible() else "static"
        if mode not in ("plan", "scan", "static"):
            raise ValueError(f"unknown serve mode {mode!r}")
        if mode in ("plan", "scan") and not self._scan_compatible():
            raise ValueError(f"mode={mode!r} needs the masked (Optimal-"
                             f"base) denoiser body; base {base!r} serves "
                             f"mode='static' only")
        self.mode = mode
        self.plan = build_plan(self.engine, num_steps,
                               threshold=plan_threshold,
                               max_buckets=max_buckets) \
            if mode == "plan" else None

    @property
    def engine(self):
        """The compiled-program cache owner (``core.GoldDiffEngine``)."""
        return self._engine

    def _scan_compatible(self) -> bool:
        """Masked-body serving needs a GoldDiff over the Optimal base
        (patch bases require static per-step patch sizes)."""
        return (hasattr(self.denoiser, "call_masked")
                and isinstance(getattr(self.denoiser, "base", None),
                               OptimalDenoiser))

    # -- batch buckets -------------------------------------------------------
    def batch_buckets(self) -> list[int]:
        """Power-of-two batch sizes served, ascending (max_batch last
        even when it is not itself a power of two)."""
        out, b = [], 1
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return out

    def _bucket_for(self, n: int) -> int:
        """Smallest batch bucket holding ``n`` rows."""
        for b in self.batch_buckets():
            if b >= n:
                return b
        return self.max_batch

    # -- per-request noise streams ------------------------------------------
    def _row_keys(self, wave: list, bucket: int):
        """One PRNG key per batch row: ``fold_in(PRNGKey(r.seed),
        ofs + i)`` for row i of a request chunk starting at global row
        ``ofs``, so a request's noise stream never depends on its
        wave-mates, the wave it lands in, or how an oversized request
        was chunked; padding rows (sliced off) fold a fixed throwaway
        seed (0).  ``wave`` holds ``(request, ofs, n)`` triples.

        Derivation is one fused vmapped program per batch bucket (a
        warmed, bounded shape set) rather than per-row ``fold_in``
        dispatches — the hot path stays zero-dispatch-per-row AND
        zero-compile after warmup."""
        seeds, idx = [], []
        for r, ofs, n in wave:
            seeds += [r.seed] * n
            idx += list(range(ofs, ofs + n))
        npad = bucket - len(idx)
        seeds += [0] * npad
        idx += list(range(npad))
        fn = self.engine.program(
            ("serve_keys", bucket),
            lambda: jax.jit(jax.vmap(lambda s, i: jax.random.fold_in(
                jax.random.PRNGKey(s), i))))
        return fn(jnp.asarray(seeds, jnp.int32),
                  jnp.asarray(idx, jnp.int32))

    def _init_noise(self, keys):
        """Terminal noise x_T = b_T * eps, one independent eps row per
        key; compiled once per batch bucket."""
        ts = sampling_timesteps(self.schedule, self.num_steps)
        b_t0 = float(self.schedule.b[int(ts[0])])
        dim = self.store.dim

        def build():
            return jax.jit(lambda k: b_t0 * jax.vmap(
                lambda kk: jax.random.normal(kk, (dim,)))(k))

        fn = self.engine.program(("serve_init", keys.shape[0], dim), build)
        return fn(keys)

    # -- sampling ------------------------------------------------------------
    def _scan_program(self, shape: tuple, compile_only: bool = False):
        """The cached one-masked-program sampler for a batch shape.
        ``compile_only`` AOT-lowers it (warmup) instead of jitting for
        a first executing call."""
        rng = jax.random.PRNGKey(0)          # split-consumed only: x_init
        key = ("serve_scan", shape, self.num_steps,  # carries randomness
               None if self.clip_value is None else float(self.clip_value))

        def body(xi):
            return sample_scan(
                self.denoiser.call_masked, self.schedule, shape, rng,
                num_steps=self.num_steps, clip_value=self.clip_value,
                x_init=xi)

        def build():
            specs = ((jax.ShapeDtypeStruct(shape, jnp.float32),)
                     if compile_only else None)
            return self.engine.jitter(body, aot_specs=specs)

        return self.engine.program(key, build)

    def _sample_bucket(self, bucket: int, keys) -> np.ndarray:
        """Run one wave at a (padded) batch-bucket size."""
        x_init = self._init_noise(keys)
        shape = (bucket, self.store.dim)
        if self.mode == "plan":
            x = sample_plan(self.denoiser.call_masked, self.schedule, shape,
                            jax.random.PRNGKey(0), self.plan,
                            clip_value=self.clip_value, x_init=x_init,
                            program_cache=self.engine.program,
                            jitter=self.engine.jitter)
        elif self.mode == "scan":
            x = self._scan_program(shape)(x_init)
        else:                                # per-step static programs
            x = sample(self.denoiser, self.schedule, shape,
                       jax.random.PRNGKey(0), num_steps=self.num_steps,
                       clip_value=self.clip_value, x_init=x_init)
        return np.asarray(x).reshape((bucket,) + self.store.image_shape)

    def warmup(self) -> dict:
        """Precompile every (batch-bucket x shape-bucket) program before
        traffic; a warm engine never touches the compiler again
        (asserted by the CI recompile guard).  Returns compile stats.

        Plan/scan programs are AOT-compiled (``jit(...).lower(shape)
        .compile()``) — no trajectory executes, so warmup pays compile
        time only.  Static mode (and any mode under a mesh, where an
        AOT executable would pin input shardings) warms by running one
        trajectory per batch bucket instead."""
        n0 = len(self.engine._programs)
        t0 = time.time()
        aot = self.engine.mesh is None and self.mode in ("plan", "scan")
        if aot:
            # the samplers' key-schedule split runs tiny op-level
            # programs (threefry split/unstack) that AOT lowering never
            # exercises — flush them now so the first real wave is pure
            # execution
            _, _ = jax.random.split(jax.random.PRNGKey(0))
        for b in self.batch_buckets():
            keys = self._row_keys([], b)
            self._init_noise(keys)           # tiny per-bucket key program
            if not aot:
                self._sample_bucket(b, keys)
            elif self.mode == "plan":
                sample_plan(self.denoiser.call_masked, self.schedule,
                            (b, self.store.dim), jax.random.PRNGKey(0),
                            self.plan, clip_value=self.clip_value,
                            program_cache=self.engine.program,
                            compile_only=True, jitter=self.engine.jitter)
            else:
                self._scan_program((b, self.store.dim), compile_only=True)
        return {"programs_compiled": len(self.engine._programs) - n0,
                "batch_buckets": self.batch_buckets(),
                "shape_buckets": (self.plan.num_buckets if self.plan
                                  else (1 if self.mode == "scan"
                                        else self.num_steps)),
                "warmup_s": time.time() - t0}

    def serve(self, requests: Iterable[Request]) -> list[Result]:
        """Greedy batching: requests are packed up to max_batch per wave,
        each wave padded up to its power-of-two batch bucket.  Oversized
        requests are chunked across as many waves as they need — every
        requested image is delivered, and each row's noise stream stays
        tied to ``(seed, global row index)``, so chunking never changes
        a request's images."""
        reqs = list(requests)
        chunks = []                              # (req index, ofs, n)
        for ri, r in enumerate(reqs):
            ofs = 0
            while True:
                n = min(r.num_images - ofs, self.max_batch)
                chunks.append((ri, ofs, n))
                ofs += n
                if ofs >= r.num_images:
                    break
        parts = [[] for _ in reqs]
        lat = [0.0 for _ in reqs]
        queue = chunks
        while queue:
            wave, used = [], 0
            while queue and used + queue[0][2] <= self.max_batch:
                c = queue.pop(0)
                wave.append(c)
                used += c[2]
            if used == 0:        # only zero-image chunks: nothing to run
                continue
            bucket = self._bucket_for(used)
            keys = self._row_keys([(reqs[ri], ofs, n)
                                   for ri, ofs, n in wave], bucket)
            t0 = time.time()
            imgs = self._sample_bucket(bucket, keys)[:used]
            dt = time.time() - t0
            at = 0
            for ri, ofs, n in wave:
                parts[ri].append(imgs[at: at + n])
                lat[ri] += dt
                at += n
        out: list[Result] = []
        for ri, r in enumerate(reqs):
            imgs = (np.concatenate(parts[ri]) if parts[ri] else
                    np.zeros((0,) + self.store.image_shape, np.float32))
            out.append(Result(r.request_id, imgs, lat[ri]))
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar_like")
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--base", default="optimal",
                    choices=["optimal", "pca", "kamb"])
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--plan", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="bucketed trajectory plan (default); --no-plan "
                         "falls back to the single worst-case-padded "
                         "masked program")
    ap.add_argument("--buckets", type=int, default=None,
                    help="force at most this many shape buckets (floor: "
                         "one per indexed/exact routing region; default: "
                         "greedy merge under --threshold)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max padded-FLOP overhead per bucket")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip precompiling the (batch x shape) buckets")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing (engine spans, plan segments, "
                         "dispatch events) and dump the event log as "
                         "JSONL to PATH on exit")
    ap.add_argument("--metrics", action="store_true",
                    help="count dispatches/compiles per program kind and "
                         "print a Prometheus text snapshot on exit")
    args = ap.parse_args()

    tracer = (obs_trace.Tracer(capacity=1 << 16) if args.trace_out
              else obs_trace.NULL_TRACER)
    if args.trace_out or args.metrics:
        obs_trace.set_tracer(tracer)
        obs_trace.install_dispatch_tracing(
            tracer, obs_metrics.REGISTRY if args.metrics else None)

    mode = "auto"
    if args.base == "optimal":
        mode = "plan" if args.plan else "scan"
    eng = ServeEngine(args.dataset, {"n": args.n}, base=args.base,
                      num_steps=args.steps, max_batch=args.batch,
                      mode=mode, plan_threshold=args.threshold,
                      max_buckets=args.buckets)
    if eng.plan is not None:
        print(eng.plan.describe())
    if not args.no_warmup:
        stats = eng.warmup()
        print(f"warmup: {stats['programs_compiled']} programs "
              f"(batch buckets {stats['batch_buckets']} x "
              f"{stats['shape_buckets']} shape buckets) "
              f"in {stats['warmup_s']:.2f}s")
    reqs = [Request(i, args.batch, seed=100 + i) for i in range(args.requests)]
    t0 = time.time()
    results = eng.serve(reqs)
    total = time.time() - t0
    for r in results:
        print(f"request {r.request_id}: {r.images.shape} "
              f"batch-latency={r.latency_s:.2f}s "
              f"finite={np.isfinite(r.images).all()}")
    n_img = sum(r.images.shape[0] for r in results)
    print(f"served {n_img} images in {total:.2f}s "
          f"({total/max(n_img,1):.3f}s/image, {args.steps} steps)")
    if args.trace_out:
        tracer.dump(args.trace_out)
        print(f"trace: {len(tracer.events())} events "
              f"({tracer.dropped} dropped) -> {args.trace_out}")
    if args.metrics:
        print(obs_metrics.REGISTRY.prometheus(), end="")


if __name__ == "__main__":
    main()
