"""Fault-tolerant serving runtime over :class:`ServeEngine`.

``ServeEngine.serve`` is a synchronous batch call: it assumes every
dispatch succeeds, every output is finite, and nobody is waiting with a
deadline.  ``ServeRuntime`` wraps the same warmed engine in the
admission / scheduling / failure machinery a service actually needs:

* **admission control** — requests are validated (``validate_request``)
  and enter a bounded queue; a full queue rejects loudly
  (``QueueFullError``) instead of buffering without bound.
* **plan-seam scheduling** — a wave of co-batched requests advances one
  *trajectory-plan segment* at a time (the PR 5 bucket seams, via
  ``sampler.plan_segment``); between segments the scheduler can admit
  new waves, expire deadlined rows, and repack shrunken waves into
  smaller warmed batch buckets.  All of it happens at program
  boundaries, so the post-warmup zero-compile guarantee holds.
* **continuous batching** — waves are per-segment *row sets*, not
  lockstep cohorts: every part (one request's row block) carries its
  own trajectory cursor, and at each seam freed slots — from delivery,
  deadline expiry, or OOM splits — accept queued requests, each new
  part starting at cursor 0 while its wave-mates keep theirs.  A wave
  whose parts sit at different cursors runs the *mixed* segment program
  (``sampler.plan_segment_mixed``): the same bucket scan with a per-row
  activity mask, so only rows at the segment's entry seam advance and
  the rest pass through untouched.  Active rows are **bit-identical**
  to the plain per-bucket program (every engine op is row-independent),
  and the per-request ``fold_in(seed, row)`` noise streams make
  placement invisible — a request admitted mid-trajectory of another is
  bitwise equal to the same request served alone.  Mixed programs are
  warmed per (batch bucket x plan bucket x plan variant), so continuous
  admission never touches the compiler.  ``RuntimeConfig(
  continuous=False)`` restores wave-at-a-time admission (the
  ``benchmarks/serve_throughput.py`` baseline).
* **deadlines** — per-request (``Request.deadline_s``) or a default;
  expiry is checked at every seam *including final delivery*, so a
  completed request is structurally within its deadline and the
  reported p99 is bounded by it.
* **retries** — transient executor failures (``faults.RETRYABLE_ERRORS``
  — injected or real ``XlaRuntimeError``) retry with exponential
  backoff and deterministic jitter; a retry re-enters the dispatch seam
  so injected faults clear by their own seeded stream.
* **degradation ladder** — four circuit breakers map failure classes to
  cheaper-but-alive configurations, all precompiled by ``warmup()``:

  ======================  =============================================
  breaker (failure)       degraded rung while open
  ======================  =============================================
  ``screen`` (non-finite  exact-routing trajectory plan (indexed
  rows in a segment)      screening bypassed; same plan when the
                          engine has no index)
  ``compile`` (post-      ``scan`` mode: one whole-trajectory program
  warmup recompiles)      per batch bucket — no per-segment lookups to
                          storm
  ``oom`` (RESOURCE_      halved admission cap + half-``num_steps``
  EXHAUSTED)              plan; an OOM-ing wave also splits in two on
                          the spot
  ``exec`` (other         retries; after ``max_retries`` the segment
  transient errors)       falls back to the closed-form Gaussian
                          (Wiener) score — finite by construction
  ======================  =============================================

* **finite-output guard** — after every segment, rows that went
  non-finite are replaced with the Gaussian-fallback segment of the
  same rows (never delivered as NaN; trips the ``screen`` breaker).
* **zero-downtime hot-swap** — ``hot_swap(store, index)`` installs a
  new golden-store epoch (same shapes: the appendable lifecycle's
  capacity-padded invariant) into the warmed engine, probes it with an
  already-compiled segment on a throwaway input, and flips the serving
  epoch under the scheduler lock.  In-flight waves carry the epoch they
  were admitted under (``_Wave.epoch``; every segment dispatch is
  pinned via ``engine.at_epoch``), so a swap mid-trajectory changes
  nothing for running requests — and because compiled programs take the
  store operands as *arguments* (``engine.jitter``), the flip costs
  zero recompiles.  A probe failure (non-finite output or an executor
  error) quarantines the candidate epoch instead of serving it: the
  old epoch keeps serving, ``epoch_quarantined`` increments, and the
  swap raises :class:`EpochProbeError`.
* **observability** — ``health()`` snapshots queue depth, breaker
  states (plus cumulative open *dwell time* per breaker), degraded
  flags, counters, p50/p99 latency (from a bounded reservoir histogram,
  not an unbounded list) and the deadline-miss rate;
  ``metrics_snapshot()`` / ``prometheus()`` export the same state plus
  any attached :class:`repro.obs.QualityMonitor`'s recall/concentration
  metrics through a ``MetricsRegistry``.  When a tracer is enabled
  (``repro.obs.trace``), every request lifecycle edge — admit, queue
  expiry, wave admission, each segment (a span), retries, splits,
  repacks, Gaussian fallbacks, delivery — lands on the unified event
  schema, so a request's full history is reconstructable from the
  trace alone.  ``benchmarks/serve_resilience.py`` turns the same
  numbers into gated BENCH cells.

Single-threaded by design: ``pump()`` runs one scheduler step (admit ->
pick wave -> run one segment -> postprocess); ``run_until_idle()``
drains inline (tests, benchmarks); ``start()``/``stop()`` run the same
loop on a daemon thread.  A lock guards queue/wave state so submitters
on other threads stay safe, while segment execution happens outside it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_plan
from repro.core.denoisers import WienerDenoiser
from repro.core.sampler import (plan_segment, plan_segment_key,
                                plan_segment_mixed, plan_segment_mixed_key,
                                sample_plan)
from repro.core.schedules import sampling_timesteps
from repro.launch.faults import RETRYABLE_ERRORS, unit_uniform
from repro.launch.serve import Request, ServeEngine
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

_SALT_JITTER = 0xB0


class QueueFullError(RuntimeError):
    """Admission rejected: the bounded request queue is at capacity."""


class EpochProbeError(RuntimeError):
    """A hot-swap candidate epoch failed its pre-flip probe (non-finite
    output or executor error) and was quarantined; the previous epoch
    keeps serving."""


def validate_request(req: Request, max_images: int) -> None:
    """Admission-time validation with actionable errors (satellite 1).

    ``bool`` is an ``int`` subclass, so it is rejected explicitly —
    ``Request(0, True, 0)`` is a bug, not one image.
    """
    ni = req.num_images
    if isinstance(ni, bool) or not isinstance(ni, (int, np.integer)):
        raise ValueError(f"request {req.request_id}: num_images must be "
                         f"an int, got {type(ni).__name__}")
    if ni < 1:
        raise ValueError(f"request {req.request_id}: num_images must be "
                         f">= 1, got {ni}")
    if ni > max_images:
        raise ValueError(f"request {req.request_id}: num_images={ni} "
                         f"exceeds the per-request cap {max_images}")
    sd = req.seed
    if isinstance(sd, bool) or not isinstance(sd, (int, np.integer)):
        raise ValueError(f"request {req.request_id}: seed must be an "
                         f"int, got {type(sd).__name__}")
    if sd < 0:
        raise ValueError(f"request {req.request_id}: seed must be "
                         f">= 0, got {sd}")
    if req.deadline_s is not None and not float(req.deadline_s) > 0.0:
        raise ValueError(f"request {req.request_id}: deadline_s must be "
                         f"positive, got {req.deadline_s}")


@dataclasses.dataclass
class RuntimeConfig:
    """Knobs for the serving runtime (defaults are test-friendly).

    ``clock``/``sleep`` are injectable so deadline and backoff behavior
    is testable with a fake clock — production uses the monotonic
    clock.  ``seed`` drives the deterministic backoff jitter.
    """

    max_queue: int = 64
    max_images: int | None = None        # per-request cap; None -> max_batch
    default_deadline_s: float | None = None
    max_retries: int = 3
    backoff_base_s: float = 0.02
    backoff_max_s: float = 0.5
    jitter_frac: float = 0.25
    breaker_threshold: int = 3
    breaker_window_s: float = 30.0
    breaker_cooldown_s: float = 2.0
    max_inflight_waves: int = 2
    continuous: bool = True              # admit into in-flight waves at seams
    seed: int = 0
    idle_sleep_s: float = 0.005
    latency_reservoir: int = 1024        # bounded p50/p99 sample size
    clock: Callable[[], float] = time.monotonic
    sleep: Callable[[float], None] = time.sleep


@dataclasses.dataclass
class Ticket:
    """Handle returned by ``submit``; filled in as the request runs."""

    request: Request
    submitted_at: float
    expiry: float | None                 # absolute clock() time, or None
    status: str = "queued"               # queued|running|done|expired|failed
    images: np.ndarray | None = None
    latency_s: float | None = None
    degraded: bool = False               # any non-primary rung touched it


class CircuitBreaker:
    """Windowed failure counter with an open/half-open/closed state.

    ``threshold`` failures inside ``window_s`` open the breaker for
    ``cooldown_s``; after the cooldown it is half-open (the ladder
    resumes the primary rung as a probe) and one recorded success
    closes it.
    """

    def __init__(self, threshold: int, window_s: float, cooldown_s: float):
        self.threshold = threshold
        self.window_s = window_s
        self.cooldown_s = cooldown_s
        self.failures: list[float] = []
        self.open_until: float | None = None
        self._opened_at: float | None = None
        self._dwell_s = 0.0              # closed episodes' open+half-open time

    def record_failure(self, now: float) -> None:
        self.failures.append(now)
        self.failures = [t for t in self.failures
                         if t > now - self.window_s]
        if len(self.failures) >= self.threshold:
            if self._opened_at is None:
                self._opened_at = now
            self.open_until = now + self.cooldown_s

    def record_success(self, now: float) -> None:
        if self.open_until is not None and now >= self.open_until:
            self.open_until = None       # half-open probe succeeded
            self.failures = []
            if self._opened_at is not None:
                self._dwell_s += max(0.0, now - self._opened_at)
                self._opened_at = None

    def dwell_s(self, now: float) -> float:
        """Cumulative seconds spent not-closed (open or half-open): the
        degradation dwell time this breaker has imposed on the ladder."""
        d = self._dwell_s
        if self._opened_at is not None:
            d += max(0.0, now - self._opened_at)
        return d

    def state(self, now: float) -> str:
        if self.open_until is None:
            return "closed"
        return "open" if now < self.open_until else "half_open"

    def is_open(self, now: float) -> bool:
        return self.state(now) == "open"


class _ExactRouting:
    """Engine view with indexed screening forced off.

    ``build_plan`` duck-types its engine (sizes / use_index / schedule /
    store); presenting ``index = None`` and ``use_index() -> False``
    yields a plan whose every bucket routes the exact screen — the
    ``screen``-breaker rung.  On an engine without an index this
    produces the identical plan (and identical program keys), so the
    rung costs nothing to warm.
    """

    index = None

    def __init__(self, engine):
        object.__setattr__(self, "_eng", engine)

    def use_index(self, t) -> bool:
        return False

    def __getattr__(self, name):
        return getattr(self._eng, name)


@dataclasses.dataclass
class _Part:
    """One ticket's contiguous row block inside a wave.

    ``cursor`` is the index of the next plan segment this part will run
    (always a bucket seam: parts enter at 0 and only advance whole
    segments, so a part's rows are exactly at ``plan.buckets[cursor]
    .start`` on the timestep grid).  Under continuous admission parts at
    different cursors co-exist in one wave; a part whose cursor reaches
    ``num_segments`` is delivered and its rows compacted away, freeing
    slots for the queue."""

    ticket: Ticket
    n: int
    cursor: int = 0


@dataclasses.dataclass
class _Wave:
    """One co-batched row set advancing through segments.

    Not a lockstep cohort: each part carries its own segment cursor
    (see :class:`_Part`), ``ServeRuntime._pick_segment`` chooses which
    cursor group advances next, and rows whose part is frozen for a
    segment pass through the mixed program untouched.  ``x`` rows are
    prefix-packed in part order; rows past ``used`` are padding."""

    seq: int
    mode: str                            # "plan" | "scan"
    plan_name: str                       # primary|exact|short|short_exact|scan
    plan: object | None                  # TrajectoryPlan for mode == "plan"
    bucket: int                          # padded batch size (warmed)
    x: np.ndarray                        # [bucket, D] fp32 state
    parts: list[_Part]                   # prefix-packed row blocks
    epoch: int = 0                       # store epoch pinned for dispatches
    retries: int = 0
    degraded: bool = False
    degrade_reported: bool = False       # monitor.on_degrade fired once
    running: bool = False

    @property
    def used(self) -> int:
        return sum(p.n for p in self.parts)

    def num_segments(self) -> int:
        return self.plan.num_buckets if self.mode == "plan" else 1

    def cursors(self) -> list[int]:
        return sorted({p.cursor for p in self.parts})


class ServeRuntime:
    """Admission, deadlines, retries and the degradation ladder (see
    module docstring) around one warmed :class:`ServeEngine`."""

    def __init__(self, eng: ServeEngine, config: RuntimeConfig | None = None,
                 monitor=None,
                 registry: obs_metrics.MetricsRegistry | None = None):
        if eng.mode not in ("plan", "scan"):
            raise ValueError(f"ServeRuntime needs a plan- or scan-mode "
                             f"engine (got mode={eng.mode!r}); static "
                             f"mode has no shared segment seams")
        self.eng = eng
        self.engine = eng.engine         # core.GoldDiffEngine (prog cache)
        self.cfg = config or RuntimeConfig()
        self.max_images = (self.cfg.max_images if self.cfg.max_images
                           is not None else eng.max_batch)
        if self.max_images > eng.max_batch:
            raise ValueError(f"max_images={self.max_images} exceeds the "
                             f"engine's max_batch={eng.max_batch}; a "
                             f"runtime wave never chunks one request "
                             f"across waves")
        # -- degraded-plan variants (all warmed by ``warmup``)
        self.plans: dict[str, object] = {}
        if eng.mode == "plan":
            ns_short = max(2, eng.num_steps // 2)
            self.plans["primary"] = eng.plan
            if self.engine.index is not None:
                exact_view = _ExactRouting(self.engine)
                self.plans["exact"] = build_plan(exact_view, eng.num_steps)
                self.plans["short_exact"] = build_plan(exact_view, ns_short)
                self.plans["short"] = build_plan(self.engine, ns_short)
            else:
                self.plans["exact"] = eng.plan
                self.plans["short"] = build_plan(self.engine, ns_short)
                self.plans["short_exact"] = self.plans["short"]
        # -- breakers: one per failure class
        mk = lambda: CircuitBreaker(self.cfg.breaker_threshold,
                                    self.cfg.breaker_window_s,
                                    self.cfg.breaker_cooldown_s)
        self.br_exec = mk()
        self.br_screen = mk()
        self.br_oom = mk()
        self.br_compile = mk()
        # -- state
        self._lock = threading.RLock()
        self._queue: list[Ticket] = []
        self._waves: list[_Wave] = []
        self._seq = 0
        self._retry_seq = 0
        self._warm = False
        self._builds_warm = 0
        self._wiener: WienerDenoiser | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.counters = {k: 0 for k in (
            "submitted", "completed", "expired", "failed", "retries",
            "finite_trips", "gauss_segments", "oom_splits", "repacks",
            "joins", "mixed_segments",
            "scan_waves", "exact_waves", "short_waves",
            "hot_swaps", "epoch_quarantined")}
        # -- observability: bounded latency reservoir (replaces the old
        # unbounded list — O(reservoir) memory no matter the traffic),
        # optional QualityMonitor, and the registry exports go through
        self.monitor = monitor
        if registry is not None:
            self.registry = registry
        elif monitor is not None:
            self.registry = monitor.registry
        else:
            self.registry = obs_metrics.REGISTRY
        self._lat_hist = obs_metrics.Histogram(
            "serve_latency_seconds", "end-to-end request latency (s)",
            reservoir=self.cfg.latency_reservoir)
        self.registry.register(self._lat_hist)

    # -- Gaussian (Wiener) fallback programs ---------------------------------
    def _wiener_den(self) -> WienerDenoiser:
        if self._wiener is None:
            self._wiener = WienerDenoiser(self.eng.store, self.eng.schedule)
        return self._wiener

    def _gauss_program(self, bucket: int, nts: int):
        """Compiled closed-form-Gaussian DDIM segment for one batch
        bucket: ``fn(x, ts, start, stop)`` runs steps [start, stop) of a
        length-``nts`` timestep grid with the Wiener posterior mean as
        the denoiser.  Rank-limited SVD form — finite for every finite
        input, no data gathers, no screening: the ladder's last rung.

        The ``"gauss_seg"`` kind is deliberately NOT in the fault
        injector's default targets; a fallback that can itself be
        faulted is not a fallback.
        """
        den = self._wiener_den()
        sched = self.eng.schedule
        clip = self.eng.clip_value
        dim = self.eng.store.dim
        key = ("gauss_seg", bucket, dim, nts,
               None if clip is None else float(clip))

        def build():
            mu, V, lam = den.mu, den.V, den.lam
            a = jnp.asarray(sched.a)
            b = jnp.asarray(sched.b)

            def seg(x, ts, start, stop):
                def body(i, x):
                    t, tp = ts[i], ts[i + 1]
                    at, bt = a[t], b[t]
                    coeff = (at * lam) / (at * at * lam + bt * bt)
                    x0 = mu + (((x - at * mu) @ V) * coeff) @ V.T
                    if clip is not None:
                        x0 = jnp.clip(x0, -clip, clip)
                    eps = (x - at * x0) / bt
                    return a[tp] * x0 + b[tp] * eps
                return jax.lax.fori_loop(start, stop, body, x)

            return jax.jit(seg)

        return self.engine.program(key, build)

    def _mixed_program(self, batch: int, plan, pb, compile_only: bool = False):
        """Compiled mixed-cursor segment ``fn(x, pos)`` for one
        (batch bucket, plan bucket): ``sampler.plan_segment_mixed`` with
        ``pos`` the per-row int32 grid cursors (rows at
        ``pb.start`` advance; everything else — frozen parts, padding —
        passes through).  Warmed for every plan variant by ``warmup``,
        so mixed-cursor waves never compile post-warmup."""
        shape = (batch, self.eng.store.dim)
        clip = self.eng.clip_value
        key = plan_segment_mixed_key(plan, pb, shape, "float32", clip)

        def build():
            seg = plan_segment_mixed(self.eng.denoiser.call_masked,
                                     self.eng.schedule, plan, pb, clip)
            specs = ((jax.ShapeDtypeStruct(shape, jnp.float32),
                      jax.ShapeDtypeStruct((batch,), jnp.int32))
                     if compile_only else None)
            return self.engine.jitter(seg, aot_specs=specs)

        return self.engine.program(key, build)

    def _segment_grid(self, wave: _Wave, seg: int) -> tuple[tuple, int, int]:
        """(ts, start, stop) of the wave's segment ``seg``."""
        if wave.mode == "plan":
            b = wave.plan.buckets[seg]
            return tuple(wave.plan.ts), b.start, b.stop
        ts = tuple(int(t) for t in
                   sampling_timesteps(self.eng.schedule, self.eng.num_steps))
        return ts, 0, len(ts) - 1

    def _run_gauss(self, wave: _Wave, seg: int, x: np.ndarray) -> np.ndarray:
        ts, start, stop = self._segment_grid(wave, seg)
        fn = self._gauss_program(wave.bucket, len(ts))
        out = fn(jnp.asarray(x), jnp.asarray(ts, jnp.int32),
                 np.int32(start), np.int32(stop))
        self.counters["gauss_segments"] += 1
        return np.asarray(jax.block_until_ready(out), np.float32)

    # -- warmup ---------------------------------------------------------------
    def warmup(self) -> dict:
        """Precompile every rung of the ladder for every batch bucket:
        the engine's own programs, the degraded plan variants, the
        scan-mode programs, and the Gaussian fallback segments.  After
        this, NO failure path touches the compiler (``health()`` tracks
        ``compiles_post_warmup`` via the engine's build counter, which
        counts evict-driven rebuilds a cache-size delta would miss)."""
        t0 = time.time()
        stats = self.eng.warmup()
        aot = self.engine.mesh is None
        dim = self.eng.store.dim
        call_masked = self.eng.denoiser.call_masked \
            if self.eng._scan_compatible() else None
        nts_set = {self.eng.num_steps + 1}
        for p in self.plans.values():
            nts_set.add(len(p.ts))
        for b in self.eng.batch_buckets():
            shape = (b, dim)
            if call_masked is not None:
                # the scan rung (plan-mode engines don't warm it)
                fn = self.eng._scan_program(shape, compile_only=aot)
                if not aot:
                    jax.block_until_ready(fn(jnp.zeros(shape, jnp.float32)))
            seen = {id(self.eng.plan)} if self.eng.mode == "plan" else set()
            for plan in self.plans.values():
                if id(plan) in seen:
                    continue
                seen.add(id(plan))
                sample_plan(call_masked, self.eng.schedule, shape,
                            jax.random.PRNGKey(0), plan,
                            clip_value=self.eng.clip_value,
                            x_init=(None if aot
                                    else jnp.zeros(shape, jnp.float32)),
                            program_cache=self.engine.program,
                            compile_only=aot, jitter=self.engine.jitter)
            # mixed-cursor (continuous-batching) segments: one program
            # per plan bucket per plan variant — including the primary
            # plan, whose PLAIN segments eng.warmup() already compiled
            seen_mix: set[int] = set()
            for plan in (self.plans.values()
                         if self.eng.mode == "plan" else ()):
                if id(plan) in seen_mix:
                    continue
                seen_mix.add(id(plan))
                for pb in plan.buckets:
                    fn = self._mixed_program(b, plan, pb, compile_only=aot)
                    if not aot:
                        jax.block_until_ready(fn(
                            jnp.zeros(shape, jnp.float32),
                            jnp.full((b,), pb.start, jnp.int32)))
            for nts in sorted(nts_set):
                ts = np.arange(nts, dtype=np.int32)[::-1].copy()
                ts = ts * 0 + 1              # any valid grid; compile only
                fn = self._gauss_program(b, nts)
                jax.block_until_ready(
                    fn(jnp.zeros(shape, jnp.float32),
                       jnp.asarray(ts, jnp.int32), np.int32(0), np.int32(1)))
        if self.monitor is not None:
            # recall probes fire at executed-step timesteps of any plan
            # variant (and the scan grid): warm every one of them so
            # monitoring never costs a post-warmup compile
            probe_ts: set[int] = set()
            for p in self.plans.values():
                probe_ts.update(int(t) for t in p.ts[:-1])
            scan_ts = sampling_timesteps(self.eng.schedule,
                                         self.eng.num_steps)
            probe_ts.update(int(t) for t in scan_ts[:-1])
            stats["probe_ts_warmed"] = self.monitor.warmup(sorted(probe_ts))
        self._warm = True
        self._builds_warm = self.engine._builds
        stats["runtime_warmup_s"] = time.time() - t0
        stats["programs_total"] = len(self.engine._programs)
        return stats

    # -- store hot-swap -------------------------------------------------------
    def _probe_epoch(self, epoch: int) -> None:
        """Dry-run one *already-warmed* program pinned at ``epoch`` on a
        throwaway zero input and require finite output.  Same shapes ->
        same compiled executable, so the probe costs zero compiles and
        exercises the new operands end to end (screen, re-rank,
        aggregate) before any user row ever touches them."""
        b = self.eng.batch_buckets()[0]
        shape = (b, self.eng.store.dim)
        x = jnp.zeros(shape, jnp.float32)
        with self.engine.at_epoch(epoch):
            if self.eng.mode == "plan":
                pb = self.eng.plan.buckets[0]
                key = plan_segment_key(self.eng.plan, pb, shape, "float32",
                                       self.eng.clip_value)
                fn = self.engine.program(key, lambda: self.engine.jitter(
                    plan_segment(self.eng.denoiser.call_masked,
                                 self.eng.schedule, self.eng.plan, pb,
                                 self.eng.clip_value)))
            else:
                fn = self.eng._scan_program(shape)
            out = np.asarray(jax.block_until_ready(fn(x)))
        if not np.isfinite(out).all():
            raise EpochProbeError(
                f"epoch {epoch} probe produced non-finite output "
                f"({int((~np.isfinite(out)).sum())} bad values)")

    def hot_swap(self, store, index=None, epoch: int | None = None,
                 probe: bool = True) -> int:
        """Swap the serving golden store without downtime or recompiles.

        Installs ``(store, index)`` as a standby epoch in the warmed
        engine (same-shape contract enforced by ``engine.swap_compat``
        — the appendable lifecycle's capacity-padded views satisfy it
        by construction), probes it (:meth:`_probe_epoch`), then flips
        the serving epoch under the scheduler lock.  Waves admitted
        before the flip finish on their own epoch (``_Wave.epoch``);
        waves admitted after see the new store.  A failed probe
        quarantines the epoch — it is retired, ``epoch_quarantined``
        increments, :class:`EpochProbeError` propagates, and the old
        epoch keeps serving untouched.

        Returns the installed epoch id (``epoch`` if given — e.g. the
        lifecycle's on-disk epoch number — else the next free int).
        """
        tr = obs_trace.tracer()
        with self._lock:
            if epoch is None:
                epoch = max(self.engine._epochs) + 1
            epoch = int(epoch)
            if epoch == self.engine.serving_epoch:
                raise ValueError(f"epoch {epoch} is already serving")
            self.engine.install_epoch(epoch, store, index)
        if probe:
            try:
                self._probe_epoch(epoch)
            except (EpochProbeError, *RETRYABLE_ERRORS) as e:
                with self._lock:
                    self.engine.retire_epoch(epoch)
                    self.counters["epoch_quarantined"] += 1
                if tr.enabled:
                    tr.event("epoch.quarantine", epoch=epoch,
                             error=type(e).__name__)
                if isinstance(e, EpochProbeError):
                    raise
                raise EpochProbeError(
                    f"epoch {epoch} probe failed: {e}") from e
        with self._lock:
            prev = self.engine.serving_epoch
            self.engine.set_serving_epoch(epoch)
            self.counters["hot_swaps"] += 1
            self._gc_epochs()
        if tr.enabled:
            tr.event("epoch.swap", epoch=epoch, prev=prev)
        return epoch

    def _gc_epochs(self) -> None:
        """Retire standby epochs no in-flight wave references (caller
        holds the lock).  Serving and wave-pinned epochs survive; the
        rest free their device operands."""
        live = {w.epoch for w in self._waves}
        live.add(self.engine.serving_epoch)
        for e in [e for e in self.engine._epochs if e not in live]:
            self.engine.retire_epoch(e)

    # -- admission ------------------------------------------------------------
    def submit(self, req: Request) -> Ticket:
        """Validate + enqueue; raises ``ValueError`` (bad request) or
        ``QueueFullError`` (admission control) instead of accepting
        work it cannot serve."""
        validate_request(req, self.max_images)
        with self._lock:
            if len(self._queue) >= self.cfg.max_queue:
                raise QueueFullError(
                    f"queue at capacity ({self.cfg.max_queue}); retry "
                    f"after the backlog drains")
            now = self.cfg.clock()
            dl = req.deadline_s if req.deadline_s is not None \
                else self.cfg.default_deadline_s
            t = Ticket(request=req, submitted_at=now,
                       expiry=None if dl is None else now + float(dl))
            self._queue.append(t)
            self.counters["submitted"] += 1
            tr = obs_trace.tracer()
            if tr.enabled:
                tr.event("request.admit", request=req.request_id,
                         images=int(req.num_images),
                         queue_depth=len(self._queue))
            return t

    def _expire_queued(self, now: float) -> None:
        keep = []
        tr = obs_trace.tracer()
        for t in self._queue:
            if t.expiry is not None and now > t.expiry:
                t.status = "expired"
                self.counters["expired"] += 1
                if tr.enabled:
                    tr.event("request.expire", request=t.request.request_id,
                             phase="queued")
            else:
                keep.append(t)
        self._queue = keep

    def _pick_rung(self, now: float) -> tuple[str, str, object, int]:
        """(mode, plan_name, plan, admission cap) for a new wave, by
        breaker state.  Precedence: recompile storms force scan mode
        (fewest cache lookups); OOM halves admission and steps; a
        tripped screen guard forces exact routing."""
        cap = self.eng.max_batch
        if self.eng.mode == "scan" or self.br_compile.is_open(now):
            return "scan", "scan", None, cap
        oom = self.br_oom.is_open(now)
        if oom:
            cap = max(1, self.eng.max_batch // 2)
        base = "short" if oom else "primary"
        if self.br_screen.is_open(now):
            base = {"primary": "exact", "short": "short_exact"}[base]
        return "plan", base, self.plans[base], cap

    def _admit(self, now: float) -> None:
        """Seam admission: fill freed slots in in-flight waves first
        (continuous batching — joined parts start at cursor 0 while
        their wave-mates keep theirs), then open new waves while the
        in-flight cap allows.

        ``request.admit`` fires exactly once, at ``submit`` time: a
        request that waits across many seams is neither re-counted nor
        re-traced here — joins emit ``wave.join`` and new waves emit
        ``wave.admit``, so per-request admit metrics stay single-count
        no matter how many seams it sat through."""
        if not self._queue:
            return
        mode, name, plan, cap = self._pick_rung(now)
        if self.cfg.continuous and mode == "plan":
            for w in self._waves:
                if not self._queue:
                    return
                if w.running or w.mode != "plan" or w.plan_name != name:
                    continue             # never mix plan variants in a wave
                if w.epoch != self.engine.serving_epoch:
                    continue             # one epoch per wave: joiners must
                self._join_wave(w, cap, now)  # see the serving store
        while self._queue and len(self._waves) < self.cfg.max_inflight_waves:
            parts: list[_Part] = []
            used = 0
            while self._queue and \
                    used + self._queue[0].request.num_images <= cap:
                t = self._queue.pop(0)
                t.status = "running"
                parts.append(_Part(t, t.request.num_images))
                used += t.request.num_images
            if not parts:
                return                   # head request exceeds current cap
            bucket = self.eng._bucket_for(used)
            keys = self.eng._row_keys(
                [(p.ticket.request, 0, p.n) for p in parts], bucket)
            x = np.asarray(jax.block_until_ready(
                self.eng._init_noise(keys)), np.float32)
            wave = _Wave(seq=self._seq, mode=mode, plan_name=name,
                         plan=plan, bucket=bucket, x=x, parts=parts,
                         epoch=self.engine.serving_epoch,
                         degraded=(name not in ("primary",)
                                   and self.eng.mode != "scan"))
            self._seq += 1
            if name == "scan" and self.eng.mode != "scan":
                self.counters["scan_waves"] += 1
            elif name in ("exact", "short_exact"):
                self.counters["exact_waves"] += 1
            if name in ("short", "short_exact"):
                self.counters["short_waves"] += 1
            self._waves.append(wave)
            tr = obs_trace.tracer()
            if tr.enabled:
                tr.event("wave.admit", wave=wave.seq, mode=mode, plan=name,
                         bucket=bucket, used=used,
                         requests=[p.ticket.request.request_id
                                   for p in parts])

    def _join_wave(self, wave: _Wave, cap: int, now: float) -> None:
        """Admit queued requests into a freed slot of an in-flight wave.

        The joining part starts its own trajectory at cursor 0; its
        terminal noise comes from the request's own ``fold_in(seed,
        row)`` stream via the same warmed per-bucket programs solo
        admission uses, so the rows are bitwise identical to the ones
        the request would get in a fresh wave.  The wave's batch bucket
        grows to the smallest warmed bucket that fits (a repack — the
        mirror image of deadline compaction's shrink)."""
        joined: list[_Part] = []
        used = wave.used
        while self._queue and \
                used + self._queue[0].request.num_images <= cap:
            t = self._queue.pop(0)
            t.status = "running"
            joined.append(_Part(t, t.request.num_images))
            used += t.request.num_images
        if not joined:
            return
        tr = obs_trace.tracer()
        bucket = self.eng._bucket_for(used)
        if bucket > wave.bucket:
            x = np.zeros((bucket, wave.x.shape[1]), np.float32)
            x[: wave.used] = wave.x[: wave.used]
            self.counters["repacks"] += 1
            if tr.enabled:
                tr.event("wave.repack", wave=wave.seq, bucket=bucket,
                         prev_bucket=wave.bucket, used=wave.used)
            wave.x, wave.bucket = x, bucket
        if not wave.x.flags.writeable:   # zero-copy view of a device
            wave.x = np.array(wave.x)    # buffer: copy before writing
        ofs = wave.used
        for p in joined:
            keys = self.eng._row_keys([(p.ticket.request, 0, p.n)],
                                      self.eng._bucket_for(p.n))
            rows = np.asarray(jax.block_until_ready(
                self.eng._init_noise(keys)), np.float32)[: p.n]
            wave.x[ofs: ofs + p.n] = rows
            wave.parts.append(p)
            self.counters["joins"] += 1
            if tr.enabled:
                tr.event("wave.join", wave=wave.seq,
                         request=p.ticket.request.request_id,
                         rows=p.n, slot=ofs, cursor=0,
                         queue_wait_s=now - p.ticket.submitted_at)
            ofs += p.n

    def _pick_wave(self, now: float) -> _Wave | None:
        """Earliest-deadline-first over waves, FIFO on ties."""
        cands = [w for w in self._waves if not w.running]
        if not cands:
            return None

        def urgency(w: _Wave):
            exps = [p.ticket.expiry for p in w.parts
                    if p.ticket.expiry is not None]
            return (min(exps) if exps else float("inf"), w.seq)

        return min(cands, key=urgency)

    def _pick_segment(self, wave: _Wave) -> int:
        """Which cursor group advances next: earliest deadline first
        (deadline correctness dominates), ties to the SMALLEST cursor —
        catch-up-and-merge scheduling.  Freezing the front group while
        fresh joiners replay the early buckets lets trailing cursors
        *reach* leading ones; parts at equal cursors automatically run
        as one dispatch from then on (``_pos_rows`` activates every
        part at the picked seam), so converging trajectories coalesce
        and share all remaining segments.  That coalescing — more rows
        per dispatch, fewer dispatches per request — is where continuous
        batching beats wave-at-a-time under sustained load
        (``benchmarks/serve_throughput.py``); draining the front group
        first would keep every join in its own private dispatch stream.
        No group starves: parts only enter at cursor 0, cursors only
        increase, and a trailing group either merges into the group
        ahead of it or leaves the wave within ``num_segments`` picks."""
        if wave.mode != "plan":
            return 0
        best, best_key = 0, None
        for c in wave.cursors():
            exps = [p.ticket.expiry for p in wave.parts
                    if p.cursor == c and p.ticket.expiry is not None]
            k = (min(exps) if exps else float("inf"), c)
            if best_key is None or k < best_key:
                best, best_key = c, k
        return best

    def _pos_rows(self, wave: _Wave, seg: int) -> tuple[np.ndarray,
                                                        np.ndarray]:
        """Per-row grid cursors + activity mask for segment ``seg``:
        ``pos[r]`` is the timestep-grid index row r sits at (its part's
        bucket seam); rows are active iff that seam is this segment's
        entry.  Padding rows get -1, which never matches a seam."""
        pos = np.full((wave.bucket,), -1, np.int32)
        ofs = 0
        for p in wave.parts:
            pos[ofs: ofs + p.n] = wave.plan.buckets[p.cursor].start
            ofs += p.n
        return pos, pos == wave.plan.buckets[seg].start

    # -- segment execution (outside the lock) ---------------------------------
    def _segment_fn(self, wave: _Wave, seg: int, mixed: bool):
        if wave.mode == "scan":
            return self.eng._scan_program((wave.bucket, self.eng.store.dim))
        plan, b = wave.plan, wave.plan.buckets[seg]
        if mixed:
            return self._mixed_program(wave.bucket, plan, b)
        clip = self.eng.clip_value
        key = plan_segment_key(plan, b, (wave.bucket, self.eng.store.dim),
                               "float32", clip)
        return self.engine.program(key, lambda: self.engine.jitter(
            plan_segment(self.eng.denoiser.call_masked, self.eng.schedule,
                         plan, b, clip)))

    def _backoff(self, attempt: int) -> None:
        self._retry_seq += 1
        u = unit_uniform(self.cfg.seed, self._retry_seq, _SALT_JITTER)
        d = min(self.cfg.backoff_max_s,
                self.cfg.backoff_base_s * (2.0 ** (attempt - 1)))
        self.cfg.sleep(max(0.0, d * (1.0 + self.cfg.jitter_frac
                                     * (2.0 * u - 1.0))))

    @staticmethod
    def _is_oom(msg: str) -> bool:
        m = msg.lower()
        return "resource_exhausted" in m or "out of memory" in m \
            or "out-of-memory" in m

    def _run_segment(self, wave: _Wave, seg: int):
        """Run segment ``seg`` of the wave with retries, the OOM split
        escape hatch, and the Gaussian fallback.  Returns
        ``("ok", new_x)`` or ``("split", None)``.  With tracing enabled
        the whole attempt loop runs inside a ``wave.segment`` span whose
        ``cursor``/``active``/``frozen`` tags record which rows advanced
        (``scripts/trace_latency.py`` reconstructs per-request
        queue/compute timelines from them)."""
        tr = obs_trace.tracer()
        # every dispatch of this wave resolves operands from the epoch
        # it was admitted under — a hot_swap between its seams changes
        # nothing for it (the swap's whole zero-downtime contract)
        if not tr.enabled:
            with self.engine.at_epoch(wave.epoch):
                return self._run_segment_inner(wave, seg, tr)
        ts, start, stop = self._segment_grid(wave, seg)
        n_act = wave.used
        if wave.mode == "plan":
            _, act = self._pos_rows(wave, seg)
            n_act = int(act[: wave.used].sum())
        with tr.span("wave.segment", wave=wave.seq, cursor=seg,
                     mode=wave.mode, plan=wave.plan_name,
                     bucket=wave.bucket, used=wave.used,
                     active=n_act, frozen=wave.used - n_act,
                     start=start, stop=stop, epoch=wave.epoch):
            with self.engine.at_epoch(wave.epoch):
                return self._run_segment_inner(wave, seg, tr)

    def _run_segment_inner(self, wave: _Wave, seg: int, tr):
        x_prev = wave.x
        mixed = False
        act = np.ones(wave.bucket, bool)
        if wave.mode == "plan":
            pos, act = self._pos_rows(wave, seg)
            # an aligned wave (every part at this seam) runs the PLAIN
            # per-bucket program — bit-identical to wave-at-a-time and
            # to ServeEngine.serve; the mixed program only dispatches
            # when cursors actually diverge
            mixed = not bool(act[: wave.used].all())
        attempt = 0
        while True:
            builds0 = self.engine._builds
            try:
                if mixed:
                    fn = self._segment_fn(wave, seg, True)
                    self.counters["mixed_segments"] += 1
                    out = fn(jnp.asarray(x_prev), jnp.asarray(pos))
                else:
                    fn = self._segment_fn(wave, seg, False)
                    out = fn(jnp.asarray(x_prev))
                out = np.asarray(jax.block_until_ready(out), np.float32)
                if self.engine._builds > builds0 and self._warm:
                    # evict-then-rebuild storms recompile without
                    # changing the cache size; the build counter sees
                    # them and arms the scan-mode rung
                    self.br_compile.record_failure(self.cfg.clock())
                else:
                    self.br_compile.record_success(self.cfg.clock())
                break
            except RETRYABLE_ERRORS as e:
                now = self.cfg.clock()
                oom = self._is_oom(str(e))
                if tr.enabled:
                    tr.event("wave.retry", wave=wave.seq, attempt=attempt,
                             oom=oom, error=type(e).__name__)
                if oom:
                    self.br_oom.record_failure(now)
                    if wave.bucket > 1:
                        return "split", None
                else:
                    self.br_exec.record_failure(now)
                attempt += 1
                self.counters["retries"] += 1
                wave.retries += 1
                if attempt > self.cfg.max_retries:
                    if tr.enabled:
                        tr.event("wave.gauss_fallback", wave=wave.seq,
                                 cursor=seg)
                    out = self._run_gauss(wave, seg, x_prev)
                    if wave.mode == "plan":
                        # frozen rows stay frozen: the Gaussian segment
                        # ran THIS segment's grid span, which only the
                        # active rows are at
                        out = np.where(act[:, None], out, x_prev)
                    wave.degraded = True
                    break
                self._backoff(attempt)
        # per-row finite guard: never let NaN/inf cross a seam.  Frozen
        # rows are untouched copies of state that already passed this
        # guard, so only active rows can trip it (and only active rows
        # may take the Gaussian replacement — it ran this segment's
        # span, not theirs).
        used = wave.used
        row_ok = np.isfinite(out[:used]).all(axis=1) | ~act[:used]
        if not row_ok.all():
            nbad = int((~row_ok).sum())
            self.counters["finite_trips"] += nbad
            if self.monitor is not None:
                self.monitor.on_finite_trips(nbad)
            if tr.enabled:
                tr.event("wave.finite_trip", wave=wave.seq, rows=nbad)
            self.br_screen.record_failure(self.cfg.clock())
            gauss = self._run_gauss(wave, seg, x_prev)
            bad = np.flatnonzero(~row_ok)
            if not out.flags.writeable:
                out = np.array(out)
            out[bad] = gauss[bad]
            wave.degraded = True
        else:
            self.br_screen.record_success(self.cfg.clock())
            self.br_exec.record_success(self.cfg.clock())
        return "ok", out

    # -- post-segment bookkeeping (under the lock) ----------------------------
    def _split(self, wave: _Wave) -> None:
        """Halve an OOM-ing wave into two waves on warmed smaller
        buckets, preserving per-ticket row blocks and each part's own
        segment cursor (children of a mixed-cursor wave stay mixed)."""
        self.counters["oom_splits"] += 1
        half, first, second, acc = wave.used / 2.0, [], [], 0
        for p in wave.parts:
            (first if acc < half else second).append(p)
            acc += p.n
        if not second:                   # single ticket: move it wholesale
            second = [first.pop()]
        self._waves.remove(wave)
        ofs = 0
        for parts in (first, second):
            if not parts:
                continue
            used = sum(p.n for p in parts)
            bucket = self.eng._bucket_for(used)
            x = np.zeros((bucket, wave.x.shape[1]), np.float32)
            x[:used] = wave.x[ofs: ofs + used]
            ofs += used
            self._waves.append(_Wave(
                seq=self._seq, mode=wave.mode, plan_name=wave.plan_name,
                plan=wave.plan, bucket=bucket, x=x, parts=parts,
                epoch=wave.epoch, retries=wave.retries, degraded=True))
            tr = obs_trace.tracer()
            if tr.enabled:
                tr.event("wave.split", wave=wave.seq, child=self._seq,
                         bucket=bucket, used=used)
            self._seq += 1

    def _deliver_part(self, wave: _Wave, p: _Part, ofs: int,
                      now: float) -> None:
        """Deliver one completed part.  The delivery-time deadline check
        keeps the "completed implies within deadline" invariant; ``ofs``
        is the part's row slot in the wave (the ``slot`` trace tag)."""
        shape = self.eng.store.image_shape
        tr = obs_trace.tracer()
        t = p.ticket
        rows = wave.x[ofs: ofs + p.n]
        if t.expiry is not None and now > t.expiry:
            t.status = "expired"         # strict: late even at the end
            self.counters["expired"] += 1
            if tr.enabled:
                tr.event("request.expire",
                         request=t.request.request_id, phase="deliver")
            return
        if not np.isfinite(rows).all():         # unreachable by design;
            t.status = "failed"                 # belt over the suspenders
            self.counters["failed"] += 1
            if tr.enabled:
                tr.event("request.failed",
                         request=t.request.request_id)
            return
        t.images = rows.reshape((p.n,) + tuple(shape)).copy()
        t.latency_s = now - t.submitted_at
        t.degraded = t.degraded or wave.degraded
        t.status = "done"
        self.counters["completed"] += 1
        self._lat_hist.observe(t.latency_s)
        if tr.enabled:
            tr.event("request.deliver", request=t.request.request_id,
                     wave=wave.seq, slot=ofs, latency_s=t.latency_s,
                     degraded=t.degraded)

    def _drop_parts(self, wave: _Wave, drop: set, now: float) -> bool:
        """Remove parts (by ``id``) from a wave — delivered or expired —
        compact survivors' rows to the prefix, and repack to the
        smallest warmed bucket that still fits (slots freed here are
        what ``_join_wave`` refills at the next seam).  Returns True if
        the wave emptied and was removed."""
        alive = [p for p in wave.parts if id(p) not in drop]
        if not alive:
            self._waves.remove(wave)
            return True
        keep = np.zeros(wave.used, bool)
        ofs = 0
        for p in wave.parts:
            if id(p) not in drop:
                keep[ofs: ofs + p.n] = True
            ofs += p.n
        used = int(keep.sum())
        bucket = self.eng._bucket_for(used)
        x = np.zeros((bucket, wave.x.shape[1]), np.float32)
        x[:used] = wave.x[: len(keep)][keep]
        if bucket < wave.bucket:
            self.counters["repacks"] += 1
            tr = obs_trace.tracer()
            if tr.enabled:
                tr.event("wave.repack", wave=wave.seq,
                         bucket=bucket, prev_bucket=wave.bucket,
                         used=used)
        wave.x, wave.bucket, wave.parts = x, bucket, alive
        return False

    def _post_segment(self, wave: _Wave, seg: int, result) -> None:
        status, out = result
        now = self.cfg.clock()
        if status == "split":
            self._split(wave)
            return
        if self.monitor is not None:
            ts, start, stop = self._segment_grid(wave, seg)
            for i in range(start, stop):
                self.monitor.record_step(int(ts[i]))
            self.monitor.maybe_probe_recall(out[:wave.used],
                                            int(ts[stop - 1]))
        wave.x = out
        nseg = wave.num_segments()
        for p in wave.parts:
            if wave.mode != "plan":
                p.cursor = nseg          # scan: whole trajectory in one go
            elif p.cursor == seg:
                p.cursor = seg + 1
        done_ids, ofs = set(), 0
        for p in wave.parts:
            if p.cursor >= nseg:
                self._deliver_part(wave, p, ofs, now)
                done_ids.add(id(p))
            ofs += p.n
        if done_ids:
            if wave.degraded and not wave.degrade_reported \
                    and self.monitor is not None:
                wave.degrade_reported = True
                self.monitor.on_degrade()
            if self._drop_parts(wave, done_ids, now):
                return
        self._compact_expired(wave, now)

    def _compact_expired(self, wave: _Wave, now: float) -> bool:
        """Bucket-seam deadline enforcement: expire deadlined tickets,
        compact survivors to the prefix, repack to a smaller warmed
        bucket when possible.  Returns True if the whole wave died."""
        drop: set = set()
        tr = obs_trace.tracer()
        for p in wave.parts:
            if p.ticket.expiry is not None and now > p.ticket.expiry:
                p.ticket.status = "expired"
                self.counters["expired"] += 1
                drop.add(id(p))
                if tr.enabled:
                    tr.event("request.expire",
                             request=p.ticket.request.request_id,
                             phase="seam", wave=wave.seq)
        if not drop:
            return False
        return self._drop_parts(wave, drop, now)

    # -- scheduler loop -------------------------------------------------------
    def pump(self) -> bool:
        """One scheduler step.  Returns True if a segment ran."""
        with self._lock:
            now = self.cfg.clock()
            self._expire_queued(now)
            # pre-admission seam: rows already past their deadline are
            # dropped BEFORE admission, so the slots they free (and the
            # smaller repacked buckets) are joinable at this very seam
            for w in list(self._waves):
                if not w.running:
                    self._compact_expired(w, now)
            self._admit(now)
            wave = self._pick_wave(now)
            if wave is None:
                return False
            seg = self._pick_segment(wave)
            wave.running = True
        try:
            result = self._run_segment(wave, seg)
        finally:
            with self._lock:
                wave.running = False
        with self._lock:
            self._post_segment(wave, seg, result)
            self._gc_epochs()            # waves done on an old epoch may
        return True                      # have been its last reference

    def run_until_idle(self, max_iters: int = 100_000) -> None:
        """Drain the queue and all in-flight waves inline.

        Audited for continuous admission: a queue that refills at every
        seam cannot starve the idle condition, because ``pump`` returns
        True whenever ANY segment ran — the sleep branch below is
        reached only when nothing was runnable at all (the head request
        exceeds a degraded admission cap while no wave has work), never
        merely because admission kept finding fresh joins.  Each pump
        that admits also advances a cursor group, and every group is
        finitely many segments from delivery, so with a finite queue the
        loop strictly consumes work."""
        for _ in range(max_iters):
            if not self.pump():
                with self._lock:
                    if not self._queue and not self._waves:
                        return
                # stalled but not idle: the head request exceeds a
                # degraded admission cap — wait out the breaker cooldown
                # instead of spinning through the iteration budget
                self.cfg.sleep(self.cfg.idle_sleep_s)
        raise RuntimeError(f"runtime did not go idle in {max_iters} "
                           f"pump iterations")

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.pump():
                    self._stop.wait(self.cfg.idle_sleep_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="serve-runtime")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- observability --------------------------------------------------------
    def health(self) -> dict:
        with self._lock:
            now = self.cfg.clock()
            finished = (self.counters["completed"]
                        + self.counters["expired"] + self.counters["failed"])
            h = {
                "queue_depth": len(self._queue),
                "inflight_waves": len(self._waves),
                "breaker_exec": self.br_exec.state(now),
                "breaker_screen": self.br_screen.state(now),
                "breaker_oom": self.br_oom.state(now),
                "breaker_compile": self.br_compile.state(now),
                "dwell_exec_s": self.br_exec.dwell_s(now),
                "dwell_screen_s": self.br_screen.dwell_s(now),
                "dwell_oom_s": self.br_oom.dwell_s(now),
                "dwell_compile_s": self.br_compile.dwell_s(now),
                "degraded_scan_mode": (self.eng.mode == "plan"
                                       and self.br_compile.is_open(now)),
                "degraded_exact_screen": self.br_screen.is_open(now),
                "degraded_reduced_batch": self.br_oom.is_open(now),
                "compiles_post_warmup": (self.engine._builds
                                         - self._builds_warm
                                         if self._warm else 0),
                "serving_epoch": self.engine.serving_epoch,
                "epochs_resident": len(self.engine._epochs),
                "p50_ms": self._lat_hist.quantile(0.5) * 1e3,
                "p95_ms": self._lat_hist.quantile(0.95) * 1e3,
                "p99_ms": self._lat_hist.quantile(0.99) * 1e3,
                "latency_samples": self._lat_hist.count,
                "deadline_miss_rate": (self.counters["expired"] / finished
                                       if finished else 0.0),
                **{f"n_{k}": v for k, v in self.counters.items()},
            }
            if self.monitor is not None:
                h.update(self.monitor.health())
            return h

    def _sync_registry(self, now: float) -> None:
        """Mirror runtime-local state (counters, breakers, queue) into
        ``self.registry`` so one registry export carries the whole
        stack's metrics (monitor metrics already live there; the
        latency histogram was registered at construction)."""
        reg = self.registry
        for k, v in self.counters.items():
            reg.gauge(f"serve_{k}_total").set(v)
        reg.gauge("serve_queue_depth").set(len(self._queue))
        reg.gauge("serve_inflight_waves").set(len(self._waves))
        reg.gauge("serve_compiles_post_warmup").set(
            self.engine._builds - self._builds_warm if self._warm else 0)
        reg.gauge("serve_serving_epoch").set(self.engine.serving_epoch)
        reg.gauge("serve_epochs_resident").set(len(self.engine._epochs))
        for name, br in (("exec", self.br_exec),
                         ("screen", self.br_screen),
                         ("oom", self.br_oom),
                         ("compile", self.br_compile)):
            reg.gauge(f"serve_breaker_{name}_open").set(
                1.0 if br.is_open(now) else 0.0)
            reg.gauge(f"serve_breaker_{name}_dwell_seconds").set(
                br.dwell_s(now))

    def metrics_snapshot(self) -> dict:
        """JSON-friendly dict of every metric in the registry."""
        with self._lock:
            self._sync_registry(self.cfg.clock())
        return self.registry.snapshot()

    def prometheus(self) -> str:
        """Prometheus text exposition of the same registry."""
        with self._lock:
            self._sync_registry(self.cfg.clock())
        return self.registry.prometheus()
