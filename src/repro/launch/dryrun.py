import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay the first statements in this module (jax
locks the device count on first backend init).  Nothing else in the repo
sets XLA_FLAGS — smoke tests and benchmarks see 1 CPU device.

Per combination we record (artifacts/dryrun/<arch>_<shape>_<mesh>.json):
  * compiled.memory_analysis()  — proves the program fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective result bytes parsed from the optimized HLO
  * the three roofline terms + MODEL_FLOPS ratio (§Roofline)

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all                # 10 x 4, single pod
  python -m repro.launch.dryrun --all --multi-pod    # 2 x 16 x 16 pass
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.distributed import hlo_analysis as H
from repro.distributed.sharding import make_rules
from repro.launch import steps
from repro.launch.inputs import SHAPES, input_specs
from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
from repro.models.module import abstract_params
from repro.models.transformer import model_specs
from repro.training import optimizer as opt

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_RULE_MODE = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode_long"}


def _abstract_opt_state(aparams, cfg=None, zero1_rules=None):
    if zero1_rules is not None:
        from repro.models.module import param_shardings
        sh = param_shardings(model_specs(cfg), zero1_rules)
        f32t = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, jax.numpy.float32,
                                              sharding=s),
            aparams, sh)
        return opt.AdamWState(step=jax.ShapeDtypeStruct((), jax.numpy.int32),
                              m=f32t, v=f32t, master=f32t)
    f32 = lambda a: jax.ShapeDtypeStruct(a.shape, jax.numpy.float32,
                                         sharding=a.sharding)
    return opt.AdamWState(
        step=jax.ShapeDtypeStruct((), jax.numpy.int32),
        m=jax.tree.map(f32, aparams),
        v=jax.tree.map(f32, aparams),
        master=jax.tree.map(f32, aparams),
    )


TRAIN_MICROBATCHES = 4     # grad accumulation: activation memory / 4
# per-arch overrides (production tunes accumulation per model size)
TRAIN_MICROBATCHES_BY_ARCH = {"dbrx-132b": 8, "jamba-v0.1-52b": 8}
SHARD_GRAD_ACCUM = False   # §Perf knob: reduce-scatter grad accumulation


def _compile_step(cfg, shape, mesh, rules, num_microbatches: int = 1,
                  zero1_rules=None):
    """Lower + compile the step program for (cfg, shape) under mesh."""
    specs = model_specs(cfg)
    aparams = abstract_params(specs, rules)
    ins = input_specs(cfg, shape, rules)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = steps.make_train_step(cfg, rules,
                                         num_microbatches=num_microbatches,
                                         shard_grad_accum=SHARD_GRAD_ACCUM,
                                         zero1_rules=zero1_rules)
            astate = _abstract_opt_state(aparams, cfg, zero1_rules)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                aparams, astate, ins)
        elif shape.kind == "prefill":
            step = steps.make_prefill_step(cfg, rules)
            lowered = jax.jit(step).lower(aparams, ins)
        else:
            step = steps.make_decode_step(cfg, rules)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                aparams, ins["cache"], ins["token"], ins["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save: bool = True, extra_rules: dict | None = None,
            tag: str = "", cfg_overrides: dict | None = None,
            zero1: bool = False,
            num_microbatches: int | None = None) -> dict:
    """Compile the full (scanned) program + two unrolled layer-count probes.

    Methodology (EXPERIMENTS §Dry-run): XLA's cost_analysis counts a while
    body ONCE, so the full scanned program under-reports per-layer costs
    by ~num_layers x (verified empirically).  We therefore compile the
    production scanned program (proof of lowering + memory_analysis, which
    IS loop-aware) plus two small *unrolled* probes with 1 and 2 layer
    periods; per-period cost = probe2 - probe1 exactly (same embed/head/
    loss prologue), and

        cost_total = probe1 + (repeats - 1) * (probe2 - probe1)

    Remaining inner-loop undercounts (attention KV scan, SSD chunk scan)
    get the analytic correction of hlo_analysis.loop_corrections.
    """
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(extra_rules or {})
    zrules = None
    if zero1:
        overrides.setdefault("embed", None)   # params replicated over data
        zrules = make_rules(_RULE_MODE[shape_name], mesh)  # opt state FSDP
    rules = make_rules(_RULE_MODE[shape_name], mesh, overrides=overrides)
    chips = mesh.devices.size

    # 1) production program (scan over layers, microbatched train):
    #    lowering proof + memory_analysis
    n_micro = num_microbatches if num_microbatches is not None else \
        TRAIN_MICROBATCHES_BY_ARCH.get(arch, TRAIN_MICROBATCHES)
    compiled, t_lower, t_compile = _compile_step(
        cfg, shape, mesh, rules, num_microbatches=n_micro,
        zero1_rules=zrules)
    mem = H.memory_summary(compiled)
    cost_scan = H.cost_summary(compiled)
    coll_scan = H.collective_bytes(compiled.as_text())

    # 2) unrolled probes at 1 and 2 periods -> exact per-layer costs
    probes = []
    for reps in (1, 2):
        pcfg = dataclasses.replace(cfg, num_layers=cfg.period * reps,
                                   scan_layers=False)
        pc, _, _ = _compile_step(pcfg, shape, mesh, rules,
                                 zero1_rules=zrules)
        probes.append((H.cost_summary(pc), H.collective_bytes(pc.as_text())))
    (c1, k1), (c2, k2) = probes
    r = cfg.repeats

    def extrap(v1, v2):
        return max(v1 + (r - 1) * (v2 - v1), 0.0)

    flops_x = extrap(c1["flops"], c2["flops"])
    bytes_x = extrap(c1["bytes"], c2["bytes"])
    coll = {k: extrap(k1[k], k2[k]) for k in k1}

    corr = H.loop_corrections(cfg, shape, chips)
    flops_c = flops_x + corr["flops"]
    bytes_c = bytes_x + corr["bytes"]
    terms = H.roofline_terms(flops_c, bytes_c, coll["total"], chips)
    mflops = H.model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_scanned_program": cost_scan, "memory": mem,
        "collectives_scanned": coll_scan,
        "probe_costs": {"p1": c1, "p2": c2},
        "collectives": coll,
        "loop_corrections": corr,
        "flops_corrected": flops_c, "bytes_corrected": bytes_c,
        "roofline": terms,
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops / chips,
        # cost_analysis flops are per-partition under SPMD
        "useful_flops_ratio": (mflops / chips) / flops_c if flops_c else None,
        "fits_hbm": (mem.get("total_hbm_bytes", 0) <= HBM_PER_CHIP)
        if mem else None,
    }
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        name = f"{arch}_{shape_name}_{rec['mesh']}{tag}.json"
        (ART_DIR / name).write_text(json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    combos = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    failures = []
    for a, s in combos:
        t0 = time.time()
        try:
            rec = run_one(a, s, args.multi_pod, tag=args.tag)
            mem = rec["memory"].get("total_hbm_bytes")
            print(f"OK   {a:24s} {s:12s} {rec['mesh']:8s} "
                  f"compile={rec['compile_s']:7.1f}s "
                  f"flops/chip={rec['flops_corrected']:.3e} "
                  f"coll={rec['collectives']['total']:.3e}B "
                  f"hbm={mem and mem/2**30 or -1:.2f}GiB "
                  f"bottleneck={rec['roofline']['bottleneck']}",
                  flush=True)
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"FAIL {a:24s} {s:12s} ({time.time()-t0:.0f}s): {e}",
                  flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        return 1
    print(f"\nall {len(combos)} combinations lowered + compiled")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
