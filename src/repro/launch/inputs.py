"""ShapeDtypeStruct stand-ins for every model input (no allocation).

The four assigned input shapes; decode shapes lower ``serve_step`` (one
new token against a seq_len KV cache), train/prefill lower full-sequence
programs.  ``[vlm]``/``[audio]`` archs get precomputed frontend
embeddings per the carve-out (DESIGN §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules
from repro.models.config import ModelConfig
from repro.models.transformer import abstract_cache


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def _tok(rules: Rules, shape, dtype=jnp.int32, axes=("batch", "seq")):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=rules.sharding(axes))


def input_specs(cfg: ModelConfig, shape: InputShape, rules: Rules) -> dict:
    """Abstract inputs for the step function of this (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    f = cfg.frontend_tokens if cfg.frontend else 0
    if shape.kind == "train":
        out = {"tokens": _tok(rules, (b, s - f)),
               "labels": _tok(rules, (b, s - f))}
        if f:
            out["embeds"] = jax.ShapeDtypeStruct(
                (b, f, cfg.d_model), jnp.float32,
                sharding=rules.sharding(("batch", "seq", "act_embed")))
        return out
    if shape.kind == "prefill":
        out = {"tokens": _tok(rules, (b, s - f))}
        if f:
            out["embeds"] = jax.ShapeDtypeStruct(
                (b, f, cfg.d_model), jnp.float32,
                sharding=rules.sharding(("batch", "seq", "act_embed")))
        return out
    if shape.kind == "decode":
        return {
            "cache": abstract_cache(cfg, b, s, rules),
            "token": jax.ShapeDtypeStruct((b,), jnp.int32,
                                          sharding=rules.sharding(("batch",))),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def concrete_inputs(cfg: ModelConfig, shape: InputShape, rng=None) -> dict:
    """Small-scale concrete inputs (smoke tests; reduced configs only)."""
    from repro.models.transformer import zero_cache
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    b, s = shape.global_batch, shape.seq_len
    f = cfg.frontend_tokens if cfg.frontend else 0
    if shape.kind in ("train", "prefill"):
        toks = jax.random.randint(rng, (b, s - f), 0, cfg.vocab_size, jnp.int32)
        out = {"tokens": toks}
        if shape.kind == "train":
            out["labels"] = jnp.roll(toks, -1, axis=1)
        if f:
            out["embeds"] = jax.random.normal(rng, (b, f, cfg.d_model),
                                              jnp.float32) * 0.02
        return out
    return {"cache": zero_cache(cfg, b, s),
            "token": jnp.zeros((b,), jnp.int32),
            "pos": jnp.asarray(s - 1, jnp.int32)}
