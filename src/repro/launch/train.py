"""Training launcher.

On real hardware this runs under the production mesh; on this CPU
container it runs reduced configs end-to-end (the examples train a ~100M
model for a few hundred steps).  The loop wires together the substrate:
token pipeline -> sharded train_step (pjit) -> AdamW -> checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --smoke --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.distributed.sharding import make_rules
from repro.launch import steps as step_lib
from repro.launch.mesh import make_production_mesh
from repro.models.module import init_params, param_count, param_shardings
from repro.models.transformer import model_specs
from repro.training import checkpoint
from repro.training import optimizer as opt


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, use_mesh: bool, log_every: int = 10):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    f = cfg.frontend_tokens if cfg.frontend else 0
    mesh = make_production_mesh() if use_mesh else None
    rules = make_rules("train" if use_mesh else "none", mesh)
    specs = model_specs(cfg)
    print(f"arch={cfg.name} params={param_count(specs)/1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    params = init_params(specs, jax.random.PRNGKey(0))
    opt_cfg = opt.AdamWConfig(lr=1e-3, total_steps=steps,
                              warmup_steps=max(steps // 10, 1))
    state = opt.init_state(params)
    tp_cfg = TokenPipelineConfig(cfg.vocab_size, seq, batch)
    # Markov-chain pipeline has learnable structure (uniform `fast_batch`
    # tokens would pin the loss at log V); cache batches: the pipeline is
    # deterministic in (cfg, step), so cycling 8 batches stays honest.
    tp = TokenPipeline(tp_cfg)
    batches = [tp.batch(i) for i in range(min(steps, 8))]

    step_fn = step_lib.make_train_step(cfg, rules, opt_cfg)
    if mesh is not None:
        ps = param_shardings(specs, rules)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1),
                          in_shardings=(ps, None, None))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for i in range(steps):
        b = dict(batches[i % len(batches)])
        if f:
            key = jax.random.PRNGKey(1000 + i)
            b = dict(b)
            b["tokens"] = b["tokens"][:, : seq - f]
            b["labels"] = b["labels"][:, : seq - f]
            b["embeds"] = 0.02 * jax.random.normal(
                key, (batch, f, cfg.d_model), jax.numpy.float32)
        params, state, metrics = step_fn(params, state, b)
        losses.append(float(metrics["nll"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if ckpt_dir:
        d = checkpoint.save(ckpt_dir, steps, {"params": params})
        print("checkpoint ->", d)
    return np.asarray(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", action="store_true",
                    help="run under the production mesh (real hardware)")
    args = ap.parse_args()
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, args.mesh)
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"delta={losses[0]-losses[-1]:+.4f}")


if __name__ == "__main__":
    main()
