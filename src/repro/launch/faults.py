"""Deterministic, seed-driven fault injection for the serving stack.

The engine's compiled-program cache (``core.engine.GoldDiffEngine
.program``) is the single dispatch seam every trajectory segment, scan
program, and static step goes through.  This module installs a hook
there (``repro.kernels.ops.set_dispatch_hook``) that draws one decision
per dispatch from a counter-based splitmix64 stream:

* same ``FaultConfig.seed`` + same dispatch order  =>  the *same*
  faults fire at the same points, independent of wall clock, retries,
  or host load (a retry is a new dispatch with its own decision, so
  injected transient errors clear deterministically);
* with no injector installed the hook slot is ``None`` and
  ``engine.program`` returns its raw cached callables — identity,
  zero overhead, zero recompiles (the CI recompile guard runs over the
  uninstalled path, and ``tests/test_faults.py`` pins the identity).

Fault kinds (rates are independent per-dispatch probabilities):

* ``nan``        — corrupt one output row to NaN host-side *after* the
  program ran (a silent kernel-NaN storm: exercises the runtime's
  per-segment finite guard and the indexed->exact breaker rung);
* ``latency``    — sleep ``latency_s`` before dispatch (stage latency
  spikes: exercises deadlines and p99 accounting);
* ``error``      — raise ``XlaRuntimeError("INTERNAL: ...")`` (a
  transient executor failure: exercises retry with backoff);
* ``oom``        — raise ``XlaRuntimeError("RESOURCE_EXHAUSTED: ...")``
  (exercises the halve-batch / shrink-steps rung);
* ``shard_drop`` — raise an ``XlaRuntimeError`` marked as a lost mesh
  shard; only fires when >1 device is visible (the emulated 8-device
  mesh in CI), inert on single-device hosts;
* ``evict``      — delete the cache entry before the hit/miss check,
  forcing a REAL recompile on the next lookup (a recompile storm:
  exercises the plan->scan breaker rung honestly).

Only program kinds in ``target_kinds`` are touched (default: the
compute segments), so key-derivation / init-noise programs and the
runtime's Gaussian fallback stay reliable by construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import numpy as np

from repro.kernels import ops
from repro.obs import trace as obs_trace

try:                                     # jax >= 0.4.14
    from jax.errors import JaxRuntimeError as XlaRuntimeError
except ImportError:                      # pragma: no cover - older jax
    from jax._src.lib import xla_client
    XlaRuntimeError = xla_client.XlaRuntimeError


class TransientExecutorError(RuntimeError):
    """Injected transient failure (non-XLA flavor, equally retryable)."""


# what the serving runtime treats as transient-and-retryable
RETRYABLE_ERRORS = (XlaRuntimeError, TransientExecutorError)

# program kinds the injector touches by default: the trajectory compute
# segments (plan buckets — plain AND mixed-cursor, so the ladder is
# exercised on continuous-batching waves too — the scan-mode program,
# static denoise steps, full scans).  Deliberately excludes
# "serve_keys" / "serve_init" (the per-request noise streams) and
# "gauss_seg" (the runtime's Gaussian fallback must stay reliable for
# the ladder's last rung to be real).
DEFAULT_TARGETS = ("plan_seg", "plan_seg_mix", "serve_scan", "denoise",
                   "fused_step", "full_scan")

FAULT_KINDS = ("nan", "latency", "error", "oom", "shard_drop", "evict")

_M64 = (1 << 64) - 1
_SALT = {"nan": 0x1, "latency": 0x2, "error": 0x3, "oom": 0x4,
         "shard_drop": 0x5, "evict": 0x6, "row": 0x65}


def _splitmix64(z: int) -> int:
    z = (z + 0x9E3779B97F4A7C15) & _M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


def unit_uniform(seed: int, n: int, salt: int = 0) -> float:
    """Deterministic uniform in [0, 1) from (seed, counter, salt).

    Pure integer hashing — no global RNG state, so interleaved
    consumers (the injector's per-kind decisions, the runtime's backoff
    jitter) never perturb each other's streams.
    """
    z = (seed * 0xD1B54A32D192ED03 + n * 0x8CB92BA72F3D8DD7
         + salt * 0x2545F4914F6CDD1D) & _M64
    return _splitmix64(z) / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-dispatch fault probabilities (all default off)."""

    seed: int = 0
    nan_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.01
    error_rate: float = 0.0
    oom_rate: float = 0.0
    shard_drop_rate: float = 0.0
    evict_rate: float = 0.0
    target_kinds: tuple = DEFAULT_TARGETS


class FaultInjector:
    """The hook object ``engine.program`` consults (see module doc).

    ``events`` records every fired fault as ``(kind, program_kind,
    counter)`` tuples — the determinism and seam-reach tests assert on
    this log.  ``dispatches`` counts wrapped executions, ``lookups``
    counts cache lookups (the evict stream), both monotone.
    """

    def __init__(self, config: FaultConfig):
        self.config = config
        self.dispatches = 0
        self.lookups = 0
        self.events: list[tuple] = []

    def _record(self, kind: str, key, n: int) -> None:
        """Log a fired fault to the legacy tuple list AND the current
        tracer (``fault.<kind>`` point events on the unified schema —
        no-ops when tracing is off), so injections appear inline with
        the dispatch/segment spans they hit."""
        self.events.append((kind, key[0], n))
        tr = obs_trace.tracer()
        if tr.enabled:
            tr.event(f"fault.{kind}", program=key[0], counter=n)

    # -- decision stream -----------------------------------------------------
    def _targets(self, key) -> bool:
        return (isinstance(key, tuple) and len(key) > 0
                and key[0] in self.config.target_kinds)

    def _hit(self, n: int, kind: str, rate: float) -> bool:
        return rate > 0.0 and \
            unit_uniform(self.config.seed, n, _SALT[kind]) < rate

    # -- hook protocol (called by GoldDiffEngine.program) --------------------
    def on_program(self, engine, key) -> None:
        """Cache-lookup hook: may evict the entry (recompile storm)."""
        if not self._targets(key):
            return
        n = self.lookups
        self.lookups += 1
        if self._hit(n, "evict", self.config.evict_rate) \
                and key in engine._programs:
            del engine._programs[key]
            self._record("evict", key, n)

    def wrap(self, key, fn):
        """Dispatch hook: returns ``fn`` or a fault-wrapped callable."""
        if not self._targets(key):
            return fn

        def wrapped(*args, **kw):
            n = self.dispatches
            self.dispatches += 1
            cfg = self.config
            if self._hit(n, "latency", cfg.latency_rate):
                self._record("latency", key, n)
                time.sleep(cfg.latency_s)
            if cfg.shard_drop_rate > 0.0 and len(jax.devices()) > 1 \
                    and self._hit(n, "shard_drop", cfg.shard_drop_rate):
                self._record("shard_drop", key, n)
                raise XlaRuntimeError(
                    "INTERNAL: injected shard dropout: mesh device "
                    "unavailable during collective")
            if self._hit(n, "oom", cfg.oom_rate):
                self._record("oom", key, n)
                raise XlaRuntimeError(
                    "RESOURCE_EXHAUSTED: injected out-of-memory "
                    "allocating temporary buffer")
            if self._hit(n, "error", cfg.error_rate):
                self._record("error", key, n)
                raise XlaRuntimeError(
                    "INTERNAL: injected transient executor failure")
            out = fn(*args, **kw)
            if self._hit(n, "nan", cfg.nan_rate):
                out = self._corrupt(out, n, key)
            return out

        return wrapped

    def _corrupt(self, out, n: int, key):
        """NaN one row of a float batch output, host-side."""
        a = np.array(out, copy=True)
        if a.ndim == 0 or not np.issubdtype(a.dtype, np.floating) \
                or a.shape[0] == 0:
            return out
        row = int(unit_uniform(self.config.seed, n, _SALT["row"])
                  * a.shape[0]) % a.shape[0]
        a[row] = np.nan
        self._record("nan", key, n)
        return a


def install(config: FaultConfig) -> FaultInjector:
    """Build an injector for ``config`` and install it as THE hook."""
    injector = FaultInjector(config)
    ops.set_dispatch_hook(injector)
    return injector


def uninstall() -> None:
    """Clear the hook: the dispatch seam is an identity again."""
    ops.set_dispatch_hook(None)


def active() -> FaultInjector | None:
    """The currently installed injector (``None`` when faults are off)."""
    return ops.dispatch_hook()


@contextlib.contextmanager
def injected(config: FaultConfig):
    """``with injected(FaultConfig(...)) as inj:`` — scoped install."""
    injector = install(config)
    try:
        yield injector
    finally:
        uninstall()


# -- on-disk store corruption (crash / bit-rot simulation) --------------------
#
# The dispatch-hook faults above attack the *compute* path; these attack
# the *persistence* path: each injector deterministically damages one
# on-disk golden-store artifact the way a real failure would, so the
# chaos suite can assert that every regime surfaces as a typed load
# error (StoreCorruptionError / StoreVersionError) or a quarantined
# epoch — never as silent garbage served to a request.

STORE_CORRUPTIONS = ("truncate", "bitflip", "stale_manifest", "torn_rename")


def corrupt_store(npz_path: str, kind: str, seed: int = 0) -> str:
    """Deterministically damage one persisted artifact.

    ``npz_path`` is the arrays file (its manifest sidecar is
    ``<npz_path>.manifest.json``); ``kind``:

    * ``truncate``       — cut the npz to 60% of its bytes (a crash
      mid-write / partial copy);
    * ``bitflip``        — flip one bit at a seed-chosen offset (media
      rot; the per-array sha256 must catch it);
    * ``stale_manifest`` — bump the manifest's format version (an
      artifact from an incompatible future writer);
    * ``torn_rename``    — overwrite npz bytes while leaving the
      manifest untouched (the rename landed but the content belongs to
      a different write — checksum mismatch).

    Returns a short description of what was done (for test output).
    """
    import json
    import os

    manifest = npz_path + ".manifest.json"
    if kind == "truncate":
        size = os.path.getsize(npz_path)
        keep = max(1, (size * 6) // 10)
        with open(npz_path, "rb+") as f:
            f.truncate(keep)
        return f"truncated {npz_path} from {size} to {keep} bytes"
    if kind == "bitflip":
        with open(npz_path, "rb+") as f:
            data = bytearray(f.read())
            ofs = int(unit_uniform(seed, 0, 0x51) * len(data)) % len(data)
            data[ofs] ^= 1 << (int(unit_uniform(seed, 1, 0x52) * 8) % 8)
            f.seek(0)
            f.write(data)
        return f"flipped one bit at offset {ofs} of {npz_path}"
    if kind == "stale_manifest":
        with open(manifest) as f:
            m = json.load(f)
        m["format_version"] = int(m.get("format_version", 1)) + 1
        with open(manifest, "w") as f:
            json.dump(m, f)
        return f"bumped {manifest} to version {m['format_version']}"
    if kind == "torn_rename":
        # a structurally valid npz whose content belongs to a DIFFERENT
        # write (same schema, different bytes) lands under the old
        # manifest: only the per-array sha256 can catch it
        with np.load(npz_path) as z:
            shapes = {k: (z[k].shape, z[k].dtype) for k in z.files}
        np.savez(npz_path, **{k: np.full(s, 0.5, dt) if
                              np.issubdtype(dt, np.floating)
                              else np.ones(s, dt) + 1
                              for k, (s, dt) in shapes.items()})
        return f"replaced {npz_path} content under its old manifest"
    raise ValueError(f"unknown store corruption {kind!r} "
                     f"(have {STORE_CORRUPTIONS})")
