"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.  The dry-run entrypoint
(``launch/dryrun.py``) sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
*before* any jax import; smoke tests and benchmarks import this module on
a 1-device CPU and simply never call ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for subprocess-based distributed tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators; EXPERIMENTS §Roofline)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
