"""Roofline-term extraction from compiled XLA artifacts (EXPERIMENTS §Roofline).

* FLOPs / bytes — ``compiled.cost_analysis()``.
* collective bytes — NOT in cost_analysis: parsed from the optimized HLO
  (``compiled.as_text()``) by summing the result-shape bytes of every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute op.  (Result bytes are the standard proxy for bytes
  crossing links; all-reduce moves ~2x this in a ring — we report the raw
  sum and keep the convention fixed across all experiments so deltas are
  comparable.)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (launch/mesh.py).
"""
from __future__ import annotations

import math
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[2,16,128]{2,1,0} all-gather(...)
_RE = re.compile(
    r"=\s+(?:\()?\s*(\w+)\[([0-9,]*)\][^=]*?\s(" + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\(")
# tuple-result collectives:  = (bf16[..], bf16[..]) all-reduce(...)
_RE_TUPLE = re.compile(
    r"=\s+\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_RE_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-kind + total result bytes of collective ops in optimized HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # avoid double counting async start/done pairs
        m = _RE_TUPLE.search(line)   # tuple results first (scalar RE would
        if m:                        # otherwise count only the first shape)
            shapes, kind = m.groups()
            for dt, dd in _RE_SHAPE.findall(shapes):
                out[kind] += _shape_bytes(dt, dd)
            continue
        m = _RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def cost_summary(compiled) -> dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        ca = None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "raw": {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and not math.isnan(float(v))
                    and ("utilization" not in k)}}


def memory_summary(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict[str, float]:
    """The three roofline terms in seconds (global work / global throughput).

    cost_analysis totals are per-module as compiled for one device-program
    under SPMD; XLA reports whole-module numbers for the partitioned
    program, i.e. per-chip work.  We therefore divide by per-chip peak.
    """
    compute = flops / PEAK_FLOPS_BF16
    memory = hbm_bytes / HBM_BW
    collective = coll_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    return terms


def loop_corrections(cfg, shape, chips: int,
                     q_chunk: int = 512, kv_chunk: int = 1024) -> dict:
    """Analytic correction for inner-loop undercounting (per chip).

    The dry-run unrolls the LAYER loop, so per-layer costs are exact; but
    two inner ``lax.scan``s remain whose bodies XLA counts once:

    * flash attention (models/layers.py): body = one (q_chunk x kv_chunk)
      tile; actual iterations = (S/q_chunk) * (S/kv_chunk).
      fwd FLOPs per tile = 4 * B * H * qc * kc * dh  (QK^T + PV).
    * SSD chunk scan (models/mamba2.py): body = one length-L chunk;
      actual iterations = S / L.
      fwd FLOPs per chunk ~= B * (L^2*N + 2*L^2*H*P + 4*L*H*P*N).

    We add the missing (iters - 1) * body cost, x4 for train (recompute
    + backward under full remat: fwd + fwd + 2*fwd), and divide by chips
    (ideal sharding).  Elementwise/softmax terms are omitted (<5% of the
    matmul cost at these sizes).  Bytes corrections use the per-tile
    operand/result traffic of the same einsums.
    """
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    b = shape.global_batch
    s = shape.seq_len
    mult = 4.0 if shape.kind == "train" else 1.0
    fl = 0.0
    by = 0.0
    dh = cfg.hdim
    h = cfg.num_heads
    for i in range(cfg.num_layers):
        li = i % cfg.period
        if cfg.mixer_kind(li) == "A" and h:
            qc = min(q_chunk, s)
            kc = min(kv_chunk, s)
            iters = (s // qc) * (s // kc)
            tile_fl = 4.0 * b * h * qc * kc * dh
            tile_by = 4.0 * b * h * (qc * dh + kc * dh + 2 * qc * kc) \
                + 2.0 * b * cfg.num_kv_heads * kc * dh
            fl += (iters - 1) * tile_fl
            by += (iters - 1) * tile_by
        elif cfg.ssm_state:
            l = min(cfg.ssm_chunk, s)
            iters = s // l
            hh = (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim
            p = cfg.ssm_head_dim
            n = cfg.ssm_state
            chunk_fl = b * (l * l * n + 2.0 * l * l * hh * p
                            + 4.0 * l * hh * p * n)
            chunk_by = 4.0 * b * l * (hh * p + 2 * n + l) \
                + 4.0 * b * hh * p * n
            fl += (iters - 1) * chunk_fl
            by += (iters - 1) * chunk_by
    return {"flops": mult * fl / chips, "bytes": mult * by / chips}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference).

    N_active excludes the embedding gather but includes the LM head; MoE
    layers count experts_per_token / num_experts of their expert params.
    """
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    n_attn = 0
    n_mlp_dense = 3 * d * ff
    n_moe_active = (3 * d * ff * cfg.experts_per_token
                    if cfg.num_experts else 0)
    if cfg.num_heads:
        hd = cfg.hdim
        n_attn = d * cfg.num_heads * hd * 2 + d * cfg.num_kv_heads * hd * 2
    n_mamba = 0
    if cfg.ssm_state:
        di = cfg.ssm_expand * d
        n_mamba = d * (2 * di + 2 * cfg.ssm_state + di // cfg.ssm_head_dim) \
            + di * d
    n = 0
    for i in range(L):
        li = i % cfg.period
        n += n_attn if cfg.mixer_kind(li) == "A" else n_mamba
        kind = cfg.mlp_kind(li)
        n += {"dense": n_mlp_dense, "moe": n_moe_active, "none": 0}[kind]
    n += d * cfg.vocab_size                      # LM head
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token / seq
