"""Distributed golden retrieval over a dataset sharded on the `data` axis.

The GoldDiff selection + aggregation pipeline, shard-parallel (DESIGN §3):

  1. every shard screens its local dataset rows with the proxy distance
     and re-ranks its local candidates exactly (embarrassingly parallel);
  2. local top-k (index, distance) pairs are all-gathered — k floats+ints
     per shard, NOT data rows;
  3. the golden set = global top-k over the gathered candidates;
  4. each shard aggregates its *owned* golden members with the unbiased
     streaming softmax and partial states merge exactly with a
     log-sum-exp ``psum`` (streaming.merge semantics), so the distributed
     estimate is bit-comparable to the single-host one.

This is the same two-stage top-k + LSE-merge pattern the decode-attention
path uses for sharded KV caches (models/layers.py) — the paper's
mechanism implemented once, reused twice.

The shard-local distance math (proxy screening and exact re-rank) goes
through the kernel ops layer (``repro.kernels.ops``, ``backend="xla"``:
shard_map bodies compile for whatever mesh platform is active, where
Pallas TPU kernels may not lower), so the matmul-form distances here are
the exact same code the single-host GoldDiffEngine runs.

**Shard-local Golden Index** (``build_shard_indexes`` +
``distributed_golden_denoise(..., index=...)``): each shard clusters its
*own* rows with k-means and step 1 becomes an IVF probe
(``ops.ivf_screen``) over only the probed clusters' local rows — the
coarse stage is sublinear per shard, O(C d + nprobe L d) instead of
O(N/S d), while steps 2-4 (local exact re-rank, two-stage top-k,
LSE-merged aggregation) are unchanged, so the merged estimate stays
bit-comparable to the single-host indexed engine.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.index.store import build_index
from repro.kernels import ops

Array = jnp.ndarray
NEG_INF = -1e30


def shard_store(store: DatasetStore, mesh: Mesh, axis: str = "data"
                ) -> DatasetStore:
    """Place the dataset rows sharded over ``axis`` (pads N to divisor)."""
    n_sh = mesh.shape[axis]
    n = store.n
    pad = (-n) % n_sh
    def pad_rows(x, fill=0.0):
        if pad == 0:
            return x
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, cfg, constant_values=fill)
    sh = NamedSharding(mesh, P(axis))
    return DatasetStore(
        X=jax.device_put(pad_rows(store.X), sh),
        proxy=jax.device_put(pad_rows(store.proxy), sh),
        # +inf norms on padded rows exclude them from every top-k
        x_norms=jax.device_put(pad_rows(store.x_norms, jnp.inf), sh),
        proxy_norms=jax.device_put(pad_rows(store.proxy_norms, jnp.inf), sh),
        image_shape=store.image_shape,
        labels=None if store.labels is None
        else jax.device_put(pad_rows(store.labels, -1), sh),
    )


class ShardedIndex(NamedTuple):
    """One GoldenIndex per dataset shard, stacked on a leading shard axis
    (every per-shard array is placed sharded over the mesh ``axis``, so
    inside ``shard_map`` each shard sees exactly its own index).
    ``perm`` maps cluster-sorted *local* positions to local row ids."""

    centroids: Array           # [S, C, dp]
    centroid_norms: Array      # [S, C]
    perm: Array                # [S, n_loc] int32 (local row ids)
    offsets: Array             # [S, C + 1] int32
    proxy_sorted: Array        # [S, n_loc, dp]
    proxy_norms_sorted: Array  # [S, n_loc]
    max_cluster: int           # global max cluster size (static pad width)

    @property
    def num_clusters(self) -> int:
        return self.centroids.shape[1]


def build_shard_indexes(store: DatasetStore, mesh: Mesh, axis: str = "data",
                        num_clusters: int | None = None,
                        key: Array | None = None, iters: int = 25
                        ) -> ShardedIndex:
    """Cluster each shard's rows independently (host-side, at setup).

    Takes the same *unsharded* store as ``shard_store`` and mirrors its
    padding, so the stacked per-shard arrays line up row-for-row with
    the sharded dataset.  Padded rows keep +inf proxy norms and are
    never screened in.
    """
    n_sh = mesh.shape[axis]
    n = store.n
    n_loc = -(-n // n_sh)
    pad = n_loc * n_sh - n
    proxy = jnp.pad(store.proxy, ((0, pad), (0, 0)))
    pnorms = jnp.pad(store.proxy_norms, (0, pad), constant_values=jnp.inf)
    c = num_clusters or max(4, int(round(math.sqrt(n_loc))))
    key = jax.random.PRNGKey(0) if key is None else key
    parts = []
    for s in range(n_sh):
        rows = slice(s * n_loc, (s + 1) * n_loc)
        sub = DatasetStore(X=proxy[rows], proxy=proxy[rows],
                           x_norms=pnorms[rows], proxy_norms=pnorms[rows],
                           image_shape=store.image_shape)
        parts.append(build_index(sub, num_clusters=c,
                                 key=jax.random.fold_in(key, s),
                                 iters=iters))
    # balance chunking can yield different window counts per shard; pad
    # every shard to the widest with empty never-probed windows (+inf
    # centroid norms, zero-row CSR spans)
    w = max(p.num_clusters for p in parts)

    def pad_part(p):
        extra = w - p.num_clusters
        return dict(
            centroids=jnp.pad(p.centroids, ((0, extra), (0, 0))),
            centroid_norms=jnp.pad(p.centroid_norms, (0, extra),
                                   constant_values=jnp.inf),
            offsets=jnp.pad(p.offsets, (0, extra), mode="edge"),
            perm=p.perm, proxy_sorted=p.proxy_sorted,
            proxy_norms_sorted=p.proxy_norms_sorted)

    padded = [pad_part(p) for p in parts]
    sh = NamedSharding(mesh, P(axis))
    stack = lambda f: jax.device_put(
        jnp.stack([p[f] for p in padded]), sh)
    return ShardedIndex(
        centroids=stack("centroids"),
        centroid_norms=stack("centroid_norms"),
        perm=stack("perm"),
        offsets=stack("offsets"),
        proxy_sorted=stack("proxy_sorted"),
        proxy_norms_sorted=stack("proxy_norms_sorted"),
        max_cluster=max(p.max_cluster for p in parts),
    )


def distributed_golden_denoise(store: DatasetStore, mesh: Mesh, q: Array,
                               sigma2: float, m: int, k: int,
                               proxy_factor: int = 4, axis: str = "data",
                               index: ShardedIndex | None = None,
                               nprobe: int | None = None) -> Array:
    """Full GoldDiff step, shard-parallel.  q: [B, D] (rescaled query).

    With ``index`` (from ``build_shard_indexes``), each shard's coarse
    screen probes ``nprobe`` of its local clusters instead of scanning
    every local row (defaults to a quarter of the clusters; pick
    per-timestep values with ``repro.index.ProbeSchedule``).
    """
    n_sh = mesh.shape[axis]
    m_loc = max(1, -(-m // n_sh))
    k_loc = max(1, -(-k // n_sh))
    if index is not None:
        nprobe = nprobe or max(1, -(-index.num_clusters // 4))
        nprobe = min(nprobe, index.num_clusters)
        m_loc = min(m_loc, nprobe * index.max_cluster)

    def local(x_sh, xn_sh, proxy_sh, pn_sh, q_rep, *ix):
        # 1. local coarse screening via the ops layer — exact matmul-form
        #    pdist, or the shard-local IVF probe when an index is given
        #    (+inf norms on padded rows exclude them from every top-k)
        q_img = q_rep.reshape(q_rep.shape[:-1] + tuple(store.image_shape))
        qp = downsample_proxy(q_img, proxy_factor)
        if ix:
            cents, cnorms, perm, offsets, psort, pnsort = (
                a.squeeze(0) for a in ix)
            mm = min(m_loc, x_sh.shape[0])
            pos, pd2 = ops.ivf_screen(qp, psort, pnsort, offsets, cents,
                                      cnorms, mm, nprobe,
                                      index.max_cluster, backend="xla")
            cand = perm[pos]                               # local row ids
            screen_valid = jnp.isfinite(pd2)
        else:
            d2p = ops.pdist(qp, proxy_sh, x_norms=pn_sh, backend="xla")
            _, cand = jax.lax.top_k(-d2p, min(m_loc, x_sh.shape[0]))
            screen_valid = True
        # 2. local exact re-rank inside candidates (matmul form over the
        #    gathered rows — no [B, m_loc, D] subtract temporaries)
        xc = x_sh[cand]                                    # [B, m_loc, D]
        d2 = ops.support_sqdist(q_rep, xc, xn_sh[cand], backend="xla")
        d2 = jnp.where(screen_valid, d2, jnp.inf)
        kk = min(k_loc, d2.shape[-1])
        neg, pos = jax.lax.top_k(-d2, kk)
        # 3. global top-k over gathered local winners
        gathered = jax.lax.all_gather(-neg, axis, axis=1)   # [B, n_sh, kk]
        flat = gathered.reshape(q_rep.shape[0], -1)
        kth = -jax.lax.top_k(-flat, min(k, flat.shape[-1]))[0][:, -1]
        # 4. aggregate locally owned golden members (d2 <= global kth)
        sel = -neg                                          # local dists [B,kk]
        keep = sel <= kth[:, None]
        lg = jnp.where(keep, -sel / (2.0 * sigma2), NEG_INF)
        m_l = jnp.max(lg, -1)
        p = jnp.exp(lg - m_l[:, None])
        l_l = jnp.sum(p, -1)
        xsel = jnp.take_along_axis(xc, pos[..., None], axis=1)
        acc_l = jnp.einsum("bk,bkd->bd", p, xsel)
        # exact LSE merge across shards
        m_g = jax.lax.pmax(m_l, axis)
        sc = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * sc, axis)
        acc_g = jax.lax.psum(acc_l * sc[:, None], axis)
        return acc_g / jnp.maximum(l_g, 1e-30)[:, None]

    spec_row = P(axis)
    ix_args = () if index is None else (
        index.centroids, index.centroid_norms, index.perm, index.offsets,
        index.proxy_sorted, index.proxy_norms_sorted)
    kw = dict(mesh=mesh,
              in_specs=(spec_row, spec_row, spec_row, spec_row, P())
              + (spec_row,) * len(ix_args),
              out_specs=P())
    if hasattr(jax, "shard_map"):                  # jax >= 0.6
        mapped = jax.shard_map(local, check_vma=False, **kw)
    else:                                          # jax 0.4.x
        from jax.experimental.shard_map import shard_map
        mapped = shard_map(local, check_rep=False, **kw)
    return mapped(store.X, store.x_norms, store.proxy, store.proxy_norms, q,
                  *ix_args)
