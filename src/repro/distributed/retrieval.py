"""Distributed golden retrieval over a dataset sharded on the `data` axis.

The GoldDiff selection + aggregation pipeline, shard-parallel (DESIGN §3):

  1. every shard screens its local dataset rows with the proxy distance
     and re-ranks its local candidates exactly (embarrassingly parallel);
  2. local top-k (index, distance) pairs are all-gathered — k floats+ints
     per shard, NOT data rows;
  3. the golden set = global top-k over the gathered candidates;
  4. each shard aggregates its *owned* golden members with the unbiased
     streaming softmax and partial states merge exactly with a
     log-sum-exp ``psum`` (streaming.merge semantics), so the distributed
     estimate is bit-comparable to the single-host one.

This is the same two-stage top-k + LSE-merge pattern the decode-attention
path uses for sharded KV caches (models/layers.py) — the paper's
mechanism implemented once, reused twice.

The shard-local distance math (proxy screening and exact re-rank) goes
through the kernel ops layer (``repro.kernels.ops``, ``backend="xla"``:
shard_map bodies compile for whatever mesh platform is active, where
Pallas TPU kernels may not lower), so the matmul-form distances here are
the exact same code the single-host GoldDiffEngine runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.kernels import ops

Array = jnp.ndarray
NEG_INF = -1e30


def shard_store(store: DatasetStore, mesh: Mesh, axis: str = "data"
                ) -> DatasetStore:
    """Place the dataset rows sharded over ``axis`` (pads N to divisor)."""
    n_sh = mesh.shape[axis]
    n = store.n
    pad = (-n) % n_sh
    def pad_rows(x, fill=0.0):
        if pad == 0:
            return x
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, cfg, constant_values=fill)
    sh = NamedSharding(mesh, P(axis))
    return DatasetStore(
        X=jax.device_put(pad_rows(store.X), sh),
        proxy=jax.device_put(pad_rows(store.proxy), sh),
        # +inf norms on padded rows exclude them from every top-k
        x_norms=jax.device_put(pad_rows(store.x_norms, jnp.inf), sh),
        proxy_norms=jax.device_put(pad_rows(store.proxy_norms, jnp.inf), sh),
        image_shape=store.image_shape,
        labels=None if store.labels is None
        else jax.device_put(pad_rows(store.labels, -1), sh),
    )


def distributed_golden_denoise(store: DatasetStore, mesh: Mesh, q: Array,
                               sigma2: float, m: int, k: int,
                               proxy_factor: int = 4,
                               axis: str = "data") -> Array:
    """Full GoldDiff step, shard-parallel.  q: [B, D] (rescaled query)."""
    n_sh = mesh.shape[axis]
    m_loc = max(1, -(-m // n_sh))
    k_loc = max(1, -(-k // n_sh))

    def local(x_sh, xn_sh, proxy_sh, pn_sh, q_rep):
        # 1. local coarse screening via the ops layer (matmul-form pdist;
        #    +inf norms on padded rows exclude them from every top-k)
        q_img = q_rep.reshape(q_rep.shape[:-1] + tuple(store.image_shape))
        qp = downsample_proxy(q_img, proxy_factor)
        d2p = ops.pdist(qp, proxy_sh, x_norms=pn_sh, backend="xla")
        _, cand = jax.lax.top_k(-d2p, min(m_loc, x_sh.shape[0]))
        # 2. local exact re-rank inside candidates (matmul form over the
        #    gathered rows — no [B, m_loc, D] subtract temporaries)
        xc = x_sh[cand]                                    # [B, m_loc, D]
        d2 = ops.support_sqdist(q_rep, xc, xn_sh[cand], backend="xla")
        kk = min(k_loc, d2.shape[-1])
        neg, pos = jax.lax.top_k(-d2, kk)
        # 3. global top-k over gathered local winners
        gathered = jax.lax.all_gather(-neg, axis, axis=1)   # [B, n_sh, kk]
        flat = gathered.reshape(q_rep.shape[0], -1)
        kth = -jax.lax.top_k(-flat, min(k, flat.shape[-1]))[0][:, -1]
        # 4. aggregate locally owned golden members (d2 <= global kth)
        sel = -neg                                          # local dists [B,kk]
        keep = sel <= kth[:, None]
        lg = jnp.where(keep, -sel / (2.0 * sigma2), NEG_INF)
        m_l = jnp.max(lg, -1)
        p = jnp.exp(lg - m_l[:, None])
        l_l = jnp.sum(p, -1)
        xsel = jnp.take_along_axis(xc, pos[..., None], axis=1)
        acc_l = jnp.einsum("bk,bkd->bd", p, xsel)
        # exact LSE merge across shards
        m_g = jax.lax.pmax(m_l, axis)
        sc = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * sc, axis)
        acc_g = jax.lax.psum(acc_l * sc[:, None], axis)
        return acc_g / jnp.maximum(l_g, 1e-30)[:, None]

    spec_row = P(axis)
    kw = dict(mesh=mesh, in_specs=(spec_row, spec_row, spec_row, spec_row,
                                   P()), out_specs=P())
    if hasattr(jax, "shard_map"):                  # jax >= 0.6
        mapped = jax.shard_map(local, check_vma=False, **kw)
    else:                                          # jax 0.4.x
        from jax.experimental.shard_map import shard_map
        mapped = shard_map(local, check_rep=False, **kw)
    return mapped(store.X, store.x_norms, store.proxy, store.proxy_norms, q)
