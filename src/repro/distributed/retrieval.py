"""Distributed golden retrieval over a dataset sharded on the `data` axis.

The GoldDiff selection + aggregation pipeline, shard-parallel (DESIGN §3):

  1. every shard screens its local dataset rows with the proxy distance
     (exact matmul-form ``ops.pdist``, or ``ops.ivf_screen_local`` over
     its slice of a globally partitioned Golden Index) and a cross-shard
     top-m threshold restricts the union to exactly the global
     candidate set;
  2. each shard re-ranks its candidates exactly and local top-k
     (index, distance) pairs are all-gathered — k floats+ints per
     shard, NOT data rows;
  3. the golden set = global top-k over the gathered candidates
     (``sharding.crossshard_kth``);
  4. each shard aggregates its *owned* golden members into an
     unnormalized softmax partial state (``ops.golden_partial_aggregate``)
     and partial states merge exactly with a log-sum-exp ``psum``
     (``sharding.lse_merge_mean``, streaming.merge semantics), so the
     distributed estimate is bit-comparable to the single-host one.

Since PR 3 the shard-local screening math AND the cross-shard merge are
the same primitives the sharded ``GoldDiffEngine`` executes
(``core/engine.py``) — this module composes them for callers that want
raw (sigma2, m, k) control without a schedule; there is exactly one
implementation of the two-stage top-k + LSE merge in the repo
(``distributed/sharding.py``), pinned against a global top-k + softmax
in ``tests/test_sharded_engine.py``.

The shard-local distance math goes through the kernel ops layer
(``repro.kernels.ops``, ``backend="xla"``: shard_map bodies compile for
whatever mesh platform is active, where Pallas TPU kernels may not
lower), so the matmul-form distances here are the exact same code the
single-host GoldDiffEngine runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dataset import DatasetStore, downsample_proxy
from repro.distributed.sharding import (crossshard_kth, kth_from_gathered,
                                        lse_merge_mean, shard_map_compat)
from repro.index.shard import ShardedLayout, shard_layout
from repro.index.store import build_index
from repro.kernels import ops

Array = jnp.ndarray
NEG_INF = -1e30


def shard_store(store: DatasetStore, mesh: Mesh, axis: str = "data"
                ) -> DatasetStore:
    """Place the dataset rows sharded over ``axis`` (pads N to divisor)."""
    n_sh = mesh.shape[axis]
    n = store.n
    pad = (-n) % n_sh
    def pad_rows(x, fill=0.0):
        if pad == 0:
            return x
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, cfg, constant_values=fill)
    sh = NamedSharding(mesh, P(axis))
    return DatasetStore(
        X=jax.device_put(pad_rows(store.X), sh),
        proxy=jax.device_put(pad_rows(store.proxy), sh),
        # +inf norms on padded rows exclude them from every top-k
        x_norms=jax.device_put(pad_rows(store.x_norms, jnp.inf), sh),
        proxy_norms=jax.device_put(pad_rows(store.proxy_norms, jnp.inf), sh),
        image_shape=store.image_shape,
        labels=None if store.labels is None
        else jax.device_put(pad_rows(store.labels, -1), sh),
    )


def build_shard_indexes(store: DatasetStore, mesh: Mesh, axis: str = "data",
                        num_clusters: int | None = None,
                        key: Array | None = None, iters: int = 25
                        ) -> ShardedLayout:
    """One *global* Golden Index, partitioned across the mesh axis.

    Builds ``repro.index.build_index`` over the full proxy embedding and
    lays it out per shard at CSR window boundaries
    (``repro.index.shard.shard_layout``) — the same layout the sharded
    ``GoldDiffEngine`` uses, so shard-local probing reproduces the
    single-host probe set exactly instead of approximating it with
    per-shard clusterings.
    """
    index = build_index(store, num_clusters=num_clusters, key=key,
                        iters=iters)
    return shard_layout(store, mesh, axis, index=index)


# -- shard-local pipeline stages (shard_map bodies; engine-callable) ---------

def local_coarse_exact(qp, proxy_loc, pnorms_loc, m_cap: int, m_sort: int,
                       m, axis: str, backend: str = "xla",
                       stream: bool = False, tile: int | None = None):
    """Shard-local exact proxy screening + cross-shard top-m threshold.

    Local top-``m_cap`` by matmul-form proxy distance, then a global
    m-th-distance cut so the surviving candidates across all shards are
    exactly the single-host top-m set (not the union of per-shard
    top-m/S approximations).  ``m`` may be traced (masked path);
    ``m_sort`` is its static bound.  Returns ``(cand, valid)``:
    [B, m_cap] local row ids + validity.

    The local screen goes through ``ops.screen_topm``: ``stream=True``
    tiles the shard's rows with a running top-m carry (O(B * (m_cap +
    tile)) live memory, the engine's streamed mode applied per shard)
    instead of materializing the [B, n_loc] distance matrix.
    """
    cand, d2p = ops.screen_topm(qp, proxy_loc, m_cap, x_norms=pnorms_loc,
                                tile=tile, stream=stream, backend=backend)
    negp = -d2p
    mth = crossshard_kth(negp, m_sort, m, axis)
    return cand, negp >= mth[:, None]


def golden_local_topk(X_loc, xn_loc, q, cand, cand_valid, k_cap: int,
                      k_sort: int, k, axis: str, backend: str = "xla",
                      strategy: str = "gather"):
    """Exact shard-local re-rank + stage-two global top-k threshold.

    Returns ``(idx, neg, kth)``: local top-``k_cap`` candidate row ids,
    their negated exact distances, and the global k-th threshold —
    ``neg >= kth[:, None]`` marks this shard's golden members.
    """
    d2 = ops.support_distances(q, X_loc, cand, x_norms=xn_loc,
                               backend=backend, strategy=strategy)
    d2 = jnp.where(cand_valid, d2, jnp.inf)
    neg, pos = jax.lax.top_k(-d2, k_cap)
    idx = jnp.take_along_axis(cand, pos, axis=-1)
    kth = crossshard_kth(neg, k_sort, k, axis)
    return idx, neg, kth


def merged_golden_mean(X_loc, idx, neg, kth, sig2, axis: str,
                       strategy: str = "gather") -> Array:
    """Aggregate owned golden members and LSE-merge across shards."""
    lg = jnp.where(neg >= kth[:, None],
                   jnp.maximum(neg / (2.0 * sig2), NEG_INF), NEG_INF)
    acc, m_l, l_l = ops.golden_partial_aggregate(X_loc, idx, lg,
                                                 strategy=strategy)
    return lse_merge_mean(acc, m_l, l_l, axis)


def fused_local_step(X_loc, xn_loc, q, qp, proxy_loc, pnorms_loc,
                     m_cap: int, m_sort: int, m, k_cap: int, k_sort: int, k,
                     sig2, axis: str, backend: str = "xla",
                     strategy: str = "gather", stream: bool = False,
                     tile: int | None = None) -> Array:
    """One fused shard-local GoldDiff step with collective-compute overlap.

    Runs the same screen -> re-rank -> aggregate math as
    :func:`local_coarse_exact` + :func:`golden_local_topk` +
    :func:`merged_golden_mean` — the same kernel ops in the same order,
    so the result is **bitwise identical** to the staged sharded path —
    but restructures the dataflow so each cross-shard collective is
    issued *before* the shard-local compute it has no dependency on:

    * the m-threshold ``all_gather`` (k floats per shard) starts before
      the exact re-rank GEMM — the threshold is only consumed by the
      post-GEMM validity mask, so the collective hides behind the
      heaviest local stage;
    * the k-threshold ``all_gather`` starts before the golden-row
      gather feeding the partial aggregate — the rows depend on ``idx``
      alone, so the prefetch overlaps the second collective.

    XLA's latency-hiding scheduler can only overlap what the dataflow
    permits; this ordering makes the independence explicit instead of
    hoping the staged graph gets rescheduled.  ``m`` / ``k`` may be
    traced (masked path); ``m_sort`` / ``k_sort`` are their static
    bounds.
    """
    cand, d2p = ops.screen_topm(qp, proxy_loc, m_cap, x_norms=pnorms_loc,
                                tile=tile, stream=stream, backend=backend)
    negp = -d2p
    # collective in flight ...
    g_m = jax.lax.all_gather(negp, axis, axis=1)
    # ... while the shard-local exact re-rank runs
    d2 = ops.support_distances(q, X_loc, cand, x_norms=xn_loc,
                               backend=backend, strategy=strategy)
    mth = kth_from_gathered(g_m, m_sort, m)
    d2 = jnp.where(negp >= mth[:, None], d2, jnp.inf)
    neg, pos = jax.lax.top_k(-d2, k_cap)
    idx = jnp.take_along_axis(cand, pos, axis=-1)
    # second collective in flight while the aggregate's golden-row
    # gather (inside golden_partial_aggregate) proceeds
    g_k = jax.lax.all_gather(neg, axis, axis=1)
    kth = kth_from_gathered(g_k, k_sort, k)
    lg = jnp.where(neg >= kth[:, None],
                   jnp.maximum(neg / (2.0 * sig2), NEG_INF), NEG_INF)
    acc, m_l, l_l = ops.golden_partial_aggregate(X_loc, idx, lg,
                                                 strategy=strategy)
    return lse_merge_mean(acc, m_l, l_l, axis)


def distributed_golden_denoise(store: DatasetStore, mesh: Mesh, q: Array,
                               sigma2: float, m: int, k: int,
                               proxy_factor: int = 4, axis: str = "data",
                               index: ShardedLayout | None = None,
                               nprobe: int | None = None) -> Array:
    """Full GoldDiff step, shard-parallel.  q: [B, D] (rescaled query).

    ``store`` must be placed with :func:`shard_store`.  With ``index``
    (from :func:`build_shard_indexes`), the coarse screen probes
    ``nprobe`` windows of the *global* index (defaults to a quarter of
    them) and every probed row feeds the exact re-rank (IVF-Flat
    capacity mode); the store rows then come from the layout's
    cluster-sorted copies, not from ``store``.
    """
    n_sh = int(mesh.shape[axis])
    if index is not None:
        c = index.centroids.shape[0]
        nprobe = min(nprobe or max(1, -(-c // 4)), c)
        w_cap = min(nprobe, index.w_max)
        cap = w_cap * index.max_cluster
        k_cap = max(1, min(k, cap))

        def local(X, xn, offs, wr, ids, q_rep, cents, cnorms):
            X, xn, offs, wr = (z[0] for z in (X, xn, offs, wr))
            del ids
            q_img = q_rep.reshape(q_rep.shape[:-1]
                                  + tuple(store.image_shape))
            qp = downsample_proxy(q_img, proxy_factor)
            cand, pd2 = ops.ivf_screen_local(
                qp, offs, cents, cnorms, wr[0], wr[1], nprobe,
                index.max_cluster, w_cap, index.n_loc, backend="xla")
            idx, neg, kth = golden_local_topk(X, xn, q_rep, cand,
                                              jnp.isfinite(pd2), k_cap,
                                              k, k, axis)
            return merged_golden_mean(X, idx, neg, kth, sigma2, axis)

        sp = P(axis)
        mapped = shard_map_compat(
            local, mesh,
            in_specs=(sp, sp, sp, sp, sp, P(), P(), P()), out_specs=P())
        return mapped(index.X, index.x_norms, index.offsets, index.wrange,
                      index.ids, q, index.centroids, index.centroid_norms)

    n_loc = store.X.shape[0] // n_sh
    m_cap = min(m, n_loc)
    k_cap = max(1, min(k, m_cap))

    def local(x_sh, xn_sh, proxy_sh, pn_sh, q_rep):
        q_img = q_rep.reshape(q_rep.shape[:-1] + tuple(store.image_shape))
        qp = downsample_proxy(q_img, proxy_factor)
        cand, valid = local_coarse_exact(qp, proxy_sh, pn_sh, m_cap, m, m,
                                         axis)
        idx, neg, kth = golden_local_topk(x_sh, xn_sh, q_rep, cand, valid,
                                          k_cap, k, k, axis)
        return merged_golden_mean(x_sh, idx, neg, kth, sigma2, axis)

    sp = P(axis)
    mapped = shard_map_compat(local, mesh, in_specs=(sp, sp, sp, sp, P()),
                              out_specs=P())
    return mapped(store.X, store.x_norms, store.proxy, store.proxy_norms, q)
