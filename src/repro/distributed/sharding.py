"""Logical-axis sharding (MaxText-style) plus cross-shard merge primitives.

Every parameter / activation carries *logical* axis names; a ``Rules``
table maps logical names to mesh axes per execution mode.  A thread-local
context makes ``shard(x, *axes)`` a no-op outside a mesh (CPU smoke tests
see one device and zero sharding machinery).

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod (launch/mesh.py).  GSPMD pads non-divisible dimensions (e.g. 40
query heads over model=16); the padding waste shows up in the roofline's
MODEL_FLOPS / HLO_FLOPs ratio, where we track it.

The bottom half of this module holds the **cross-shard merge
primitives** used inside ``shard_map`` bodies by both the sharded
``GoldDiffEngine`` (core/engine.py) and the standalone distributed
retrieval path (distributed/retrieval.py) — the two-stage top-k
threshold, the exact log-sum-exp softmax-state merge, and the gathered
global top-k.  They are the *only* implementation of the cross-shard
screening math in the repo; keeping them here (engine-callable, free of
engine state) is what lets ``tests/test_sharded_engine.py`` pin
"two-stage merge == global top-k + softmax" once for every consumer.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh | None
    table: dict[str, Any]  # logical axis -> mesh axis | tuple | None

    def spec(self, logical_axes: tuple, shape: tuple | None = None) -> P:
        """Resolve logical axes to a PartitionSpec.

        When ``shape`` is given, mesh axes whose (cumulative) size does not
        evenly divide the dimension are dropped — NamedSharding on real
        avals requires exact divisibility (non-divisible cases, e.g. 40 q
        heads over model=16, use the flattened head*dim layouts instead;
        see models/layers.py).
        """
        if self.mesh is None:
            return P()
        out = []
        used: set[str] = set()
        for i, ax in enumerate(logical_axes):
            m = self.table.get(ax) if ax is not None else None
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a in self.mesh.axis_names
                       and a not in used)
            if shape is not None:
                keep = []
                prod = 1
                for a in ms:
                    prod *= self.mesh.shape[a]
                    if shape[i] % prod == 0:
                        keep.append(a)
                    else:
                        break
                ms = tuple(keep)
            used.update(ms)
            out.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*out)

    def sharding(self, logical_axes: tuple,
                 shape: tuple | None = None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def _pod(mesh: Mesh | None) -> tuple[str, ...]:
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def make_rules(mode: str, mesh: Mesh | None = None,
               overrides: dict | None = None) -> Rules:
    """mode: 'train' | 'prefill' | 'decode' | 'decode_long' | 'none'."""
    if mode == "none" or mesh is None:
        return Rules(None, {})
    batch = _pod(mesh)
    base = {
        # weights
        "embed": batch,          # FSDP / ZeRO-3 over the data axis
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "experts": "model",
        "expert_mlp": batch,     # second shard dim of expert weights
        "mamba_inner": "model",
        "mamba_conv": "model",
        "mamba_heads": "model",
        "layers": None,
        # activations
        "batch": batch,
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_experts": "model",
        "kv_seq": None,
    }
    if mode == "train":
        # Shard the residual stream's d_model over `model` (Megatron-SP
        # analogue): the remat-saved per-layer activations [B, S, d]
        # dominate train HBM (43 GiB/chip for qwen2.5-32b unsharded).
        # d_model divides 16 for every assigned arch; sharding SEQ instead
        # provokes involuntary SPMD rematerialization inside the flash
        # attention q-chunk dynamic_slice (observed: +40% HBM).
        base["act_embed"] = "model"
    elif mode == "prefill":
        base["act_embed"] = "model"
        base["kv_seq"] = "model"       # prefill writes a model-sharded cache
    elif mode == "decode":
        base["kv_seq"] = "model"       # flash-decoding: split-S over model
        base["act_heads"] = None       # q replicated for the seq-split merge
    elif mode == "decode_long":
        base["kv_seq"] = ("data", "model") if "pod" not in mesh.axis_names \
            else ("pod", "data", "model")
        base["batch"] = None           # global_batch = 1
        base["act_heads"] = None
        base["expert_mlp"] = ("data",)
        base["embed"] = ("data",)
    else:
        raise ValueError(mode)
    if overrides:
        base.update(overrides)
    return Rules(mesh, base)


@contextlib.contextmanager
def use_rules(rules: Rules):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def current_rules() -> Rules:
    r = getattr(_CTX, "rules", None)
    return r if r is not None else Rules(None, {})


def shard(x, *logical_axes):
    """Constrain activation sharding (no-op without an active mesh)."""
    r = current_rules()
    if r.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, r.sharding(tuple(logical_axes), tuple(x.shape)))


def mesh_axis_size(*names: str) -> int:
    r = current_rules()
    if r.mesh is None:
        return 1
    n = 1
    for name in names:
        if name in r.mesh.axis_names:
            n *= r.mesh.shape[name]
    return n


# -- cross-shard merge primitives (shard_map bodies only) --------------------

def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` (jax >= 0.6) or the experimental fallback,
    with replication checking off (outputs are psum/pmax-replicated)."""
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, check_rep=False, **kw)


def crossshard_kth(neg_local: jnp.ndarray, k_sort: int, k,
                   axis: str) -> jnp.ndarray:
    """Value of the k-th *largest* entry across all shards; [B].

    Stage two of the two-stage top-k: every shard contributes its local
    top candidates ``neg_local`` [B, k_loc] (use negated distances so
    "largest" means "closest"; invalid slots -inf/NEG_INF sort last),
    the [B, S * k_loc] gather is k_loc floats per shard — never data
    rows — and the returned threshold selects exactly the global top-k
    (``neg >= kth``), matching a single-host ``top_k`` up to ties.

    ``k_sort`` is the static sort width (an upper bound on k); ``k``
    itself may be a traced scalar, which is how the masked (scan/pjit)
    engine path varies k_t inside one program.
    """
    g = jax.lax.all_gather(neg_local, axis, axis=1)
    return kth_from_gathered(g, k_sort, k)


def kth_from_gathered(g: jnp.ndarray, k_sort: int, k) -> jnp.ndarray:
    """Threshold-extraction half of :func:`crossshard_kth`, for callers
    that issue the ``all_gather`` themselves.

    The fused sharded step (``distributed/retrieval.fused_local_step``)
    starts the gather *before* the shard-local exact re-rank — the two
    have no data dependency, so the collective hides behind the GEMM —
    and only then extracts the threshold from the landed buffer.  Keeping
    the extraction here (same top_k, same clip) guarantees the overlap
    form selects bit-for-bit the same candidates as the staged
    ``crossshard_kth``.
    """
    flat = g.reshape(g.shape[0], -1)
    k_sort = min(k_sort, flat.shape[-1])
    vals = jax.lax.top_k(flat, k_sort)[0]
    kidx = jnp.clip(jnp.asarray(k, jnp.int32) - 1, 0, k_sort - 1)
    kidx = jnp.broadcast_to(jnp.reshape(kidx, (1, 1)), (vals.shape[0], 1))
    return jnp.take_along_axis(vals, kidx, axis=-1)[:, 0]


def gather_global_topk(ids_local: jnp.ndarray, neg_local: jnp.ndarray,
                       k: int, axis: str) -> jnp.ndarray:
    """Global top-k ids across shards: gather (id, score) pairs — k ints
    + k floats per shard — and re-select; [B, k] (static k)."""
    g_neg = jax.lax.all_gather(neg_local, axis, axis=1)
    g_ids = jax.lax.all_gather(ids_local, axis, axis=1)
    b = neg_local.shape[0]
    pos = jax.lax.top_k(g_neg.reshape(b, -1), k)[1]
    return jnp.take_along_axis(g_ids.reshape(b, -1), pos, axis=-1)


def lse_merge_mean(acc: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray,
                   axis: str) -> jnp.ndarray:
    """Exact log-sum-exp merge of per-shard softmax partial states.

    ``(acc [B, D], m [B], l [B])`` are the unnormalized weighted sum,
    running max-logit, and running partition sum of each shard's golden
    members (``streaming.merge`` semantics).  Shards with no members
    carry the *finite* ``NEG_INF`` sentinel max, so their scale factor
    underflows to exactly 0 and the merged estimate is bit-comparable
    to the single-host softmax up to fp32 reduction order.
    """
    m_g = jax.lax.pmax(m, axis)
    # NaN guard: if EVERY shard carries a hard -inf max (a degenerate
    # all-masked candidate set that bypassed the finite sentinel),
    # ``m - m_g`` is ``-inf - -inf`` = NaN; such shards have zero
    # weight by definition, so their scale is forced to 0 and the merge
    # degrades to a finite zero-mean instead of propagating NaN.
    diff = m - m_g
    sc = jnp.where(jnp.isnan(diff), 0.0, jnp.exp(diff))
    l_g = jax.lax.psum(l * sc, axis)
    acc_g = jax.lax.psum(acc * sc[:, None], axis)
    return acc_g / jnp.maximum(l_g, 1e-30)[:, None]
