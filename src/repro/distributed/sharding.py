"""Logical-axis sharding (MaxText-style) for the model zoo.

Every parameter / activation carries *logical* axis names; a ``Rules``
table maps logical names to mesh axes per execution mode.  A thread-local
context makes ``shard(x, *axes)`` a no-op outside a mesh (CPU smoke tests
see one device and zero sharding machinery).

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod (launch/mesh.py).  GSPMD pads non-divisible dimensions (e.g. 40
query heads over model=16); the padding waste shows up in the roofline's
MODEL_FLOPS / HLO_FLOPs ratio, where we track it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh | None
    table: dict[str, Any]  # logical axis -> mesh axis | tuple | None

    def spec(self, logical_axes: tuple, shape: tuple | None = None) -> P:
        """Resolve logical axes to a PartitionSpec.

        When ``shape`` is given, mesh axes whose (cumulative) size does not
        evenly divide the dimension are dropped — NamedSharding on real
        avals requires exact divisibility (non-divisible cases, e.g. 40 q
        heads over model=16, use the flattened head*dim layouts instead;
        see models/layers.py).
        """
        if self.mesh is None:
            return P()
        out = []
        used: set[str] = set()
        for i, ax in enumerate(logical_axes):
            m = self.table.get(ax) if ax is not None else None
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a in self.mesh.axis_names
                       and a not in used)
            if shape is not None:
                keep = []
                prod = 1
                for a in ms:
                    prod *= self.mesh.shape[a]
                    if shape[i] % prod == 0:
                        keep.append(a)
                    else:
                        break
                ms = tuple(keep)
            used.update(ms)
            out.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*out)

    def sharding(self, logical_axes: tuple,
                 shape: tuple | None = None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def _pod(mesh: Mesh | None) -> tuple[str, ...]:
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def make_rules(mode: str, mesh: Mesh | None = None,
               overrides: dict | None = None) -> Rules:
    """mode: 'train' | 'prefill' | 'decode' | 'decode_long' | 'none'."""
    if mode == "none" or mesh is None:
        return Rules(None, {})
    batch = _pod(mesh)
    base = {
        # weights
        "embed": batch,          # FSDP / ZeRO-3 over the data axis
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "experts": "model",
        "expert_mlp": batch,     # second shard dim of expert weights
        "mamba_inner": "model",
        "mamba_conv": "model",
        "mamba_heads": "model",
        "layers": None,
        # activations
        "batch": batch,
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_experts": "model",
        "kv_seq": None,
    }
    if mode == "train":
        # Shard the residual stream's d_model over `model` (Megatron-SP
        # analogue): the remat-saved per-layer activations [B, S, d]
        # dominate train HBM (43 GiB/chip for qwen2.5-32b unsharded).
        # d_model divides 16 for every assigned arch; sharding SEQ instead
        # provokes involuntary SPMD rematerialization inside the flash
        # attention q-chunk dynamic_slice (observed: +40% HBM).
        base["act_embed"] = "model"
    elif mode == "prefill":
        base["act_embed"] = "model"
        base["kv_seq"] = "model"       # prefill writes a model-sharded cache
    elif mode == "decode":
        base["kv_seq"] = "model"       # flash-decoding: split-S over model
        base["act_heads"] = None       # q replicated for the seq-split merge
    elif mode == "decode_long":
        base["kv_seq"] = ("data", "model") if "pod" not in mesh.axis_names \
            else ("pod", "data", "model")
        base["batch"] = None           # global_batch = 1
        base["act_heads"] = None
        base["expert_mlp"] = ("data",)
        base["embed"] = ("data",)
    else:
        raise ValueError(mode)
    if overrides:
        base.update(overrides)
    return Rules(mesh, base)


@contextlib.contextmanager
def use_rules(rules: Rules):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def current_rules() -> Rules:
    r = getattr(_CTX, "rules", None)
    return r if r is not None else Rules(None, {})


def shard(x, *logical_axes):
    """Constrain activation sharding (no-op without an active mesh)."""
    r = current_rules()
    if r.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, r.sharding(tuple(logical_axes), tuple(x.shape)))


def mesh_axis_size(*names: str) -> int:
    r = current_rules()
    if r.mesh is None:
        return 1
    n = 1
    for name in names:
        if name in r.mesh.axis_names:
            n *= r.mesh.shape[name]
    return n
