"""Composable decoder: dense / MoE / Mamba2 / hybrid blocks, scan-over-layers.

Execution modes:
  * train    — full-sequence forward, causal flash attention, remat per
               block, scan over layer repeats (HLO size O(1) in depth).
  * prefill  — same forward, additionally materializes the KV/SSM caches.
  * decode   — one new token against a seq_len cache (the serve_step the
               decode_32k / long_500k dry-run shapes lower).  Attention
               uses flash-decoding (split-S LSE merge over the mesh axes
               holding the cache) or *golden attention* — the paper's
               coarse-to-fine subset selection on the KV cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_rules, mesh_axis_size, shard
from repro.models import layers as L
from repro.models import mamba2, moe
from repro.models.config import ModelConfig
from repro.models.module import ParamSpec, stack_specs

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _attn_dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.hdim)


def _mamba_dims(cfg: ModelConfig) -> mamba2.MambaDims:
    return mamba2.MambaDims(cfg.d_model, cfg.ssm_expand * cfg.d_model,
                            cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv)


def _layer_specs(cfg: ModelConfig, i: int) -> dict:
    dt = cfg.param_dtype
    sp: dict[str, Any] = {"ln1": L.rmsnorm_spec(cfg.d_model),
                          "ln2": L.rmsnorm_spec(cfg.d_model)}
    if cfg.mixer_kind(i) == "A":
        sp["attn"] = L.attn_specs(cfg.d_model, _attn_dims(cfg), dt, cfg.qkv_bias)
    else:
        sp["mamba"] = mamba2.mamba_specs(_mamba_dims(cfg), dt)
    kind = cfg.mlp_kind(i)
    if kind == "moe":
        sp["moe"] = moe.moe_specs(cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
    elif kind == "dense":
        sp["mlp"] = L.mlp_specs(cfg.d_model, cfg.d_ff, dt)
    else:
        del sp["ln2"]
    return sp


def model_specs(cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    period = {f"l{i}": _layer_specs(cfg, i) for i in range(cfg.period)}
    sp = {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                           dt, "embed", scale=0.02),
        "blocks": stack_specs(period, cfg.repeats),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                  ("embed", "vocab"), dt, scale=0.02)
    return sp


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract (shape, logical_axes, dtype) tree for the decode cache."""
    dt = cfg.param_dtype
    out = {}
    for i in range(cfg.period):
        if cfg.mixer_kind(i) == "A":
            shp = (cfg.repeats, batch, cfg.num_kv_heads, seq_len, cfg.hdim)
            ax = ("layers", "batch", "cache_heads", "kv_seq", None)
            out[f"l{i}"] = {"k": (shp, ax, dt), "v": (shp, ax, dt)}
            if (cfg.attn_kind_decode == "golden"
                    and cfg.golden_cached_summaries):
                nb = seq_len // cfg.golden_block_size
                out[f"l{i}"]["summ"] = (
                    (cfg.repeats, batch, cfg.num_kv_heads, nb, cfg.hdim),
                    ("layers", "batch", "cache_heads", "kv_seq", None), dt)
        else:
            d = _mamba_dims(cfg)
            out[f"l{i}"] = {
                "conv": ((cfg.repeats, batch, d.conv_width - 1, d.conv_dim),
                         ("layers", "batch", None, "mamba_conv"), dt),
                "ssm": ((cfg.repeats, batch, d.heads, d.head_dim, d.state),
                        ("layers", "batch", "mamba_heads", None, None),
                        jnp.float32),
            }
    return out


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int, rules):
    def mk(leaf):
        shp, ax, dt = leaf
        return jax.ShapeDtypeStruct(shp, dt, sharding=rules.sharding(ax, shp))
    return jax.tree.map(mk, cache_specs(cfg, batch, seq_len),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


def zero_cache(cfg: ModelConfig, batch: int, seq_len: int):
    def mk(leaf):
        shp, ax, dt = leaf
        return jnp.zeros(shp, dt)
    return jax.tree.map(mk, cache_specs(cfg, batch, seq_len),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# attention paths
# ---------------------------------------------------------------------------

def _kv_axes(rules) -> tuple[str, ...]:
    if rules.mesh is None:
        return ()
    m = rules.table.get("kv_seq")
    if m is None:
        return ()
    ms = (m,) if isinstance(m, str) else tuple(m)
    return tuple(a for a in ms if a in rules.mesh.axis_names)


def _decode_attention(cfg: ModelConfig, q: Array, kc: Array, vc: Array,
                      mask: Array, summ: Array | None = None) -> Array:
    """q: [B, Hkv, G, dh]; kc/vc: [B, Hkv, S, dh]; mask: [B, S] -> [B,Hkv,G,dh]."""
    rules = current_rules()
    kv_axes = _kv_axes(rules)

    def local(qq, kk, vv, mm, ss):
        if cfg.attn_kind_decode == "golden":
            nsh = mesh_axis_size(*kv_axes) if kv_axes else 1
            kb = max(1, cfg.golden_blocks // nsh)
            m, l, acc = L.golden_decode_partials(qq, kk, vv, mm, kb,
                                                 cfg.golden_block_size,
                                                 summaries=ss)
        else:
            m, l, acc = L.decode_attention_local(qq, kk, vv, mm)
        if kv_axes:
            return L.merge_partials_psum(m, l, acc, kv_axes)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if not kv_axes:
        return local(q, kc, vc, mask, summ).astype(q.dtype)

    P = jax.sharding.PartitionSpec
    batch = rules.table.get("batch")
    kv = rules.table.get("kv_seq")
    in_specs = [P(batch, None, None, None), P(batch, None, kv, None),
                P(batch, None, kv, None), P(batch, kv)]
    args = [q, kc, vc, mask]
    if summ is not None:
        in_specs.append(P(batch, None, kv, None))
        args.append(summ)
        fn = local
    else:
        fn = lambda qq, kk, vv, mm: local(qq, kk, vv, mm, None)
    out = jax.shard_map(
        fn, mesh=rules.mesh,
        in_specs=tuple(in_specs),
        out_specs=P(batch, None, None, None),
        check_vma=False,
    )(*args)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_mixer_full(cfg: ModelConfig, i: int, p: dict, x: Array,
                      positions: Array, want_cache: bool):
    """Train/prefill mixer.  Returns (y, cache_entry | None)."""
    if cfg.mixer_kind(i) == "A":
        dims = _attn_dims(cfg)
        q, k, v = L.qkv_proj(p["attn"], x, dims, positions, cfg.rope_theta)
        q = shard(q, "batch", "seq", "act_heads", None)
        o = L.flash_attention(q, k, v, dims, q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk)
        b, s = o.shape[:2]
        y = o.reshape(b, s, -1) @ p["attn"]["wo"]
        cache = None
        if want_cache:
            kc = k.transpose(0, 2, 1, 3)
            cache = {"k": kc, "v": v.transpose(0, 2, 1, 3)}
            if (cfg.attn_kind_decode == "golden"
                    and cfg.golden_cached_summaries):
                full = jnp.ones(kc.shape[:1] + kc.shape[2:3], bool)
                cache["summ"] = L.block_summaries(kc, full,
                                                  cfg.golden_block_size)
        return y, cache
    y = mamba2.mamba_apply(p["mamba"], x, _mamba_dims(cfg), cfg.ssm_chunk)
    cache = None
    if want_cache:
        # prefill -> decode handoff: rerun tail for conv state, final ssm state
        d = _mamba_dims(cfg)
        _, xbc, dt = mamba2._in_proj(p["mamba"], x)
        conv = xbc[:, -(d.conv_width - 1):, :]
        xbc_c = mamba2._causal_conv(xbc, p["mamba"]["conv_w"],
                                    p["mamba"]["conv_b"])
        xs = xbc_c[..., : d.d_inner]
        b_in = xbc_c[..., d.d_inner: d.d_inner + d.state]
        c_in = xbc_c[..., d.d_inner + d.state:]
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["mamba"]["dt_bias"])
        a = -jnp.exp(p["mamba"]["a_log"])
        bsz, s = x.shape[:2]
        xh = xs.reshape(bsz, s, d.heads, d.head_dim)
        _, state = mamba2.ssd_chunked(xh, dtv, a, b_in, c_in,
                                      p["mamba"]["d_skip"], cfg.ssm_chunk)
        cache = {"conv": conv, "ssm": state.astype(jnp.float32)}
    return y, cache


def _apply_mixer_decode(cfg: ModelConfig, i: int, p: dict, x1: Array,
                        cache: dict, pos: Array):
    """Decode mixer.  x1: [B, d]; returns (y [B, d], new_cache)."""
    if cfg.mixer_kind(i) == "A":
        dims = _attn_dims(cfg)
        xs = x1[:, None, :]
        q, k, v = L.qkv_proj(p["attn"], xs, dims,
                             jnp.full((1,), pos, jnp.int32)[None, :],
                             cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.transpose(0, 2, 1, 3), pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.transpose(0, 2, 1, 3), pos, axis=2)
        b = x1.shape[0]
        s = kc.shape[2]
        mask = jnp.arange(s)[None, :] <= pos                    # [1,S]->[B,S]
        mask = jnp.broadcast_to(mask, (b, s))
        qg = q[:, 0].reshape(b, dims.num_kv_heads, dims.q_per_kv, dims.head_dim)
        new_cache = {"k": kc, "v": vc}
        summ = None
        if "summ" in cache:
            # Incremental proxy maintenance from the just-written key only:
            # running-mean update m <- m + (k_new - m)/c, c = pos%bs + 1.
            # Slicing the KV cache here instead would dynamic_slice its
            # SHARDED seq axis and force a full K all-gather per layer
            # (137 GB/step measured, §Perf round 2).
            bs = cfg.golden_block_size
            blk = pos // bs
            c = (pos % bs + 1).astype(jnp.float32)
            k_new = k.transpose(0, 2, 1, 3).astype(jnp.float32)  # [B,Hkv,1,dh]
            old = jax.lax.dynamic_slice_in_dim(
                cache["summ"], blk, 1, axis=2).astype(jnp.float32)
            mean = jnp.where(c == 1.0, k_new, old + (k_new - old) / c)
            summ = jax.lax.dynamic_update_slice_in_dim(
                cache["summ"], mean.astype(cache["summ"].dtype), blk, axis=2)
            new_cache["summ"] = summ
        o = _decode_attention(cfg, qg, kc, vc, mask, summ)
        y = o.reshape(b, -1) @ p["attn"]["wo"]
        return y, new_cache
    y, new = mamba2.mamba_decode_step(p["mamba"], x1, cache, _mamba_dims(cfg))
    return y, new


def _apply_mlp(cfg: ModelConfig, i: int, p: dict, x: Array):
    """x: [B, S, d] -> (y, aux)."""
    if cfg.mlp_kind(i) == "none":
        return jnp.zeros_like(x), jnp.zeros((), jnp.float32)
    if cfg.mlp_kind(i) == "moe":
        return moe.moe_apply(p["moe"], x, cfg.num_experts,
                             cfg.experts_per_token, cfg.capacity_factor,
                             cfg.moe_group_size)
    return L.mlp_apply(p["mlp"], x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: dict, tokens: Array) -> Array:
    return params["embed"][tokens]


def _lm_head(cfg: ModelConfig, params: dict, x: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w
    return shard(logits, "batch", "seq", "vocab")


def forward_full(cfg: ModelConfig, params: dict, x: Array,
                 want_cache: bool = False, mode: str = "train"):
    """Full-sequence forward.  x: [B, S, d] embeddings.

    Returns (logits [B,S,V], cache|None, aux_loss).
    """
    positions = jnp.arange(x.shape[1])[None, :]

    def block_body(x, block_params):
        caches = {}
        aux_tot = jnp.zeros((), jnp.float32)
        for i in range(cfg.period):
            p = block_params[f"l{i}"]
            x = shard(x, "batch", "seq", "act_embed")
            h, cache = _apply_mixer_full(cfg, i, p,
                                         L.rmsnorm(p["ln1"], x), positions,
                                         want_cache)
            x = x + h
            if cfg.mlp_kind(i) != "none":
                h, aux = _apply_mlp(cfg, i, p, L.rmsnorm(p["ln2"], x))
                x = x + h
                aux_tot = aux_tot + aux
            if want_cache:
                caches[f"l{i}"] = cache
        return x, (caches if want_cache else None, aux_tot)

    body = block_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(block_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, (caches, auxes) = jax.lax.scan(
            lambda c, bp: body(c, bp), x, params["blocks"])
        aux_total = jnp.sum(auxes)
    else:
        cache_list, aux_total = [], jnp.zeros((), jnp.float32)
        for r in range(cfg.repeats):
            bp = jax.tree.map(lambda leaf: leaf[r], params["blocks"])
            x, (cr, aux) = body(x, bp)
            aux_total = aux_total + aux
            cache_list.append(cr)
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
                  if want_cache else None)
    x = L.rmsnorm(params["final_norm"], x)
    logits = _lm_head(cfg, params, x)
    return logits, caches, aux_total


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            aux_weight: float = 0.01):
    """batch: tokens [B,S] (+ optional embeds [B,F,d], loss_mask [B,S])."""
    x = embed_tokens(cfg, params, batch["tokens"])
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if "embeds" in batch:                       # modality frontend stub
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        pad = jnp.zeros(batch["embeds"].shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        fmask = jnp.concatenate(
            [jnp.zeros(pad.shape, bool),
             jnp.ones(batch["tokens"].shape, bool)], axis=1)
        mask = fmask if mask is None else jnp.concatenate(
            [jnp.zeros(pad.shape, bool), mask], axis=1)
    logits, _, aux = forward_full(cfg, params, x, mode="train")
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        denom = jnp.maximum(jnp.sum(mask), 1)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    zloss = 1e-4 * jnp.mean(logz ** 2)
    return loss + aux_weight * aux + zloss, {"nll": loss, "aux": aux}


def prefill(cfg: ModelConfig, params: dict, tokens: Array,
            embeds: Array | None = None):
    """Returns (last-position logits [B, V], cache)."""
    x = embed_tokens(cfg, params, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    logits, cache, _ = forward_full(cfg, params, x, want_cache=True,
                                    mode="prefill")
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: Array,
                pos: Array):
    """One decode step.  token: [B] int32; pos: scalar int32.

    Returns (logits [B, V], new_cache)."""
    x = params["embed"][token]                                   # [B, d]

    def block_body(x1, xs):
        block_params, block_cache = xs
        new_cache = {}
        for i in range(cfg.period):
            p = block_params[f"l{i}"]
            h, nc = _apply_mixer_decode(cfg, i, p,
                                        L.rmsnorm(p["ln1"], x1),
                                        block_cache[f"l{i}"], pos)
            x1 = x1 + h
            if cfg.mlp_kind(i) != "none":
                h, _ = _apply_mlp(cfg, i, p,
                                  L.rmsnorm(p["ln2"], x1[:, None, :]))
                x1 = x1 + h[:, 0, :]
            new_cache[f"l{i}"] = nc
        return x1, new_cache

    if cfg.scan_layers:
        # K/V ride in the scan CARRY (updated in place layer-by-layer with
        # dynamic_update_index) rather than as xs->ys streams: the xs/ys
        # form double-buffers the full stacked cache (observed +4.5
        # GiB/chip on musicgen decode_32k).  Small leaves (golden block
        # summaries, mamba conv/ssm states) stay on the xs/ys stream —
        # carry-slicing them provokes involuntary SPMD rematerialization
        # when their sharded axes interact with the layer dynamic_slice.
        def is_big(path_key: str) -> bool:
            return path_key in ("k", "v")

        big = {li: {kk: vv for kk, vv in lc.items() if is_big(kk)}
               for li, lc in cache.items()}
        small = {li: {kk: vv for kk, vv in lc.items() if not is_big(kk)}
                 for li, lc in cache.items()}

        def carry_body(carry, inp):
            x1, big_all = carry
            r, block_params, small_r = inp
            big_r = jax.tree.map(
                lambda leaf: jax.lax.dynamic_index_in_dim(
                    leaf, r, keepdims=False), big_all)
            block_cache = {li: {**big_r.get(li, {}), **small_r.get(li, {})}
                           for li in big_r}
            x1, nc = block_body(x1, (block_params, block_cache))
            nc_big = {li: {kk: vv for kk, vv in lc.items() if is_big(kk)}
                      for li, lc in nc.items()}
            nc_small = {li: {kk: vv for kk, vv in lc.items()
                             if not is_big(kk)} for li, lc in nc.items()}
            big_all = jax.tree.map(
                lambda leaf, new: jax.lax.dynamic_update_index_in_dim(
                    leaf, new.astype(leaf.dtype), r, axis=0),
                big_all, nc_big)
            return (x1, big_all), nc_small

        (x, new_big), new_small = jax.lax.scan(
            carry_body, (x, big),
            (jnp.arange(cfg.repeats), params["blocks"], small))
        new_cache = {li: {**new_big.get(li, {}), **new_small.get(li, {})}
                     for li in cache}
    else:
        ncs = []
        for r in range(cfg.repeats):
            bp = jax.tree.map(lambda leaf: leaf[r], params["blocks"])
            bc = jax.tree.map(lambda leaf: leaf[r], cache)
            x, nc = block_body(x, (bp, bc))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    x = L.rmsnorm(params["final_norm"], x)
    logits = x @ (params["embed"].T if cfg.tie_embeddings
                  else params["lm_head"])
    return logits, new_cache
