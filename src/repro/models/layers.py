"""Shared transformer layers: RMSNorm, RoPE, GQA attention (flash-style
chunked for train/prefill, split-S merged for decode, golden block-sparse
for long contexts), SwiGLU MLP.

All attention paths use the same online-softmax algebra as
``repro.core.streaming`` — the paper's unbiased streaming softmax is one
mechanism reused for (a) the dataset posterior and (b) the KV-cache
posterior (DESIGN §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.module import ParamSpec

Array = jnp.ndarray
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), (None,), jnp.float32, "ones")


def rmsnorm(w: Array, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype),
    }


def mlp_apply(p: dict, x: Array) -> Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", "seq", "act_mlp")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    head_dim: int

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


def attn_specs(d_model: int, dims: AttnDims, dtype, qkv_bias: bool) -> dict:
    # Weights keep the (heads * head_dim) axis FLAT so the model-axis
    # sharding divides evenly even when num_heads doesn't (e.g. 40 q heads
    # over model=16: 40*128 = 5120 divides; 40 does not).
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    sp = {
        "wq": ParamSpec((d_model, h * dh), ("embed", "heads"), dtype),
        "wk": ParamSpec((d_model, kv * dh), ("embed", "kv_heads"), dtype),
        "wv": ParamSpec((d_model, kv * dh), ("embed", "kv_heads"), dtype),
        "wo": ParamSpec((h * dh, d_model), ("heads", "embed"), dtype),
    }
    if qkv_bias:
        sp["bq"] = ParamSpec((h * dh,), ("heads",), dtype, "zeros")
        sp["bk"] = ParamSpec((kv * dh,), ("kv_heads",), dtype, "zeros")
        sp["bv"] = ParamSpec((kv * dh,), ("kv_heads",), dtype, "zeros")
    return sp


def qkv_proj(p: dict, x: Array, dims: AttnDims, positions: Array,
             rope_theta: float) -> tuple[Array, Array, Array]:
    b, s = x.shape[:2]
    h, kv, dh = dims.num_heads, dims.num_kv_heads, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if rope_theta > 0:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


def flash_attention(q: Array, k: Array, v: Array, dims: AttnDims,
                    causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024) -> Array:
    """Memory-efficient causal attention (pure JAX, double lax.scan).

    q: [B, S, H, dh]; k/v: [B, S, Hkv, dh] -> [B, S, H, dh].
    Online softmax keeps the working set at O(q_chunk * kv_chunk).
    """
    b, s, h, dh = q.shape
    g = dims.q_per_kv
    hkv = dims.num_kv_heads
    scale = dh ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0
    nq, nk = s // q_chunk, s // kv_chunk

    # [B, Hkv, G, nq, qc, dh] / [B, Hkv, nk, kc, dh]
    qr = q.reshape(b, s, hkv, g, dh).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(b, hkv, g, nq, q_chunk, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b, hkv, nk, kv_chunk, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b, hkv, nk, kv_chunk, dh)

    def q_block(qi, qc_data):
        def kv_block(carry, ki):
            m, l, acc = carry
            kc = kr[:, :, ki]
            vc = vr[:, :, ki]
            s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qc_data.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s_ = jnp.where(mask, s_, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_, -1))
            p_ = jnp.exp(s_ - m_new[..., None])
            sc = jnp.exp(m - m_new)
            l_new = l * sc + jnp.sum(p_, -1)
            acc_new = acc * sc[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p_, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32))
        # causal: kv blocks after this q block contribute nothing; still
        # scanned (masked) — structural simplicity over FLOP savings; the
        # perf pass (§Perf) revisits this.
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda qi: q_block(qi, qr[:, :, :, qi]), jnp.arange(nq))
    # out: [nq, B, Hkv, G, qc, dh] -> [B, S, H, dh]
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh).astype(q.dtype)


def decode_attention_local(q: Array, k: Array, v: Array, length_mask: Array,
                           ) -> tuple[Array, Array, Array]:
    """Single-token attention partials over a (possibly local) KV shard.

    q: [B, Hkv, G, dh]; k/v: [B, Hkv, S, dh]; length_mask: [B, S] bool.
    Returns the online-softmax partial (m, l, acc) so callers can merge
    across KV shards (flash-decoding split-S).
    """
    dh = q.shape[-1]
    s_ = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * dh ** -0.5
    s_ = jnp.where(length_mask[:, None, None, :], s_, NEG_INF)
    m = jnp.max(s_, -1)
    p = jnp.exp(s_ - m[..., None])
    l = jnp.sum(p, -1)
    acc = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return m, l, acc


def merge_partials_psum(m: Array, l: Array, acc: Array,
                        axis_names) -> Array:
    """Exact LSE merge of decode partials across mesh axes (inside shard_map)."""
    m_g = jax.lax.pmax(m, axis_names)
    sc = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * sc, axis_names)
    acc_g = jax.lax.psum(acc * sc[..., None], axis_names)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]


def block_summaries(k: Array, length_mask: Array, block_size: int) -> Array:
    """Masked mean-pooled key blocks: [B,Hkv,S,dh] -> [B,Hkv,nb,dh]."""
    b, hkv, s, dh = k.shape
    nb = s // block_size
    lm = length_mask.reshape(b, nb, block_size)
    cnt = jnp.maximum(jnp.sum(lm, -1), 1)[:, None, :, None]
    return ((k.reshape(b, hkv, nb, block_size, dh)
             * lm[:, None, :, :, None]).sum(3) / cnt).astype(k.dtype)


def golden_decode_partials(q: Array, k: Array, v: Array, length_mask: Array,
                           num_blocks: int, block_size: int,
                           summaries: Array | None = None
                           ) -> tuple[Array, Array, Array]:
    """Golden attention (paper Sec. 3.4 on the KV cache): coarse-screen
    block summaries, then exact partials over the top-k golden blocks only.

    Shapes as in decode_attention_local; returns mergeable partials.
    When ``summaries`` (cached, incrementally updated) is given, the O(S)
    re-pooling is skipped — per-step proxy work is O(S/block) (§Perf).
    """
    b, hkv, g, dh = q.shape
    s = k.shape[2]
    nb = s // block_size
    kb = min(num_blocks, nb)
    lm = length_mask.reshape(b, nb, block_size)
    summ = (block_summaries(k, length_mask, block_size)
            if summaries is None else summaries)                # [B,Hkv,nb,dh]
    qbar = q.mean(2)
    scores = jnp.einsum("bhd,bhnd->bhn", qbar.astype(jnp.float32),
                        summ.astype(jnp.float32))
    scores = jnp.where(jnp.any(lm, -1)[:, None, :], scores, NEG_INF)
    _, idx = jax.lax.top_k(scores, kb)                          # [B,Hkv,kb]
    # gather golden blocks
    kblk = k.reshape(b, hkv, nb, block_size, dh)
    vblk = v.reshape(b, hkv, nb, block_size, dh)
    take = idx[..., None, None]
    kg = jnp.take_along_axis(kblk, jnp.broadcast_to(
        take, (b, hkv, kb, block_size, dh)), axis=2)
    vg = jnp.take_along_axis(vblk, jnp.broadcast_to(
        take, (b, hkv, kb, block_size, dh)), axis=2)
    mg = jnp.take_along_axis(lm[:, None].repeat(hkv, 1), jnp.broadcast_to(
        idx[..., None], (b, hkv, kb, block_size)), axis=2)
    s_ = jnp.einsum("bhgd,bhkcd->bhgkc", q.astype(jnp.float32),
                    kg.astype(jnp.float32)) * dh ** -0.5
    s_ = jnp.where(mg[:, :, None], s_, NEG_INF).reshape(b, hkv, g, kb * block_size)
    m = jnp.max(s_, -1)
    p = jnp.exp(s_ - m[..., None]).reshape(b, hkv, g, kb, block_size)
    l = jnp.sum(p, (-1, -2))
    acc = jnp.einsum("bhgkc,bhkcd->bhgd", p, vg.astype(jnp.float32))
    return m, l, acc
