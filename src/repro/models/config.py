"""Architecture configuration for the model zoo."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    moe_every: int = 0             # 0 = no MoE; 1 = every layer; 2 = alternate
    moe_offset: int = 0
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # mixer pattern within one repeating period ("A"=attention, "M"=mamba)
    pattern: tuple[str, ...] = ("A",)
    # flash-attention tile sizes (train/prefill working set + saved
    # residual granularity; §Perf knobs)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # decode attention: "full" (flash-decoding) or "golden" (paper-derived
    # top-k block-sparse; licenses long_500k for attention archs)
    attn_kind_decode: str = "full"
    golden_blocks: int = 64
    golden_block_size: int = 128
    # §Perf: keep block summaries IN the KV cache, updated incrementally at
    # append time — per-step proxy cost O(S/block) instead of recomputing
    # all means O(S) (the paper precomputes its dataset proxy once; this is
    # the KV-cache analogue)
    golden_cached_summaries: bool = False
    # modality frontend stub (DESIGN §4 carve-out)
    frontend: str | None = None    # None | "vision" | "audio"
    frontend_tokens: int = 0
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True
    # scan over layer repeats (small HLO, fast compile) vs unrolled python
    # loop (exact cost_analysis: XLA counts a while body ONCE, so scanned
    # models under-report FLOPs/bytes/collectives by ~num_layers x; the
    # dry-run unrolls for roofline fidelity)
    scan_layers: bool = True
    # citation for the exact config (public pool provenance)
    source: str = ""

    def __post_init__(self):
        assert self.num_layers % len(self.pattern) == 0
        if self.moe_every:
            assert len(self.pattern) % self.moe_every == 0 or \
                len(self.pattern) == 1

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 512 so the vocab axis shards
        evenly over any mesh axis size we use (e.g. InternVL2's 151655
        would otherwise force replicated [B,S,V] logits — a 16x per-chip
        activation blowup observed in the first dry-run)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def repeats(self) -> int:
        return self.num_layers // self.period

    @property
    def param_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def mixer_kind(self, i: int) -> str:
        return self.pattern[i]

    def mlp_kind(self, i: int) -> str:
        if self.d_ff == 0:
            return "none"          # pure mixer stack (e.g. Mamba-2)
        if self.moe_every and (i % self.moe_every == self.moe_offset
                               % self.moe_every):
            return "moe"
        return "dense"

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                d_ff: int = 512, num_experts: int | None = None,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (<=4 experts, d_model<=512)."""
        period = min(len(self.pattern), num_layers)
        pat = self.pattern[:period]
        nl = max(num_layers // period * period, period)
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        ne = (min(self.num_experts, 4) if num_experts is None else num_experts) \
            if self.num_experts else 0
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=nl, d_model=d_model,
            num_heads=0 if self.num_heads == 0 else heads,
            num_kv_heads=0 if self.num_kv_heads == 0 else kv,
            head_dim=d_model // heads,
            d_ff=0 if self.d_ff == 0 else d_ff,    # keep pure-mixer family
            vocab_size=vocab, pattern=pat,
            num_experts=ne, experts_per_token=min(self.experts_per_token, 2),
            moe_group_size=64, ssm_head_dim=32 if self.ssm_state else 64,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            frontend_tokens=min(self.frontend_tokens, 16),
            golden_blocks=4, golden_block_size=16,
            dtype="float32", remat=False)
