"""Mixture-of-Experts layer: grouped GShard-style top-k dispatch.

Tokens are processed in groups of ``group_size`` so the dispatch/combine
tensors stay O(T * k * capacity_factor) rather than O(T^2 / E) (DESIGN
§6).  Experts are sharded over the ``model`` mesh axis; the dispatch
einsum contracts the token dim against the expert dim, which GSPMD lowers
to the MoE all-to-all.

Connection to the paper: top-k routing *is* a golden-subset selection over
the expert posterior — we reuse the same "select support, renormalize,
aggregate" structure (router softmax renormalized over the top-k support),
so Theorem 1's truncation bound applies to the router approximation too.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.module import ParamSpec

Array = jnp.ndarray


def moe_specs(d_model: int, d_ff: int, num_experts: int, dtype) -> dict:
    e = num_experts
    return {
        "router": ParamSpec((d_model, e), ("embed", None), jnp.float32,
                            scale=0.02),
        "w_gate": ParamSpec((e, d_model, d_ff), ("experts", "embed", "mlp"), dtype),
        "w_up": ParamSpec((e, d_model, d_ff), ("experts", "embed", "mlp"), dtype),
        "w_down": ParamSpec((e, d_ff, d_model), ("experts", "mlp", "embed"), dtype),
    }


def moe_apply(p: dict, x: Array, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, group_size: int = 512
              ) -> tuple[Array, Array]:
    """x: [B, S, d] -> (y: [B, S, d], aux_loss: scalar)."""
    b, s, d = x.shape
    t = b * s
    g_sz = min(group_size, t)
    ng = t // g_sz
    assert ng * g_sz == t, f"tokens {t} not divisible by group {g_sz}"
    e, k = num_experts, top_k
    cap = max(1, int(math.ceil(g_sz * k / e * capacity_factor)))

    xg = x.reshape(ng, g_sz, d)
    logits = (xg.astype(jnp.float32) @ p["router"])              # [g,t,E]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # [g,t,k]
    # renormalize over the selected support (the golden-subset softmax)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Build dispatch/combine one ROUTING CHOICE at a time: materializing
    # the [g, k*t, E, C] one-hot at once replicates k x the already-large
    # dispatch tensor (the 40+ GiB/chip blowup the dry-run caught on
    # dbrx/jamba).  Accumulators are bf16 and explicitly sharded.
    mask = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)       # [g,t,k,E]
    prio = mask.transpose(0, 2, 1, 3).reshape(ng, k * g_sz, e)
    pos_flat = jnp.cumsum(prio, axis=1) - 1.0                     # [g,k*t,E]
    pos = pos_flat.reshape(ng, k, g_sz, e).transpose(0, 2, 1, 3)  # [g,t,k,E]
    dispatch = jnp.zeros((ng, g_sz, e, cap), x.dtype)
    combine = jnp.zeros((ng, g_sz, e, cap), x.dtype)
    for j in range(k):
        keep_j = (pos[:, :, j] < cap) & (mask[:, :, j] > 0)       # [g,t,E]
        d_j = (jax.nn.one_hot(pos[:, :, j], cap, dtype=x.dtype)
               * keep_j[..., None].astype(x.dtype))               # [g,t,E,C]
        dispatch = dispatch + d_j
        combine = combine + d_j * gate_vals[:, :, j, None, None].astype(x.dtype)
        dispatch = shard(dispatch, "batch", None, "act_experts", None)
        combine = shard(combine, "batch", None, "act_experts", None)

    # per-expert activations carry g*E*C ~= k*cf*T token-slots of d/f width —
    # they MUST shard over the group dim (data) as well as experts (model);
    # sharding only over `model` left 18 GiB/chip on dbrx prefill.
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    xe = shard(xe, "batch", "act_experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = shard(h, "batch", "act_experts", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = shard(ye, "batch", "act_experts", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)

    # load-balance auxiliary loss (Switch/GShard form)
    me = probs.mean(axis=(0, 1))                                  # [E]
    top1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    ce = top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d), aux
