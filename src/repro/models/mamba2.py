"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) in pure JAX.

TPU adaptation (DESIGN §3): the chunked SSD form turns the selective-scan
into MXU-friendly per-chunk matmuls (intra-chunk "attention-like" block +
inter-chunk recurrence carried by ``lax.scan``), instead of the CUDA
parallel-scan kernels of the original.  Chunk length defaults to 128 so
the intra-chunk matrices are MXU-aligned.

Layout: d_inner = expand * d_model, H = d_inner / head_dim heads,
state size N, single B/C group.  Decode keeps (conv_state, ssm_state)
caches and costs O(1) per token — the attention-free arch runs
``long_500k`` natively (DESIGN §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.module import ParamSpec

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_inner: int
    head_dim: int
    state: int
    conv_width: int = 4

    @property
    def heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.state  # x, B, C share the conv

    @property
    def proj_dim(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.state + self.heads


def mamba_specs(dims: MambaDims, dtype) -> dict:
    # z / xBC / dt projections are SEPARATE weights so each output dim
    # shards evenly over the model axis (the fused proj_dim generally
    # doesn't divide: 2*d_inner + 2N + H is odd-sized).
    return {
        "in_z": ParamSpec((dims.d_model, dims.d_inner),
                          ("embed", "mamba_inner"), dtype),
        "in_xbc": ParamSpec((dims.d_model, dims.conv_dim),
                            ("embed", "mamba_conv"), dtype),
        "in_dt": ParamSpec((dims.d_model, dims.heads),
                           ("embed", "mamba_heads"), dtype),
        "conv_w": ParamSpec((dims.conv_width, dims.conv_dim),
                            (None, "mamba_conv"), dtype, scale=0.5),
        "conv_b": ParamSpec((dims.conv_dim,), ("mamba_conv",), dtype, "zeros"),
        "a_log": ParamSpec((dims.heads,), ("mamba_heads",), jnp.float32, "arange"),
        "dt_bias": ParamSpec((dims.heads,), ("mamba_heads",), jnp.float32, "zeros"),
        "d_skip": ParamSpec((dims.heads,), ("mamba_heads",), jnp.float32, "ones"),
        "norm_w": ParamSpec((dims.d_inner,), ("mamba_inner",), jnp.float32, "ones"),
        "out_proj": ParamSpec((dims.d_inner, dims.d_model),
                              ("mamba_inner", "embed"), dtype),
    }


def _in_proj(p: dict, x: Array):
    return x @ p["in_z"], x @ p["in_xbc"], x @ p["in_dt"]


def _gated_norm(w: Array, x: Array, z: Array, eps: float = 1e-6) -> Array:
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over seq.  xbc: [B, S, C]; w: [W, C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def ssd_chunked(x: Array, dt: Array, a: Array, b_in: Array, c_in: Array,
                d_skip: Array, chunk: int = 128,
                init_state: Array | None = None
                ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (post-softplus); a: [H] (negative);
    b_in/c_in: [B, S, N]; d_skip: [H].
    Returns (y: [B, S, H, P], final_state: [B, H, P, N]).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = b_in.reshape(bsz, nc, chunk, n)
    cr = c_in.reshape(bsz, nc, chunk, n)
    adt = dtr * a                                       # [B,nc,L,H] (<= 0)
    cum = jnp.cumsum(adt, axis=2)                       # within-chunk cumsum

    state0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    @jax.checkpoint
    def chunk_body(state, ci):
        # rematerialized per chunk: the intra-chunk [B,L,L,H] attention-like
        # tensors would otherwise all be saved for backward (observed 74
        # GiB/chip on jamba's 1-period probe); with remat only the [B,H,P,N]
        # carry per chunk persists.
        xc = xr[:, ci].astype(jnp.float32)              # [B,L,H,P]
        dtc = dtr[:, ci]
        bc = br[:, ci].astype(jnp.float32)              # [B,L,N]
        cc = cr[:, ci].astype(jnp.float32)
        cumc = cum[:, ci]                               # [B,L,H]
        # intra-chunk: att[b,h,i,j] = (c_i . b_j) exp(cum_i - cum_j) dt_j, j<=i
        seg = cumc[:, :, None, :] - cumc[:, None, :, :]  # [B,i,j,H]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        att = jnp.einsum("bin,bjn->bij", cc, bc)[..., None] \
            * jnp.exp(jnp.where(causal[None, :, :, None], seg, -jnp.inf)) \
            * dtc[:, None, :, :]                         # [B,i,j,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, xc)
        # inter-chunk: y_i += (c_i exp(cum_i)) . state
        y_inter = jnp.einsum("bin,bih,bhpn->bihp", cc, jnp.exp(cumc), state)
        # state update: state' = exp(cum_L) state + sum_j exp(cum_L - cum_j) dt_j b_j x_j
        decay_all = jnp.exp(cumc[:, -1])                 # [B,H]
        w_j = jnp.exp(cumc[:, -1, None, :] - cumc) * dtc  # [B,L,H]
        state_add = jnp.einsum("bjh,bjn,bjhp->bhpn", w_j, bc, xc)
        state_new = state * decay_all[:, :, None, None] + state_add
        return state_new, (y_intra + y_inter).astype(x.dtype)

    state, ys = jax.lax.scan(chunk_body, state0, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    y = y + (d_skip[None, None, :, None] * x.astype(jnp.float32)).astype(x.dtype)
    return y, state


def mamba_apply(p: dict, x: Array, dims: MambaDims, chunk: int = 128) -> Array:
    """Full-sequence (train / prefill) mixer.  x: [B, S, d_model]."""
    z, xbc, dt = _in_proj(p, x)
    z = shard(z, "batch", "seq", "act_mlp")
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., : dims.d_inner]
    b_in = xbc[..., dims.d_inner: dims.d_inner + dims.state]
    c_in = xbc[..., dims.d_inner + dims.state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    bsz, s = x.shape[:2]
    xh = xs.reshape(bsz, s, dims.heads, dims.head_dim)
    y, _ = ssd_chunked(xh, dt, a, b_in, c_in, p["d_skip"], chunk)
    y = y.reshape(bsz, s, dims.d_inner)
    y = _gated_norm(p["norm_w"], y, z)
    return y @ p["out_proj"]


def mamba_decode_step(p: dict, x: Array, cache: dict, dims: MambaDims
                      ) -> tuple[Array, dict]:
    """One-token decode.  x: [B, d_model]; cache: {conv: [B,W-1,C], ssm: [B,H,P,N]}."""
    z, xbc, dt = _in_proj(p, x)
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None, :]], 1)  # [B,W,C]
    xbc_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"])
                        + p["conv_b"])
    new_conv = conv_in[:, 1:]
    xs = xbc_c[..., : dims.d_inner]
    b_in = xbc_c[..., dims.d_inner: dims.d_inner + dims.state].astype(jnp.float32)
    c_in = xbc_c[..., dims.d_inner + dims.state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                          # [B,H]
    xh = xs.reshape(x.shape[0], dims.heads, dims.head_dim).astype(jnp.float32)
    add = jnp.einsum("bh,bn,bhp->bhpn", dt, b_in, xh)
    ssm = cache["ssm"].astype(jnp.float32) * decay[..., None, None] + add
    y = jnp.einsum("bn,bhpn->bhp", c_in, ssm)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(x.shape[0], dims.d_inner).astype(x.dtype)
    y = _gated_norm(p["norm_w"], y, z)
    return y @ p["out_proj"], {"conv": new_conv, "ssm": ssm.astype(cache["ssm"].dtype)}


def mamba_cache_specs(dims: MambaDims, batch: int, dtype):
    """Abstract cache shapes (+logical axes) for one mamba layer."""
    return {
        "conv": (((batch, dims.conv_width - 1, dims.conv_dim),
                  ("batch", None, "mamba_conv")), dtype),
        "ssm": (((batch, dims.heads, dims.head_dim, dims.state),
                 ("batch", "mamba_heads", None, None)), jnp.float32),
    }
