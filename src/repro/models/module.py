"""Minimal functional parameter system (flax is not installed; pure JAX).

A model definition is a pytree of ``ParamSpec`` leaves; ``init_params``
materializes it, ``abstract_params`` produces sharded
``ShapeDtypeStruct``s for ``.lower()`` dry-runs without ever allocating,
and ``param_shardings`` yields the matching ``NamedSharding`` tree for
``jax.jit(in_shardings=...)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed | mamba_a | arange
    scale: float | None = None    # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            f"{self.shape} vs {self.logical_axes}"


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_specs(tree):
    return jax.tree.leaves(tree, is_leaf=_is_spec), \
        jax.tree.structure(tree, is_leaf=_is_spec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "arange":  # e.g. Mamba A_log init: log(1..n)
        n = spec.shape[-1]
        v = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(v, spec.shape).astype(spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 1.0
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def init_params(spec_tree, rng: jax.Array):
    leaves, treedef = tree_specs(spec_tree)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree, rules: Rules):
    """ShapeDtypeStruct tree with shardings attached (dry-run input)."""
    def mk(s: ParamSpec):
        sh = rules.sharding(s.logical_axes, s.shape)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return jax.tree.map(mk, spec_tree, is_leaf=_is_spec)


def param_shardings(spec_tree, rules: Rules):
    return jax.tree.map(lambda s: rules.sharding(s.logical_axes, s.shape),
                        spec_tree, is_leaf=_is_spec)


def param_count(spec_tree) -> int:
    leaves, _ = tree_specs(spec_tree)
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_specs(spec_tree, repeats: int):
    """Add a leading 'layers' axis to every leaf (scan-over-layers)."""
    def mk(s: ParamSpec):
        return ParamSpec((repeats,) + s.shape, ("layers",) + s.logical_axes,
                         s.dtype, s.init, s.scale)
    return jax.tree.map(mk, spec_tree, is_leaf=_is_spec)
