"""Model zoo: composable dense/MoE/SSM/hybrid decoder + modality stubs."""
from repro.models.config import ModelConfig
from repro.models.module import (ParamSpec, abstract_params, init_params,
                                 param_count, param_shardings)
from repro.models.transformer import (abstract_cache, cache_specs,
                                      decode_step, loss_fn, model_specs,
                                      prefill, zero_cache)

__all__ = [
    "ModelConfig", "ParamSpec", "abstract_params", "init_params",
    "param_count", "param_shardings", "abstract_cache", "cache_specs",
    "decode_step", "loss_fn", "model_specs", "prefill", "zero_cache",
]
