"""Render dry-run artifacts into EXPERIMENTS.md (replaces the HTML-comment
placeholders with generated markdown tables).

  PYTHONPATH=src python scripts/render_experiments.py
"""
import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def rows(mesh: str):
    out = []
    for p in sorted(ART.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh or "_hc_" in p.name:
            continue
        out.append(d)
    out.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"])))
    return out


def roofline_md(mesh: str) -> str:
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "bottleneck | HBM GiB | fits | useful |",
             "|---|---|---|---|---|---|---|---|---|"]
    for d in rows(mesh):
        r = d["roofline"]
        hbm = d["memory"].get("total_hbm_bytes", 0) / 2**30
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['bottleneck']} | {hbm:.2f} | "
            f"{'yes' if d.get('fits_hbm') else 'NO'} | "
            f"{d.get('useful_flops_ratio') or 0:.3f} |")
    return "\n".join(lines)


def analysis_md() -> str:
    lines = ["Per-pair dominant bottleneck and the one-line lever "
             "(full JSON incl. per-kind collective bytes in "
             "`artifacts/dryrun/`):", ""]
    lever = {
        ("train", "collective"): "cut FSDP weight gathers (ZeRO-1) / "
                                 "per-layer d-gathers (act sharding)",
        ("train", "memory"): "more microbatches or tighter remat",
        ("train", "compute"): "near roofline — reduce remat recompute",
        ("prefill", "collective"): "overlap KV-cache writes; reduce "
                                   "act_embed gathers",
        ("prefill", "memory"): "larger attention tiles (fewer passes over KV)",
        ("decode", "memory"): "cache reads dominate — golden attention / "
                              "cached summaries cut bytes read per step",
        ("decode", "collective"): "batch the LSE merges across layers",
    }
    lines += ["| arch | shape | bottleneck | MODEL/HLO | what would move it |",
              "|---|---|---|---|---|"]
    for d in rows("16x16"):
        r = d["roofline"]
        kind = "decode" if d["shape"] in ("decode_32k", "long_500k") else \
            d["shape"].split("_")[0]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['bottleneck']} | "
            f"{d.get('useful_flops_ratio') or 0:.3f} | "
            f"{lever.get((kind, r['bottleneck']), '—')} |")
    return "\n".join(lines)


def main():
    text = EXP.read_text()
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n### |\n## )",
                  "<!-- ROOFLINE_TABLE -->\n" + roofline_md("16x16") + "\n",
                  text, flags=re.S)
    text = re.sub(r"<!-- MULTIPOD_TABLE -->.*?(?=\n## )",
                  "<!-- MULTIPOD_TABLE -->\n" + roofline_md("2x16x16") + "\n",
                  text, flags=re.S)
    text = re.sub(r"<!-- ROOFLINE_ANALYSIS -->.*?(?=\n## )",
                  "<!-- ROOFLINE_ANALYSIS -->\n" + analysis_md() + "\n",
                  text, flags=re.S)
    EXP.write_text(text)
    print("EXPERIMENTS.md updated:",
          len(rows("16x16")), "single-pod rows,",
          len(rows("2x16x16")), "multi-pod rows")


if __name__ == "__main__":
    main()
