#!/usr/bin/env python
"""Docs gate: fail on broken intra-repo links or stale module refs.

Scans README.md and docs/*.md for (a) relative markdown links whose
target doesn't exist, and (b) backtick-quoted repo paths
(``src/...``, ``tests/...``, ``scripts/...``, ``benchmarks/...``) or
dotted ``repro.*`` module names that no longer resolve — so a rename
or deletion fails CI instead of silently rotting the docs.

  python scripts/check_docs.py [--root .]
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
CODE = re.compile(r"`([A-Za-z0-9_./-]+)`")
PATH_PREFIXES = ("src/", "tests/", "scripts/", "benchmarks/", "docs/",
                 "examples/", ".github/")


def module_exists(root: str, dotted: str) -> bool:
    rel = os.path.join("src", *dotted.split("."))
    return (os.path.exists(os.path.join(root, rel + ".py"))
            or os.path.isdir(os.path.join(root, rel)))


def check_file(root: str, path: str) -> list[str]:
    errs = []
    text = open(path).read()
    base = os.path.dirname(path)
    for m in LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            errs.append(f"{path}: broken link -> {target}")
    for m in CODE.finditer(text):
        ref = m.group(1)
        if ref.startswith(PATH_PREFIXES):
            if not os.path.exists(os.path.join(root, ref.rstrip("/"))):
                errs.append(f"{path}: stale path reference `{ref}`")
        elif re.fullmatch(r"repro(\.\w+)+", ref) and \
                not module_exists(root, ref):
            errs.append(f"{path}: stale module reference `{ref}`")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".")
    args = ap.parse_args()
    files = [p for p in [os.path.join(args.root, "README.md")]
             + sorted(glob.glob(os.path.join(args.root, "docs", "*.md")))
             if os.path.exists(p)]
    errs = [e for p in files for e in check_file(args.root, p)]
    for e in errs:
        print(e)
    print(f"check_docs: {len(files)} file(s), {len(errs)} error(s)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
