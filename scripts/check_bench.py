#!/usr/bin/env python
"""Tier-2 perf gate: fail if any recorded BENCH_*.json speedup < 1.0x.

Every ``BENCH_*.json`` is a flat ``name -> value`` record where timing
cells are microseconds per call and ``recall/...`` cells are recall
fractions in [0, 1].  Cells pair up when their names differ only by a
(baseline, subject) method segment:

  BENCH_engine.json  static/seed_eager/...   vs  static/engine_xla/...
  BENCH_index.json   table1/exact_coarse/... vs  table1/indexed_coarse/...

For each pair the speedup baseline/subject must stay >= the threshold
(default 1.0, i.e. the optimized path never regresses past its
baseline), and every recall cell must stay >= 0.95.  *Budget* pairs
(``BUDGET_PAIRS``) run the other way: the subject may exceed its
baseline, but only by the listed factor — e.g. the trajectory plan's
padded FLOPs (BENCH_serve.json) must stay <= 1.2x static mode's, and
traced warm steps (``obs/.../obs_traced_us``) must stay <= 1.03x the
untraced baseline.  ``roofline/...`` cells are validated separately:
achieved GFLOP/s / GB/s must never exceed the measured machine peaks
and all four core stages must be present (``check_roofline``).  Run
it from the repo root:

  PYTHONPATH=src python scripts/check_bench.py [--threshold 1.0] [--dir .]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# baseline method segment -> optimized method segment
PAIRS = {
    "seed_eager": "engine_xla",
    "exact_coarse": "indexed_coarse",
    "exact_step": "indexed_step",
    # peak-temp-memory pair (bytes): the streamed screen must never
    # allocate MORE than the materialized [B, N] form it replaces
    "materialized_mem": "streamed_mem",
    # fused single-pass step (kernels/fused_step.py) vs the staged
    # screen -> rerank -> aggregate pipeline, both pinned to the
    # streamed + gather regime (the large-N shape where the staged
    # path materializes the [B, m, D] candidate tensor): wall-clock on
    # identical static steps ...
    "staged_step_us": "fused_step_us",
    # ... and peak temp bytes from the same two bodies (the fused
    # kernel must eliminate the staged path's [B, m, D] candidate
    # materialization, never allocate more)
    "staged_step_mem": "fused_step_mem",
}
# budget pairs run the OTHER way: the subject may cost MORE than the
# baseline, but only up to the listed factor.  Used for the trajectory
# plan's padded candidate/support FLOPs (BENCH_serve.json): bucketed
# shape compilation must stay within 1.2x of per-step static mode.
BUDGET_PAIRS = {
    "static_flops": ("plan_flops", 1.2),
    # the serving runtime's delivery-time deadline check makes
    # "completed" imply "within deadline", so p99 <= deadline holds
    # structurally (BENCH_resilience.json) — gate it at exactly 1.0x
    "p99_budget_us": ("p99_us", 1.0),
    # the full-scan parity cell (BENCH_engine.json): the seed was
    # already in matmul form on this path, so routing it through
    # ops.golden_aggregate is a ~1.0x pair by construction — gate that
    # the routing costs at most 20% (timer noise on a ~7 ms op swings
    # ~10% under median-of-3), not that it "speeds up"
    "seed_matmul_us": ("ops_routed_us", 1.2),
    # tracing must be effectively free: a warm engine step with the
    # tracer ENABLED (obs/.../obs_traced_us) may cost at most 3% over
    # the same step with tracing off (benchmarks/roofline.py emits the
    # pair into BENCH_engine.json)
    "obs_base_us": ("obs_traced_us", 1.03),
    # incremental ingest (BENCH_ingest.json, benchmarks/ingest.py):
    # getting 10% new rows live-and-durable via the appendable store
    # must stay >= 5x faster than a full kmeans rebuild of the grown
    # store, i.e. append <= 0.2x the rebuild
    "ingest_rebuild_us": ("ingest_append_us", 0.2),
    # continuous batching (BENCH_serve.json, benchmarks/
    # serve_throughput.py): at identical flash-crowd offered load,
    # mid-trajectory admission must deliver at least 1.5x lower p99
    # end-to-end latency than wave-at-a-time, i.e. the continuous
    # subject stays <= 2/3x its wave baseline
    "wave_p99_steps": ("continuous_p99_steps", 2.0 / 3.0),
}
RECALL_MIN = 0.95
# completion/ cells are delivered/admitted fractions under fault
# injection (BENCH_resilience.json): the runtime must finish 100% of
# what it admits in every regime
COMPLETION_MIN = 1.0
# parity/ cells are exactness fractions (e.g. streamed-vs-materialized
# top-m candidate sets), much tighter than recall: identical up to ties
PARITY_MIN = 0.999
# roofline/ validation: every achieved cell must stay at or below the
# measured machine peak (the analytic traffic model is optimistic, so
# achieved > peak means the cost model or the timer is lying), and the
# record must cover all core pipeline stages (including the fused
# single-pass step kind)
ROOFLINE_STAGES = ("screen", "rerank", "aggregate", "full_scan",
                   "fused_step")


def check_roofline(path: str, record: dict) -> list[str]:
    """Validate ``roofline/...`` cells (no-op when none are present)."""
    cells = {k: v for k, v in record.items() if k.startswith("roofline/")}
    if not cells:
        return []
    peaks = {"achieved_gflops": record.get("roofline/peak/peak_gflops"),
             "achieved_gbps": record.get("roofline/peak/peak_gbps")}
    failures = []
    for metric, peak in sorted(peaks.items()):
        if peak is None:
            failures.append(f"{path}: roofline cells present but "
                            f"roofline/peak/peak_{metric.split('_')[1]} "
                            f"is missing")
        elif peak <= 0:
            failures.append(f"{path}: roofline peak for {metric} is "
                            f"non-positive ({peak})")
    stages_seen = set()
    for name, value in sorted(cells.items()):
        parts = name.split("/")
        metric = parts[-1]
        if metric not in peaks:
            continue                     # the peak cells themselves
        stages_seen.add(parts[-2])
        if value <= 0:
            failures.append(f"{path}: {name} = {value} (achieved "
                            f"throughput must be positive)")
            continue
        peak = peaks[metric]
        if peak is not None and peak > 0 and value > peak:
            failures.append(f"{path}: {name} = {value:.4g} exceeds the "
                            f"measured peak {peak:.4g} "
                            f"({value / peak:.2f}x) — cost model or "
                            f"timer is inconsistent")
    missing = [s for s in ROOFLINE_STAGES if s not in stages_seen]
    if missing:
        failures.append(f"{path}: roofline record is missing required "
                        f"stage cell(s): {', '.join(missing)}")
    return failures


def check_file(path: str, threshold: float) -> list[str]:
    """Gate one BENCH_*.json record; returns human-readable failures.

    Malformed input (unreadable file, invalid JSON, a non-object top
    level, or non-numeric cells) is a *failure with a clear message*,
    never an unhandled traceback — CI must report "your bench record is
    broken", not crash.
    """
    try:
        with open(path) as f:
            record = json.load(f)
    except OSError as e:
        return [f"{path}: unreadable bench record ({e})"]
    except json.JSONDecodeError as e:
        return [f"{path}: malformed JSON ({e})"]
    if not isinstance(record, dict):
        return [f"{path}: expected a JSON object of name -> value cells, "
                f"got {type(record).__name__}"]
    if not record:
        return [f"{path}: empty bench record (no cells to gate)"]
    bad = sorted(name for name, value in record.items()
                 if isinstance(value, bool)
                 or not isinstance(value, (int, float)))
    if bad:
        return [f"{path}: non-numeric cell(s): {', '.join(bad[:5])}"
                + (f" (+{len(bad) - 5} more)" if len(bad) > 5 else "")]
    failures = check_roofline(path, record)
    for name, value in sorted(record.items()):
        if name.startswith("roofline/"):
            continue                     # gated by check_roofline above
        if name.startswith("recall/"):
            if not 0.0 <= value <= 1.0:
                failures.append(f"{path}: {name} = {value} outside [0, 1] "
                                f"(not a recall fraction)")
            elif value < RECALL_MIN:
                failures.append(f"{path}: {name} = {value:.4f} < "
                                f"{RECALL_MIN} (recall floor)")
            continue
        if name.startswith("completion/"):
            if not 0.0 <= value <= 1.0:
                failures.append(f"{path}: {name} = {value} outside [0, 1] "
                                f"(not a completion fraction)")
            elif value < COMPLETION_MIN:
                failures.append(f"{path}: {name} = {value:.4f} < "
                                f"{COMPLETION_MIN} (completion floor)")
            continue
        if name.startswith("parity/"):
            if not 0.0 <= value <= 1.0:
                failures.append(f"{path}: {name} = {value} outside [0, 1] "
                                f"(not a parity fraction)")
            elif value < PARITY_MIN:
                failures.append(f"{path}: {name} = {value:.4f} < "
                                f"{PARITY_MIN} (exact-parity floor)")
            continue
        parts = name.split("/")
        for i, seg in enumerate(parts):
            budget = BUDGET_PAIRS.get(seg)
            if budget is not None:
                subj_seg, factor = budget
                subj_name = "/".join(parts[:i] + [subj_seg] + parts[i + 1:])
                if subj_name in record:
                    subj_val = record[subj_name]
                    if value <= 0:
                        failures.append(f"{path}: {name} has non-positive "
                                        f"value {value}")
                    elif subj_val <= 0:
                        failures.append(f"{path}: {subj_name} has "
                                        f"non-positive value {subj_val}")
                    elif subj_val > factor * value:
                        failures.append(
                            f"{path}: {subj_name} = {subj_val:.4g} exceeds "
                            f"{factor:.2f}x its budget baseline {name} = "
                            f"{value:.4g} (ratio "
                            f"{subj_val / value:.2f}x)")
            subj = PAIRS.get(seg)
            if subj is None:
                continue
            subj_name = "/".join(parts[:i] + [subj] + parts[i + 1:])
            if subj_name not in record:
                continue
            subj_us = record[subj_name]
            # *_mem pairs hold bytes, not microseconds: report their
            # ratio as a memory reduction, not a speedup
            label = ("mem reduction" if subj.endswith("_mem")
                     else "speedup")
            if subj_us <= 0:
                failures.append(f"{path}: {subj_name} has non-positive "
                                f"value {subj_us}")
                continue
            speedup = value / subj_us
            if speedup < threshold:
                failures.append(
                    f"{path}: {subj_name} {label} {speedup:.2f}x vs "
                    f"{name} < {threshold:.2f}x")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=1.0,
                    help="minimum allowed baseline/optimized speedup")
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json records")
    args = ap.parse_args()

    paths = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not paths:
        print(f"check_bench: no BENCH_*.json under {args.dir!r}")
        return 1
    failures = []
    checked = 0
    for p in paths:
        fails = check_file(p, args.threshold)
        failures.extend(fails)
        checked += 1
        status = "FAIL" if fails else "ok"
        print(f"check_bench: {p}: {status}")
    for f in failures:
        print(f"  {f}")
    print(f"check_bench: {checked} file(s), {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
