#!/usr/bin/env python
"""Produce observability artifacts from a small traced serve workload.

Stands up an indexed plan-mode ``ServeEngine`` + ``ServeRuntime`` with
tracing, dispatch metrics, and the online ``QualityMonitor`` all
enabled, serves a handful of requests, and writes:

  <out>/trace.jsonl    — the unified span/event log (one JSON per line)
  <out>/metrics.json   — MetricsRegistry snapshot (typed cells)
  <out>/metrics.prom   — the same registry in Prometheus text format
  <out>/health.json    — ``ServeRuntime.health()`` (includes the
                         recall-proxy / concentration summary)

CI's tier-2 job uploads the directory, so every perf run carries a
browsable trace + metrics record next to its BENCH_*.json cells:

  PYTHONPATH=src python scripts/obs_dump.py --out artifacts/obs
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import make_dataset                       # noqa: E402
from repro.index import build_index                       # noqa: E402
from repro.launch.runtime import (RuntimeConfig,          # noqa: E402
                                  ServeRuntime)
from repro.launch.serve import Request, ServeEngine       # noqa: E402
from repro.obs import QualityMonitor                      # noqa: E402
from repro.obs import metrics as obs_metrics              # noqa: E402
from repro.obs import trace as obs_trace                  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/obs",
                    help="output directory for the artifact files")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    store = make_dataset("cifar_like", n=args.n)
    ix = build_index(store, num_clusters=32)
    eng = ServeEngine("cifar_like", {"n": args.n}, base="optimal",
                      num_steps=args.steps, max_batch=args.batch,
                      mode="plan", index=ix, index_mode="always")
    registry = obs_metrics.MetricsRegistry()
    monitor = QualityMonitor(eng.engine, registry=registry,
                             sample_rate=1.0)
    rt = ServeRuntime(eng, RuntimeConfig(max_queue=64), monitor=monitor)

    tracer = obs_trace.Tracer(capacity=1 << 16)
    obs_trace.set_tracer(tracer)
    hook = obs_trace.install_dispatch_tracing(tracer, registry)
    try:
        stats = rt.warmup()
        for i in range(args.requests):
            rt.submit(Request(i, args.batch, seed=100 + i))
        rt.run_until_idle()
    finally:
        obs_trace.uninstall_dispatch_tracing(hook)
        obs_trace.set_tracer(None)

    health = rt.health()
    tracer.dump(os.path.join(args.out, "trace.jsonl"))
    with open(os.path.join(args.out, "metrics.json"), "w") as f:
        json.dump(rt.metrics_snapshot(), f, indent=2, sort_keys=True)
    with open(os.path.join(args.out, "metrics.prom"), "w") as f:
        f.write(rt.prometheus())
    with open(os.path.join(args.out, "health.json"), "w") as f:
        json.dump(health, f, indent=2, sort_keys=True)

    n_ev = len(tracer.events())
    print(f"obs_dump: {n_ev} trace events ({tracer.dropped} dropped), "
          f"{len(rt.metrics_snapshot())} metrics, "
          f"compiles_post_warmup={health['compiles_post_warmup']}, "
          f"recall_p50={health['screen_recall_p50']:.4f}, "
          f"warmup={stats.get('runtime_warmup_s', 0):.1f}s -> {args.out}")
    if health["compiles_post_warmup"] != 0:
        print("obs_dump: FAIL — observability caused post-warmup "
              "compiles", file=sys.stderr)
        return 1
    if n_ev == 0:
        print("obs_dump: FAIL — empty trace", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
