#!/usr/bin/env python
"""Reconstruct per-request queue/segment timelines from a trace dump.

Reads the ``--trace-out`` JSONL a serving CLI writes (the unified
event schema of ``repro/obs/trace.py``: one JSON object per line with
``seq``/``ts``/``kind``/``name``/``span``/``parent``/``tags``) and
rebuilds each request's lifecycle from the runtime's events:

  request.admit -> wave.admit | wave.join -> wave.segment* ->
  request.deliver | request.expire

For every delivered request it splits end-to-end latency into

* **queue**  — submit until the request entered a wave (fresh wave or
  mid-trajectory join),
* **active** — summed ``wave.segment`` span durations that advanced
  the request's own cursor group,
* **frozen** — wave-resident time spent in segments that advanced a
  *different* cursor group (mixed-cursor waves: co-batched neighbors'
  catch-up or drain),

and prints a p50/p99 queue-vs-compute breakdown — the table the
serving runbook (docs/SERVING.md) uses for tail-latency triage.
Cursor attribution follows each part's seam progression; waves that
OOM-split mid-flight keep their timelines via the ``wave.split``
child id.

  PYTHONPATH=src python scripts/trace_latency.py TRACE.jsonl [--per-request]
  PYTHONPATH=src python scripts/trace_latency.py --demo

``--demo`` drives a small ServeRuntime over one flash-crowd schedule
twice — wave-at-a-time vs continuous admission — dumps both traces,
and analyzes each: the before/after evidence for mid-trajectory
admission (see BENCH_serve.json ``throughput/`` cells for the gated
version).
"""
from __future__ import annotations

import argparse
import json
import sys


def load_events(path: str) -> list[dict]:
    evs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                evs.append(json.loads(line))
    return sorted(evs, key=lambda e: e["seq"])


def reconstruct(events: list[dict]) -> dict:
    """request_id -> timeline dict (see module docstring)."""
    reqs: dict = {}
    segments = []                # (ts, wave, cursor, dur)
    span_open: dict = {}         # span id -> begin event (wave.segment)
    child_of: dict = {}          # split child wave -> parent wave
    for e in events:
        name, tags = e["name"], e["tags"]
        if e["kind"] == "begin" and name == "wave.segment":
            span_open[e["span"]] = e
        elif e["kind"] == "end" and e["span"] in span_open:
            b = span_open.pop(e["span"])
            segments.append((b["ts"], b["tags"]["wave"],
                             b["tags"].get("cursor", 0),
                             tags.get("dur", 0.0)))
        elif name == "request.admit":
            reqs[tags["request"]] = {"submit_ts": e["ts"], "start_ts": None,
                                     "end_ts": None, "wave": None,
                                     "status": "queued", "latency_s": None}
        elif name == "wave.admit":
            for rid in tags.get("requests", []):
                if rid in reqs and reqs[rid]["start_ts"] is None:
                    reqs[rid].update(start_ts=e["ts"], wave=tags["wave"],
                                     status="running")
        elif name == "wave.join":
            r = reqs.get(tags["request"])
            if r is not None and r["start_ts"] is None:
                r.update(start_ts=e["ts"], wave=tags["wave"],
                         status="running")
        elif name == "wave.split":
            child_of[tags["child"]] = tags["wave"]
        elif name == "request.deliver":
            r = reqs.get(tags["request"])
            if r is not None:
                r.update(end_ts=e["ts"], status="done",
                         latency_s=tags.get("latency_s"))
                # delivery names the final wave: follow splits back so
                # earlier segments still attribute to this request
                w = tags["wave"]
                lineage = {w}
                while w in child_of:
                    w = child_of[w]
                    lineage.add(w)
                r["waves"] = lineage
        elif name == "request.expire":
            r = reqs.get(tags["request"])
            if r is not None:
                r.update(end_ts=e["ts"], status="expired")
    # attribute segment durations: a segment advances the request's
    # cursor group iff its cursor equals the request's current cursor
    for r in reqs.values():
        r["active_s"] = r["frozen_s"] = 0.0
        if r["start_ts"] is None or r["end_ts"] is None:
            continue
        waves = r.get("waves") or ({r["wave"]} if r["wave"] is not None
                                   else set())
        cursor = 0
        for ts, wave, seg, dur in segments:
            if wave not in waves or not r["start_ts"] <= ts <= r["end_ts"]:
                continue
            if seg == cursor:
                r["active_s"] += dur
                cursor += 1
            else:
                r["frozen_s"] += dur
        r["queue_s"] = r["start_ts"] - r["submit_ts"]
        r["total_s"] = r["end_ts"] - r["submit_ts"]
    return reqs


def _pct(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q / 100 * (len(xs) - 1))))
    return xs[i]


def report(reqs: dict, per_request: bool = False, out=sys.stdout) -> None:
    done = {k: r for k, r in reqs.items() if r["status"] == "done"}
    other = len(reqs) - len(done)
    if per_request:
        out.write(f"{'request':>8} {'queue_ms':>9} {'active_ms':>10} "
                  f"{'frozen_ms':>10} {'total_ms':>9}\n")
        for rid, r in sorted(done.items()):
            out.write(f"{rid!s:>8} {r['queue_s'] * 1e3:>9.2f} "
                      f"{r['active_s'] * 1e3:>10.2f} "
                      f"{r['frozen_s'] * 1e3:>10.2f} "
                      f"{r['total_s'] * 1e3:>9.2f}\n")
    cols = [("queue", "queue_s"), ("active", "active_s"),
            ("frozen", "frozen_s"), ("total", "total_s")]
    out.write(f"{len(done)} delivered"
              + (f", {other} queued/expired/lost" if other else "")
              + " — latency breakdown (ms):\n")
    out.write(f"{'':>8}" + "".join(f"{c:>10}" for c, _ in cols) + "\n")
    for q in (50, 99):
        vals = [_pct([r[k] for r in done.values()], q) for _, k in cols]
        out.write(f"{'p%d' % q:>8}"
                  + "".join(f"{v * 1e3:>10.2f}" for v in vals) + "\n")


def _demo() -> None:
    """Drive one flash-crowd schedule both ways and analyze the dumps."""
    from repro.launch.runtime import RuntimeConfig, ServeRuntime
    from repro.launch.serve import Request, ServeEngine
    from repro.obs.trace import Tracer, set_tracer

    eng = ServeEngine("gmm", {"n": 512, "dim": 16}, num_steps=16,
                      max_batch=8, plan_threshold=0.05)
    arrivals = []                # (request_id, pumps-before-submit)
    for lead in range(0, 12, 4):
        arrivals.append((lead, 0 if lead == 0 else 12))
        arrivals += [(lead + j, 1 if j == 1 else 0) for j in (1, 2, 3)]
    for continuous in (False, True):
        mode = "continuous" if continuous else "wave"
        tr = Tracer(capacity=1 << 16)
        prev = set_tracer(tr)
        try:
            rt = ServeRuntime(eng, RuntimeConfig(continuous=continuous))
            rt.warmup()
            tickets = []
            for rid, gap in arrivals:
                for _ in range(gap):
                    rt.pump()
                tickets.append(rt.submit(Request(rid, 2, seed=100 + rid)))
            rt.run_until_idle()
        finally:
            set_tracer(prev)
        path = f"trace_demo_{mode}.jsonl"
        tr.dump(path)
        print(f"== {mode} admission ({path}) ==")
        report(reconstruct(load_events(path)))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", nargs="?", help="JSONL from --trace-out")
    ap.add_argument("--per-request", action="store_true",
                    help="print one row per delivered request")
    ap.add_argument("--demo", action="store_true",
                    help="generate + analyze wave-vs-continuous demo "
                         "traces (writes trace_demo_*.jsonl)")
    args = ap.parse_args()
    if args.demo:
        _demo()
        return 0
    if not args.trace:
        ap.error("need a trace path (or --demo)")
    reqs = reconstruct(load_events(args.trace))
    if not reqs:
        print("no request.admit events found — was the trace taken from "
              "a ServeRuntime (not a bare ServeEngine.serve call)?")
        return 1
    report(reqs, per_request=args.per_request)
    return 0


if __name__ == "__main__":
    sys.exit(main())
